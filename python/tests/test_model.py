"""L2 correctness: step graphs — DDIM algebra, regime behaviour, baselines."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _setup(seed, k=128, d=48, spread=1.0):
    rng = np.random.default_rng(seed)
    x_t = jnp.asarray(rng.normal(size=d), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(k, d)) * spread, jnp.float32)
    mask = jnp.ones(k, jnp.float32)
    return x_t, cand, mask


# ------------------------------------------------------------------- DDIM --

def test_ddim_terminal_step_returns_posterior_mean():
    """alpha_prev = 1 must return f_hat exactly (x_0 prediction)."""
    x_t, cand, mask = _setup(0)
    alphas = jnp.asarray([0.5, 1.0], jnp.float32)
    x_prev, f_hat, _ = model.golden_step(x_t, cand, mask, alphas)
    np.testing.assert_allclose(x_prev, f_hat, rtol=1e-5, atol=1e-5)


def test_ddim_identity_when_alpha_unchanged():
    """alpha_prev == alpha_t must be the identity map on x_t."""
    x_t, cand, mask = _setup(1)
    alphas = jnp.asarray([0.37, 0.37], jnp.float32)
    x_prev, _, _ = model.golden_step(x_t, cand, mask, alphas)
    np.testing.assert_allclose(x_prev, x_t, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    a_t=st.floats(0.01, 0.95),
    a_prev=st.floats(0.02, 1.0),
)
def test_ddim_update_algebra(seed, a_t, a_prev):
    """ddim_update reproduces the closed form for arbitrary f_hat."""
    rng = np.random.default_rng(seed)
    d = 16
    x_t = jnp.asarray(rng.normal(size=d), jnp.float32)
    f = jnp.asarray(rng.normal(size=d), jnp.float32)
    got = model.ddim_update(x_t, f, a_t, a_prev)
    eps = (np.asarray(x_t) - np.sqrt(a_t) * np.asarray(f)) / np.sqrt(1 - a_t)
    want = np.sqrt(a_prev) * np.asarray(f) + np.sqrt(max(1 - a_prev, 0)) * eps
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- golden vs jnp --

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), a_t=st.floats(0.05, 0.9))
def test_golden_step_pallas_matches_jnp_twin(seed, a_t):
    x_t, cand, mask = _setup(seed)
    alphas = jnp.asarray([a_t, min(a_t * 1.5, 1.0)], jnp.float32)
    xp1, f1, s1 = model.golden_step(x_t, cand, mask, alphas)
    xp2, f2, s2 = model.golden_step_jnp(x_t, cand, mask, alphas)
    np.testing.assert_allclose(xp1, xp2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(f1, f2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ regime laws --

def test_low_noise_step_snaps_to_nearest_neighbour():
    """Selection regime: alpha -> 1 collapses the posterior to top-1."""
    x_t, cand, mask = _setup(5, k=64, d=8)
    alphas = jnp.asarray([0.9999, 1.0], jnp.float32)
    _, f_hat, stats = model.golden_step(x_t, cand, mask, alphas)
    q = np.asarray(x_t) / np.sqrt(0.9999)
    nn = int(np.argmin(((np.asarray(cand) - q) ** 2).sum(1)))
    np.testing.assert_allclose(f_hat, cand[nn], rtol=1e-3, atol=1e-3)
    assert float(stats[3]) > 0.99  # top-1 weight ~ 1
    assert float(stats[2]) < 0.05  # entropy ~ 0


def test_high_noise_step_approaches_global_mean():
    """Integration regime: alpha -> 0 makes weights near-uniform."""
    x_t, cand, mask = _setup(6, k=256, d=8)
    alphas = jnp.asarray([1e-4, 1e-3], jnp.float32)
    _, f_hat, stats = model.golden_step(x_t, cand, mask, alphas)
    gmean = np.asarray(cand).mean(axis=0)
    np.testing.assert_allclose(f_hat, gmean, rtol=0.2, atol=0.2)
    assert float(stats[2]) > np.log(256) * 0.8  # entropy near log K


# --------------------------------------------------------------- PCA path --

def _pca_setup(seed, k=256, d=48, r=8):
    rng = np.random.default_rng(seed)
    x_t = jnp.asarray(rng.normal(size=d), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    mask = jnp.ones(k, jnp.float32)
    basis, _ = np.linalg.qr(rng.normal(size=(d, r)))
    basis = jnp.asarray(basis.T, jnp.float32)  # [R, D] orthonormal rows
    center = jnp.asarray(rng.normal(size=d), jnp.float32)
    return x_t, cand, mask, basis, center


def test_pca_ss_matches_reference_subspace_softmax():
    x_t, cand, mask, basis, center = _pca_setup(7)
    alphas = jnp.asarray([0.4, 0.6], jnp.float32)
    _, f_hat, _ = model.pca_step_ss(x_t, cand, mask, basis, center, alphas)

    q = np.asarray(x_t) / np.sqrt(0.4)
    zq = np.asarray(basis) @ (q - np.asarray(center))
    zc = (np.asarray(cand) - np.asarray(center)) @ np.asarray(basis).T
    scale = 0.4 / (2 * 0.6)
    logits = -((zc - zq) ** 2).sum(1) * scale
    w = np.exp(logits - logits.max())
    w /= w.sum()
    np.testing.assert_allclose(f_hat, w @ np.asarray(cand), rtol=1e-3, atol=1e-3)


def test_pca_wss_is_flatter_than_ss():
    """The biased WSS output must be closer to the global mean (smoothing
    bias, Fig. 2) than the unbiased SS output, in a low-noise setting."""
    x_t, cand, mask, basis, center = _pca_setup(8)
    alphas = jnp.asarray([0.99, 1.0], jnp.float32)
    _, f_ss, _ = model.pca_step_ss(x_t, cand, mask, basis, center, alphas)
    _, f_wss, _ = model.pca_step_wss(x_t, cand, mask, basis, center, alphas)
    gmean = np.asarray(cand).mean(0)
    assert np.linalg.norm(np.asarray(f_wss) - gmean) < np.linalg.norm(
        np.asarray(f_ss) - gmean
    )


def test_pca_wss_equals_mean_of_block_means():
    x_t, cand, mask, basis, center = _pca_setup(9, k=64)
    alphas = jnp.asarray([0.5, 0.7], jnp.float32)
    _, f_wss, _ = model.pca_step_wss(x_t, cand, mask, basis, center, alphas, blocks=4)

    q = np.asarray(x_t) / np.sqrt(0.5)
    zq = np.asarray(basis) @ (q - np.asarray(center))
    zc = (np.asarray(cand) - np.asarray(center)) @ np.asarray(basis).T
    logits = -((zc - zq) ** 2).sum(1) * (0.5 / (2 * 0.5))
    means = []
    for blk in range(4):
        lg = logits[blk * 16 : (blk + 1) * 16]
        w = np.exp(lg - lg.max())
        w /= w.sum()
        means.append(w @ np.asarray(cand)[blk * 16 : (blk + 1) * 16])
    np.testing.assert_allclose(f_wss, np.mean(means, axis=0), rtol=1e-3, atol=1e-3)


# -------------------------------------------------------------- Kamb path --

def test_kamb_patch1_on_flat_images_matches_pixelwise_softmax():
    rng = np.random.default_rng(10)
    h = w = 6
    c = 1
    k = 32
    x_t = jnp.asarray(rng.normal(size=h * w * c), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(k, h * w * c)), jnp.float32)
    mask = jnp.ones(k, jnp.float32)
    alphas = jnp.asarray([0.5, 0.8], jnp.float32)
    _, f_hat, _ = model.kamb_step(x_t, cand, mask, alphas, h=h, w=w, c=c, patch=1)

    q = np.asarray(x_t).reshape(h, w) / np.sqrt(0.5)
    ci = np.asarray(cand).reshape(k, h, w)
    scale = 0.5 / (2 * 0.5)
    logits = -((ci - q) ** 2) * scale  # patch=1: pixelwise
    m = logits.max(0)
    p = np.exp(logits - m)
    want = (p * ci).sum(0) / p.sum(0)
    np.testing.assert_allclose(
        np.asarray(f_hat).reshape(h, w), want, rtol=1e-3, atol=1e-3
    )


def test_kamb_output_within_candidate_pixel_range():
    rng = np.random.default_rng(11)
    h = w = 8
    cch = 3
    k = 16
    x_t = jnp.asarray(rng.normal(size=h * w * cch), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(k, h * w * cch)), jnp.float32)
    alphas = jnp.asarray([0.3, 0.5], jnp.float32)
    _, f_hat, _ = model.kamb_step(
        x_t, cand, jnp.ones(k, jnp.float32), alphas, h=h, w=w, c=cch, patch=3
    )
    ci = np.asarray(cand).reshape(k, -1)
    assert np.all(np.asarray(f_hat) <= ci.max(0) + 1e-4)
    assert np.all(np.asarray(f_hat) >= ci.min(0) - 1e-4)


# ------------------------------------------------------------ Wiener path --

def test_wiener_gaussian_fixed_point():
    """If x_t is exactly the (scaled) mean, wiener returns the mean."""
    d = 32
    mean = jnp.asarray(np.linspace(-1, 1, d), jnp.float32)
    var = jnp.ones(d, jnp.float32) * 0.5
    a_t = 0.6
    x_t = jnp.sqrt(a_t) * mean
    alphas = jnp.asarray([a_t, 0.9], jnp.float32)
    _, f_hat, _ = model.wiener_step(x_t, mean, var, alphas)
    np.testing.assert_allclose(f_hat, mean, rtol=1e-4, atol=1e-4)


def test_wiener_shrinkage_direction():
    """High noise shrinks towards the mean; low noise trusts the query."""
    d = 8
    rng = np.random.default_rng(12)
    mean = jnp.zeros(d, jnp.float32)
    var = jnp.ones(d, jnp.float32)
    q = rng.normal(size=d).astype(np.float32)

    for a_t, closeness in [(0.01, 0.1), (0.999, 0.9)]:
        x_t = jnp.asarray(np.sqrt(a_t) * q)
        alphas = jnp.asarray([a_t, 1.0], jnp.float32)
        _, f_hat, _ = model.wiener_step(x_t, mean, var, alphas)
        ratio = np.linalg.norm(np.asarray(f_hat)) / np.linalg.norm(q)
        if closeness < 0.5:
            assert ratio < 0.15
        else:
            assert ratio > 0.85


# -------------------------------------------------------------- distances --

def test_exact_dist_matches_ref():
    rng = np.random.default_rng(13)
    d, m = 48, 256
    x_t = jnp.asarray(rng.normal(size=d), jnp.float32)
    cand = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
    (got,) = model.exact_dist(x_t, cand, jnp.asarray([0.25], jnp.float32))
    q = np.asarray(x_t) / 0.5
    want = ((np.asarray(cand) - q) ** 2).sum(1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_proxy_dist_matches_ref():
    rng = np.random.default_rng(14)
    pd, m = 48, 512
    qp = jnp.asarray(rng.normal(size=pd), jnp.float32)
    table = jnp.asarray(rng.normal(size=(m, pd)), jnp.float32)
    (got,) = model.proxy_dist(qp, table)
    np.testing.assert_allclose(got, ref.sqdist_ref(qp, table), rtol=1e-4, atol=1e-3)
