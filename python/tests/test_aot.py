"""AOT pipeline: lowering produces parseable HLO text and a coherent
manifest with the exact input signatures the rust runtime expects."""

import json
import os

import pytest

from compile import aot
from compile.presets import PRESETS, k_buckets, m_buckets, next_pow2


def test_next_pow2():
    assert next_pow2(1) == 1
    assert next_pow2(2) == 2
    assert next_pow2(3) == 4
    assert next_pow2(50000) == 65536
    assert next_pow2(8000) == 8192


def test_bucket_ladders_cover_full_dataset():
    for p in PRESETS.values():
        ks = k_buckets(p)
        assert ks == sorted(ks)
        assert ks[-1] >= p.n, p.name
        ms = m_buckets(p)
        assert ms[-1] >= p.n, p.name


def test_preset_proxy_is_sixteenth_of_spatial():
    p = PRESETS["cifar-sim"]
    assert p.d == 16 * 16 * 3
    assert p.proxy_d == 4 * 4 * 3  # s = 1/4 both spatial dims


def test_moons_plan_has_no_image_variants():
    names = [name for name, *_ in aot.artifact_plan(PRESETS["moons"])]
    assert not any("pca" in n or "kamb" in n or "wiener" in n for n in names)
    assert any(n.startswith("golden_step") for n in names)


def test_imagenet_plan_is_conditional_and_large():
    p = PRESETS["imagenet-sim"]
    assert p.conditional and p.n == 50000 and p.classes == 1000
    ks = [meta["k"] for _, _, _, meta in aot.artifact_plan(p) if meta["variant"] == "golden_step"]
    assert 65536 in ks  # the Optimal full-scan bucket exists


def test_build_moons_writes_hlo_and_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out, presets=["moons"])
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["format"] == 1
    arts = manifest["artifacts"]
    assert len(arts) >= 5
    for a in arts:
        path = os.path.join(out, a["file"])
        assert os.path.exists(path), a["file"]
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # input arity matches the variant
        if a["variant"] == "golden_step":
            assert len(a["inputs"]) == 4
            assert a["inputs"][1] == [a["k"], 2]  # cand: [K, D]


def test_build_is_incremental(tmp_path):
    out = str(tmp_path / "artifacts")
    aot.build(out, presets=["moons"])
    path = os.path.join(out, "golden_step__moons__k32.hlo.txt")
    before = os.path.getmtime(path)
    aot.build(out, presets=["moons"])  # second run must not rewrite
    assert os.path.getmtime(path) == before


@pytest.mark.parametrize("variant,n_in", [
    ("golden_step", 4),
    ("pca_step_ss", 6),
    ("pca_step_wss", 6),
    ("kamb_step", 4),
    ("exact_dist", 3),
    ("proxy_dist", 2),
])
def test_plan_input_arity(variant, n_in):
    plan = list(aot.artifact_plan(PRESETS["cifar-sim"]))
    matching = [p for p in plan if p[3]["variant"] == variant]
    assert matching, variant
    for _, _, specs, _ in matching:
        assert len(specs) == n_in
