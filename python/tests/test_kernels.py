"""L1 correctness: Pallas kernels vs pure-jnp references.

hypothesis sweeps shapes, masks, scales and data distributions; any
streaming/blocking/masking error in the kernels shows up as an allclose
failure against ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.golden_aggregate import golden_aggregate, logit_aggregate
from compile.kernels.sqdist import sqdist

RTOL, ATOL = 2e-4, 2e-5


def _data(seed, k, d, valid_frac=1.0, spread=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=d) * spread, jnp.float32)
    c = jnp.asarray(rng.normal(size=(k, d)) * spread, jnp.float32)
    nvalid = max(1, int(k * valid_frac))
    mask = np.zeros(k, np.float32)
    mask[rng.choice(k, size=nvalid, replace=False)] = 1.0
    return q, c, jnp.asarray(mask)


# ----------------------------------------------------------------- sqdist --

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([4, 32, 128, 256, 512]),
    d=st.sampled_from([2, 3, 16, 48, 257]),
)
def test_sqdist_matches_ref(seed, k, d):
    q, c, _ = _data(seed, k, d)
    got = sqdist(q, c)
    want = ref.sqdist_ref(q, c)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-3)


def test_sqdist_zero_distance():
    c = jnp.ones((8, 5), jnp.float32) * 3.0
    d = sqdist(jnp.ones(5, jnp.float32) * 3.0, c)
    np.testing.assert_allclose(d, np.zeros(8), atol=1e-5)


def test_sqdist_single_block_vs_many_blocks():
    q, c, _ = _data(7, 512, 16)
    np.testing.assert_allclose(
        sqdist(q, c, block_k=512), sqdist(q, c, block_k=64), rtol=1e-5, atol=1e-4
    )


# ------------------------------------------------------- golden_aggregate --

@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([8, 64, 128, 256, 1024]),
    d=st.sampled_from([2, 16, 48, 130]),
    valid_frac=st.sampled_from([0.05, 0.3, 1.0]),
    scale=st.sampled_from([1e-3, 0.1, 1.0, 25.0]),
)
def test_golden_aggregate_matches_ref(seed, k, d, valid_frac, scale):
    q, c, mask = _data(seed, k, d, valid_frac)
    f, m, lse, ml = golden_aggregate(q, c, mask, scale)
    fr, mr, lser, mlr = ref.golden_aggregate_ref(q, c, mask, scale)
    np.testing.assert_allclose(f, fr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(m, mr, rtol=RTOL, atol=1e-4)
    np.testing.assert_allclose(lse, lser, rtol=RTOL, atol=1e-4)
    np.testing.assert_allclose(ml, mlr, rtol=RTOL, atol=1e-3)


def test_golden_aggregate_single_valid_row_returns_that_row():
    """k_min = 1 degenerate case: posterior collapses to the lone sample."""
    q, c, _ = _data(3, 64, 16)
    mask = np.zeros(64, np.float32)
    mask[17] = 1.0
    f, _, _, _ = golden_aggregate(q, c, jnp.asarray(mask), 0.5)
    np.testing.assert_allclose(f, c[17], rtol=1e-5, atol=1e-5)


def test_golden_aggregate_huge_scale_selects_nearest():
    """scale -> inf (sigma -> 0): streaming softmax must remain stable and
    pick the nearest neighbour (the paper's low-noise selection regime)."""
    q, c, mask = _data(11, 128, 8)
    f, m, lse, _ = golden_aggregate(q, c, mask, 1e4)
    d2 = np.asarray(ref.sqdist_ref(q, c))
    nn = int(np.argmin(d2))
    np.testing.assert_allclose(f, c[nn], rtol=1e-3, atol=1e-3)
    assert np.isfinite(float(lse))


def test_golden_aggregate_zero_scale_is_uniform_mean():
    """scale -> 0 (sigma -> inf): weights become uniform over valid rows —
    the paper's high-noise Monte-Carlo-integrator regime."""
    q, c, mask = _data(13, 256, 8, valid_frac=0.5)
    f, _, _, _ = golden_aggregate(q, c, mask, 0.0)
    want = np.asarray(c)[np.asarray(mask) > 0].mean(axis=0)
    np.testing.assert_allclose(f, want, rtol=1e-4, atol=1e-4)


def test_golden_aggregate_block_size_invariance():
    q, c, mask = _data(5, 512, 24, valid_frac=0.4)
    f1, *_ = golden_aggregate(q, c, mask, 2.0, block_k=512)
    f2, *_ = golden_aggregate(q, c, mask, 2.0, block_k=32)
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-5)


def test_golden_aggregate_masked_rows_do_not_contribute():
    """Changing masked-out rows must not change the result at all."""
    q, c, mask = _data(9, 128, 16, valid_frac=0.25)
    f1, *_ = golden_aggregate(q, c, mask, 1.0)
    c2 = np.asarray(c).copy()
    c2[np.asarray(mask) == 0] = 1e6
    f2, *_ = golden_aggregate(q, jnp.asarray(c2), mask, 1.0)
    np.testing.assert_allclose(f1, f2, rtol=1e-5, atol=1e-5)


def test_golden_aggregate_output_in_convex_hull():
    """f_hat is a convex combination of the candidates (posterior mean)."""
    q, c, mask = _data(21, 64, 4)
    f, *_ = golden_aggregate(q, c, mask, 0.7)
    lo = np.asarray(c).min(axis=0) - 1e-4
    hi = np.asarray(c).max(axis=0) + 1e-4
    assert np.all(np.asarray(f) >= lo) and np.all(np.asarray(f) <= hi)


# -------------------------------------------------------- logit_aggregate --

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([8, 128, 256, 512]),
    d=st.sampled_from([4, 32, 108]),
    valid_frac=st.sampled_from([0.1, 1.0]),
)
def test_logit_aggregate_matches_ref(seed, k, d, valid_frac):
    rng = np.random.default_rng(seed)
    _, c, mask = _data(seed, k, d, valid_frac)
    logits = jnp.asarray(rng.normal(size=k) * 5.0, jnp.float32)
    f, m, lse, ml = logit_aggregate(logits, c, mask)
    fr, mr, lser, mlr = ref.logit_aggregate_ref(logits, c, mask)
    np.testing.assert_allclose(f, fr, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(lse, lser, rtol=RTOL, atol=1e-4)


def test_logit_aggregate_is_shift_invariant():
    """softmax(logits + const) == softmax(logits) — online max handles it."""
    _, c, mask = _data(31, 128, 8, 0.5)
    logits = jnp.asarray(np.random.default_rng(31).normal(size=128), jnp.float32)
    f1, *_ = logit_aggregate(logits, c, mask)
    f2, *_ = logit_aggregate(logits + 100.0, c, mask)
    np.testing.assert_allclose(f1, f2, rtol=1e-4, atol=1e-4)


# --------------------------------------------------- theorem-1 truncation --

@pytest.mark.parametrize("scale", [0.05, 0.5, 5.0, 50.0])
def test_truncation_error_respects_theorem1_bound(scale):
    """|| f_D - f_S ||_2 <= 2 R (N - k) exp(-Delta_k)  (paper Thm. 1)."""
    rng = np.random.default_rng(42)
    n, d, k = 256, 16, 32
    q = jnp.asarray(rng.normal(size=d), jnp.float32)
    c = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    full_mask = jnp.ones(n, jnp.float32)

    logits = np.asarray(ref.masked_logits_ref(q, c, full_mask, scale))
    order = np.argsort(-logits)
    topk_mask = np.zeros(n, np.float32)
    topk_mask[order[:k]] = 1.0

    f_full, *_ = ref.golden_aggregate_ref(q, c, full_mask, scale)
    f_trunc, *_ = ref.golden_aggregate_ref(q, c, jnp.asarray(topk_mask), scale)

    err = float(np.linalg.norm(np.asarray(f_full) - np.asarray(f_trunc)))
    radius = float(np.max(np.linalg.norm(np.asarray(c), axis=1)))
    gap = float(logits[order[0]] - logits[order[k]])
    bound = 2.0 * radius * (n - k) * np.exp(-gap)
    assert err <= bound + 1e-5, f"err {err} > bound {bound}"
