"""AOT entrypoint: lower every (variant, preset, bucket) step graph to HLO
*text* + write ``artifacts/manifest.json`` for the rust runtime.

Run via ``make artifacts`` (no-op when inputs are unchanged):

    cd python && python -m compile.aot --out-dir ../artifacts

HLO text — NOT ``lowered.compile()`` / proto ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the runtime's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the HLO text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .presets import (
    KAMB_PATCHES,
    PCA_RANK,
    PRESETS,
    WSS_BLOCKS,
    Preset,
    k_buckets,
    m_buckets,
    next_pow2,
)

F32 = jnp.float32


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation (return_tuple=True) -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _block_k(k: int) -> int:
    """Tile height for the streaming kernels: bounded grid depth so the
    interpret-mode loop stays shallow for huge buckets."""
    if k <= 128:
        return k
    return max(128, k // 64)


def artifact_plan(preset: Preset):
    """Yield (name, fn, arg_specs, meta) for every graph of one preset.

    Serving variants (``golden_step``, ``pca_step_*``, ``exact_dist``) are
    the pure-jnp twins — XLA fuses them into tight CPU kernels. The Pallas
    streaming-kernel builds ride along as ``*_pallas`` variants at a reduced
    bucket set: they are the TPU-structured artifacts and the
    kernel-vs-graph validation/perf ablation (interpret=True is a
    correctness vehicle on CPU, ~10-70× slower than the fused twin —
    EXPERIMENTS.md §Perf).
    """
    d = preset.d
    pd = preset.proxy_d
    image = preset.h > 1
    ks = k_buckets(preset)
    pallas_ks = sorted({ks[0], 512, 2048} & set(ks)) or [ks[0]]

    for k in ks:
        bk = _block_k(k)
        yield (
            f"golden_step__{preset.name}__k{k}",
            model.golden_step_jnp,
            [spec(d), spec(k, d), spec(k), spec(2)],
            {"variant": "golden_step", "k": k},
        )
        if k in pallas_ks:
            yield (
                f"golden_step_pallas__{preset.name}__k{k}",
                functools.partial(_golden_step_blocked, block_k=bk),
                [spec(d), spec(k, d), spec(k), spec(2)],
                {"variant": "golden_step_pallas", "k": k, "block_k": bk},
            )
        if image:
            pca_specs = [spec(d), spec(k, d), spec(k), spec(PCA_RANK, d), spec(d), spec(2)]
            yield (
                f"pca_step_ss__{preset.name}__k{k}",
                model.pca_step_ss_jnp,
                pca_specs,
                {"variant": "pca_step_ss", "k": k, "r": PCA_RANK},
            )
            yield (
                f"pca_step_wss__{preset.name}__k{k}",
                model.pca_step_wss_jnp,
                pca_specs,
                {"variant": "pca_step_wss", "k": k, "r": PCA_RANK},
            )
            if k in pallas_ks:
                yield (
                    f"pca_step_ss_pallas__{preset.name}__k{k}",
                    functools.partial(_pca_ss_blocked, block_k=bk),
                    pca_specs,
                    {"variant": "pca_step_ss_pallas", "k": k, "r": PCA_RANK, "block_k": bk},
                )

    if image:
        # Kamb only at the full-scan bucket and one golden-subset bucket —
        # the baseline and its GoldDiff-wrapped form (Tab. 5).
        full = next_pow2(preset.n)
        for k in sorted({512, full}):
            for p in KAMB_PATCHES:
                fn = functools.partial(
                    model.kamb_step, h=preset.h, w=preset.w, c=preset.c, patch=p
                )
                yield (
                    f"kamb_step__{preset.name}__k{k}__p{p}",
                    fn,
                    [spec(d), spec(k, d), spec(k), spec(2)],
                    {"variant": "kamb_step", "k": k, "p": p},
                )
        yield (
            f"wiener_step__{preset.name}",
            model.wiener_step,
            [spec(d), spec(d), spec(d), spec(2)],
            {"variant": "wiener_step", "k": 0},
        )

    for m in m_buckets(preset):
        yield (
            f"exact_dist__{preset.name}__k{m}",
            model.exact_dist_jnp,
            [spec(d), spec(m, d), spec(1)],
            {"variant": "exact_dist", "k": m},
        )
    yield (
        f"exact_dist_pallas__{preset.name}__k{m_buckets(preset)[0]}",
        _exact_dist_blocked,
        [spec(d), spec(m_buckets(preset)[0], d), spec(1)],
        {"variant": "exact_dist_pallas", "k": m_buckets(preset)[0]},
    )

    full = next_pow2(preset.n)
    yield (
        f"proxy_dist__{preset.name}__k{full}",
        model.proxy_dist,
        [spec(pd), spec(full, pd)],
        {"variant": "proxy_dist", "k": full},
    )


# --- blocked wrappers (block size is a lowering-time choice) ---------------

def _golden_step_blocked(x_t, cand, mask, alphas, *, block_k):
    from .kernels.golden_aggregate import golden_aggregate

    alpha_t, alpha_prev = alphas[0], alphas[1]
    q = x_t / jnp.sqrt(alpha_t)
    scale = model._scale_from_alpha(alpha_t)
    f_hat, m, lse, mean_logit = golden_aggregate(q, cand, mask, scale, block_k=block_k)
    x_prev = model.ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, model._stats_vec(m, lse, mean_logit)


def _pca_ss_blocked(x_t, cand, mask, basis, center, alphas, *, block_k):
    from .kernels.golden_aggregate import logit_aggregate

    alpha_t, alpha_prev = alphas[0], alphas[1]
    logits = model._pca_logits(x_t, cand, basis, center, alpha_t)
    f_hat, m, lse, mean_logit = logit_aggregate(logits, cand, mask, block_k=block_k)
    x_prev = model.ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, model._stats_vec(m, lse, mean_logit)


def _pca_wss_blocked(x_t, cand, mask, basis, center, alphas, *, block_k):
    del block_k  # WSS is block-averaged by construction (J fixed)
    return model.pca_step_wss(x_t, cand, mask, basis, center, alphas, blocks=WSS_BLOCKS)


def _exact_dist_blocked(x_t, cand, alpha):
    return model.exact_dist(x_t, cand, alpha)


# ---------------------------------------------------------------------------

def build(out_dir: str, only: str | None = None, presets: list[str] | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "format": 1,
        "pca_rank": PCA_RANK,
        "wss_blocks": WSS_BLOCKS,
        "kamb_patches": list(KAMB_PATCHES),
        "presets": [],
        "artifacts": [],
    }
    names = presets or list(PRESETS)
    for pname in names:
        preset = PRESETS[pname]
        manifest["presets"].append(
            {
                "name": preset.name,
                "paper_name": preset.paper_name,
                "n": preset.n,
                "h": preset.h,
                "w": preset.w,
                "c": preset.c,
                "d": preset.d,
                "proxy_d": preset.proxy_d,
                "classes": preset.classes,
                "conditional": preset.conditional,
                "full_bucket": next_pow2(preset.n),
            }
        )
        for name, fn, arg_specs, meta in artifact_plan(preset):
            if only and only not in name:
                continue
            fname = f"{name}.hlo.txt"
            path = os.path.join(out_dir, fname)
            entry = {
                "name": name,
                "file": fname,
                "preset": preset.name,
                "d": preset.d,
                "inputs": [list(s.shape) for s in arg_specs],
                **meta,
            }
            manifest["artifacts"].append(entry)
            if os.path.exists(path) and os.path.getsize(path) > 0:
                continue  # incremental: make drives staleness via mtimes
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)", flush=True)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--presets", default=None, help="comma-separated preset names")
    args = ap.parse_args()
    presets = args.presets.split(",") if args.presets else None
    build(args.out_dir, only=args.only, presets=presets)


if __name__ == "__main__":
    sys.exit(main())
