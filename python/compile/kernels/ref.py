"""Pure-jnp reference oracles for every Pallas kernel (L1 correctness spec).

These are the ground truth the pytest/hypothesis suite checks the Pallas
kernels against. They are intentionally written in the most direct form
(materialise the full logit vector, plain softmax) so that any streaming /
blocking error in the kernels shows up as a numeric mismatch.
"""

from __future__ import annotations

import jax.numpy as jnp

BIG = 1e30


def sqdist_ref(q: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distances ||q - c_i||^2.

    q: [d], c: [K, d]  ->  [K]
    """
    diff = c - q[None, :]
    return jnp.sum(diff * diff, axis=-1)


def masked_logits_ref(q, c, mask, scale):
    """Gaussian-kernel logits -||q - c_i||^2 * scale, invalid rows at -BIG.

    scale = 1 / (2 sigma_t^2); mask: [K] in {0, 1}.
    """
    return -sqdist_ref(q, c) * scale - (1.0 - mask) * BIG


def golden_aggregate_ref(q, c, mask, scale):
    """Exact (non-streaming) masked softmax aggregation — Eq. (2) of the
    paper restricted to the golden subset.

    Returns (f_hat [D], m [], lse [], mean_logit []).
    """
    logits = masked_logits_ref(q, c, mask, scale)
    return logit_aggregate_ref(logits, c, mask)


def logit_aggregate_ref(logits, c, mask):
    """Masked softmax aggregation from externally supplied logits
    (PCA-subspace path). Returns (f_hat, m, lse, mean_logit)."""
    logits = logits - (1.0 - mask) * BIG
    m = jnp.max(logits)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    f_hat = (p @ c) / l
    lse = m + jnp.log(l)
    mean_logit = jnp.sum(p * logits) / l
    return f_hat, m, lse, mean_logit


def softmax_stats_ref(logits, mask):
    """(top-1 weight, entropy) of the masked softmax distribution."""
    logits = logits - (1.0 - mask) * BIG
    m = jnp.max(logits)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    lse = m + jnp.log(l)
    entropy = lse - jnp.sum(p * logits) / l
    return jnp.max(p / l), entropy
