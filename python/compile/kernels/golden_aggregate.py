"""L1 hot-spot: masked *streaming-softmax* weighted aggregation over the
Golden Subset (Sec. 3.2 of the paper; unbiased streaming softmax of
Dao et al. 2022), as a Pallas kernel.

The kernel walks the candidate axis K in blocks of ``block_k`` rows and keeps
a FlashAttention-style online-softmax carry:

    m   — running max logit
    l   — running denominator  sum exp(logit - m)
    s   — running numerator    sum exp(logit - m) * logit   (for entropy)
    acc — running weighted sum sum exp(logit - m) * x_i     ([D])

TPU mapping (see DESIGN.md §Hardware-Adaptation): the carry lives in
revisited output blocks (the VMEM-scratch role shared memory plays in the
GPU FlashAttention formulation); the dominant term of the distance
||q - x_i||^2 = ||q||^2 - 2 q·x_i + ||x_i||^2 is computed as a
(block_k × D)·(D) matvec which maps onto the MXU systolic array rather than
the elementwise subtract-square form. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot run Mosaic custom-calls; real-TPU perf is estimated from
the BlockSpec footprint in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INIT = -1e30


def _golden_kernel(q_ref, c_ref, mask_ref, scale_ref, o_ref, m_ref, l_ref, s_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    q = q_ref[...]  # [1, D]
    c = c_ref[...]  # [BK, D]
    mask = mask_ref[...][0]  # [BK]
    scale = scale_ref[0, 0]

    # ||q - x_i||^2 = ||q||^2 - 2 q.x_i + ||x_i||^2 ; the q.x_i term is the
    # MXU-friendly matvec.
    qq = jnp.sum(q * q)
    qx = jnp.dot(c, q[0])  # [BK]
    xx = jnp.sum(c * c, axis=1)  # [BK]
    d2 = qq - 2.0 * qx + xx
    logits = -d2 * scale - (1.0 - mask) * 1e30

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new) * mask  # [BK]

    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    s_ref[0, 0] = s_ref[0, 0] * corr + jnp.sum(p * logits)
    o_ref[...] = o_ref[...] * corr + (p @ c)[None, :]
    m_ref[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("block_k",))
def golden_aggregate(q, c, mask, scale, *, block_k: int = 128):
    """Streaming masked softmax aggregation.

    Args:
      q:     [D] noisy query (already divided by sqrt(alpha_t)).
      c:     [K, D] golden-subset candidates (padded to the bucket size).
      mask:  [K] validity mask in {0,1} (padding rows are 0).
      scale: scalar 1/(2 sigma_t^2).
      block_k: candidate rows per grid step (VMEM tile height).

    Returns:
      (f_hat [D], m [], lse [], mean_logit []) exactly matching
      ``ref.golden_aggregate_ref`` up to float32 roundoff.
    """
    k, d = c.shape
    bk = min(block_k, k)
    assert k % bk == 0, f"bucket {k} not divisible by block {bk}"
    grid = (k // bk,)
    q2 = q.reshape(1, d).astype(jnp.float32)
    mask2 = mask.reshape(1, k).astype(jnp.float32)
    scale2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)

    out_shapes = [
        jax.ShapeDtypeStruct((1, d), jnp.float32),  # acc
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # m
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # l
        jax.ShapeDtypeStruct((1, 1), jnp.float32),  # s
    ]
    acc, m, l, s = pl.pallas_call(
        _golden_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((1, bk), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=out_shapes,
        interpret=True,
    )(q2, c.astype(jnp.float32), mask2, scale2)

    l0 = l[0, 0]
    f_hat = acc[0] / l0
    lse = m[0, 0] + jnp.log(l0)
    mean_logit = s[0, 0] / l0
    return f_hat, m[0, 0], lse, mean_logit


def _logit_kernel(lg_ref, c_ref, mask_ref, o_ref, m_ref, l_ref, s_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        s_ref[...] = jnp.zeros_like(s_ref)

    c = c_ref[...]  # [BK, D]
    mask = mask_ref[...][0]  # [BK]
    logits = lg_ref[...][0] - (1.0 - mask) * 1e30

    m_prev = m_ref[0, 0]
    m_new = jnp.maximum(m_prev, jnp.max(logits))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new) * mask

    l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p)
    s_ref[0, 0] = s_ref[0, 0] * corr + jnp.sum(p * logits)
    o_ref[...] = o_ref[...] * corr + (p @ c)[None, :]
    m_ref[0, 0] = m_new


@functools.partial(jax.jit, static_argnames=("block_k",))
def logit_aggregate(logits, c, mask, *, block_k: int = 128):
    """Streaming masked softmax aggregation from precomputed logits
    (the PCA-subspace path: logits computed in the rank-R subspace,
    aggregation over the full-D candidates).

    Returns (f_hat [D], m [], lse [], mean_logit []).
    """
    k, d = c.shape
    bk = min(block_k, k)
    assert k % bk == 0
    grid = (k // bk,)
    lg2 = logits.reshape(1, k).astype(jnp.float32)
    mask2 = mask.reshape(1, k).astype(jnp.float32)

    out_shapes = [
        jax.ShapeDtypeStruct((1, d), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
        jax.ShapeDtypeStruct((1, 1), jnp.float32),
    ]
    acc, m, l, s = pl.pallas_call(
        _logit_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bk), lambda i: (0, i)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((1, bk), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=out_shapes,
        interpret=True,
    )(lg2, c.astype(jnp.float32), mask2)

    l0 = l[0, 0]
    return acc[0] / l0, m[0, 0], m[0, 0] + jnp.log(l0), s[0, 0] / l0
