"""L1: blocked squared-distance kernel (Pallas, interpret mode).

One kernel serves three call-sites in the L2 graphs:

  * the *Adaptive Coarse Screening* proxy scan — distances between the
    s=1/4 average-pooled query and the proxy table (Sec. 3.4, Eq. 4);
  * the *Precision Golden Set Selection* exact distances inside the
    candidate pool C_t (Eq. 5);
  * PCA-subspace logits (distances between rank-R projections).

The candidate table is tiled (block_k × d) over a 1-D grid; each grid step
emits one block of the distance vector. The q·x_i cross term is an
MXU-friendly matvec, as in ``golden_aggregate``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_kernel(q_ref, c_ref, o_ref):
    q = q_ref[...]  # [1, d]
    c = c_ref[...]  # [BK, d]
    qq = jnp.sum(q * q)
    qx = jnp.dot(c, q[0])
    xx = jnp.sum(c * c, axis=1)
    o_ref[...] = (qq - 2.0 * qx + xx)[None, :]


@functools.partial(jax.jit, static_argnames=("block_k",))
def sqdist(q, c, *, block_k: int = 256):
    """||q - c_i||^2 for all rows of c.

    q: [d], c: [K, d] -> [K] (float32). K must be divisible by the block.
    """
    k, d = c.shape
    bk = min(block_k, k)
    assert k % bk == 0, f"{k} % {bk} != 0"
    out = pl.pallas_call(
        _sqdist_kernel,
        grid=(k // bk,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, k), jnp.float32),
        interpret=True,
    )(q.reshape(1, d).astype(jnp.float32), c.astype(jnp.float32))
    return out[0]
