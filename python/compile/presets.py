"""Dataset presets shared by the compile path and (via artifacts/manifest.json)
the rust coordinator.

Each preset mirrors one dataset of the paper's evaluation protocol (Sec. 4.1),
scaled to the CPU testbed per DESIGN.md §3 (Substitutions). ``d`` is the
flattened dimension, ``proxy_d`` the s=1/4 spatially-downsampled proxy
dimension used by Adaptive Coarse Screening (Sec. 3.4).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Preset:
    name: str
    paper_name: str
    n: int
    h: int
    w: int
    c: int
    classes: int
    conditional: bool = False

    @property
    def d(self) -> int:
        return self.h * self.w * self.c

    @property
    def proxy_d(self) -> int:
        # s = 1/4 spatial average pooling (moons is already 2-D: identity).
        if self.h == 1:
            return self.w * self.c
        return (self.h // 4) * (self.w // 4) * self.c


PRESETS: dict[str, Preset] = {
    p.name: p
    for p in [
        Preset("moons", "Moons (Fig. 1)", 2000, 1, 2, 1, 2),
        Preset("mnist-sim", "MNIST", 8000, 16, 16, 1, 10),
        Preset("fashion-sim", "Fashion-MNIST", 8000, 16, 16, 1, 10),
        Preset("cifar-sim", "CIFAR-10", 10000, 16, 16, 3, 10),
        Preset("celeba-sim", "CelebA-HQ", 6000, 24, 24, 3, 40),
        Preset("afhq-sim", "AFHQv2", 6000, 24, 24, 3, 3),
        Preset("imagenet-sim", "ImageNet-1K", 50000, 16, 16, 3, 1000, True),
    ]
}

#: rank of the local PCA bases (Lukoianov et al. baseline).
PCA_RANK = 32

#: Kamb patch sizes compiled (the p_t schedule snaps to the nearest).
KAMB_PATCHES = (3, 7)

#: number of averaging blocks in the biased Weighted Streaming Softmax.
WSS_BLOCKS = 8

#: dense power-of-two ladder: tight bucket padding halves the wasted
#: gather+compute vs a 4×-spaced ladder (§Perf iteration 3)
_K_LADDER = (32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def k_buckets(preset: Preset) -> list[int]:
    """Aggregation-bucket ladder for a preset: powers of two up to the
    padded full-dataset size (the full bucket doubles as the Optimal
    full-scan variant)."""
    full = next_pow2(preset.n)
    ks = [k for k in _K_LADDER if k < full]
    return ks + [full]


def m_buckets(preset: Preset) -> list[int]:
    """Candidate-pool ladder for the exact-distance refine stage."""
    full = next_pow2(preset.n)
    ms = [m for m in (512, 2048, 8192, 16384) if m < full]
    return ms + [full]
