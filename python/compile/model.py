"""L2: the analytical-denoiser step graphs (JAX), calling the L1 Pallas
kernels, lowered once per (variant, preset, bucket) by ``aot.py``.

Every function here is a *pure* jax function over float32 arrays with static
shapes; ``aot.py`` jit-lowers each to HLO text for the rust runtime. Nothing
in this module runs on the request path.

Diffusion convention (Sec. 3.1 of the paper):

    x_t = sqrt(a_t) x_0 + sqrt(1 - a_t) eps ,   sigma_t^2 = (1 - a_t) / a_t
    q_t = x_t / sqrt(a_t)                       (the "descaled" query)
    logits_i = -||q_t - x_i||^2 / (2 sigma_t^2)

The DDIM (eta = 0) update used throughout (10-step default, as in the paper):

    eps_hat = (x_t - sqrt(a_t) f_hat) / sqrt(1 - a_t)
    x_prev  = sqrt(a_prev) f_hat + sqrt(1 - a_prev) eps_hat
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.golden_aggregate import golden_aggregate, logit_aggregate
from .kernels.sqdist import sqdist

EPS = 1e-12


# ---------------------------------------------------------------------------
# shared pieces
# ---------------------------------------------------------------------------

def _scale_from_alpha(alpha_t):
    """1 / (2 sigma_t^2) with sigma_t^2 = (1 - a_t)/a_t."""
    return alpha_t / (2.0 * (1.0 - alpha_t) + EPS)


def ddim_update(x_t, f_hat, alpha_t, alpha_prev):
    """Deterministic DDIM step from x_t to x_{t-1} given the posterior mean."""
    sa_t = jnp.sqrt(alpha_t)
    s1a_t = jnp.sqrt(jnp.maximum(1.0 - alpha_t, EPS))
    eps_hat = (x_t - sa_t * f_hat) / s1a_t
    return jnp.sqrt(alpha_prev) * f_hat + jnp.sqrt(jnp.maximum(1.0 - alpha_prev, 0.0)) * eps_hat


def _stats_vec(m, lse, mean_logit):
    """[max_logit, logsumexp, entropy, top1_weight] of the posterior."""
    entropy = lse - mean_logit
    top1 = jnp.exp(m - lse)
    return jnp.stack([m, lse, entropy, top1])


# ---------------------------------------------------------------------------
# GoldDiff / Optimal step (Eq. 2 restricted to the golden subset S_t;
# with mask == 1 and the full-N bucket this *is* the Optimal denoiser)
# ---------------------------------------------------------------------------

def golden_step(x_t, cand, mask, alphas):
    """One analytical denoising step over a (padded) golden subset.

    x_t: [D]; cand: [K, D]; mask: [K] in {0,1}; alphas: [2] = (a_t, a_prev).
    Returns (x_prev [D], f_hat [D], stats [4]).
    """
    alpha_t, alpha_prev = alphas[0], alphas[1]
    q = x_t / jnp.sqrt(alpha_t)
    scale = _scale_from_alpha(alpha_t)
    f_hat, m, lse, mean_logit = golden_aggregate(q, cand, mask, scale)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


def golden_step_jnp(x_t, cand, mask, alphas):
    """Pure-jnp twin of ``golden_step`` (no Pallas) — the XLA-fusion
    reference point for the §Perf L1-vs-L2 comparison."""
    alpha_t, alpha_prev = alphas[0], alphas[1]
    q = x_t / jnp.sqrt(alpha_t)
    scale = _scale_from_alpha(alpha_t)
    d2 = jnp.sum((cand - q[None, :]) ** 2, axis=1)
    logits = -d2 * scale - (1.0 - mask) * 1e30
    m = jnp.max(logits)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    f_hat = (p @ cand) / l
    lse = m + jnp.log(l)
    mean_logit = jnp.sum(p * logits) / l
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


# ---------------------------------------------------------------------------
# PCA denoiser (Lukoianov et al.) — subspace logits; SS (unbiased) and WSS
# (biased, block-averaged) weightings. GoldDiff-wrapped PCA = same graphs at
# small-k buckets.
# ---------------------------------------------------------------------------

def _pca_logits(x_t, cand, basis, center, alpha_t, *, use_pallas=True):
    """Logits from rank-R subspace distances: z = B (x - mu)."""
    q = x_t / jnp.sqrt(alpha_t)
    zq = basis @ (q - center)  # [R]
    zc = (cand - center[None, :]) @ basis.T  # [K, R]
    if use_pallas:
        d2 = sqdist(zq, zc)
    else:
        d2 = jnp.sum((zc - zq[None, :]) ** 2, axis=1)
    return -d2 * _scale_from_alpha(alpha_t)


def _ss_aggregate_jnp(logits, cand, mask):
    """Pure-jnp masked softmax aggregation (XLA-fusion serving twin of the
    L1 streaming kernel; numerically identical up to roundoff)."""
    logits = logits - (1.0 - mask) * 1e30
    m = jnp.max(logits)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    f_hat = (p @ cand) / l
    lse = m + jnp.log(l)
    mean_logit = jnp.sum(p * logits) / l
    return f_hat, m, lse, mean_logit


def pca_step_ss(x_t, cand, mask, basis, center, alphas):
    """PCA denoiser with the *unbiased* streaming softmax (Dao et al. 2022).
    This is the paper's "PCA (Unbiased)" row; on golden-subset buckets it is
    GoldDiff-on-PCA, the paper's primary configuration."""
    alpha_t, alpha_prev = alphas[0], alphas[1]
    logits = _pca_logits(x_t, cand, basis, center, alpha_t)
    f_hat, m, lse, mean_logit = logit_aggregate(logits, cand, mask)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


def pca_step_ss_jnp(x_t, cand, mask, basis, center, alphas):
    """Pure-jnp twin of ``pca_step_ss`` — the serving-path variant (the
    Pallas interpret loop is a CPU correctness vehicle; XLA fuses this twin
    into one tight kernel on the CPU PJRT backend)."""
    alpha_t, alpha_prev = alphas[0], alphas[1]
    logits = _pca_logits(x_t, cand, basis, center, alpha_t, use_pallas=False)
    f_hat, m, lse, mean_logit = _ss_aggregate_jnp(logits, cand, mask)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


def pca_step_wss_jnp(x_t, cand, mask, basis, center, alphas, *, blocks: int = 8):
    """Pure-jnp twin of ``pca_step_wss`` (subspace logits without the Pallas
    sqdist; the WSS block-averaging itself was already pure jnp)."""
    alpha_t, alpha_prev = alphas[0], alphas[1]
    k, d = cand.shape
    logits = _pca_logits(x_t, cand, basis, center, alpha_t, use_pallas=False) - (
        1.0 - mask
    ) * 1e30

    kb = k // blocks
    lg = logits.reshape(blocks, kb)
    mk = mask.reshape(blocks, kb)
    cb = cand.reshape(blocks, kb, d)
    m_j = jnp.max(lg, axis=1)
    p_j = jnp.exp(lg - m_j[:, None]) * mk
    l_j = jnp.sum(p_j, axis=1)
    means = jnp.einsum("jk,jkd->jd", p_j, cb) / (l_j[:, None] + EPS)
    nonempty = (l_j > 0.0).astype(jnp.float32)
    f_hat = jnp.sum(means * nonempty[:, None], axis=0) / (jnp.sum(nonempty) + EPS)

    m = jnp.max(lg)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    lse = m + jnp.log(l + EPS)
    mean_logit = jnp.sum(p * logits) / (l + EPS)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


def pca_step_wss(x_t, cand, mask, basis, center, alphas, *, blocks: int = 8):
    """PCA denoiser with the *biased* Weighted Streaming Softmax: the
    candidate axis is split into ``blocks`` batches, each batch contributes
    its own softmax mean, and batch means are averaged (batch-level
    averaging). This reproduces the weight-flattening trick of the PCA
    baseline and its over-smoothing failure mode (Fig. 2 / Sec. 3.2).
    """
    alpha_t, alpha_prev = alphas[0], alphas[1]
    k, d = cand.shape
    logits = _pca_logits(x_t, cand, basis, center, alpha_t) - (1.0 - mask) * 1e30

    kb = k // blocks
    lg = logits.reshape(blocks, kb)
    mk = mask.reshape(blocks, kb)
    cb = cand.reshape(blocks, kb, d)

    m_j = jnp.max(lg, axis=1)  # [J]
    p_j = jnp.exp(lg - m_j[:, None]) * mk  # [J, kb]
    l_j = jnp.sum(p_j, axis=1)  # [J]
    means = jnp.einsum("jk,jkd->jd", p_j, cb) / (l_j[:, None] + EPS)  # [J, D]
    # batch-level averaging over non-empty blocks — the flattening bias.
    nonempty = (l_j > 0.0).astype(jnp.float32)
    f_hat = jnp.sum(means * nonempty[:, None], axis=0) / (jnp.sum(nonempty) + EPS)

    # stats from the exact (global) weights, for apples-to-apples telemetry
    m = jnp.max(lg)
    p = jnp.exp(logits - m) * mask
    l = jnp.sum(p)
    lse = m + jnp.log(l + EPS)
    mean_logit = jnp.sum(p * logits) / (l + EPS)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(m, lse, mean_logit)


# ---------------------------------------------------------------------------
# Kamb (patch-based) denoiser — per-pixel softmax over patch distances,
# expressed with reduce_window so it lowers to one fused XLA graph.
# ---------------------------------------------------------------------------

def kamb_step(x_t, cand, mask, alphas, *, h: int, w: int, c: int, patch: int):
    """Patch-based analytical denoiser (Kamb & Ganguli 2024).

    For every pixel location, weights are a softmax over the N candidates of
    the local patch distance (window ``patch``), and the output pixel is the
    weighted average of candidate pixels — translation-equivariant locality.

    x_t: [D]; cand: [K, D]; mask: [K]; alphas: [2]. D = h*w*c.
    """
    alpha_t, alpha_prev = alphas[0], alphas[1]
    k = cand.shape[0]
    q = (x_t / jnp.sqrt(alpha_t)).reshape(h, w, c)
    ci = cand.reshape(k, h, w, c)

    diff2 = jnp.sum((ci - q[None]) ** 2, axis=-1)  # [K, h, w]
    pad = patch // 2
    # mean patch distance via summed-window / window-size (same padding)
    win = jax.lax.reduce_window(
        diff2,
        0.0,
        jax.lax.add,
        window_dimensions=(1, patch, patch),
        window_strides=(1, 1, 1),
        padding=((0, 0), (pad, pad), (pad, pad)),
    )
    ones = jax.lax.reduce_window(
        jnp.ones_like(diff2[:1]),
        0.0,
        jax.lax.add,
        window_dimensions=(1, patch, patch),
        window_strides=(1, 1, 1),
        padding=((0, 0), (pad, pad), (pad, pad)),
    )
    patch_d2 = win / ones  # [K, h, w]

    scale = _scale_from_alpha(alpha_t)
    logits = -patch_d2 * scale - (1.0 - mask)[:, None, None] * 1e30  # [K,h,w]
    m = jnp.max(logits, axis=0, keepdims=True)
    p = jnp.exp(logits - m) * mask[:, None, None]
    l = jnp.sum(p, axis=0, keepdims=True)
    wts = p / (l + EPS)  # [K, h, w]
    f_img = jnp.einsum("khw,khwc->hwc", wts, ci)
    f_hat = f_img.reshape(-1)

    # stats from the centre pixel's distribution (representative telemetry)
    lg_c = logits[:, h // 2, w // 2]
    mc = jnp.max(lg_c)
    pc = jnp.exp(lg_c - mc) * mask
    lc = jnp.sum(pc)
    lse = mc + jnp.log(lc + EPS)
    mean_logit = jnp.sum(pc * lg_c) / (lc + EPS)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    return x_prev, f_hat, _stats_vec(mc, lse, mean_logit)


# ---------------------------------------------------------------------------
# Wiener filter — global-Gaussian closed form; no dataset access at runtime.
# ---------------------------------------------------------------------------

def wiener_step(x_t, mean, var, alphas):
    """Classical Wiener denoiser: fit N(mean, diag(var)) to the data and
    shrink towards the mean — complexity independent of N (Tab. 1)."""
    alpha_t, alpha_prev = alphas[0], alphas[1]
    q = x_t / jnp.sqrt(alpha_t)
    sigma2 = (1.0 - alpha_t) / (alpha_t + EPS)
    f_hat = mean + (var / (var + sigma2)) * (q - mean)
    x_prev = ddim_update(x_t, f_hat, alpha_t, alpha_prev)
    zeros = jnp.zeros(4, jnp.float32)
    return x_prev, f_hat, zeros


# ---------------------------------------------------------------------------
# Retrieval graphs — exact refine distances and the coarse proxy scan.
# ---------------------------------------------------------------------------

def exact_dist(x_t, cand, alpha):
    """||x_t/sqrt(a_t) - c_i||^2 over the candidate pool C_t (Eq. 5 input)."""
    q = x_t / jnp.sqrt(alpha[0])
    return (sqdist(q, cand),)


def exact_dist_jnp(x_t, cand, alpha):
    """Pure-jnp twin of ``exact_dist`` (serving path)."""
    q = x_t / jnp.sqrt(alpha[0])
    return (jnp.sum((cand - q[None, :]) ** 2, axis=1),)


def proxy_dist(qp, table):
    """Coarse-screening distances in the s=1/4 proxy space (Eq. 4 input)."""
    return (sqdist(qp, table),)
