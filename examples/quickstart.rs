//! Quickstart: load a dataset, start the GoldDiff engine, generate a few
//! samples, and compare GoldDiff against the full-scan Optimal denoiser.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Everything here goes through the public API: `EngineConfig` → `Engine`
//! → `submit`/`generate`, with the PJRT-compiled step graphs underneath.

use golddiff::config::EngineConfig;
use golddiff::coordinator::Engine;
use golddiff::denoiser::DenoiserKind;

fn main() -> anyhow::Result<()> {
    // 1. configure: the CIFAR-10 stand-in, 10-step DDIM, paper budgets
    let cfg = EngineConfig {
        preset: "cifar-sim".into(),
        ..Default::default()
    };
    println!("starting engine (first run synthesises data/cifar-sim.gds)…");
    let engine = Engine::start(cfg)?;

    // 2. generate 4 samples with GoldDiff (the paper's primary config:
    //    GoldDiff retrieval + PCA-subspace weighting + unbiased softmax)
    for seed in 0..4u64 {
        let resp = engine.generate(DenoiserKind::GoldDiffPca, seed, None)?;
        let k_first = resp.steps.first().map(|s| s.k_used).unwrap_or(0);
        let k_last = resp.steps.last().map(|s| s.k_used).unwrap_or(0);
        println!(
            "seed {seed}: {} dims in {:.3}s — golden subset {} → {} (Counter-Monotonic Schedule)",
            resp.sample.len(),
            resp.latency_secs,
            k_first,
            k_last,
        );
    }

    // 3. the same seed through the exact full-scan Optimal denoiser —
    //    GoldDiff's output should track it closely at a fraction of the cost
    let gold = engine.generate(DenoiserKind::GoldDiff, 0, None)?;
    let opt = engine.generate(DenoiserKind::Optimal, 0, None)?;
    let mse: f64 = gold
        .sample
        .iter()
        .zip(&opt.sample)
        .map(|(a, b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        / gold.sample.len() as f64;
    let t_gold: f64 = gold.steps.iter().map(|s| s.dispatch_secs + s.scan_secs).sum();
    let t_opt: f64 = opt.steps.iter().map(|s| s.dispatch_secs + s.scan_secs).sum();
    println!(
        "\nGoldDiff vs Optimal (same seed): MSE {mse:.5}, compute {:.3}s vs {:.3}s (×{:.1})",
        t_gold,
        t_opt,
        t_opt / t_gold.max(1e-9)
    );

    println!("\nengine stats: {}", engine.stats_json());
    engine.shutdown();
    Ok(())
}
