//! Fig. 1 reproduction as a runnable demo: Posterior Progressive
//! Concentration on the Moons dataset, rendered as ASCII — watch the
//! golden support shrink from the global manifold to a local
//! neighbourhood as the reverse process runs.
//!
//!     cargo run --release --example moons_concentration

use golddiff::benchlib::figures::full_posterior_weights;
use golddiff::data::store;
use golddiff::oracle::GmmOracle;
use golddiff::sampler;
use golddiff::schedule::noise::{NoiseSchedule, ScheduleKind};
use golddiff::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let ds = store::load_or_synthesize(std::path::Path::new("data"), "moons", 0)?;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let oracle = GmmOracle::new(ds.gmm.clone());

    let mut rng = Pcg64::new(4);
    let mut x = sampler::init_noise(ds.d, &mut rng);

    println!("Posterior Progressive Concentration (Fig. 1) — Moons, N = {}", ds.n);
    println!("★ = current x_t, # = high posterior weight, · = training data\n");

    for step in 0..sched.steps {
        let w = full_posterior_weights(&ds, &x, &sched, step);
        let eff = golddiff::metrics::effective_support(&w);
        let s90 = golddiff::metrics::support_at_mass(&w, 0.9);
        render(&ds, &w, &x);
        println!(
            "t = {:>2}/10   σ² = {:>8.3}   effective support = {:>7.1}   90% mass in {:>4} samples\n",
            sched.steps - step,
            sched.sigma2(step),
            eff,
            s90
        );
        let f = oracle.denoise(&x, sched.alpha_bar(step));
        x = sampler::ddim_update(
            &x,
            &f,
            sched.alpha_bar(step),
            sched.alpha_prev(step),
            0.0,
            &mut rng,
        );
    }
    println!("final sample: ({:.3}, {:.3}) — on the moons manifold", x[0], x[1]);
    Ok(())
}

/// 2-D ASCII density plot of posterior weights over the training set.
fn render(ds: &golddiff::Dataset, w: &[f32], x: &[f32]) {
    const W: usize = 64;
    const H: usize = 20;
    let (x0, x1, y0, y1) = (-1.8f32, 2.8, -1.3, 1.8);
    let mut grid = vec![0.0f32; W * H];
    let mut data = vec![false; W * H];
    for i in 0..ds.n {
        let p = ds.row(i);
        let gx = (((p[0] - x0) / (x1 - x0)) * W as f32) as isize;
        let gy = (((p[1] - y0) / (y1 - y0)) * H as f32) as isize;
        if (0..W as isize).contains(&gx) && (0..H as isize).contains(&gy) {
            let idx = gy as usize * W + gx as usize;
            grid[idx] += w[i];
            data[idx] = true;
        }
    }
    let wmax = grid.iter().copied().fold(0.0f32, f32::max).max(1e-12);
    let star = (
        (((x[0] - x0) / (x1 - x0)) * W as f32) as isize,
        (((x[1] - y0) / (y1 - y0)) * H as f32) as isize,
    );
    for gy in (0..H).rev() {
        let mut line = String::with_capacity(W);
        for gx in 0..W {
            if star == (gx as isize, gy as isize) {
                line.push('★');
                continue;
            }
            let v = grid[gy * W + gx] / wmax;
            line.push(if v > 0.5 {
                '#'
            } else if v > 0.1 {
                '+'
            } else if v > 0.01 {
                ':'
            } else if data[gy * W + gx] {
                '·'
            } else {
                ' '
            });
        }
        println!("{line}");
    }
}
