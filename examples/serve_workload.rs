//! End-to-end serving driver (the repo's headline validation run): start
//! the engine + TCP server, fire a batched request workload at it from
//! client threads, and report latency/throughput — recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     cargo run --release --example serve_workload -- [--preset cifar-sim]
//!         [--requests 24] [--clients 4]
//!
//! The workload mixes GoldDiff and baseline methods, exercising the full
//! stack: TCP protocol → bounded queue (backpressure) → continuous batcher
//! → coarse scan → golden-subset gather → PJRT dispatch → DDIM update.

use std::sync::Arc;

use golddiff::config::EngineConfig;
use golddiff::coordinator::Engine;
use golddiff::server::{Client, Server};
use golddiff::util::cli::Args;
use golddiff::util::timer::TimingStats;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let preset = args.get_or("preset", "cifar-sim").to_string();
    let requests = args.usize_or("requests", 24);
    let clients = args.usize_or("clients", 4);

    let cfg = EngineConfig {
        preset: preset.clone(),
        ..Default::default()
    };
    let engine = Arc::new(Engine::start(cfg)?);
    let server = Server::start(Arc::clone(&engine), "127.0.0.1:0")?;
    println!("serving {preset} on {} — {requests} requests over {clients} clients", server.addr);

    let addr = server.addr;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            std::thread::spawn(move || -> anyhow::Result<TimingStats> {
                let mut client = Client::connect(&addr)?;
                assert!(client.ping()?);
                let mut lat = TimingStats::new();
                let my_requests = (requests + clients - 1) / clients;
                for i in 0..my_requests {
                    let method = match (c + i) % 3 {
                        0 => "golddiff-pca",
                        1 => "golddiff",
                        _ => "wiener",
                    };
                    let t = std::time::Instant::now();
                    let mut resp = client.generate(method, (c * 1000 + i) as u64, None)?;
                    // honour backpressure: retry briefly on busy
                    let mut tries = 0;
                    while resp.get("ok").and_then(golddiff::util::json::Json::as_bool)
                        != Some(true)
                        && tries < 50
                    {
                        std::thread::sleep(std::time::Duration::from_millis(50));
                        resp = client.generate(method, (c * 1000 + i) as u64, None)?;
                        tries += 1;
                    }
                    anyhow::ensure!(
                        resp.get("ok").and_then(golddiff::util::json::Json::as_bool)
                            == Some(true),
                        "request failed: {resp}"
                    );
                    lat.record(t.elapsed());
                }
                Ok(lat)
            })
        })
        .collect();

    let mut all = TimingStats::new();
    for h in handles {
        all.merge(&h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\n== workload summary ==");
    println!("requests completed : {}", all.count());
    println!("wall time          : {wall:.2}s");
    println!("throughput         : {:.2} req/s", all.count() as f64 / wall);
    println!("latency p50        : {:.3}s", all.percentile(0.5));
    println!("latency p95        : {:.3}s", all.percentile(0.95));
    println!("latency p99        : {:.3}s", all.percentile(0.99));
    println!("latency mean       : {:.3}s", all.mean());
    // machine-greppable BENCH lines — whole-request percentiles plus the
    // engine's per-stage distributions (scan = coarse screen + exact
    // refine, dispatch = XLA aggregation, tick = one whole tick group,
    // step = one sequence's share of a tick, labelled by the configured
    // solver), so a regression in one stage can't hide behind the
    // aggregate mean. The CI bench-smoke leg greps these.
    let stats = engine.stats_json();
    let stat = |key: String| {
        stats
            .get(&key)
            .and_then(golddiff::util::json::Json::as_f64)
            .unwrap_or(0.0)
    };
    println!(
        "BENCH serve_workload requests={} throughput_rps={:.2} p50_s={:.4} p95_s={:.4} p99_s={:.4}",
        all.count(),
        all.count() as f64 / wall,
        all.percentile(0.5),
        all.percentile(0.95),
        all.percentile(0.99)
    );
    let solver = stats
        .get("solver")
        .and_then(golddiff::util::json::Json::as_str)
        .unwrap_or("ddim")
        .to_string();
    for stage in ["scan", "dispatch", "tick", "step"] {
        println!(
            "BENCH serve_stage stage={stage} solver={solver} p50_s={:.6} p95_s={:.6} p99_s={:.6}",
            stat(format!("{stage}_p50_s")),
            stat(format!("{stage}_p95_s")),
            stat(format!("{stage}_p99_s"))
        );
    }
    println!("\nengine stats: {}", engine.stats_json());
    // degradation counters ride the health payload: `status` flips to
    // "degraded" when a tier stood down, `workers_lost`/`remote_retries`
    // account the distributed tier's fault history (stats carries
    // `deadline_expired` and `degraded_tiers` alongside)
    println!("engine health: {}", engine.health_json());
    println!("peak RSS           : {:.2} GiB", golddiff::util::mem::gib(golddiff::util::mem::peak_rss_bytes()));

    server.stop();
    Ok(())
}
