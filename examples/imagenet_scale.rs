//! The paper's milestone reproduced: analytical diffusion at ImageNet-1K
//! scale (sim: N = 50,000, 1000 classes), class-conditional generation
//! through the serving engine — the configuration where full-scan PCA is
//! intractable per step and GoldDiff stays interactive.
//!
//!     cargo run --release --example imagenet_scale -- [--count 8] [--compare]
//!
//! `--compare` additionally times one full-scan PCA step for the ×speedup
//! headline (slow: it really does scan all 50k rows through the 65536
//! bucket).

use golddiff::config::EngineConfig;
use golddiff::coordinator::Engine;
use golddiff::denoiser::DenoiserKind;
use golddiff::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let count = args.usize_or("count", 8);

    println!("loading imagenet-sim (first run synthesises ~150 MB, takes a minute)…");
    let cfg = EngineConfig {
        preset: "imagenet-sim".into(),
        ..Default::default()
    };
    let engine = Engine::start(cfg)?;

    // class-conditional generation across a spread of the 1000 classes
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..count)
        .map(|i| {
            let class = ((i * 131) % 1000) as u32;
            engine
                .submit(DenoiserKind::GoldDiffPca, i as u64, Some(class))
                .map(|rx| (class, rx))
        })
        .collect::<Result<_, _>>()?;
    for (class, rx) in rxs {
        let resp = rx.recv()?;
        let scan: f64 = resp.steps.iter().map(|s| s.scan_secs).sum();
        let disp: f64 = resp.steps.iter().map(|s| s.dispatch_secs).sum();
        println!(
            "class {class:4}: latency {:.3}s (scan {scan:.3}s, dispatch {disp:.3}s), k {} → {}",
            resp.latency_secs,
            resp.steps.first().map(|s| s.k_used).unwrap_or(0),
            resp.steps.last().map(|s| s.k_used).unwrap_or(0),
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n{count} conditional samples in {wall:.2}s — {:.2} samples/s on ImageNet-1K scale",
        count as f64 / wall
    );
    println!("engine stats: {}", engine.stats_json());

    if args.flag("compare") {
        println!("\ntiming one full-scan unconditional PCA step for reference…");
        let resp = engine.generate(DenoiserKind::Pca, 0, None)?;
        let per_step: f64 = resp
            .steps
            .iter()
            .map(|s| s.dispatch_secs + s.scan_secs)
            .sum::<f64>()
            / resp.steps.len() as f64;
        println!("full-scan PCA: {per_step:.3}s per step (×N=50k scan)");
    }

    engine.shutdown();
    Ok(())
}
