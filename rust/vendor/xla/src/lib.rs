//! Offline stub of the `xla` PJRT bindings.
//!
//! The serving stack compiles against this API surface; every call that
//! would actually touch PJRT returns [`Error::Unavailable`]. The runtime
//! layer only reaches these calls when `artifacts/manifest.json` exists,
//! which implies a machine with compiled HLO artifacts — on such machines
//! the real bindings (xla-rs) replace this path dependency in
//! `rust/Cargo.toml` without any source change.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// PJRT is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "xla stub: {what} requires the real PJRT bindings")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side handle to a parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable("Literal::to_vec"))
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// CPU PJRT client (stub). Construction succeeds so the engine can open
/// its runtime and fail lazily with a clear message only if a dispatch is
/// actually attempted without artifacts.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unavailable("PjRtClient::buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_paths_error_with_clear_message() {
        assert!(PjRtClient::cpu().is_ok());
        let e = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("real PJRT bindings"));
    }
}
