//! Minimal offline stand-in for the `anyhow` crate, vendored so the repo
//! builds with zero network access. Covers the API surface this codebase
//! uses: `Error`, `Result<T, E = Error>`, the `Context` extension trait on
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Messages chain like anyhow's `{:#}` rendering (`context: cause`).

use std::fmt::{self, Debug, Display};

/// A boxed-free error: the rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context layer (the anyhow convention: outermost first).
    pub fn context<C: Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket conversion
// coherent (`?` works on any std error type).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result` with the defaulted error parameter the codebase relies
/// on (e.g. `Result<T, SubmitError>` reuses the same alias).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension: `.context(..)` / `.with_context(|| ..)` on results
/// and options.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn context_chains_outermost_first() {
        let e: Result<()> = io_fail().context("loading config");
        let msg = format!("{:#}", e.unwrap_err());
        assert!(msg.starts_with("loading config: "), "{msg}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        let e = anyhow!("x = {}", 3);
        assert_eq!(e.to_string(), "x = 3");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert_eq!(f(false).unwrap_err().to_string(), "flag was false");
    }
}
