//! PJRT runtime: loads `artifacts/*.hlo.txt` (HLO text — see DESIGN.md §2),
//! compiles them on the CPU PJRT client, and caches one
//! `PjRtLoadedExecutable` per (variant, preset, bucket).
//!
//! This is the only module that touches the `xla` crate. The request path
//! is: gather golden subset (L3) → `upload` → `run_*` dispatch →
//! tuple-decomposed f32 outputs. Dataset-sized device buffers (the
//! full-scan candidate matrix, the proxy table) are uploaded once and
//! reused across steps via `DeviceTensor`.
//!
//! Thread model: XLA's CPU PJRT client is internally thread-safe and runs
//! each dispatch on its Eigen pool, but the `xla` crate's wrappers hold raw
//! pointers (auto-`!Send`). The coordinator therefore owns the runtime from
//! a single executor thread (vLLM-style model executor); `SendRuntime` is
//! the documented escape hatch that moves the whole runtime into that
//! thread.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{Context, Result};

pub use manifest::{ArtifactMeta, Manifest, PresetMeta};

/// A device-resident tensor (uploaded once, reused across dispatches).
pub struct DeviceTensor {
    pub buffer: xla::PjRtBuffer,
    pub dims: Vec<usize>,
}

/// Stats vector layout produced by every `*_step` graph.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepStats {
    pub max_logit: f32,
    pub logsumexp: f32,
    pub entropy: f32,
    pub top1_weight: f32,
}

/// Output of a `*_step` dispatch.
pub struct StepOutput {
    pub x_prev: Vec<f32>,
    pub f_hat: Vec<f32>,
    pub stats: StepStats,
}

pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: std::cell::RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// compile counter (perf telemetry)
    pub compiles: std::cell::Cell<usize>,
}

impl Runtime {
    /// Open the artifact directory and its manifest (lazy compilation).
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: Default::default(),
            compiles: std::cell::Cell::new(0),
        })
    }

    /// Fetch (compile-on-first-use) an executable by artifact name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(Rc::clone(exe));
        }
        let meta = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("unknown artifact `{name}`"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        self.compiles.set(self.compiles.get() + 1);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), Rc::clone(&exe));
        Ok(exe)
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<DeviceTensor> {
        let buffer = self
            .client
            .buffer_from_host_buffer(data, dims, None)
            .context("host->device upload")?;
        Ok(DeviceTensor {
            buffer,
            dims: dims.to_vec(),
        })
    }

    /// Dispatch an executable on device buffers; returns the decomposed
    /// output tuple as f32 vectors.
    pub fn run(&self, name: &str, args: &[&DeviceTensor]) -> Result<Vec<Vec<f32>>> {
        let exe = self.executable(name)?;
        let bufs: Vec<&xla::PjRtBuffer> = args.iter().map(|t| &t.buffer).collect();
        let result = exe
            .execute_b(&bufs)
            .with_context(|| format!("executing {name}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        let parts = lit.to_tuple().context("tuple decompose")?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().context("literal to_vec"))
            .collect()
    }

    /// Dispatch a `*_step` graph: (x_t, cand, mask, …, alphas) →
    /// (x_prev, f_hat, stats).
    pub fn run_step(&self, name: &str, args: &[&DeviceTensor]) -> Result<StepOutput> {
        let mut outs = self.run(name, args)?;
        anyhow::ensure!(
            outs.len() == 3,
            "{name}: expected 3 outputs, got {}",
            outs.len()
        );
        let stats_v = outs.pop().unwrap();
        let f_hat = outs.pop().unwrap();
        let x_prev = outs.pop().unwrap();
        Ok(StepOutput {
            x_prev,
            f_hat,
            stats: StepStats {
                max_logit: stats_v[0],
                logsumexp: stats_v[1],
                entropy: stats_v[2],
                top1_weight: stats_v[3],
            },
        })
    }

    /// Dispatch a distance graph (`exact_dist` / `proxy_dist`): → one vector.
    pub fn run_dist(&self, name: &str, args: &[&DeviceTensor]) -> Result<Vec<f32>> {
        let mut outs = self.run(name, args)?;
        anyhow::ensure!(outs.len() == 1, "{name}: expected 1 output");
        Ok(outs.pop().unwrap())
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Moves a `Runtime` into the coordinator's executor thread.
///
/// SAFETY: the PJRT C API is thread-compatible (XLA documents PjRtClient /
/// PjRtLoadedExecutable as safe to call from any thread); the rust wrappers
/// are `!Send` only because they hold raw pointers. We never *share* the
/// runtime across threads — `SendRuntime` is consumed by exactly one
/// executor thread and all access stays on that thread afterwards.
pub struct SendRuntime(pub Runtime);
unsafe impl Send for SendRuntime {}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::new(dir).unwrap())
        } else {
            None
        }
    }

    #[test]
    fn compiles_and_runs_golden_step_vs_cpu_reference() {
        let Some(rt) = runtime() else { return };
        let k = 32usize;
        let d = 2usize;
        let mut rng = crate::util::rng::Pcg64::new(1);
        let x_t: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let cand: Vec<f32> = (0..k * d).map(|_| rng.normal()).collect();
        let mut mask = vec![0.0f32; k];
        for m in mask.iter_mut().take(20) {
            *m = 1.0;
        }
        let alphas = [0.4f32, 0.7f32];

        let bx = rt.upload(&x_t, &[d]).unwrap();
        let bc = rt.upload(&cand, &[k, d]).unwrap();
        let bm = rt.upload(&mask, &[k]).unwrap();
        let ba = rt.upload(&alphas, &[2]).unwrap();
        let out = rt
            .run_step("golden_step__moons__k32", &[&bx, &bc, &bm, &ba])
            .unwrap();

        // CPU reference: same math via StreamingSoftmax + ddim_update
        let q: Vec<f32> = x_t.iter().map(|&v| v / alphas[0].sqrt()).collect();
        let scale = alphas[0] / (2.0 * (1.0 - alphas[0]));
        let items: Vec<(f32, &[f32])> = (0..20)
            .map(|i| {
                let row = &cand[i * d..(i + 1) * d];
                let dd: f32 = row.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                (-dd * scale, row)
            })
            .collect();
        let (f_ref, stats_ref) =
            crate::denoiser::softmax::ss_aggregate(d, items.iter().copied());
        for j in 0..d {
            assert!(
                (out.f_hat[j] - f_ref[j]).abs() < 1e-4,
                "f_hat[{j}]: {} vs {}",
                out.f_hat[j],
                f_ref[j]
            );
        }
        assert!((out.stats.top1_weight - stats_ref.top1_weight).abs() < 1e-4);
        assert!((out.stats.entropy - stats_ref.entropy).abs() < 1e-3);

        // DDIM update agreement
        let mut rng2 = crate::util::rng::Pcg64::new(0);
        let x_ref =
            crate::sampler::ddim_update(&x_t, &f_ref, alphas[0], alphas[1], 0.0, &mut rng2);
        for j in 0..d {
            assert!((out.x_prev[j] - x_ref[j]).abs() < 1e-4);
        }
    }

    #[test]
    fn exact_dist_matches_cpu() {
        let Some(rt) = runtime() else { return };
        let m = 512usize;
        let d = 2usize;
        let mut rng = crate::util::rng::Pcg64::new(2);
        let x_t: Vec<f32> = (0..d).map(|_| rng.normal()).collect();
        let cand: Vec<f32> = (0..m * d).map(|_| rng.normal()).collect();
        let alpha = [0.25f32];
        let bx = rt.upload(&x_t, &[d]).unwrap();
        let bc = rt.upload(&cand, &[m, d]).unwrap();
        let ba = rt.upload(&alpha, &[1]).unwrap();
        let dists = rt
            .run_dist("exact_dist__moons__k512", &[&bx, &bc, &ba])
            .unwrap();
        assert_eq!(dists.len(), m);
        let q: Vec<f32> = x_t.iter().map(|&v| v / 0.5).collect();
        for i in (0..m).step_by(37) {
            let row = &cand[i * d..(i + 1) * d];
            let want: f32 = row.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
            assert!((dists[i] - want).abs() < 1e-3, "{i}: {} vs {want}", dists[i]);
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = runtime() else { return };
        let _ = rt.executable("golden_step__moons__k32").unwrap();
        let before = rt.compiles.get();
        let _ = rt.executable("golden_step__moons__k32").unwrap();
        assert_eq!(rt.compiles.get(), before);
        assert!(rt.cached_executables() >= 1);
    }
}
