//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: every AOT-lowered executable with its variant,
//! preset, bucket size and input signature.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{parse, Json};

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub variant: String,
    pub preset: String,
    pub d: usize,
    /// bucket size (K for steps, M for dist graphs; 0 for wiener)
    pub k: usize,
    /// Kamb patch size (0 when n/a)
    pub p: usize,
    /// PCA rank (0 when n/a)
    pub r: usize,
    /// input shapes, in call order
    pub inputs: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct PresetMeta {
    pub name: String,
    pub paper_name: String,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub d: usize,
    pub proxy_d: usize,
    pub classes: usize,
    pub conditional: bool,
    pub full_bucket: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
    pub presets: Vec<PresetMeta>,
    pub pca_rank: usize,
    pub wss_blocks: usize,
    pub kamb_patches: Vec<usize>,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::from_json(&parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let arts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest: missing artifacts")?;
        let artifacts = arts
            .iter()
            .map(|a| {
                let inputs = a
                    .get("inputs")
                    .and_then(Json::as_arr)
                    .context("artifact missing inputs")?
                    .iter()
                    .map(|shape| {
                        shape
                            .as_arr()
                            .map(|dims| {
                                dims.iter().filter_map(Json::as_usize).collect::<Vec<_>>()
                            })
                            .context("bad shape")
                    })
                    .collect::<Result<Vec<_>>>()?;
                Ok(ArtifactMeta {
                    name: a.str_field("name")?.to_string(),
                    file: a.str_field("file")?.to_string(),
                    variant: a.str_field("variant")?.to_string(),
                    preset: a.str_field("preset")?.to_string(),
                    d: a.num_field("d")? as usize,
                    k: a.get("k").and_then(Json::as_usize).unwrap_or(0),
                    p: a.get("p").and_then(Json::as_usize).unwrap_or(0),
                    r: a.get("r").and_then(Json::as_usize).unwrap_or(0),
                    inputs,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let presets = j
            .get("presets")
            .and_then(Json::as_arr)
            .context("manifest: missing presets")?
            .iter()
            .map(|p| {
                Ok(PresetMeta {
                    name: p.str_field("name")?.to_string(),
                    paper_name: p.str_field("paper_name")?.to_string(),
                    n: p.num_field("n")? as usize,
                    h: p.num_field("h")? as usize,
                    w: p.num_field("w")? as usize,
                    c: p.num_field("c")? as usize,
                    d: p.num_field("d")? as usize,
                    proxy_d: p.num_field("proxy_d")? as usize,
                    classes: p.num_field("classes")? as usize,
                    conditional: p.get("conditional").and_then(Json::as_bool).unwrap_or(false),
                    full_bucket: p.num_field("full_bucket")? as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest {
            artifacts,
            presets,
            pca_rank: j.get("pca_rank").and_then(Json::as_usize).unwrap_or(32),
            wss_blocks: j.get("wss_blocks").and_then(Json::as_usize).unwrap_or(8),
            kamb_patches: j
                .get("kamb_patches")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_else(|| vec![3, 7]),
        })
    }

    pub fn preset(&self, name: &str) -> Option<&PresetMeta> {
        self.presets.iter().find(|p| p.name == name)
    }

    /// Find the artifact of `variant` for `preset` at bucket `k`
    /// (and patch `p` for kamb variants).
    pub fn find(&self, variant: &str, preset: &str, k: usize, p: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.variant == variant && a.preset == preset && a.k == k && a.p == p)
    }

    /// Ascending bucket ladder available for (variant, preset).
    pub fn buckets(&self, variant: &str, preset: &str) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.variant == variant && a.preset == preset)
            .map(|a| a.k)
            .collect();
        ks.sort_unstable();
        ks.dedup();
        ks
    }

    /// Smallest compiled bucket that fits `want` (or the largest available).
    pub fn bucket_for(&self, variant: &str, preset: &str, want: usize) -> Option<usize> {
        let ks = self.buckets(variant, preset);
        ks.iter().copied().find(|&b| b >= want).or(ks.last().copied())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        parse(
            r#"{
          "format": 1, "pca_rank": 32, "wss_blocks": 8, "kamb_patches": [3, 7],
          "presets": [{"name":"moons","paper_name":"Moons","n":2000,"h":1,"w":2,
                       "c":1,"d":2,"proxy_d":2,"classes":2,"conditional":false,
                       "full_bucket":2048}],
          "artifacts": [
            {"name":"golden_step__moons__k32","file":"golden_step__moons__k32.hlo.txt",
             "variant":"golden_step","preset":"moons","d":2,"k":32,
             "inputs":[[2],[32,2],[32],[2]]},
            {"name":"golden_step__moons__k2048","file":"f2","variant":"golden_step",
             "preset":"moons","d":2,"k":2048,"inputs":[[2],[2048,2],[2048],[2]]}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::from_json(&sample_manifest()).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.preset("moons").unwrap().full_bucket, 2048);
        let a = m.find("golden_step", "moons", 32, 0).unwrap();
        assert_eq!(a.inputs[1], vec![32, 2]);
        assert!(m.find("golden_step", "moons", 64, 0).is_none());
    }

    #[test]
    fn bucket_ladder_and_rounding() {
        let m = Manifest::from_json(&sample_manifest()).unwrap();
        assert_eq!(m.buckets("golden_step", "moons"), vec![32, 2048]);
        assert_eq!(m.bucket_for("golden_step", "moons", 10), Some(32));
        assert_eq!(m.bucket_for("golden_step", "moons", 33), Some(2048));
        assert_eq!(m.bucket_for("golden_step", "moons", 99999), Some(2048));
        assert_eq!(m.bucket_for("golden_step", "nope", 1), None);
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = std::path::Path::new("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.presets.len() >= 7);
            assert!(m.artifacts.len() > 100);
            // every preset has a full-scan golden bucket
            for p in &m.presets {
                assert!(
                    m.find("golden_step", &p.name, p.full_bucket, 0).is_some(),
                    "{} missing full bucket",
                    p.name
                );
            }
        }
    }
}
