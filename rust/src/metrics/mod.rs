//! Evaluation metrics (the paper's Efficacy axis: MSE and r² against the
//! oracle; posterior telemetry: entropy, top-1 weight, logit gap; spectrum
//! split for the Fig. 2 smoothing-bias quantification) and table writers.

pub mod tables;

/// Mean squared error between two vectors.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Coefficient of determination r² of prediction `pred` against target
/// `target` (1 - SS_res/SS_tot), matching the paper's efficacy metric:
/// how much of the oracle's output variance the analytical estimate explains.
pub fn r_squared(pred: &[f32], target: &[f32]) -> f64 {
    assert_eq!(pred.len(), target.len());
    let n = target.len() as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mean = target.iter().map(|&v| v as f64).sum::<f64>() / n;
    let ss_tot: f64 = target.iter().map(|&v| (v as f64 - mean).powi(2)).sum();
    let ss_res: f64 = pred
        .iter()
        .zip(target)
        .map(|(&p, &t)| (t as f64 - p as f64).powi(2))
        .sum();
    if ss_tot < 1e-12 {
        return if ss_res < 1e-12 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Accumulates (pred, target) pairs across samples/steps and reports the
/// pooled MSE and r² exactly as the paper's "averaged over 128 samples".
#[derive(Debug, Default, Clone)]
pub struct EfficacyAccum {
    ss_res: f64,
    sum_t: f64,
    sum_t2: f64,
    count: f64,
}

impl EfficacyAccum {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, pred: &[f32], target: &[f32]) {
        assert_eq!(pred.len(), target.len());
        for (&p, &t) in pred.iter().zip(target) {
            let (p, t) = (p as f64, t as f64);
            self.ss_res += (p - t) * (p - t);
            self.sum_t += t;
            self.sum_t2 += t * t;
            self.count += 1.0;
        }
    }

    pub fn mse(&self) -> f64 {
        if self.count == 0.0 {
            0.0
        } else {
            self.ss_res / self.count
        }
    }

    pub fn r2(&self) -> f64 {
        if self.count == 0.0 {
            return 0.0;
        }
        let mean = self.sum_t / self.count;
        let ss_tot = self.sum_t2 - self.count * mean * mean;
        if ss_tot < 1e-12 {
            return 0.0;
        }
        1.0 - self.ss_res / ss_tot
    }

    pub fn n(&self) -> u64 {
        self.count as u64
    }
}

/// Shannon entropy (nats) of a weight distribution (already normalised).
pub fn entropy(weights: &[f32]) -> f64 {
    weights
        .iter()
        .filter(|&&w| w > 0.0)
        .map(|&w| -(w as f64) * (w as f64).ln())
        .sum()
}

/// Effective support size exp(H) — the paper's "golden support" measure in
/// Fig. 1/3a: how many samples carry non-negligible posterior mass.
pub fn effective_support(weights: &[f32]) -> f64 {
    entropy(weights).exp()
}

/// Smallest prefix of the sorted-descending weights covering `mass`.
pub fn support_at_mass(weights: &[f32], mass: f64) -> usize {
    let mut sorted: Vec<f32> = weights.to_vec();
    sorted.sort_by(|a, b| b.total_cmp(a));
    let mut acc = 0.0f64;
    for (i, &w) in sorted.iter().enumerate() {
        acc += w as f64;
        if acc >= mass {
            return i + 1;
        }
    }
    sorted.len()
}

/// High-frequency energy ratio of a flattened image: energy not captured by
/// the s=1/4 low-pass, over total energy. Quantifies the Fig. 2 smoothing
/// bias (WSS outputs lose high-frequency energy).
pub fn highfreq_energy_ratio(x: &[f32], h: usize, w: usize, c: usize) -> f64 {
    if h < 4 || w < 4 {
        return 0.0;
    }
    let low = crate::data::synthetic::proxy_embed(x, h, w, c);
    // upsample low back to full res (nearest) and measure residual energy
    let (pw, _ph) = (w / 4, h / 4);
    let mut res = 0.0f64;
    let mut tot = 0.0f64;
    for y in 0..h {
        for xx in 0..w {
            for ch in 0..c {
                let v = x[(y * w + xx) * c + ch] as f64;
                let l = low[((y / 4) * pw + (xx / 4)) * c + ch] as f64;
                res += (v - l) * (v - l);
                tot += v * v;
            }
        }
    }
    if tot < 1e-12 {
        0.0
    } else {
        res / tot
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_and_r2_basics() {
        let t = [1.0f32, 2.0, 3.0, 4.0];
        assert_eq!(mse(&t, &t), 0.0);
        assert!((r_squared(&t, &t) - 1.0).abs() < 1e-12);
        let mean = [2.5f32; 4];
        assert!(r_squared(&mean, &t).abs() < 1e-9); // predicting the mean → r²=0
        let bad = [4.0f32, 3.0, 2.0, 1.0];
        assert!(r_squared(&bad, &t) < 0.0); // worse than the mean → negative
    }

    #[test]
    fn accum_matches_pooled_computation() {
        let mut acc = EfficacyAccum::new();
        let p1 = [1.0f32, 2.0];
        let t1 = [1.5f32, 2.5];
        let p2 = [3.0f32, 10.0];
        let t2 = [3.5f32, 9.0];
        acc.update(&p1, &t1);
        acc.update(&p2, &t2);
        let pooled_p = [1.0f32, 2.0, 3.0, 10.0];
        let pooled_t = [1.5f32, 2.5, 3.5, 9.0];
        assert!((acc.mse() - mse(&pooled_p, &pooled_t)).abs() < 1e-12);
        assert!((acc.r2() - r_squared(&pooled_p, &pooled_t)).abs() < 1e-9);
        assert_eq!(acc.n(), 4);
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let w = vec![0.25f32; 4];
        assert!((entropy(&w) - (4.0f64).ln()).abs() < 1e-9);
        assert!((effective_support(&w) - 4.0).abs() < 1e-6);
    }

    #[test]
    fn entropy_delta_is_zero() {
        let w = [1.0f32, 0.0, 0.0];
        assert_eq!(entropy(&w), 0.0);
        assert!((effective_support(&w) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn support_at_mass_counts_prefix() {
        let w = [0.5f32, 0.3, 0.15, 0.05];
        assert_eq!(support_at_mass(&w, 0.5), 1);
        assert_eq!(support_at_mass(&w, 0.8), 2);
        assert_eq!(support_at_mass(&w, 0.99), 4);
    }

    #[test]
    fn highfreq_ratio_detects_smoothing() {
        // checkerboard (pure high frequency) vs constant (pure low)
        let (h, w, c) = (8, 8, 1);
        let mut sharp = vec![0.0f32; h * w];
        for y in 0..h {
            for x in 0..w {
                sharp[y * w + x] = if (x + y) % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let flat = vec![1.0f32; h * w];
        assert!(highfreq_energy_ratio(&sharp, h, w, c) > 0.9);
        assert!(highfreq_energy_ratio(&flat, h, w, c) < 1e-9);
    }
}
