//! Markdown / CSV table writers for the bench harnesses: every paper table
//! is emitted in the same row/column layout the paper prints, plus a JSON
//! dump for machine comparison in EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::Path;

use crate::util::json::Json;

/// A rectangular results table with row labels.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), cells));
        self
    }

    /// Render GitHub-flavoured markdown.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = write!(out, "| Method |");
        for c in &self.columns {
            let _ = write!(out, " {c} |");
        }
        let _ = writeln!(out);
        let _ = write!(out, "|---|");
        for _ in &self.columns {
            let _ = write!(out, "---|");
        }
        let _ = writeln!(out);
        for (label, cells) in &self.rows {
            let _ = write!(out, "| {label} |");
            for c in cells {
                let _ = write!(out, " {c} |");
            }
            let _ = writeln!(out);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "method,{}", self.columns.join(","));
        for (label, cells) in &self.rows {
            let _ = writeln!(out, "{label},{}", cells.join(","));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut obj = Json::obj();
        obj.set("title", self.title.as_str());
        obj.set(
            "columns",
            Json::Arr(self.columns.iter().map(|c| Json::Str(c.clone())).collect()),
        );
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(label, cells)| {
                let mut r = Json::obj();
                r.set("method", label.as_str());
                r.set(
                    "cells",
                    Json::Arr(cells.iter().map(|c| Json::Str(c.clone())).collect()),
                );
                r
            })
            .collect();
        obj.set("rows", Json::Arr(rows));
        obj
    }

    /// Print to stdout and persist markdown + json under `out/`.
    pub fn emit(&self, out_dir: &Path, stem: &str) -> anyhow::Result<()> {
        println!("{}", self.to_markdown());
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(out_dir.join(format!("{stem}.md")), self.to_markdown())?;
        std::fs::write(
            out_dir.join(format!("{stem}.json")),
            self.to_json().to_string_compact(),
        )?;
        Ok(())
    }
}

/// Format helpers shared by the bench harnesses.
pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

pub fn fmt_ms(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3}s")
    } else {
        format!("{:.3}ms", seconds * 1e3)
    }
}

pub fn fmt_speedup(base: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "-".into();
    }
    format!("×{:.1}", base / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_layout() {
        let mut t = Table::new("Tab. X", &["MSE", "Time"]);
        t.row("PCA", vec!["0.008".into(), "2.802s".into()]);
        t.row("GoldDiff", vec!["0.007".into(), "0.087s".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| PCA | 0.008 | 2.802s |"));
        assert!(md.contains("| Method | MSE | Time |"));
    }

    #[test]
    fn csv_and_json() {
        let mut t = Table::new("t", &["a"]);
        t.row("m", vec!["1".into()]);
        assert_eq!(t.to_csv(), "method,a\nm,1\n");
        let j = t.to_json();
        assert_eq!(j.get("title").unwrap().as_str(), Some("t"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row("m", vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ms(2.5), "2.500s");
        assert_eq!(fmt_ms(0.0123), "12.300ms");
        assert_eq!(fmt_speedup(10.0, 0.5), "×20.0");
    }
}
