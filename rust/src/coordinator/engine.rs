//! The serving engine: a continuous-batching loop on a dedicated executor
//! thread (the vLLM "model executor" shape).
//!
//! Clients `submit` generation requests into a bounded queue (backpressure)
//! and receive a completion channel. The executor thread owns the PJRT
//! runtime (`SendRuntime`), admits requests up to `max_active`, and on each
//! tick:
//!
//!   1. groups live sequences by (method, step, k-bucket) — `batcher`;
//!   2. advances every sequence one denoising step through its
//!      `XlaDenoiser` (retrieval in rust, math in XLA);
//!   3. completes sequences that reached the end of the schedule.
//!
//! Requests at different timesteps coexist (continuous batching): a new
//! request's "prefill-like" large-k steps interleave with older requests'
//! "decode-like" small-k steps.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{group_tick, Group, SeqKey};
use super::queue::{BoundedQueue, SubmitError};
use super::request::{GenRequest, GenResponse, StepTelemetry};
use super::stats::EngineStats;
use super::xla_denoiser::XlaDenoiser;
use crate::config::EngineConfig;
use crate::data::dataset::{Dataset, IvfPartition, ShardIvfPartition};
use crate::data::shard::ShardPlan;
use crate::data::store;
use crate::denoiser::gaussian::{resolve_switch, GaussSwitch};
use crate::denoiser::{DenoiserKind, StepContext};
use crate::index::backend::{RetrievalBackend, RetrievalBackendKind};
use crate::index::remote::RemoteShardBackend;
use crate::runtime::{Runtime, SendRuntime};
use crate::sampler::{self, Solver};
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::{NoiseSchedule, ScheduleKind};
use crate::schedule::steps::{churn_prior, StepPlan};
use crate::util::rng::Pcg64;

struct Submission {
    req: GenRequest,
    submitted: Instant,
    reply: mpsc::Sender<GenResponse>,
}

struct ActiveSeq {
    req: GenRequest,
    reply: mpsc::Sender<GenResponse>,
    x: Vec<f32>,
    step: usize,
    rng: Pcg64,
    telemetry: Vec<StepTelemetry>,
    submitted: Instant,
    started: Instant,
    /// set when this sequence's group tick failed or panicked; the
    /// completion sweep answers it with `"error":<reason>` and drops it
    failed: Option<&'static str>,
}

/// Poison-tolerant stats lock: a recovered panic inside a worker tick must
/// not wedge telemetry for the rest of the process.
fn lock_stats(stats: &Mutex<EngineStats>) -> std::sync::MutexGuard<'_, EngineStats> {
    stats.lock().unwrap_or_else(|p| p.into_inner())
}

pub struct Engine {
    queue: Arc<BoundedQueue<Submission>>,
    stats: Arc<Mutex<EngineStats>>,
    handle: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    pub d: usize,
    pub preset: String,
    pub steps: usize,
}

impl Engine {
    /// Load (or synthesise) the dataset, open the runtime, spawn the
    /// executor thread.
    ///
    /// Corpus residency: `cfg.resident = false` — or `shards > 1` with a
    /// positive `mem_budget_mb`, which implies the out-of-core mode —
    /// serves the corpus **data-free**: the `.gds` store is opened via
    /// [`store::open_streaming`] (headers, proxies, shard bounds and stats
    /// only; the `data` section never loads) and rows stream
    /// shard-at-a-time through a budget-bounded LRU. Output is
    /// byte-identical to a resident engine.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        let resident = cfg.resident && !(cfg.shards > 1 && cfg.mem_budget_mb > 0);
        let store_path = store::store_path(&cfg.data_dir, &cfg.preset);
        // a freshly synthesised store is saved with the engine's shard
        // plan so the streaming path can seek per-shard sections
        let mut ds = if resident {
            store::load_or_synthesize_sharded(&cfg.data_dir, &cfg.preset, cfg.seed, cfg.shards)
                .context("loading dataset")?
        } else {
            store::ensure_store(&cfg.data_dir, &cfg.preset, cfg.seed, cfg.shards.max(1))
                .context("materialising the store to stream from")?;
            store::open_streaming(&store_path, cfg.shards.max(1), cfg.mem_budget_mb)
                .context("opening dataset for streaming")?
        };
        let kind = ScheduleKind::parse(&cfg.schedule)
            .with_context(|| format!("unknown schedule {}", cfg.schedule))?;
        let sched = NoiseSchedule::new(kind, cfg.steps);
        let backend_kind = RetrievalBackendKind::parse(&cfg.backend)
            .with_context(|| format!("unknown retrieval backend {}", cfg.backend))?;
        if backend_kind == RetrievalBackendKind::ClusterPruned && cfg.shards <= 1 {
            // the IVF partition persists in the .gds store; only a config
            // mismatch (lists/seed) pays the k-means here, and the result
            // is written back (best-effort, resident corpora only — a
            // streamed dataset cannot rewrite its own backing store) so
            // the next start skips it. (A sharded cluster backend
            // partitions per shard instead — see below.)
            let lists = cfg.clusters.clamp(1, ds.n.max(1));
            let stale = ds
                .ivf
                .as_ref()
                .is_none_or(|p| !p.matches(lists, cfg.seed));
            if stale {
                ds.ivf = Some(IvfPartition::compute(&ds, lists, cfg.seed));
                if ds.is_resident() {
                    let _ = store::save(&ds, &store_path);
                }
            }
        }
        if backend_kind == RetrievalBackendKind::ClusterPruned && cfg.shards > 1 {
            // satellite: the *per-shard* partitions persist too, so a
            // sharded cluster engine stops paying per-shard k-means on
            // every start. k-means runs over the proxies (always
            // resident), so streamed datasets compute — they just skip
            // the write-back.
            let ns = ShardPlan::new(ds.n, cfg.shards).count();
            let per_shard = cfg.clusters.max(1).div_ceil(ns).max(1);
            let stale = ds
                .shard_ivf
                .as_ref()
                .is_none_or(|p| !p.matches(ns, per_shard, cfg.seed));
            if stale {
                ds.shard_ivf =
                    Some(ShardIvfPartition::compute(&ds, cfg.shards, per_shard, cfg.seed));
                if ds.is_resident() {
                    let _ = store::save_sharded(&ds, &store_path, cfg.shards);
                }
            }
        }
        let ds = Arc::new(ds);
        // built once per engine (cluster-pruned reuses the persisted IVF
        // partitions here) and shared by every denoiser so telemetry
        // aggregates in one place; row residency routes through the
        // dataset's source, so a streamed corpus serves every backend kind.
        // With worker addresses (external fleet) or `remote_workers > 0`
        // (self-spawned loopback fleet) the retrieval tier goes
        // distributed; `remote_workers = 0` is the byte-identical
        // degenerate case — the plain in-process build below.
        let backend: Arc<dyn RetrievalBackend> = if !cfg.worker_addrs.is_empty() {
            Arc::new(RemoteShardBackend::connect(
                &ds,
                backend_kind,
                cfg.backend_opts(),
                &cfg.worker_addrs,
                cfg.remote_fallback,
                cfg.remote_op_timeout_ms,
            )?)
        } else if cfg.remote_workers > 0 {
            Arc::new(RemoteShardBackend::loopback(
                Arc::clone(&ds),
                backend_kind,
                cfg.backend_opts(),
                cfg.remote_workers,
                cfg.remote_fallback,
                cfg.remote_op_timeout_ms,
            )?)
        } else {
            backend_kind.build(&ds, cfg.backend_opts())
        };
        let runtime = SendRuntime(Runtime::new(&cfg.artifacts_dir)?);

        let queue = Arc::new(BoundedQueue::<Submission>::new(cfg.queue_depth));
        let stats = Arc::new(Mutex::new(EngineStats::new()));
        {
            let mut st = lock_stats(&stats);
            st.backend = backend_kind.name().to_string();
            st.shards = cfg.shards.max(1);
            st.resident = ds.is_resident();
            // config echo: what the quantised-tier counters mean depends
            // on whether the tiers were on (the backend build gates them
            // on `kernel` too, which the counters themselves reveal)
            st.quant = cfg.quant;
            st.gauss = cfg.gauss;
            // load-time integrity outcome: tiers that stood down on a
            // checksum mismatch, and the mismatch count itself (streamed
            // read failures add on top via record_source)
            st.degraded_tiers = ds.degraded.clone();
            st.checksum_failures_load = ds.checksum_failures;
            st.checksum_failures = ds.checksum_failures;
        }
        let d = ds.d;
        let preset = cfg.preset.clone();
        let steps = cfg.steps;

        let q2 = Arc::clone(&queue);
        let s2 = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("golddiff-executor".into())
            .spawn(move || {
                executor_loop(runtime, ds, sched, cfg, backend, q2, s2);
            })?;

        Ok(Engine {
            queue,
            stats,
            handle: Some(handle),
            next_id: std::sync::atomic::AtomicU64::new(1),
            d,
            preset,
            steps,
        })
    }

    /// Submit a request; returns the completion channel. Blocks under
    /// backpressure.
    pub fn submit(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<mpsc::Receiver<GenResponse>> {
        self.submit_with_deadline(method, seed, class, None)
    }

    /// `submit` with a per-request deadline: a request still queued when
    /// `deadline_ms` elapses is dropped at dequeue — before any retrieval
    /// work — and answered `"error":"deadline_exceeded"`.
    pub fn submit_with_deadline(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
        deadline_ms: Option<u64>,
    ) -> Result<mpsc::Receiver<GenResponse>> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, method, seed);
        req.class = class;
        req.deadline_ms = deadline_ms;
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_stats(&self.stats);
            st.submitted += 1;
        }
        self.queue
            .submit(Submission {
                req,
                submitted: Instant::now(),
                reply: tx,
            })
            .map_err(|e| anyhow::anyhow!("submit failed: {e:?}"))?;
        Ok(rx)
    }

    /// Fail-fast submit (server path).
    pub fn try_submit(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        self.try_submit_with_deadline(method, seed, class, None)
    }

    /// Fail-fast submit with an optional deadline (server path).
    pub fn try_submit_with_deadline(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
        deadline_ms: Option<u64>,
    ) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, method, seed);
        req.class = class;
        req.deadline_ms = deadline_ms;
        let (tx, rx) = mpsc::channel();
        {
            let mut st = lock_stats(&self.stats);
            st.submitted += 1;
        }
        match self.queue.try_submit(Submission {
            req,
            submitted: Instant::now(),
            reply: tx,
        }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                lock_stats(&self.stats).rejected += 1;
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn generate(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<GenResponse> {
        let rx = self.submit(method, seed, class)?;
        rx.recv().context("engine dropped the request")
    }

    pub fn stats_json(&self) -> crate::util::json::Json {
        lock_stats(&self.stats).to_json()
    }

    /// Liveness + degradation summary (the `health` op): `ok` when every
    /// optional tier loaded clean, `degraded` with the stood-down tiers
    /// otherwise, plus the fault counters.
    pub fn health_json(&self) -> crate::util::json::Json {
        lock_stats(&self.stats).health_json()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Max sequences in flight (per tick) — bounded by dispatch serialisation
/// on the CPU PJRT client; scans for the whole group still parallelise.
const MAX_ACTIVE: usize = 32;

fn executor_loop(
    runtime: SendRuntime,
    ds: Arc<Dataset>,
    sched: NoiseSchedule,
    cfg: EngineConfig,
    backend: Arc<dyn RetrievalBackend>,
    queue: Arc<BoundedQueue<Submission>>,
    stats: Arc<Mutex<EngineStats>>,
) {
    let rt = std::rc::Rc::new(runtime.0);
    let warm_start = cfg.warm_start;
    let mut denoisers: HashMap<DenoiserKind, XlaDenoiser> = HashMap::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let buckets = rt.manifest.buckets("golden_step", &ds.name);
    let budget = BudgetSchedule::new(
        ds.n,
        ((ds.n as f64 * cfg.m_min_frac) as usize).max(1),
        ((ds.n as f64 * cfg.m_max_frac) as usize).max(1),
        ((ds.n as f64 * cfg.k_min_frac) as usize).max(1),
        ((ds.n as f64 * cfg.k_max_frac) as usize).max(1),
        &buckets,
    );
    // the Gaussian fast-path switch point, resolved once per engine: a
    // forced override pins the prefix length, `auto` evaluates the error
    // bound against the corpus spread. A dataset without a usable moment
    // tier (streamed legacy store, or a tier pinned degraded by a
    // checksum mismatch at load) resolves to 0 — the fast path stands
    // down to full retrieval, serving continues byte-identically.
    let mut gauss_auto_tol: Option<f64> = None;
    let gauss_switch = if cfg.gauss {
        match ds.gauss_moments() {
            Some(gm) => {
                let mode = GaussSwitch::parse(&cfg.gauss_switch).unwrap_or_else(|| {
                    eprintln!(
                        "golddiff: engine: unrecognised gauss_switch `{}`; using auto",
                        cfg.gauss_switch
                    );
                    GaussSwitch::Auto
                });
                if mode == GaussSwitch::Auto {
                    // bound-driven mode: the denoiser re-evaluates the
                    // switch per request class, so a tight class holds its
                    // Gaussian prefix longer than the corpus at large
                    gauss_auto_tol = Some(cfg.gauss_tol);
                }
                resolve_switch(mode, &sched, gm, cfg.gauss_tol)
            }
            None => 0,
        }
    } else {
        0
    };
    let solver = Solver::parse(&cfg.solver).unwrap_or_else(|| {
        eprintln!(
            "golddiff: engine: unrecognised solver `{}`; using ddim",
            cfg.solver
        );
        Solver::Ddim
    });
    let mid = solver
        .needs_mid_schedule()
        .then(|| sampler::mid_schedule(&sched));
    // the budgeted step plan, cut once per engine from the schedule-prior
    // churn signal: ticks go where the golden support moves fastest, the
    // gauss prefix rides free, everything else coasts (the solvers jump
    // placed point to placed point through the exponential DDIM map)
    let plan = StepPlan::budgeted(&sched, cfg.step_budget, gauss_switch, &churn_prior(&sched));
    lock_stats(&stats).solver = solver.name().to_string();

    loop {
        // ---- admission -------------------------------------------------
        let room = MAX_ACTIVE.saturating_sub(active.len());
        let newly = if active.is_empty() {
            let batch = queue.pop_batch(room.max(1)); // blocks when idle
            if batch.is_empty() && queue.is_closed() {
                break;
            }
            batch
        } else {
            queue.try_pop_batch(room)
        };
        let now = Instant::now();
        for sub in newly {
            // deadline gate: an expired request is answered here, before
            // any noise init or retrieval work happens on its behalf
            if let Some(dl) = sub.req.deadline_ms {
                let waited = sub.submitted.elapsed();
                if waited.as_millis() as u64 >= dl {
                    lock_stats(&stats).deadline_expired += 1;
                    let _ = sub.reply.send(GenResponse::failed(
                        sub.req.id,
                        "deadline_exceeded",
                        waited.as_secs_f64(),
                    ));
                    continue;
                }
            }
            let mut rng = Pcg64::with_stream(sub.req.seed, 0x5a3);
            let x = sampler::init_noise(ds.d, &mut rng);
            active.push(ActiveSeq {
                req: sub.req,
                reply: sub.reply,
                x,
                step: 0,
                rng,
                telemetry: Vec::with_capacity(plan.len()),
                submitted: sub.submitted,
                started: now,
                failed: None,
            });
        }
        if active.is_empty() {
            continue;
        }

        // ---- one scheduler tick -----------------------------------------
        // `ActiveSeq::step` is a *plan position*; the grid step it maps to
        // keys the group (budgets and contexts are grid-step functions).
        // Under the default full plan position == grid step exactly.
        let keys: Vec<SeqKey> = active
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let gstep = plan.placed[s.step];
                let b = budget.at(&sched, gstep);
                SeqKey {
                    seq: i,
                    method: s.req.method,
                    step: gstep,
                    k_bucket: b.k_bucket,
                }
            })
            .collect();
        for group in group_tick(&keys) {
            // deadline re-check between tick groups: a request whose
            // deadline elapsed mid-trajectory stops HERE — before its next
            // retrieval pass — instead of burning the rest of a long
            // trajectory it can no longer deliver. (The dequeue-time gate
            // above only catches deadlines that expired while queued.)
            // The completion sweep below answers the expired sequences.
            let mut group = group;
            group.seqs.retain(|&si| {
                let seq = &mut active[si];
                match seq.req.deadline_ms {
                    Some(dl) if seq.submitted.elapsed().as_millis() as u64 >= dl => {
                        seq.failed = Some("deadline_exceeded");
                        lock_stats(&stats).deadline_expired += 1;
                        false
                    }
                    _ => true,
                }
            });
            if group.seqs.is_empty() {
                continue;
            }
            // the group's tightest remaining budget rides to the retrieval
            // tier, so a remote worker can refuse ops whose requester has
            // already expired instead of burning the scan
            let mut remaining: Option<u64> = None;
            for &si in &group.seqs {
                if let Some(dl) = active[si].req.deadline_ms {
                    let waited = active[si].submitted.elapsed().as_millis() as u64;
                    let left = dl.saturating_sub(waited);
                    remaining = Some(remaining.map_or(left, |r| r.min(left)));
                }
            }
            backend.set_deadline(remaining);
            // a failing (or panicking) group must not take the engine down:
            // its sequences answer `"error":"internal"` and serving
            // continues. AssertUnwindSafe is sound here because on any
            // unwind the group's state is discarded wholesale — its
            // sequences are failed and its denoiser is rebuilt fresh.
            let ticked = catch_unwind(AssertUnwindSafe(|| {
                step_group_once(
                    &group,
                    &mut denoisers,
                    &rt,
                    &ds,
                    &sched,
                    &budget,
                    &backend,
                    warm_start,
                    gauss_switch,
                    gauss_auto_tol,
                    solver,
                    &plan,
                    mid.as_ref(),
                    &mut active,
                    &stats,
                )
            }));
            let failed = match ticked {
                Ok(Ok(())) => false,
                Ok(Err(err)) => {
                    eprintln!(
                        "golddiff: engine: group tick failed ({} seq(s)): {err:#}",
                        group.seqs.len()
                    );
                    true
                }
                Err(_panic) => {
                    // the panic payload already printed via the hook
                    eprintln!(
                        "golddiff: engine: recovered a panicking group tick ({} seq(s))",
                        group.seqs.len()
                    );
                    lock_stats(&stats).panics_recovered += 1;
                    true
                }
            };
            if failed {
                // the denoiser may hold half-updated caches — drop it and
                // let the next request for this method rebuild it
                denoisers.remove(&group.method);
                for &si in &group.seqs {
                    active[si].failed = Some("internal");
                }
            }
        }

        // ---- completions -------------------------------------------------
        let total_steps = plan.len();
        let mut i = 0;
        while i < active.len() {
            if let Some(reason) = active[i].failed {
                let seq = active.swap_remove(i);
                let latency = seq.submitted.elapsed().as_secs_f64();
                let _ = seq
                    .reply
                    .send(GenResponse::failed(seq.req.id, reason, latency));
                continue;
            }
            if active[i].step >= total_steps {
                let seq = active.swap_remove(i);
                let latency = seq.submitted.elapsed().as_secs_f64();
                let queue_delay = seq.started.duration_since(seq.submitted).as_secs_f64();
                {
                    let mut st = lock_stats(&stats);
                    st.completed += 1;
                    st.latency.record_secs(latency);
                    st.queue_delay.record_secs(queue_delay);
                }
                let _ = seq.reply.send(GenResponse {
                    id: seq.req.id,
                    sample: seq.x,
                    steps: seq.telemetry,
                    latency_secs: latency,
                    queue_secs: queue_delay,
                    error: None,
                });
            } else {
                i += 1;
            }
        }
    }
}

/// One group's scheduler tick: ensure the denoiser exists, run one batched
/// retrieval + dispatch for every sequence in the group (plus one batched
/// corrector refine under a higher-order solver), fold the results back
/// into the live state. Any error propagates to the caller, which fails
/// the group without killing the engine.
#[allow(clippy::too_many_arguments)]
fn step_group_once(
    group: &Group,
    denoisers: &mut HashMap<DenoiserKind, XlaDenoiser>,
    rt: &std::rc::Rc<Runtime>,
    ds: &Arc<Dataset>,
    sched: &NoiseSchedule,
    budget: &BudgetSchedule,
    backend: &Arc<dyn RetrievalBackend>,
    warm_start: bool,
    gauss_switch: usize,
    gauss_auto_tol: Option<f64>,
    solver: Solver,
    plan: &StepPlan,
    mid: Option<&NoiseSchedule>,
    active: &mut [ActiveSeq],
    stats: &Arc<Mutex<EngineStats>>,
) -> Result<()> {
    if !denoisers.contains_key(&group.method) {
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(rt), ds, group.method)
            .context("denoiser init")?
            .with_budget(budget.clone())
            .with_retrieval(Arc::clone(backend))
            .with_warm_start(warm_start)
            .with_gauss(gauss_switch);
        if let Some(tol) = gauss_auto_tol {
            den = den.with_gauss_auto(tol);
        }
        denoisers.insert(group.method, den);
    }
    let den = denoisers.get_mut(&group.method).expect("just inserted");
    let t_tick = Instant::now();
    // every sequence here shares (method, grid step, k-bucket) — and so
    // one plan position and one (from, to) jump
    let pos = active[group.seqs[0]].step;
    let from = plan.placed[pos];
    let to = plan.target_of(pos);
    debug_assert_eq!(group.step, from);
    let a = sched.alpha_bar(from);
    let ap = if to < sched.steps {
        sched.alpha_bar(to)
    } else {
        1.0
    };
    // predictor: one batched retrieval for the whole group, then dispatch
    let xs: Vec<&[f32]> = group.seqs.iter().map(|&si| active[si].x.as_slice()).collect();
    let ctx_store: Vec<StepContext> = group
        .seqs
        .iter()
        .map(|&si| StepContext {
            ds,
            sched,
            step: from,
            class: active[si].req.class,
        })
        .collect();
    let ctxs: Vec<&StepContext> = ctx_store.iter().collect();
    let results = den.step_group(&xs, &ctxs).context("dispatch failed")?;
    drop(ctxs);
    drop(xs);
    // higher-order solvers evaluate a corrector score at the target point
    // (Heun) or the doubled-grid midpoint (Dpm2) over the predictor
    // group's stashed golden-subset union — one refine, no second screen.
    // Terminal ticks (no next noise level) and closed-form Gaussian ticks
    // coast first-order, mirroring `sampler::Solver::advance`.
    let correct: Vec<usize> = if solver == Solver::Ddim || to >= sched.steps {
        Vec::new()
    } else {
        (0..group.seqs.len()).filter(|&j| !results[j].1.gauss).collect()
    };
    let mut f_corr: HashMap<usize, Vec<f32>> = HashMap::new();
    if !correct.is_empty() {
        let (csched, cstep, a_eval) = match solver {
            Solver::Heun => (sched, to, ap),
            Solver::Dpm2 => {
                let ms = mid.expect("dpm2 carries the doubled midpoint schedule");
                (ms, from + to, ms.alpha_bar(from + to))
            }
            Solver::Ddim => unreachable!("filtered above"),
        };
        // the predictor jump is deterministic (η = 0 draws no noise), so
        // each sequence's rng stream is untouched until the final update
        let x_preds: Vec<Vec<f32>> = correct
            .iter()
            .map(|&j| {
                let seq = &mut active[group.seqs[j]];
                sampler::ddim_update(&seq.x, &results[j].0.f_hat, a, a_eval, 0.0, &mut seq.rng)
            })
            .collect();
        let cctx_store: Vec<StepContext> = correct
            .iter()
            .map(|&j| StepContext {
                ds,
                sched: csched,
                step: cstep,
                class: active[group.seqs[j]].req.class,
            })
            .collect();
        let cxs: Vec<&[f32]> = x_preds.iter().map(|v| v.as_slice()).collect();
        let cctxs: Vec<&StepContext> = cctx_store.iter().collect();
        let fs = den
            .corrector_group(&cxs, &cctxs)
            .context("corrector dispatch failed")?;
        f_corr.extend(correct.iter().copied().zip(fs));
    }
    let step_each = t_tick.elapsed().as_secs_f64() / group.seqs.len() as f64;
    let group_scan: f64 = results.iter().map(|(_, tel)| tel.scan_secs).sum();
    for (j, (&si, (out, tel))) in group.seqs.iter().zip(results).enumerate() {
        let seq = &mut active[si];
        seq.telemetry.push(StepTelemetry {
            k_bucket: tel.k_bucket,
            m_used: tel.m_used,
            k_used: tel.k_used,
            scan_secs: tel.scan_secs,
            dispatch_secs: tel.dispatch_secs,
            entropy: out.stats.entropy,
            top1_weight: out.stats.top1_weight,
        });
        let eta = seq.req.eta;
        seq.x = match f_corr.remove(&j) {
            // second-order slope through the same exponential map:
            // trapezoid average for Heun, the midpoint f̂ for Dpm2
            Some(f_c) => {
                let f: Vec<f32> = match solver {
                    Solver::Heun => out
                        .f_hat
                        .iter()
                        .zip(&f_c)
                        .map(|(&p, &c)| 0.5 * (p + c))
                        .collect(),
                    _ => f_c,
                };
                sampler::ddim_update(&seq.x, &f, a, ap, eta, &mut seq.rng)
            }
            // coasting jump or ancestral noise: the graph's x_prev only
            // knows the adjacent grid step, so the host applies the map
            None if eta > 0.0 || to != from + 1 => {
                sampler::ddim_update(&seq.x, &out.f_hat, a, ap, eta, &mut seq.rng)
            }
            // the graph already produced the deterministic DDIM update
            None => out.x_prev,
        };
        seq.step += 1;
        let mut st = lock_stats(stats);
        st.steps_executed += 1;
        st.scan_time.record_secs(tel.scan_secs);
        st.dispatch_time.record_secs(tel.dispatch_secs);
        st.tick_time.record_secs(tel.scan_secs + tel.dispatch_secs);
        st.step_time.record_secs(step_each);
    }
    // fold the Gaussian-tier and few-step counters BEFORE the backend
    // snapshot lands: the backend never saw those ticks, so
    // `record_backend` knows to leave the folded fields alone
    let (gauss_ticks, screens_skipped) = den.take_gauss_counts();
    let (corrector_refines, screens_reused) = den.take_fewstep_counts();
    let mut st = lock_stats(stats);
    st.gauss_ticks += gauss_ticks;
    st.screens_skipped += screens_skipped;
    st.corrector_refines += corrector_refines;
    st.screens_reused += screens_reused;
    if !plan.is_full() {
        st.ticks_placed += group.seqs.len() as u64;
    }
    if gauss_ticks == 0 {
        // a Gaussian group does no retrieval — recording its zero would
        // skew the group-retrieval latency distribution
        st.retrieval_time.record_secs(group_scan);
    }
    st.record_backend(backend.stats());
    // streamed corpora additionally surface the row source's own
    // residency counters (the authoritative record when the
    // monolithic backends stream without a shard layer)
    st.record_source(ds.source_stats());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return None;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            // these tests pin the legacy full-grid ddim serving contract
            // (step counts, per-step budgets); the few-step paths have
            // their own dedicated test below
            solver: "ddim".into(),
            step_budget: 0,
            ..Default::default()
        };
        Some(Engine::start(cfg).unwrap())
    }

    #[test]
    fn serves_one_request_end_to_end() {
        let Some(eng) = engine() else { return };
        let resp = eng.generate(DenoiserKind::GoldDiff, 7, None).unwrap();
        assert_eq!(resp.sample.len(), 2);
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        assert_eq!(resp.steps.len(), 10);
        assert!(resp.latency_secs > 0.0);
        // k budgets shrink along the retrieval segment (under the CI
        // gauss leg the first ticks are closed-form with k_used = 0, so
        // anchor on the first *retrieval* tick rather than step 0)
        let first_retrieval = resp.steps.iter().find(|s| s.k_used > 0).unwrap();
        assert!(resp.steps.last().unwrap().k_used < first_retrieval.k_used);
        eng.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete_deterministically() {
        let Some(eng) = engine() else { return };
        let rxs: Vec<_> = (0..6)
            .map(|i| eng.submit(DenoiserKind::GoldDiff, 100 + i, None).unwrap())
            .collect();
        let samples: Vec<Vec<f32>> = rxs.into_iter().map(|rx| rx.recv().unwrap().sample).collect();
        assert_eq!(samples.len(), 6);
        // same seed twice gives identical output even under batching
        let a = eng.generate(DenoiserKind::GoldDiff, 100, None).unwrap();
        assert_eq!(a.sample, samples[0]);
        let j = eng.stats_json();
        assert!(j.get("completed").unwrap().as_f64().unwrap() >= 7.0);
        eng.shutdown();
    }

    #[test]
    fn every_backend_serves_identical_samples() {
        // the retrieval backends are exact (nprobe = 0), so the engine must
        // produce bit-identical samples whichever one the config selects
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for backend in ["flat", "batched", "cluster"] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: std::env::temp_dir().join("golddiff_engine_test"),
                backend: backend.into(),
                clusters: 8,
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 4242, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()), "{backend}");
            let j = eng.stats_json();
            assert_eq!(
                j.get("retrieval_backend").unwrap().as_str(),
                Some(backend)
            );
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "flat vs batched");
        assert_eq!(samples[0], samples[2], "flat vs cluster");
    }

    #[test]
    fn batched_backend_amortises_proxy_passes() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            backend: "batched".into(),
            ..Default::default()
        };
        let eng = Engine::start(cfg).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| eng.submit(DenoiserKind::GoldDiff, 900 + i, None).unwrap())
            .collect();
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let j = eng.stats_json();
        let passes = j.get("proxy_passes").unwrap().as_f64().unwrap();
        let queries = j.get("retrieval_queries").unwrap().as_f64().unwrap();
        assert!(
            passes < queries,
            "batched ticks must share passes: {passes} passes for {queries} queries"
        );
        eng.shutdown();
    }

    #[test]
    fn cluster_engine_persists_ivf_partition() {
        // satellite: the first cluster start computes + persists the IVF
        // partition; the store then carries it for later starts to reuse
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_ivf_test");
        std::fs::remove_dir_all(&data_dir).ok();
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: data_dir.clone(),
            backend: "cluster".into(),
            clusters: 8,
            ..Default::default()
        };
        let eng = Engine::start(cfg.clone()).unwrap();
        let resp = eng.generate(DenoiserKind::GoldDiff, 5, None).unwrap();
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        eng.shutdown();

        let ds = store::load(&store::store_path(&data_dir, "moons")).unwrap();
        let ivf = ds.ivf.expect("cluster start must persist the partition");
        assert!(ivf.matches(8usize.clamp(1, ds.n.max(1)), cfg.seed));
        assert_eq!(ivf.assignments.len(), ds.n);

        // a second start with the same config serves identically off the
        // persisted partition (no k-means mismatch)
        let eng2 = Engine::start(cfg).unwrap();
        let again = eng2.generate(DenoiserKind::GoldDiff, 5, None).unwrap();
        assert_eq!(again.sample, resp.sample);
        eng2.shutdown();
        std::fs::remove_dir_all(&data_dir).ok();
    }

    #[test]
    fn sharded_engine_serves_identical_samples_and_reports_telemetry() {
        // the sharded merge layer is exact, so a sharded + memory-budgeted
        // engine must serve byte-identical samples to the monolithic one,
        // while the stats surface the shard telemetry end to end
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for shards in [1usize, 4] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: std::env::temp_dir().join("golddiff_engine_shard_test"),
                backend: "batched".into(),
                shards,
                mem_budget_mb: if shards > 1 { 1 } else { 0 },
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 77, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()), "shards={shards}");
            let j = eng.stats_json();
            assert_eq!(
                j.get("shards").unwrap().as_f64(),
                Some(shards as f64),
                "config shard count surfaces"
            );
            if shards > 1 {
                let scanned = j.get("shards_scanned").unwrap().as_f64().unwrap();
                let skipped = j.get("shards_skipped").unwrap().as_f64().unwrap();
                assert!(
                    scanned + skipped > 0.0,
                    "sharded serving must record shard scans"
                );
            }
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "shards=1 vs shards=4");
    }

    #[test]
    fn streamed_engine_serves_byte_identical_samples() {
        // the out-of-core engine (resident = false, bounded budget) must
        // serve byte-identical samples to the resident one and surface the
        // streaming telemetry through the stats op
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_streamed_test");
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for resident in [true, false] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: data_dir.clone(),
                backend: "batched".into(),
                shards: 4,
                mem_budget_mb: if resident { 0 } else { 1 },
                resident,
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 321, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()));
            let j = eng.stats_json();
            assert_eq!(
                j.get("resident").unwrap().as_bool(),
                Some(resident),
                "the stats op must surface the serving mode"
            );
            if !resident {
                let streamed = j.get("rows_streamed").unwrap().as_f64().unwrap();
                assert!(streamed > 0.0, "streamed serving must stream rows");
            }
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "resident vs streamed");
    }

    #[test]
    fn unknown_backend_fails_fast() {
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            backend: "warp-drive".into(),
            ..Default::default()
        };
        assert!(Engine::start(cfg).is_err());
    }

    #[test]
    fn expired_deadline_is_dropped_before_any_retrieval() {
        let Some(eng) = engine() else { return };
        // deadline 0: already expired when the executor dequeues it
        let rx = eng
            .submit_with_deadline(DenoiserKind::GoldDiff, 7, None, Some(0))
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.error.as_deref(), Some("deadline_exceeded"));
        assert!(resp.sample.is_empty() && resp.steps.is_empty());
        let j = eng.stats_json();
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("retrieval_queries").unwrap().as_f64(),
            Some(0.0),
            "an expired request must trigger zero retrieval work"
        );
        assert_eq!(j.get("steps_executed").unwrap().as_f64(), Some(0.0));
        // the engine still serves after the drop
        let ok = eng.generate(DenoiserKind::GoldDiff, 7, None).unwrap();
        assert!(ok.error.is_none());
        assert_eq!(ok.sample.len(), 2);
        eng.shutdown();
    }

    #[test]
    fn mid_trajectory_deadline_stops_between_tick_groups() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            steps: 1000,
            // a step budget would finish the trajectory in a handful of
            // ticks and beat the deadline this test relies on
            step_budget: 0,
            ..Default::default()
        };
        let eng = Engine::start(cfg).unwrap();
        // tight but NOT already expired: the dequeue gate passes, at least
        // the first tick group runs, and the between-group re-check stops
        // the trajectory long before step 1000 (the PR-8 regression: this
        // used to burn the whole schedule and only fail later arrivals)
        let rx = eng
            .submit_with_deadline(DenoiserKind::GoldDiff, 3, None, Some(50))
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.error.as_deref(), Some("deadline_exceeded"));
        let j = eng.stats_json();
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(1.0));
        let steps = j.get("steps_executed").unwrap().as_f64().unwrap();
        assert!(steps >= 1.0, "the request must have started its trajectory");
        assert!(steps < 1000.0, "the expired request must stop early");
        assert_eq!(j.get("completed").unwrap().as_f64(), Some(0.0));
        eng.shutdown();
    }

    #[test]
    fn remote_loopback_engine_serves_identical_samples() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut samples = Vec::new();
        for workers in [0usize, 2] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: std::env::temp_dir().join("golddiff_engine_test"),
                shards: 3,
                remote_workers: workers,
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 23, None).unwrap();
            assert!(resp.error.is_none());
            let j = eng.stats_json();
            if workers > 0 {
                assert!(
                    j.get("remote_ops").unwrap().as_f64().unwrap() > 0.0,
                    "retrieval must have gone over the wire"
                );
                assert_eq!(j.get("workers_lost").unwrap().as_f64(), Some(0.0));
                let h = eng.health_json();
                assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));
            }
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "loopback workers vs in-process");
    }

    #[test]
    fn panicking_request_answers_internal_and_engine_keeps_serving() {
        let Some(eng) = engine() else { return };
        // moons has 2 classes: class 9999 indexes class_rows out of range
        // inside the retrieval step and panics on the executor thread — the
        // request must answer "internal" and the engine must stay up
        let resp = eng.generate(DenoiserKind::GoldDiff, 11, Some(9999)).unwrap();
        assert_eq!(resp.error.as_deref(), Some("internal"));
        assert!(resp.sample.is_empty());
        let j = eng.stats_json();
        assert!(j.get("panics_recovered").unwrap().as_f64().unwrap() >= 1.0);
        // same engine, fresh denoiser, clean request
        let ok = eng.generate(DenoiserKind::GoldDiff, 11, None).unwrap();
        assert!(ok.error.is_none());
        assert!(ok.sample.iter().all(|v| v.is_finite()));
        eng.shutdown();
    }

    /// Flip one payload byte in the middle of a named store section.
    fn corrupt_section(path: &std::path::Path, section: &str) {
        use crate::util::json::Json;
        let mut bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header =
            crate::util::json::parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        let sections = header.get("sections").and_then(Json::as_arr).unwrap();
        let sec = sections
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some(section))
            .unwrap_or_else(|| panic!("no section `{section}`"));
        let off = sec.get("offset").and_then(Json::as_f64).unwrap() as usize;
        let len = sec.get("len").and_then(Json::as_f64).unwrap() as usize * 4;
        bytes[8 + hlen + off + len / 2] ^= 0x40;
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn corrupt_quant_tier_degrades_health_and_serves_identically() {
        // Tentpole acceptance: a store with a corrupted optional section
        // still starts, health reports the stood-down tier, and the output
        // is byte-identical to the quant-off exact path
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_degraded_test");
        std::fs::remove_dir_all(&data_dir).ok();
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: data_dir.clone(),
            ..Default::default()
        };
        // clean start synthesises + persists the store, gives the baseline
        let eng = Engine::start(cfg.clone()).unwrap();
        let want = eng.generate(DenoiserKind::GoldDiff, 99, None).unwrap();
        eng.shutdown();

        corrupt_section(&store::store_path(&data_dir, "moons"), "quant_err");
        let eng = Engine::start(cfg).unwrap();
        let h = eng.health_json();
        assert_eq!(
            h.get("status").and_then(crate::util::json::Json::as_str),
            Some("degraded")
        );
        let tiers = h.get("degraded_tiers").unwrap().as_arr().unwrap();
        assert!(
            tiers
                .iter()
                .any(|t| t.as_str() == Some("quant")),
            "health must name the stood-down tier"
        );
        assert!(h.get("checksum_failures").unwrap().as_f64().unwrap() >= 1.0);
        let got = eng.generate(DenoiserKind::GoldDiff, 99, None).unwrap();
        assert!(got.error.is_none());
        assert_eq!(got.sample, want.sample, "exact f32 path, byte-identical");
        eng.shutdown();
        std::fs::remove_dir_all(&data_dir).ok();
    }

    #[test]
    fn gauss_fast_path_skips_screens_and_hands_off_to_retrieval() {
        // PR-9 acceptance: with the fast path on, tick groups above the
        // switch point execute zero coarse screens and zero refines
        // (pinned by per-step telemetry AND the engine counters), then
        // retrieval takes over for the rest of the trajectory
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_gauss_test");
        std::fs::remove_dir_all(&data_dir).ok();
        let mut cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: data_dir.clone(),
            // the per-step and query-delta assertions below assume the
            // full-grid first-order trajectory
            solver: "ddim".into(),
            step_budget: 0,
            ..Default::default()
        };
        cfg.gauss = false;
        let eng = Engine::start(cfg.clone()).unwrap();
        let off = eng.generate(DenoiserKind::GoldDiff, 55, None).unwrap();
        let off_queries = eng
            .stats_json()
            .get("retrieval_queries")
            .unwrap()
            .as_f64()
            .unwrap();
        eng.shutdown();

        cfg.gauss = true;
        cfg.gauss_switch = "3".into(); // forced: pin the prefix length
        let eng = Engine::start(cfg).unwrap();
        let on = eng.generate(DenoiserKind::GoldDiff, 55, None).unwrap();
        assert!(on.error.is_none());
        assert!(on.sample.iter().all(|v| v.is_finite()));
        assert_eq!(on.steps.len(), 10);
        // the Gaussian prefix does no retrieval at all
        for s in &on.steps[..3] {
            assert_eq!(s.m_used, 0, "gauss tick must screen nothing");
            assert_eq!(s.k_used, 0, "gauss tick must refine nothing");
        }
        // retrieval resumes with its usual budgets after the switch point
        assert!(on.steps[3].k_used > 0, "retrieval takes over at the switch");
        let j = eng.stats_json();
        assert_eq!(j.get("gauss").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("gauss_ticks").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("screens_skipped").unwrap().as_f64(), Some(3.0));
        assert_eq!(
            j.get("retrieval_queries").unwrap().as_f64(),
            Some(off_queries - 3.0),
            "each Gaussian tick removes exactly one retrieval query"
        );
        let h = eng.health_json();
        assert_eq!(
            h.get("status").and_then(crate::util::json::Json::as_str),
            Some("ok")
        );
        assert_eq!(h.get("gauss_ticks").unwrap().as_f64(), Some(3.0));
        // the off-path trajectory also ran 10 full-retrieval steps — sanity
        assert!(off.steps.iter().all(|s| s.k_used > 0));
        eng.shutdown();
        std::fs::remove_dir_all(&data_dir).ok();
    }

    #[test]
    fn corrupt_gauss_tier_stands_down_and_serves_like_gauss_off() {
        // degradation contract: a corrupted `gauss_*` section must not
        // take serving down — the engine starts, health names the
        // stood-down tier, zero ticks go through the closed form, and
        // samples are byte-identical to a gauss-off engine
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_gauss_corrupt_test");
        std::fs::remove_dir_all(&data_dir).ok();
        let mut cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: data_dir.clone(),
            ..Default::default()
        };
        cfg.gauss = false;
        let eng = Engine::start(cfg.clone()).unwrap();
        let want = eng.generate(DenoiserKind::GoldDiff, 99, None).unwrap();
        eng.shutdown();

        corrupt_section(&store::store_path(&data_dir, "moons"), "gauss_mean");
        cfg.gauss = true;
        cfg.gauss_switch = "3".into();
        let eng = Engine::start(cfg).unwrap();
        let h = eng.health_json();
        assert_eq!(
            h.get("status").and_then(crate::util::json::Json::as_str),
            Some("degraded")
        );
        let tiers = h.get("degraded_tiers").unwrap().as_arr().unwrap();
        assert!(
            tiers.iter().any(|t| t.as_str() == Some("gauss")),
            "health must name the stood-down moment tier"
        );
        assert!(h.get("checksum_failures").unwrap().as_f64().unwrap() >= 1.0);
        let got = eng.generate(DenoiserKind::GoldDiff, 99, None).unwrap();
        assert!(got.error.is_none());
        assert_eq!(
            eng.stats_json().get("gauss_ticks").unwrap().as_f64(),
            Some(0.0),
            "a stood-down tier serves zero Gaussian ticks"
        );
        assert_eq!(got.sample, want.sample, "full retrieval, byte-identical");
        eng.shutdown();
        std::fs::remove_dir_all(&data_dir).ok();
    }

    #[test]
    fn heun_engine_reuses_screens_and_a_budget_coasts() {
        // few-step serving: under heun every retrieval tick below the
        // terminal runs one corrector refine over the predictor pool, and
        // a step budget serves the trajectory in fewer ticks end to end
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_fewstep_test"),
            ..Default::default()
        };
        cfg.solver = "heun".into();
        cfg.step_budget = 0;
        let eng = Engine::start(cfg.clone()).unwrap();
        let resp = eng.generate(DenoiserKind::GoldDiff, 33, None).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        assert_eq!(resp.steps.len(), 10, "full grid: every point ticks");
        let j = eng.stats_json();
        assert_eq!(j.get("solver").unwrap().as_str(), Some("heun"));
        let refines = j.get("corrector_refines").unwrap().as_f64().unwrap();
        assert_eq!(refines, 9.0, "every tick but the terminal corrects");
        let reused = j.get("screens_reused").unwrap().as_f64().unwrap();
        assert!(
            reused > 0.0 && reused <= refines,
            "pool reuse must engage: {reused} of {refines}"
        );
        assert_eq!(
            j.get("ticks_placed").unwrap().as_f64(),
            Some(0.0),
            "a full plan places nothing"
        );
        eng.shutdown();

        cfg.step_budget = 5;
        let eng = Engine::start(cfg).unwrap();
        let resp = eng.generate(DenoiserKind::GoldDiff, 33, None).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        assert_eq!(resp.steps.len(), 5, "the budget caps the placed ticks");
        let j = eng.stats_json();
        assert_eq!(j.get("steps_executed").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("ticks_placed").unwrap().as_f64(), Some(5.0));
        eng.shutdown();
    }

    #[test]
    fn health_starts_ok() {
        let Some(eng) = engine() else { return };
        let h = eng.health_json();
        assert_eq!(h.get("status").and_then(crate::util::json::Json::as_str), Some("ok"));
        assert!(h
            .get("degraded_tiers")
            .unwrap()
            .as_arr()
            .unwrap()
            .is_empty());
        eng.shutdown();
    }

    #[test]
    fn mixed_methods_coexist() {
        let Some(eng) = engine() else { return };
        let r1 = eng.submit(DenoiserKind::GoldDiff, 1, None).unwrap();
        let r2 = eng.submit(DenoiserKind::Optimal, 1, None).unwrap();
        let s1 = r1.recv().unwrap();
        let s2 = r2.recv().unwrap();
        // same seed, different methods — same init noise, near-identical
        // outcomes at low noise (golden ≈ optimal), but both must be finite
        assert!(s1.sample.iter().all(|v| v.is_finite()));
        assert!(s2.sample.iter().all(|v| v.is_finite()));
        eng.shutdown();
    }
}
