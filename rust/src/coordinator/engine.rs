//! The serving engine: a continuous-batching loop on a dedicated executor
//! thread (the vLLM "model executor" shape).
//!
//! Clients `submit` generation requests into a bounded queue (backpressure)
//! and receive a completion channel. The executor thread owns the PJRT
//! runtime (`SendRuntime`), admits requests up to `max_active`, and on each
//! tick:
//!
//!   1. groups live sequences by (method, step, k-bucket) — `batcher`;
//!   2. advances every sequence one denoising step through its
//!      `XlaDenoiser` (retrieval in rust, math in XLA);
//!   3. completes sequences that reached the end of the schedule.
//!
//! Requests at different timesteps coexist (continuous batching): a new
//! request's "prefill-like" large-k steps interleave with older requests'
//! "decode-like" small-k steps.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{group_tick, SeqKey};
use super::queue::{BoundedQueue, SubmitError};
use super::request::{GenRequest, GenResponse, StepTelemetry};
use super::stats::EngineStats;
use super::xla_denoiser::XlaDenoiser;
use crate::config::EngineConfig;
use crate::data::dataset::{Dataset, IvfPartition, ShardIvfPartition};
use crate::data::shard::ShardPlan;
use crate::data::store;
use crate::denoiser::{DenoiserKind, StepContext};
use crate::index::backend::{RetrievalBackend, RetrievalBackendKind};
use crate::runtime::{Runtime, SendRuntime};
use crate::sampler;
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::{NoiseSchedule, ScheduleKind};
use crate::util::rng::Pcg64;

struct Submission {
    req: GenRequest,
    submitted: Instant,
    reply: mpsc::Sender<GenResponse>,
}

struct ActiveSeq {
    req: GenRequest,
    reply: mpsc::Sender<GenResponse>,
    x: Vec<f32>,
    step: usize,
    rng: Pcg64,
    telemetry: Vec<StepTelemetry>,
    submitted: Instant,
    started: Instant,
}

pub struct Engine {
    queue: Arc<BoundedQueue<Submission>>,
    stats: Arc<Mutex<EngineStats>>,
    handle: Option<JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    pub d: usize,
    pub preset: String,
    pub steps: usize,
}

impl Engine {
    /// Load (or synthesise) the dataset, open the runtime, spawn the
    /// executor thread.
    ///
    /// Corpus residency: `cfg.resident = false` — or `shards > 1` with a
    /// positive `mem_budget_mb`, which implies the out-of-core mode —
    /// serves the corpus **data-free**: the `.gds` store is opened via
    /// [`store::open_streaming`] (headers, proxies, shard bounds and stats
    /// only; the `data` section never loads) and rows stream
    /// shard-at-a-time through a budget-bounded LRU. Output is
    /// byte-identical to a resident engine.
    pub fn start(cfg: EngineConfig) -> Result<Engine> {
        let resident = cfg.resident && !(cfg.shards > 1 && cfg.mem_budget_mb > 0);
        let store_path = store::store_path(&cfg.data_dir, &cfg.preset);
        // a freshly synthesised store is saved with the engine's shard
        // plan so the streaming path can seek per-shard sections
        let mut ds = if resident {
            store::load_or_synthesize_sharded(&cfg.data_dir, &cfg.preset, cfg.seed, cfg.shards)
                .context("loading dataset")?
        } else {
            store::ensure_store(&cfg.data_dir, &cfg.preset, cfg.seed, cfg.shards.max(1))
                .context("materialising the store to stream from")?;
            store::open_streaming(&store_path, cfg.shards.max(1), cfg.mem_budget_mb)
                .context("opening dataset for streaming")?
        };
        let kind = ScheduleKind::parse(&cfg.schedule)
            .with_context(|| format!("unknown schedule {}", cfg.schedule))?;
        let sched = NoiseSchedule::new(kind, cfg.steps);
        let backend_kind = RetrievalBackendKind::parse(&cfg.backend)
            .with_context(|| format!("unknown retrieval backend {}", cfg.backend))?;
        if backend_kind == RetrievalBackendKind::ClusterPruned && cfg.shards <= 1 {
            // the IVF partition persists in the .gds store; only a config
            // mismatch (lists/seed) pays the k-means here, and the result
            // is written back (best-effort, resident corpora only — a
            // streamed dataset cannot rewrite its own backing store) so
            // the next start skips it. (A sharded cluster backend
            // partitions per shard instead — see below.)
            let lists = cfg.clusters.clamp(1, ds.n.max(1));
            let stale = ds
                .ivf
                .as_ref()
                .is_none_or(|p| !p.matches(lists, cfg.seed));
            if stale {
                ds.ivf = Some(IvfPartition::compute(&ds, lists, cfg.seed));
                if ds.is_resident() {
                    let _ = store::save(&ds, &store_path);
                }
            }
        }
        if backend_kind == RetrievalBackendKind::ClusterPruned && cfg.shards > 1 {
            // satellite: the *per-shard* partitions persist too, so a
            // sharded cluster engine stops paying per-shard k-means on
            // every start. k-means runs over the proxies (always
            // resident), so streamed datasets compute — they just skip
            // the write-back.
            let ns = ShardPlan::new(ds.n, cfg.shards).count();
            let per_shard = cfg.clusters.max(1).div_ceil(ns).max(1);
            let stale = ds
                .shard_ivf
                .as_ref()
                .is_none_or(|p| !p.matches(ns, per_shard, cfg.seed));
            if stale {
                ds.shard_ivf =
                    Some(ShardIvfPartition::compute(&ds, cfg.shards, per_shard, cfg.seed));
                if ds.is_resident() {
                    let _ = store::save_sharded(&ds, &store_path, cfg.shards);
                }
            }
        }
        let ds = Arc::new(ds);
        // built once per engine (cluster-pruned reuses the persisted IVF
        // partitions here) and shared by every denoiser so telemetry
        // aggregates in one place; row residency routes through the
        // dataset's source, so a streamed corpus serves every backend kind
        let backend: Arc<dyn RetrievalBackend> = backend_kind.build(&ds, cfg.backend_opts());
        let runtime = SendRuntime(Runtime::new(&cfg.artifacts_dir)?);

        let queue = Arc::new(BoundedQueue::<Submission>::new(cfg.queue_depth));
        let stats = Arc::new(Mutex::new(EngineStats::new()));
        {
            let mut st = stats.lock().unwrap();
            st.backend = backend_kind.name().to_string();
            st.shards = cfg.shards.max(1);
            st.resident = ds.is_resident();
            // config echo: what the quantised-tier counters mean depends
            // on whether the tiers were on (the backend build gates them
            // on `kernel` too, which the counters themselves reveal)
            st.quant = cfg.quant;
        }
        let d = ds.d;
        let preset = cfg.preset.clone();
        let steps = cfg.steps;

        let q2 = Arc::clone(&queue);
        let s2 = Arc::clone(&stats);
        let handle = std::thread::Builder::new()
            .name("golddiff-executor".into())
            .spawn(move || {
                executor_loop(runtime, ds, sched, cfg, backend, q2, s2);
            })?;

        Ok(Engine {
            queue,
            stats,
            handle: Some(handle),
            next_id: std::sync::atomic::AtomicU64::new(1),
            d,
            preset,
            steps,
        })
    }

    /// Submit a request; returns the completion channel. Blocks under
    /// backpressure.
    pub fn submit(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<mpsc::Receiver<GenResponse>> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, method, seed);
        req.class = class;
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.stats.lock().unwrap();
            st.submitted += 1;
        }
        self.queue
            .submit(Submission {
                req,
                submitted: Instant::now(),
                reply: tx,
            })
            .map_err(|e| anyhow::anyhow!("submit failed: {e:?}"))?;
        Ok(rx)
    }

    /// Fail-fast submit (server path).
    pub fn try_submit(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<mpsc::Receiver<GenResponse>, SubmitError> {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let mut req = GenRequest::new(id, method, seed);
        req.class = class;
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.stats.lock().unwrap();
            st.submitted += 1;
        }
        match self.queue.try_submit(Submission {
            req,
            submitted: Instant::now(),
            reply: tx,
        }) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.stats.lock().unwrap().rejected += 1;
                Err(e)
            }
        }
    }

    /// Convenience: submit and wait.
    pub fn generate(
        &self,
        method: DenoiserKind,
        seed: u64,
        class: Option<u32>,
    ) -> Result<GenResponse> {
        let rx = self.submit(method, seed, class)?;
        rx.recv().context("engine dropped the request")
    }

    pub fn stats_json(&self) -> crate::util::json::Json {
        self.stats.lock().unwrap().to_json()
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Graceful shutdown: stop accepting, drain, join.
    pub fn shutdown(mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.queue.close();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Max sequences in flight (per tick) — bounded by dispatch serialisation
/// on the CPU PJRT client; scans for the whole group still parallelise.
const MAX_ACTIVE: usize = 32;

fn executor_loop(
    runtime: SendRuntime,
    ds: Arc<Dataset>,
    sched: NoiseSchedule,
    cfg: EngineConfig,
    backend: Arc<dyn RetrievalBackend>,
    queue: Arc<BoundedQueue<Submission>>,
    stats: Arc<Mutex<EngineStats>>,
) {
    let rt = std::rc::Rc::new(runtime.0);
    let warm_start = cfg.warm_start;
    let mut denoisers: HashMap<DenoiserKind, XlaDenoiser> = HashMap::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let buckets = rt.manifest.buckets("golden_step", &ds.name);
    let budget = BudgetSchedule::new(
        ds.n,
        ((ds.n as f64 * cfg.m_min_frac) as usize).max(1),
        ((ds.n as f64 * cfg.m_max_frac) as usize).max(1),
        ((ds.n as f64 * cfg.k_min_frac) as usize).max(1),
        ((ds.n as f64 * cfg.k_max_frac) as usize).max(1),
        &buckets,
    );

    loop {
        // ---- admission -------------------------------------------------
        let room = MAX_ACTIVE.saturating_sub(active.len());
        let newly = if active.is_empty() {
            let batch = queue.pop_batch(room.max(1)); // blocks when idle
            if batch.is_empty() && queue.is_closed() {
                break;
            }
            batch
        } else {
            queue.try_pop_batch(room)
        };
        let now = Instant::now();
        for sub in newly {
            let mut rng = Pcg64::with_stream(sub.req.seed, 0x5a3);
            let x = sampler::init_noise(ds.d, &mut rng);
            active.push(ActiveSeq {
                req: sub.req,
                reply: sub.reply,
                x,
                step: 0,
                rng,
                telemetry: Vec::with_capacity(sched.steps),
                submitted: sub.submitted,
                started: now,
            });
        }
        if active.is_empty() {
            continue;
        }

        // ---- one scheduler tick -----------------------------------------
        let keys: Vec<SeqKey> = active
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let b = budget.at(&sched, s.step);
                SeqKey {
                    seq: i,
                    method: s.req.method,
                    step: s.step,
                    k_bucket: b.k_bucket,
                }
            })
            .collect();
        for group in group_tick(&keys) {
            let den = denoisers.entry(group.method).or_insert_with(|| {
                XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, group.method)
                    .expect("denoiser init")
                    .with_budget(budget.clone())
                    .with_retrieval(Arc::clone(&backend))
                    .with_warm_start(warm_start)
            });
            // one batched retrieval for the whole group, then dispatch —
            // every sequence here shares (method, step, k-bucket)
            let xs: Vec<&[f32]> = group.seqs.iter().map(|&si| active[si].x.as_slice()).collect();
            let ctx_store: Vec<StepContext> = group
                .seqs
                .iter()
                .map(|&si| StepContext {
                    ds: &ds,
                    sched: &sched,
                    step: active[si].step,
                    class: active[si].req.class,
                })
                .collect();
            let ctxs: Vec<&StepContext> = ctx_store.iter().collect();
            let results = den.step_group(&xs, &ctxs).expect("dispatch failed");
            drop(ctxs);
            drop(xs);
            let group_scan: f64 = results.iter().map(|(_, tel)| tel.scan_secs).sum();
            for (&si, (out, tel)) in group.seqs.iter().zip(results) {
                let seq = &mut active[si];
                seq.telemetry.push(StepTelemetry {
                    k_bucket: tel.k_bucket,
                    m_used: tel.m_used,
                    k_used: tel.k_used,
                    scan_secs: tel.scan_secs,
                    dispatch_secs: tel.dispatch_secs,
                    entropy: out.stats.entropy,
                    top1_weight: out.stats.top1_weight,
                });
                // the graph already produced the deterministic DDIM update;
                // apply ancestral noise on the host only when eta > 0
                seq.x = if seq.req.eta > 0.0 {
                    sampler::ddim_update(
                        &seq.x,
                        &out.f_hat,
                        sched.alpha_bar(seq.step),
                        sched.alpha_prev(seq.step),
                        seq.req.eta,
                        &mut seq.rng,
                    )
                } else {
                    out.x_prev
                };
                seq.step += 1;
                let mut st = stats.lock().unwrap();
                st.steps_executed += 1;
                st.scan_time.record_secs(tel.scan_secs);
                st.dispatch_time.record_secs(tel.dispatch_secs);
            }
            let mut st = stats.lock().unwrap();
            st.retrieval_time.record_secs(group_scan);
            st.record_backend(backend.stats());
            // streamed corpora additionally surface the row source's own
            // residency counters (the authoritative record when the
            // monolithic backends stream without a shard layer)
            st.record_source(ds.source_stats());
        }

        // ---- completions -------------------------------------------------
        let total_steps = sched.steps;
        let mut i = 0;
        while i < active.len() {
            if active[i].step >= total_steps {
                let seq = active.swap_remove(i);
                let latency = seq.submitted.elapsed().as_secs_f64();
                let queue_delay = seq.started.duration_since(seq.submitted).as_secs_f64();
                {
                    let mut st = stats.lock().unwrap();
                    st.completed += 1;
                    st.latency.record_secs(latency);
                    st.queue_delay.record_secs(queue_delay);
                }
                let _ = seq.reply.send(GenResponse {
                    id: seq.req.id,
                    sample: seq.x,
                    steps: seq.telemetry,
                    latency_secs: latency,
                    queue_secs: queue_delay,
                });
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return None;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            ..Default::default()
        };
        Some(Engine::start(cfg).unwrap())
    }

    #[test]
    fn serves_one_request_end_to_end() {
        let Some(eng) = engine() else { return };
        let resp = eng.generate(DenoiserKind::GoldDiff, 7, None).unwrap();
        assert_eq!(resp.sample.len(), 2);
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        assert_eq!(resp.steps.len(), 10);
        assert!(resp.latency_secs > 0.0);
        // k budgets shrink along the trajectory
        assert!(resp.steps.last().unwrap().k_used < resp.steps[0].k_used);
        eng.shutdown();
    }

    #[test]
    fn concurrent_requests_all_complete_deterministically() {
        let Some(eng) = engine() else { return };
        let rxs: Vec<_> = (0..6)
            .map(|i| eng.submit(DenoiserKind::GoldDiff, 100 + i, None).unwrap())
            .collect();
        let samples: Vec<Vec<f32>> = rxs.into_iter().map(|rx| rx.recv().unwrap().sample).collect();
        assert_eq!(samples.len(), 6);
        // same seed twice gives identical output even under batching
        let a = eng.generate(DenoiserKind::GoldDiff, 100, None).unwrap();
        assert_eq!(a.sample, samples[0]);
        let j = eng.stats_json();
        assert!(j.get("completed").unwrap().as_f64().unwrap() >= 7.0);
        eng.shutdown();
    }

    #[test]
    fn every_backend_serves_identical_samples() {
        // the retrieval backends are exact (nprobe = 0), so the engine must
        // produce bit-identical samples whichever one the config selects
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for backend in ["flat", "batched", "cluster"] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: std::env::temp_dir().join("golddiff_engine_test"),
                backend: backend.into(),
                clusters: 8,
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 4242, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()), "{backend}");
            let j = eng.stats_json();
            assert_eq!(
                j.get("retrieval_backend").unwrap().as_str(),
                Some(backend)
            );
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "flat vs batched");
        assert_eq!(samples[0], samples[2], "flat vs cluster");
    }

    #[test]
    fn batched_backend_amortises_proxy_passes() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            backend: "batched".into(),
            ..Default::default()
        };
        let eng = Engine::start(cfg).unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| eng.submit(DenoiserKind::GoldDiff, 900 + i, None).unwrap())
            .collect();
        for rx in rxs {
            let _ = rx.recv().unwrap();
        }
        let j = eng.stats_json();
        let passes = j.get("proxy_passes").unwrap().as_f64().unwrap();
        let queries = j.get("retrieval_queries").unwrap().as_f64().unwrap();
        assert!(
            passes < queries,
            "batched ticks must share passes: {passes} passes for {queries} queries"
        );
        eng.shutdown();
    }

    #[test]
    fn cluster_engine_persists_ivf_partition() {
        // satellite: the first cluster start computes + persists the IVF
        // partition; the store then carries it for later starts to reuse
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_ivf_test");
        std::fs::remove_dir_all(&data_dir).ok();
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: data_dir.clone(),
            backend: "cluster".into(),
            clusters: 8,
            ..Default::default()
        };
        let eng = Engine::start(cfg.clone()).unwrap();
        let resp = eng.generate(DenoiserKind::GoldDiff, 5, None).unwrap();
        assert!(resp.sample.iter().all(|v| v.is_finite()));
        eng.shutdown();

        let ds = store::load(&store::store_path(&data_dir, "moons")).unwrap();
        let ivf = ds.ivf.expect("cluster start must persist the partition");
        assert!(ivf.matches(8usize.clamp(1, ds.n.max(1)), cfg.seed));
        assert_eq!(ivf.assignments.len(), ds.n);

        // a second start with the same config serves identically off the
        // persisted partition (no k-means mismatch)
        let eng2 = Engine::start(cfg).unwrap();
        let again = eng2.generate(DenoiserKind::GoldDiff, 5, None).unwrap();
        assert_eq!(again.sample, resp.sample);
        eng2.shutdown();
        std::fs::remove_dir_all(&data_dir).ok();
    }

    #[test]
    fn sharded_engine_serves_identical_samples_and_reports_telemetry() {
        // the sharded merge layer is exact, so a sharded + memory-budgeted
        // engine must serve byte-identical samples to the monolithic one,
        // while the stats surface the shard telemetry end to end
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for shards in [1usize, 4] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: std::env::temp_dir().join("golddiff_engine_shard_test"),
                backend: "batched".into(),
                shards,
                mem_budget_mb: if shards > 1 { 1 } else { 0 },
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 77, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()), "shards={shards}");
            let j = eng.stats_json();
            assert_eq!(
                j.get("shards").unwrap().as_f64(),
                Some(shards as f64),
                "config shard count surfaces"
            );
            if shards > 1 {
                let scanned = j.get("shards_scanned").unwrap().as_f64().unwrap();
                let skipped = j.get("shards_skipped").unwrap().as_f64().unwrap();
                assert!(
                    scanned + skipped > 0.0,
                    "sharded serving must record shard scans"
                );
            }
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "shards=1 vs shards=4");
    }

    #[test]
    fn streamed_engine_serves_byte_identical_samples() {
        // the out-of-core engine (resident = false, bounded budget) must
        // serve byte-identical samples to the resident one and surface the
        // streaming telemetry through the stats op
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            return;
        }
        let data_dir = std::env::temp_dir().join("golddiff_engine_streamed_test");
        let mut samples: Vec<Vec<f32>> = Vec::new();
        for resident in [true, false] {
            let cfg = EngineConfig {
                preset: "moons".into(),
                data_dir: data_dir.clone(),
                backend: "batched".into(),
                shards: 4,
                mem_budget_mb: if resident { 0 } else { 1 },
                resident,
                ..Default::default()
            };
            let eng = Engine::start(cfg).unwrap();
            let resp = eng.generate(DenoiserKind::GoldDiff, 321, None).unwrap();
            assert!(resp.sample.iter().all(|v| v.is_finite()));
            let j = eng.stats_json();
            assert_eq!(
                j.get("resident").unwrap().as_bool(),
                Some(resident),
                "the stats op must surface the serving mode"
            );
            if !resident {
                let streamed = j.get("rows_streamed").unwrap().as_f64().unwrap();
                assert!(streamed > 0.0, "streamed serving must stream rows");
            }
            samples.push(resp.sample);
            eng.shutdown();
        }
        assert_eq!(samples[0], samples[1], "resident vs streamed");
    }

    #[test]
    fn unknown_backend_fails_fast() {
        let cfg = EngineConfig {
            preset: "moons".into(),
            data_dir: std::env::temp_dir().join("golddiff_engine_test"),
            backend: "warp-drive".into(),
            ..Default::default()
        };
        assert!(Engine::start(cfg).is_err());
    }

    #[test]
    fn mixed_methods_coexist() {
        let Some(eng) = engine() else { return };
        let r1 = eng.submit(DenoiserKind::GoldDiff, 1, None).unwrap();
        let r2 = eng.submit(DenoiserKind::Optimal, 1, None).unwrap();
        let s1 = r1.recv().unwrap();
        let s2 = r2.recv().unwrap();
        // same seed, different methods — same init noise, near-identical
        // outcomes at low noise (golden ≈ optimal), but both must be finite
        assert!(s1.sample.iter().all(|v| v.is_finite()));
        assert!(s2.sample.iter().all(|v| v.is_finite()));
        eng.shutdown();
    }
}
