//! Generation requests, responses, and live-sequence state.

use crate::denoiser::DenoiserKind;
use crate::util::json::Json;

/// A generation job submitted to the engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub method: DenoiserKind,
    /// sampling seed (initial noise + any ancestral noise)
    pub seed: u64,
    /// conditional class (ImageNet-sim)
    pub class: Option<u32>,
    /// DDIM stochasticity
    pub eta: f32,
    /// per-request deadline, measured from submission: a request still
    /// queued when it expires is dropped at dequeue — before any retrieval
    /// work — and answered `"error":"deadline_exceeded"`. `None` = no
    /// deadline (the seed behaviour).
    pub deadline_ms: Option<u64>,
}

impl GenRequest {
    pub fn new(id: u64, method: DenoiserKind, seed: u64) -> GenRequest {
        GenRequest {
            id,
            method,
            seed,
            class: None,
            eta: 0.0,
            deadline_ms: None,
        }
    }

    pub fn with_class(mut self, class: u32) -> Self {
        self.class = Some(class);
        self
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id)
            .set("method", self.method.name())
            .set("seed", self.seed)
            .set("eta", self.eta as f64);
        if let Some(c) = self.class {
            j.set("class", c as usize);
        }
        if let Some(dl) = self.deadline_ms {
            j.set("deadline_ms", dl);
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GenRequest> {
        let method = j
            .get("method")
            .and_then(Json::as_str)
            .and_then(DenoiserKind::parse)
            .ok_or_else(|| anyhow::anyhow!("bad or missing method"))?;
        Ok(GenRequest {
            id: strict_u64_field(j, "id")?.unwrap_or(0),
            method,
            seed: strict_u64_field(j, "seed")?.unwrap_or(0),
            class: strict_u32_field(j, "class")?,
            eta: match j.get("eta") {
                None | Some(Json::Null) => 0.0,
                Some(v) => v
                    .as_f64()
                    .filter(|e| e.is_finite())
                    .ok_or_else(|| anyhow::anyhow!("bad_field:eta"))? as f32,
            },
            deadline_ms: strict_u64_field(j, "deadline_ms")?,
        })
    }
}

/// Strictly-validated optional u64 protocol field: absent (or `null`) is
/// `None`; present-but-malformed — negative, fractional, ≥ 2^53 (where an
/// f64-backed number silently loses integer precision), or not a number at
/// all — errors with the machine-readable `bad_field:<name>` reason instead
/// of saturating through an `as` cast.
pub fn strict_u64_field(j: &Json, name: &str) -> anyhow::Result<Option<u64>> {
    match j.get(name) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_strict_u64()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("bad_field:{name}")),
    }
}

/// [`strict_u64_field`] additionally bounded to `u32` (class ids and other
/// small protocol integers) — `{"class":-1}` answers `bad_field:class`
/// instead of silently generating class 0.
pub fn strict_u32_field(j: &Json, name: &str) -> anyhow::Result<Option<u32>> {
    match j.get(name) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_strict_u32()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("bad_field:{name}")),
    }
}

/// Per-step telemetry attached to a finished request.
#[derive(Debug, Clone, Default)]
pub struct StepTelemetry {
    pub k_bucket: usize,
    pub m_used: usize,
    pub k_used: usize,
    pub scan_secs: f64,
    pub dispatch_secs: f64,
    pub entropy: f32,
    pub top1_weight: f32,
}

/// The finished generation — or its failure. `error` is `None` on
/// success; a failed request carries the machine-readable reason
/// (`"deadline_exceeded"`, `"internal"`) with an empty sample.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub sample: Vec<f32>,
    pub steps: Vec<StepTelemetry>,
    /// end-to-end latency (submit → completion)
    pub latency_secs: f64,
    /// queueing delay before the first step
    pub queue_secs: f64,
    /// failure reason; `None` = the request completed
    pub error: Option<String>,
}

impl GenResponse {
    /// A failure reply: empty sample, no steps, the reason attached.
    pub fn failed(id: u64, error: &str, latency_secs: f64) -> GenResponse {
        GenResponse {
            id,
            sample: Vec::new(),
            steps: Vec::new(),
            latency_secs,
            queue_secs: latency_secs,
            error: Some(error.to_string()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id)
            .set("latency_secs", self.latency_secs)
            .set("queue_secs", self.queue_secs)
            .set("steps", self.steps.len())
            .set("sample", self.sample.as_slice());
        if let Some(e) = &self.error {
            j.set("error", e.as_str());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenRequest::new(42, DenoiserKind::GoldDiff, 7).with_class(3);
        let rt = GenRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(rt.id, 42);
        assert_eq!(rt.method, DenoiserKind::GoldDiff);
        assert_eq!(rt.seed, 7);
        assert_eq!(rt.class, Some(3));
        assert_eq!(rt.deadline_ms, None, "no deadline unless requested");
    }

    #[test]
    fn deadline_roundtrips_through_json() {
        let r = GenRequest::new(1, DenoiserKind::GoldDiff, 2).with_deadline_ms(250);
        let rt = GenRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(rt.deadline_ms, Some(250));
    }

    #[test]
    fn rejects_bad_method() {
        let j = crate::util::json::parse(r#"{"id":1,"method":"nope","seed":0}"#).unwrap();
        assert!(GenRequest::from_json(&j).is_err());
    }

    #[test]
    fn rejects_out_of_range_numeric_fields() {
        // the PR-8 regression: {"class":-1} used to saturate to class 0
        // through `as u32`; it must answer a clean bad_field error instead
        let cases = [
            (r#"{"method":"golddiff","class":-1}"#, "bad_field:class"),
            (r#"{"method":"golddiff","class":1.5}"#, "bad_field:class"),
            (r#"{"method":"golddiff","class":4294967296}"#, "bad_field:class"),
            (r#"{"method":"golddiff","class":"0"}"#, "bad_field:class"),
            (r#"{"method":"golddiff","seed":-3}"#, "bad_field:seed"),
            // 2^53: the first integer an f64 JSON number cannot carry
            // exactly — a seed this large would silently lose precision
            (
                r#"{"method":"golddiff","seed":9007199254740992}"#,
                "bad_field:seed",
            ),
            (r#"{"method":"golddiff","id":2.25}"#, "bad_field:id"),
            (r#"{"method":"golddiff","deadline_ms":-1}"#, "bad_field:deadline_ms"),
            (r#"{"method":"golddiff","eta":"x"}"#, "bad_field:eta"),
        ];
        for (text, want) in cases {
            let j = crate::util::json::parse(text).unwrap();
            let err = GenRequest::from_json(&j).unwrap_err().to_string();
            assert_eq!(err, want, "for {text}");
        }
        // the largest exactly-representable values still parse
        let j = crate::util::json::parse(
            r#"{"method":"golddiff","seed":9007199254740991,"class":4294967295}"#,
        )
        .unwrap();
        let r = GenRequest::from_json(&j).unwrap();
        assert_eq!(r.seed, 9_007_199_254_740_991);
        assert_eq!(r.class, Some(u32::MAX));
    }

    #[test]
    fn response_json_has_sample() {
        let r = GenResponse {
            id: 1,
            sample: vec![0.5, -0.5],
            steps: vec![],
            latency_secs: 0.1,
            queue_secs: 0.01,
            error: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("sample").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("error").is_none(), "success replies carry no error");
    }

    #[test]
    fn failed_response_carries_the_reason() {
        let r = GenResponse::failed(9, "deadline_exceeded", 0.05);
        assert!(r.sample.is_empty() && r.steps.is_empty());
        let j = r.to_json();
        assert_eq!(
            j.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );
    }
}
