//! Generation requests, responses, and live-sequence state.

use crate::denoiser::DenoiserKind;
use crate::util::json::Json;

/// A generation job submitted to the engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: u64,
    pub method: DenoiserKind,
    /// sampling seed (initial noise + any ancestral noise)
    pub seed: u64,
    /// conditional class (ImageNet-sim)
    pub class: Option<u32>,
    /// DDIM stochasticity
    pub eta: f32,
    /// per-request deadline, measured from submission: a request still
    /// queued when it expires is dropped at dequeue — before any retrieval
    /// work — and answered `"error":"deadline_exceeded"`. `None` = no
    /// deadline (the seed behaviour).
    pub deadline_ms: Option<u64>,
}

impl GenRequest {
    pub fn new(id: u64, method: DenoiserKind, seed: u64) -> GenRequest {
        GenRequest {
            id,
            method,
            seed,
            class: None,
            eta: 0.0,
            deadline_ms: None,
        }
    }

    pub fn with_class(mut self, class: u32) -> Self {
        self.class = Some(class);
        self
    }

    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id)
            .set("method", self.method.name())
            .set("seed", self.seed)
            .set("eta", self.eta as f64);
        if let Some(c) = self.class {
            j.set("class", c as usize);
        }
        if let Some(dl) = self.deadline_ms {
            j.set("deadline_ms", dl);
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GenRequest> {
        let method = j
            .get("method")
            .and_then(Json::as_str)
            .and_then(DenoiserKind::parse)
            .ok_or_else(|| anyhow::anyhow!("bad or missing method"))?;
        Ok(GenRequest {
            id: j.get("id").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            method,
            seed: j.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            class: j.get("class").and_then(Json::as_f64).map(|c| c as u32),
            eta: j.get("eta").and_then(Json::as_f64).unwrap_or(0.0) as f32,
            deadline_ms: j.get("deadline_ms").and_then(Json::as_f64).map(|v| v as u64),
        })
    }
}

/// Per-step telemetry attached to a finished request.
#[derive(Debug, Clone, Default)]
pub struct StepTelemetry {
    pub k_bucket: usize,
    pub m_used: usize,
    pub k_used: usize,
    pub scan_secs: f64,
    pub dispatch_secs: f64,
    pub entropy: f32,
    pub top1_weight: f32,
}

/// The finished generation — or its failure. `error` is `None` on
/// success; a failed request carries the machine-readable reason
/// (`"deadline_exceeded"`, `"internal"`) with an empty sample.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: u64,
    pub sample: Vec<f32>,
    pub steps: Vec<StepTelemetry>,
    /// end-to-end latency (submit → completion)
    pub latency_secs: f64,
    /// queueing delay before the first step
    pub queue_secs: f64,
    /// failure reason; `None` = the request completed
    pub error: Option<String>,
}

impl GenResponse {
    /// A failure reply: empty sample, no steps, the reason attached.
    pub fn failed(id: u64, error: &str, latency_secs: f64) -> GenResponse {
        GenResponse {
            id,
            sample: Vec::new(),
            steps: Vec::new(),
            latency_secs,
            queue_secs: latency_secs,
            error: Some(error.to_string()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("id", self.id)
            .set("latency_secs", self.latency_secs)
            .set("queue_secs", self.queue_secs)
            .set("steps", self.steps.len())
            .set("sample", self.sample.as_slice());
        if let Some(e) = &self.error {
            j.set("error", e.as_str());
        }
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenRequest::new(42, DenoiserKind::GoldDiff, 7).with_class(3);
        let rt = GenRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(rt.id, 42);
        assert_eq!(rt.method, DenoiserKind::GoldDiff);
        assert_eq!(rt.seed, 7);
        assert_eq!(rt.class, Some(3));
        assert_eq!(rt.deadline_ms, None, "no deadline unless requested");
    }

    #[test]
    fn deadline_roundtrips_through_json() {
        let r = GenRequest::new(1, DenoiserKind::GoldDiff, 2).with_deadline_ms(250);
        let rt = GenRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(rt.deadline_ms, Some(250));
    }

    #[test]
    fn rejects_bad_method() {
        let j = crate::util::json::parse(r#"{"id":1,"method":"nope","seed":0}"#).unwrap();
        assert!(GenRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_json_has_sample() {
        let r = GenResponse {
            id: 1,
            sample: vec![0.5, -0.5],
            steps: vec![],
            latency_secs: 0.1,
            queue_secs: 0.01,
            error: None,
        };
        let j = r.to_json();
        assert_eq!(j.get("sample").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("error").is_none(), "success replies carry no error");
    }

    #[test]
    fn failed_response_carries_the_reason() {
        let r = GenResponse::failed(9, "deadline_exceeded", 0.05);
        assert!(r.sample.is_empty() && r.steps.is_empty());
        let j = r.to_json();
        assert_eq!(
            j.get("error").and_then(Json::as_str),
            Some("deadline_exceeded")
        );
    }
}
