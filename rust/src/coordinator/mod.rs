//! The L3 serving coordinator — the paper's system contribution expressed
//! as a vLLM-style engine:
//!
//! * [`request`] — generation requests / responses / sequence state.
//! * [`queue`] — bounded submission queue (backpressure).
//! * [`batcher`] — groups live sequences by (method, k-bucket) so one
//!   scheduler tick amortises scans and keeps dispatch order cache-friendly.
//! * [`xla_denoiser`] — the XLA-artifact-backed denoiser (all heavy math in
//!   PJRT executables; rust does retrieval, gather and orchestration).
//! * [`engine`] — the continuous-batching serving loop on a dedicated
//!   executor thread, with admission control and per-request telemetry.
//! * [`stats`] — latency/throughput accounting.
//!
//! The paper's Integration→Selection transition (Sec. 3.3) is visible here
//! as a serving policy: early steps are "prefill-like" (large k_t, coarse
//! retrieval, compute-bound dispatches), late steps "decode-like" (small
//! k_t, precise retrieval, retrieval-bound) — the batcher keeps the two
//! phases in separate dispatch groups.

pub mod batcher;
pub mod engine;
pub mod queue;
pub mod request;
pub mod stats;
pub mod xla_denoiser;

pub use engine::Engine;
pub use request::{GenRequest, GenResponse};
