//! Engine telemetry: request latency distribution, throughput, per-phase
//! step timing (scan vs dispatch — the Integration/Selection split).

use std::time::Instant;

use crate::util::json::Json;
use crate::util::timer::TimingStats;

#[derive(Debug)]
pub struct EngineStats {
    pub started_at: Instant,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub steps_executed: u64,
    pub latency: TimingStats,
    pub queue_delay: TimingStats,
    pub scan_time: TimingStats,
    pub dispatch_time: TimingStats,
}

impl Default for EngineStats {
    fn default() -> Self {
        EngineStats {
            started_at: Instant::now(),
            submitted: 0,
            completed: 0,
            rejected: 0,
            steps_executed: 0,
            latency: TimingStats::new(),
            queue_delay: TimingStats::new(),
            scan_time: TimingStats::new(),
            dispatch_time: TimingStats::new(),
        }
    }
}

impl EngineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    pub fn steps_per_sec(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.steps_executed as f64 / secs
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("steps_executed", self.steps_executed)
            .set("throughput_rps", self.throughput_rps())
            .set("steps_per_sec", self.steps_per_sec())
            .set("latency_p50_s", self.latency.percentile(0.5))
            .set("latency_p95_s", self.latency.percentile(0.95))
            .set("latency_mean_s", self.latency.mean())
            .set("queue_p50_s", self.queue_delay.percentile(0.5))
            .set("scan_mean_s", self.scan_time.mean())
            .set("dispatch_mean_s", self.dispatch_time.mean());
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_summary_has_all_fields() {
        let mut s = EngineStats::new();
        s.submitted = 10;
        s.completed = 8;
        s.latency.record_secs(0.5);
        s.latency.record_secs(1.5);
        let j = s.to_json();
        assert_eq!(j.get("completed").unwrap().as_f64(), Some(8.0));
        assert!(j.get("latency_p50_s").unwrap().as_f64().unwrap() >= 0.5);
        assert!(j.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
    }
}
