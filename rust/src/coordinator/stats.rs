//! Engine telemetry: request latency distribution, throughput, per-phase
//! step timing (scan vs dispatch — the Integration/Selection split), and
//! the retrieval backend's cumulative counters (proxy passes, cluster
//! pruning) surfaced per tick.

use std::time::Instant;

use crate::util::json::Json;
use crate::util::timer::TimingStats;

#[derive(Debug)]
pub struct EngineStats {
    pub started_at: Instant,
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub steps_executed: u64,
    pub latency: TimingStats,
    pub queue_delay: TimingStats,
    pub scan_time: TimingStats,
    pub dispatch_time: TimingStats,
    /// whole sequence-steps (scan + dispatch) — the per-tick distribution
    /// the serve bench reports percentiles over
    pub tick_time: TimingStats,
    /// wall-clock of each batched group retrieval (one sample per group)
    pub retrieval_time: TimingStats,
    /// whole solver steps (predictor + any corrector refine), one sample
    /// per sequence-step — under a higher-order solver this is the number
    /// the per-tick `tick_time` split cannot see
    pub step_time: TimingStats,
    /// retrieval backend name ("flat" / "batched" / "cluster")
    pub backend: String,
    /// active solver name ("ddim" / "heun" / "dpm2") — config echo, so
    /// the serve bench can label its percentiles per solver
    pub solver: String,
    /// cumulative backend counters (latest snapshot)
    pub proxy_passes: u64,
    pub retrieval_queries: u64,
    pub rows_scanned: u64,
    pub clusters_scanned: u64,
    pub clusters_pruned: u64,
    /// kernel telemetry: (query-group × row-block) tiles evaluated,
    /// early-retired tiles, and refine-ladder row visits
    pub tiles_evaluated: u64,
    pub kernel_exits: u64,
    pub refine_rows: u64,
    /// heap-aware ordering telemetry: blocks visited out of storage order,
    /// and the (query, row) evaluations the strip exits cut short
    pub blocks_reordered: u64,
    pub exit_gain_rows: u64,
    /// configured corpus shard count (1 = monolithic backends)
    pub shards: usize,
    /// sharded-retrieval telemetry: (query, shard) scans executed vs
    /// avoided, and cold-shard row-block LRU evictions
    pub shards_scanned: u64,
    pub shards_skipped: u64,
    pub shard_evictions: u64,
    /// is the full-resolution corpus resident (false = streamed serving)
    pub resident: bool,
    /// out-of-core telemetry: rows read off the `.gds` store, and the
    /// high-water mark of resident row-block bytes under the LRU budget
    pub rows_streamed: u64,
    pub peak_row_bytes: u64,
    /// are the quantised screen/refine tiers enabled (config echo)
    pub quant: bool,
    /// quantised-tier telemetry: rows screened on int8 bounds, rows the
    /// bound alone excluded, and survivors rescored in exact f32
    /// (`quant_rows_screened == bound_rejects + rescore_rows`)
    pub quant_rows_screened: u64,
    pub rescore_rows: u64,
    pub bound_rejects: u64,
    /// is the Gaussian-score fast path enabled (config echo)
    pub gauss: bool,
    /// Gaussian-tier telemetry: sequence-ticks served closed-form, and the
    /// coarse screens (with their refines) those ticks made unnecessary.
    /// Engine-folded from the denoiser — the retrieval backend never sees
    /// a Gaussian tick, so `record_backend` must leave these alone.
    pub gauss_ticks: u64,
    pub screens_skipped: u64,
    /// few-step telemetry (engine-folded like the gauss counters):
    /// corrector score evaluations run by a higher-order solver, the
    /// subset of them that re-used the predictor tick's golden pool
    /// instead of paying a second coarse screen, and sequence-ticks
    /// executed under a budgeted step plan (0 on the full grid)
    pub corrector_refines: u64,
    pub screens_reused: u64,
    pub ticks_placed: u64,
    /// optional tiers that stood down at store load ("quant", "ivf",
    /// "shard_ivf") because their sections were corrupt — the `health` op
    /// reports `degraded` while this is non-empty
    pub degraded_tiers: Vec<String>,
    /// checksum mismatches seen while loading the store (optional
    /// sections; required-section mismatches fail the start instead)
    pub checksum_failures_load: u64,
    /// checksum mismatches on streamed reads (each retried; persistent
    /// corruption fails the request, never serves rows)
    pub checksum_failures: u64,
    /// transient streamed-read failures recovered by the bounded retry
    pub retries: u64,
    /// faults the deterministic injector put into streamed reads
    pub faults_injected: u64,
    /// remote-tier telemetry: ops answered by shard workers, worker
    /// round-trips retried on transients, and workers whose retry budget
    /// was exhausted (the remote tier stands down — `degraded_tiers`
    /// gains `"remote"` — and serving continues in-process)
    pub remote_ops: u64,
    pub remote_retries: u64,
    pub workers_lost: u64,
    /// requests dropped because their deadline expired — at dequeue, or
    /// between tick groups mid-trajectory
    pub deadline_expired: u64,
    /// panicking request groups caught by the worker's `catch_unwind`
    /// (each answered `"error":"internal"`; the engine keeps serving)
    pub panics_recovered: u64,
}

impl Default for EngineStats {
    fn default() -> Self {
        EngineStats {
            started_at: Instant::now(),
            submitted: 0,
            completed: 0,
            rejected: 0,
            steps_executed: 0,
            latency: TimingStats::new(),
            queue_delay: TimingStats::new(),
            scan_time: TimingStats::new(),
            dispatch_time: TimingStats::new(),
            tick_time: TimingStats::new(),
            retrieval_time: TimingStats::new(),
            step_time: TimingStats::new(),
            backend: String::new(),
            solver: "ddim".to_string(),
            proxy_passes: 0,
            retrieval_queries: 0,
            rows_scanned: 0,
            clusters_scanned: 0,
            clusters_pruned: 0,
            tiles_evaluated: 0,
            kernel_exits: 0,
            refine_rows: 0,
            blocks_reordered: 0,
            exit_gain_rows: 0,
            shards: 1,
            shards_scanned: 0,
            shards_skipped: 0,
            shard_evictions: 0,
            resident: true,
            rows_streamed: 0,
            peak_row_bytes: 0,
            quant: false,
            quant_rows_screened: 0,
            rescore_rows: 0,
            bound_rejects: 0,
            gauss: false,
            gauss_ticks: 0,
            screens_skipped: 0,
            corrector_refines: 0,
            screens_reused: 0,
            ticks_placed: 0,
            degraded_tiers: Vec::new(),
            checksum_failures_load: 0,
            checksum_failures: 0,
            retries: 0,
            faults_injected: 0,
            remote_ops: 0,
            remote_retries: 0,
            workers_lost: 0,
            deadline_expired: 0,
            panics_recovered: 0,
        }
    }
}

impl EngineStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn throughput_rps(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.completed as f64 / secs
        }
    }

    pub fn steps_per_sec(&self) -> f64 {
        let secs = self.started_at.elapsed().as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.steps_executed as f64 / secs
        }
    }

    /// Record a backend telemetry snapshot (cumulative counters).
    pub fn record_backend(&mut self, snap: crate::index::backend::RetrievalStats) {
        self.proxy_passes = snap.proxy_passes;
        self.retrieval_queries = snap.queries;
        self.rows_scanned = snap.rows_scanned;
        self.clusters_scanned = snap.clusters_scanned;
        self.clusters_pruned = snap.clusters_pruned;
        self.tiles_evaluated = snap.tiles_evaluated;
        self.kernel_exits = snap.kernel_exits;
        self.refine_rows = snap.refine_rows;
        self.blocks_reordered = snap.blocks_reordered;
        self.exit_gain_rows = snap.exit_gain_rows;
        self.shards_scanned = snap.shards_scanned;
        self.shards_skipped = snap.shards_skipped;
        self.shard_evictions = snap.shard_evictions;
        self.rows_streamed = snap.rows_streamed;
        self.peak_row_bytes = snap.peak_row_bytes;
        self.quant_rows_screened = snap.quant_rows_screened;
        self.rescore_rows = snap.rescore_rows;
        self.bound_rejects = snap.bound_rejects;
        self.retries = snap.retries;
        self.checksum_failures = self.checksum_failures_load + snap.checksum_failures;
        self.faults_injected = snap.faults_injected;
        self.remote_ops = snap.remote_ops;
        self.remote_retries = snap.remote_retries;
        self.workers_lost = snap.workers_lost;
        // `snap.gauss_ticks` / `snap.screens_skipped` — and the few-step
        // counters `corrector_refines` / `screens_reused` / `ticks_placed`
        // — are deliberately NOT assigned: backend snapshots always report
        // 0 for them (the backend never sees those ticks as such) and the
        // engine folds the real counts in directly — assigning here would
        // zero them every tick
        // a lost worker degrades the remote tier exactly like a corrupt
        // optional section degrades quant/ivf at load: serving continues
        // (in-process), `health` reports it until restart
        if snap.workers_lost > 0 && !self.degraded_tiers.iter().any(|t| t == "remote") {
            self.degraded_tiers.push("remote".to_string());
        }
    }

    /// Record the row source's residency snapshot — the authoritative
    /// out-of-core counters for a streamed corpus (`None` = resident, a
    /// no-op so backend-layer numbers stand). Runs after `record_backend`
    /// in the engine loop, so these assignments win for monolithic
    /// streamed backends whose cache stats carry no source counters.
    pub fn record_source(&mut self, snap: Option<crate::data::rows::RowSourceStats>) {
        if let Some(s) = snap {
            self.rows_streamed = s.rows_streamed;
            self.peak_row_bytes = s.peak_row_bytes;
            self.retries = s.retries;
            self.checksum_failures = self.checksum_failures_load + s.checksum_failures;
            self.faults_injected = s.faults_injected;
        }
    }

    /// The `{"op":"health"}` payload: `ok` while every tier runs at full
    /// fidelity, `degraded` when optional tiers stood down at load —
    /// serving continues either way (on the exact f32 path), which is the
    /// point: degradation is a telemetry state, not an outage.
    pub fn health_json(&self) -> Json {
        let mut j = Json::obj();
        let status = if self.degraded_tiers.is_empty() {
            "ok"
        } else {
            "degraded"
        };
        j.set("status", status)
            .set(
                "degraded_tiers",
                Json::Arr(
                    self.degraded_tiers
                        .iter()
                        .map(|t| Json::Str(t.clone()))
                        .collect(),
                ),
            )
            .set("checksum_failures", self.checksum_failures as usize)
            .set("retries", self.retries as usize)
            .set("workers_lost", self.workers_lost as usize)
            .set("remote_retries", self.remote_retries as usize)
            .set("deadline_expired", self.deadline_expired as usize)
            .set("panics_recovered", self.panics_recovered as usize)
            // a degraded gauss tier shows up both in `degraded_tiers` and
            // as a tick count pinned at 0 while the switch wanted ticks
            .set("gauss_ticks", self.gauss_ticks as usize)
            .set("screens_skipped", self.screens_skipped as usize)
            // the few-step fold rides along: a reuse count pinned at 0
            // under a higher-order solver means the corrector is paying
            // full screens — worth an operator's look
            .set("corrector_refines", self.corrector_refines as usize)
            .set("screens_reused", self.screens_reused as usize)
            .set("ticks_placed", self.ticks_placed as usize);
        j
    }

    /// Proxy rows evaluated per full table traversal (≈ n for a batched
    /// group — each row-block load serves the whole query tile — while the
    /// flat backend pays n rows per query).
    pub fn rows_per_pass(&self) -> f64 {
        if self.proxy_passes == 0 {
            0.0
        } else {
            self.rows_scanned as f64 / self.proxy_passes as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("submitted", self.submitted)
            .set("completed", self.completed)
            .set("rejected", self.rejected)
            .set("steps_executed", self.steps_executed)
            .set("throughput_rps", self.throughput_rps())
            .set("steps_per_sec", self.steps_per_sec())
            .set("latency_p50_s", self.latency.percentile(0.5))
            .set("latency_p95_s", self.latency.percentile(0.95))
            .set("latency_p99_s", self.latency.percentile(0.99))
            .set("latency_mean_s", self.latency.mean())
            .set("queue_p50_s", self.queue_delay.percentile(0.5))
            .set("scan_mean_s", self.scan_time.mean())
            // per-stage percentiles (scan = coarse screen + exact refine,
            // dispatch = the XLA aggregation, tick = one whole step) — the
            // serve bench reports these instead of means alone
            .set("scan_p50_s", self.scan_time.percentile(0.5))
            .set("scan_p95_s", self.scan_time.percentile(0.95))
            .set("scan_p99_s", self.scan_time.percentile(0.99))
            .set("dispatch_mean_s", self.dispatch_time.mean())
            .set("dispatch_p50_s", self.dispatch_time.percentile(0.5))
            .set("dispatch_p95_s", self.dispatch_time.percentile(0.95))
            .set("dispatch_p99_s", self.dispatch_time.percentile(0.99))
            .set("tick_p50_s", self.tick_time.percentile(0.5))
            .set("tick_p95_s", self.tick_time.percentile(0.95))
            .set("tick_p99_s", self.tick_time.percentile(0.99))
            // whole solver steps (predictor + corrector), labelled by the
            // active solver so serve benches can compare ddim/heun/dpm2
            .set("step_p50_s", self.step_time.percentile(0.5))
            .set("step_p95_s", self.step_time.percentile(0.95))
            .set("step_p99_s", self.step_time.percentile(0.99))
            .set("solver", self.solver.as_str())
            .set("retrieval_mean_s", self.retrieval_time.mean())
            .set("retrieval_backend", self.backend.as_str())
            .set("proxy_passes", self.proxy_passes as usize)
            .set("retrieval_queries", self.retrieval_queries as usize)
            .set("rows_scanned", self.rows_scanned as usize)
            .set("rows_per_pass", self.rows_per_pass())
            .set("clusters_scanned", self.clusters_scanned as usize)
            .set("clusters_pruned", self.clusters_pruned as usize)
            .set("tiles_evaluated", self.tiles_evaluated as usize)
            .set("kernel_exits", self.kernel_exits as usize)
            .set("refine_rows", self.refine_rows as usize)
            .set("blocks_reordered", self.blocks_reordered as usize)
            .set("exit_gain_rows", self.exit_gain_rows as usize)
            .set("shards", self.shards)
            .set("shards_scanned", self.shards_scanned as usize)
            .set("shards_skipped", self.shards_skipped as usize)
            .set("shard_evictions", self.shard_evictions as usize)
            .set("resident", self.resident)
            .set("rows_streamed", self.rows_streamed as usize)
            .set("peak_row_bytes", self.peak_row_bytes as usize)
            .set("quant", self.quant)
            .set("quant_rows_screened", self.quant_rows_screened as usize)
            .set("rescore_rows", self.rescore_rows as usize)
            .set("bound_rejects", self.bound_rejects as usize)
            .set("gauss", self.gauss)
            .set("gauss_ticks", self.gauss_ticks as usize)
            .set("screens_skipped", self.screens_skipped as usize)
            // few-step telemetry: corrector refines, the ones that reused
            // the predictor's golden pool, and budget-placed ticks
            .set("corrector_refines", self.corrector_refines as usize)
            .set("screens_reused", self.screens_reused as usize)
            .set("ticks_placed", self.ticks_placed as usize)
            .set(
                "degraded_tiers",
                Json::Arr(
                    self.degraded_tiers
                        .iter()
                        .map(|t| Json::Str(t.clone()))
                        .collect(),
                ),
            )
            .set("checksum_failures", self.checksum_failures as usize)
            .set("retries", self.retries as usize)
            .set("faults_injected", self.faults_injected as usize)
            .set("remote_ops", self.remote_ops as usize)
            .set("remote_retries", self.remote_retries as usize)
            .set("workers_lost", self.workers_lost as usize)
            .set("deadline_expired", self.deadline_expired as usize)
            .set("panics_recovered", self.panics_recovered as usize);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_summary_has_all_fields() {
        let mut s = EngineStats::new();
        s.submitted = 10;
        s.completed = 8;
        s.latency.record_secs(0.5);
        s.latency.record_secs(1.5);
        let j = s.to_json();
        assert_eq!(j.get("completed").unwrap().as_f64(), Some(8.0));
        assert!(j.get("latency_p50_s").unwrap().as_f64().unwrap() >= 0.5);
        assert!(j.get("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("retrieval_backend").is_some());
        assert_eq!(j.get("proxy_passes").unwrap().as_f64(), Some(0.0));
        // shard telemetry is always present (the server's `stats` op
        // forwards this json verbatim, so operators see it without a
        // debugger even on a monolithic engine)
        assert_eq!(j.get("shards").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("shards_scanned").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("shards_skipped").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("shard_evictions").unwrap().as_f64(), Some(0.0));
        // out-of-core telemetry is always present too
        assert_eq!(j.get("resident").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("rows_streamed").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("peak_row_bytes").unwrap().as_f64(), Some(0.0));
        // quantised-tier telemetry is always present (zero when off)
        assert_eq!(j.get("quant").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("quant_rows_screened").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("rescore_rows").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("bound_rejects").unwrap().as_f64(), Some(0.0));
        // gaussian-tier telemetry is always present (zero when off)
        assert_eq!(j.get("gauss").unwrap().as_bool(), Some(false));
        assert_eq!(j.get("gauss_ticks").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("screens_skipped").unwrap().as_f64(), Some(0.0));
        // few-step telemetry is always present (zero under plain ddim)
        assert_eq!(j.get("solver").unwrap().as_str(), Some("ddim"));
        assert_eq!(j.get("corrector_refines").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("screens_reused").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("ticks_placed").unwrap().as_f64(), Some(0.0));
        // per-stage percentiles ride alongside the means
        for key in [
            "latency_p99_s",
            "scan_p50_s",
            "scan_p95_s",
            "scan_p99_s",
            "dispatch_p50_s",
            "dispatch_p95_s",
            "dispatch_p99_s",
            "tick_p50_s",
            "tick_p95_s",
            "tick_p99_s",
            "step_p50_s",
            "step_p95_s",
            "step_p99_s",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // fault-tolerance telemetry is always present (zero when clean)
        assert_eq!(j.get("checksum_failures").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("retries").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("faults_injected").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("deadline_expired").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("panics_recovered").unwrap().as_f64(), Some(0.0));
        // remote-tier telemetry is always present (zero on a single node)
        assert_eq!(j.get("remote_ops").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("remote_retries").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("workers_lost").unwrap().as_f64(), Some(0.0));
        assert_eq!(
            j.get("degraded_tiers").unwrap().as_arr().unwrap().len(),
            0,
            "clean load degrades nothing"
        );
    }

    #[test]
    fn health_json_reflects_degraded_tiers() {
        let mut s = EngineStats::new();
        let h = s.health_json();
        assert_eq!(h.get("status").and_then(Json::as_str), Some("ok"));
        s.degraded_tiers = vec!["quant".to_string()];
        s.checksum_failures_load = 1;
        s.checksum_failures = 1;
        s.deadline_expired = 2;
        s.panics_recovered = 1;
        let h = s.health_json();
        assert_eq!(h.get("status").and_then(Json::as_str), Some("degraded"));
        let tiers = h.get("degraded_tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].as_str(), Some("quant"));
        assert_eq!(h.get("checksum_failures").unwrap().as_f64(), Some(1.0));
        assert_eq!(h.get("deadline_expired").unwrap().as_f64(), Some(2.0));
        assert_eq!(h.get("panics_recovered").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn backend_snapshot_is_reflected() {
        let mut s = EngineStats::new();
        s.backend = "cluster".into();
        s.shards = 4;
        s.record_backend(crate::index::backend::RetrievalStats {
            proxy_passes: 4,
            queries: 12,
            rows_scanned: 1000,
            clusters_scanned: 40,
            clusters_pruned: 24,
            tiles_evaluated: 96,
            kernel_exits: 7,
            refine_rows: 320,
            blocks_reordered: 18,
            exit_gain_rows: 224,
            shards_scanned: 44,
            shards_skipped: 4,
            shard_evictions: 2,
            rows_streamed: 880,
            peak_row_bytes: 4096,
            quant_rows_screened: 512,
            rescore_rows: 64,
            bound_rejects: 448,
            retries: 3,
            checksum_failures: 1,
            faults_injected: 5,
            remote_ops: 30,
            remote_retries: 2,
            workers_lost: 0,
            gauss_ticks: 0,
            screens_skipped: 0,
            corrector_refines: 0,
            screens_reused: 0,
            ticks_placed: 0,
        });
        let j = s.to_json();
        assert_eq!(j.get("clusters_pruned").unwrap().as_f64(), Some(24.0));
        assert_eq!(j.get("retrieval_queries").unwrap().as_f64(), Some(12.0));
        assert_eq!(j.get("tiles_evaluated").unwrap().as_f64(), Some(96.0));
        assert_eq!(j.get("kernel_exits").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.get("refine_rows").unwrap().as_f64(), Some(320.0));
        assert_eq!(j.get("blocks_reordered").unwrap().as_f64(), Some(18.0));
        assert_eq!(j.get("exit_gain_rows").unwrap().as_f64(), Some(224.0));
        assert_eq!(j.get("rows_per_pass").unwrap().as_f64(), Some(250.0));
        assert_eq!(j.get("shards").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("shards_scanned").unwrap().as_f64(), Some(44.0));
        assert_eq!(j.get("shards_skipped").unwrap().as_f64(), Some(4.0));
        assert_eq!(j.get("shard_evictions").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("rows_streamed").unwrap().as_f64(), Some(880.0));
        assert_eq!(j.get("peak_row_bytes").unwrap().as_f64(), Some(4096.0));
        assert_eq!(j.get("quant_rows_screened").unwrap().as_f64(), Some(512.0));
        assert_eq!(j.get("rescore_rows").unwrap().as_f64(), Some(64.0));
        assert_eq!(j.get("bound_rejects").unwrap().as_f64(), Some(448.0));
        assert_eq!(j.get("retries").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("checksum_failures").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("faults_injected").unwrap().as_f64(), Some(5.0));
        assert_eq!(j.get("remote_ops").unwrap().as_f64(), Some(30.0));
        assert_eq!(j.get("remote_retries").unwrap().as_f64(), Some(2.0));
        assert!(
            s.degraded_tiers.is_empty(),
            "healthy workers degrade nothing"
        );
        // engine-folded gauss counters survive backend snapshots (which
        // always carry 0 for them — the backend never sees a gauss tick)
        s.gauss_ticks = 5;
        s.screens_skipped = 5;
        s.corrector_refines = 9;
        s.screens_reused = 8;
        s.ticks_placed = 4;
        s.record_backend(crate::index::backend::RetrievalStats::default());
        assert_eq!(s.gauss_ticks, 5, "record_backend must not zero the fold");
        assert_eq!(s.screens_skipped, 5);
        assert_eq!(s.corrector_refines, 9, "few-step fold survives too");
        assert_eq!(s.screens_reused, 8);
        assert_eq!(s.ticks_placed, 4);
        let jg = s.to_json();
        assert_eq!(jg.get("gauss_ticks").unwrap().as_f64(), Some(5.0));
        assert_eq!(jg.get("screens_skipped").unwrap().as_f64(), Some(5.0));
        assert_eq!(jg.get("corrector_refines").unwrap().as_f64(), Some(9.0));
        assert_eq!(jg.get("screens_reused").unwrap().as_f64(), Some(8.0));
        assert_eq!(jg.get("ticks_placed").unwrap().as_f64(), Some(4.0));
        let hg = s.health_json();
        assert_eq!(hg.get("gauss_ticks").unwrap().as_f64(), Some(5.0));
        assert_eq!(hg.get("screens_skipped").unwrap().as_f64(), Some(5.0));
        assert_eq!(hg.get("corrector_refines").unwrap().as_f64(), Some(9.0));
        assert_eq!(hg.get("screens_reused").unwrap().as_f64(), Some(8.0));
        // exhausting a worker's retry budget degrades the remote tier —
        // once, idempotently across later snapshots
        s.record_backend(crate::index::backend::RetrievalStats {
            workers_lost: 1,
            ..Default::default()
        });
        s.record_backend(crate::index::backend::RetrievalStats {
            workers_lost: 1,
            ..Default::default()
        });
        assert_eq!(s.workers_lost, 1);
        assert_eq!(
            s.degraded_tiers.iter().filter(|t| *t == "remote").count(),
            1,
            "remote degradation is recorded once"
        );
        let h = s.health_json();
        assert_eq!(h.get("status").and_then(Json::as_str), Some("degraded"));
        assert_eq!(h.get("workers_lost").unwrap().as_f64(), Some(1.0));
        // the source snapshot overrides the backend copy when streamed
        s.record_source(Some(crate::data::rows::RowSourceStats {
            rows_streamed: 1000,
            peak_row_bytes: 9000,
            retries: 4,
            checksum_failures: 2,
            faults_injected: 6,
            ..Default::default()
        }));
        assert_eq!(s.rows_streamed, 1000);
        assert_eq!(s.retries, 4, "source counters are authoritative");
        assert_eq!(s.faults_injected, 6);
        assert_eq!(s.checksum_failures, 2);
        s.record_source(None);
        assert_eq!(s.rows_streamed, 1000, "resident snapshot is a no-op");
        // load-time failures add on top of streamed-read failures
        s.checksum_failures_load = 3;
        s.record_source(Some(crate::data::rows::RowSourceStats {
            checksum_failures: 2,
            ..Default::default()
        }));
        assert_eq!(s.checksum_failures, 5, "load + streamed totals");
        assert_eq!(
            j.get("retrieval_backend").unwrap().as_str(),
            Some("cluster")
        );
    }
}
