//! The dispatch batcher: groups live sequences by (method, k-bucket) for a
//! scheduler tick.
//!
//! All sequences in a group share a compiled executable, so the executor
//! runs them back-to-back while the executable (and its tiles) stay hot —
//! and the coarse scans for the whole group run concurrently on the scan
//! pool before any dispatch happens (scan/dispatch phase separation). The
//! invariant tested below is the one the engine relies on: a group never
//! mixes buckets or methods, and every sequence appears in exactly one
//! group per tick.

use crate::denoiser::DenoiserKind;

/// Minimal view of a live sequence the batcher needs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeqKey {
    pub seq: usize,
    pub method: DenoiserKind,
    /// sampling-point index this tick executes
    pub step: usize,
    /// padded aggregation bucket for this step
    pub k_bucket: usize,
}

/// One dispatch group of a tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Group {
    pub method: DenoiserKind,
    pub step: usize,
    pub k_bucket: usize,
    pub seqs: Vec<usize>,
}

/// Group sequences by (method, step, k_bucket); groups are ordered largest
/// bucket first ("prefill-like" work before "decode-like", so early-phase
/// requests do not starve behind a long tail of cheap late steps).
pub fn group_tick(seqs: &[SeqKey]) -> Vec<Group> {
    let mut groups: Vec<Group> = Vec::new();
    for s in seqs {
        match groups.iter_mut().find(|g| {
            g.method == s.method && g.step == s.step && g.k_bucket == s.k_bucket
        }) {
            Some(g) => g.seqs.push(s.seq),
            None => groups.push(Group {
                method: s.method,
                step: s.step,
                k_bucket: s.k_bucket,
                seqs: vec![s.seq],
            }),
        }
    }
    groups.sort_by(|a, b| b.k_bucket.cmp(&a.k_bucket).then(a.step.cmp(&b.step)));
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};

    #[test]
    fn groups_never_mix_and_cover_everything() {
        forall(29, 200, |rng| {
            let n = gen::usize_in(rng, 0, 64);
            let methods = [
                DenoiserKind::GoldDiff,
                DenoiserKind::Optimal,
                DenoiserKind::Pca,
            ];
            let seqs: Vec<SeqKey> = (0..n)
                .map(|i| SeqKey {
                    seq: i,
                    method: methods[rng.below(3)],
                    step: gen::usize_in(rng, 0, 9),
                    k_bucket: gen::pow2_in(rng, 32, 8192),
                })
                .collect();
            let groups = group_tick(&seqs);
            let mut seen = vec![false; n];
            for g in &groups {
                for &sid in &g.seqs {
                    crate::prop_assert!(!seen[sid], "seq {sid} in two groups");
                    seen[sid] = true;
                    let key = &seqs[sid];
                    crate::prop_assert!(
                        key.method == g.method
                            && key.step == g.step
                            && key.k_bucket == g.k_bucket,
                        "seq {sid} grouped under wrong key"
                    );
                }
            }
            crate::prop_assert!(seen.iter().all(|&s| s), "sequence dropped");
            Ok(())
        });
    }

    #[test]
    fn big_buckets_dispatch_first() {
        let seqs = vec![
            SeqKey { seq: 0, method: DenoiserKind::GoldDiff, step: 9, k_bucket: 32 },
            SeqKey { seq: 1, method: DenoiserKind::GoldDiff, step: 0, k_bucket: 2048 },
            SeqKey { seq: 2, method: DenoiserKind::GoldDiff, step: 9, k_bucket: 32 },
        ];
        let groups = group_tick(&seqs);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].k_bucket, 2048);
        assert_eq!(groups[1].seqs, vec![0, 2]);
    }

    #[test]
    fn empty_input_is_empty() {
        assert!(group_tick(&[]).is_empty());
    }
}
