//! The XLA-artifact-backed denoiser: every paper method dispatched through
//! the PJRT executables that `python/compile/aot.py` lowered, so the bench
//! timing comparisons share one compute substrate.
//!
//! Hot-path split per DESIGN.md:
//!   rust (L3): budget schedule → coarse retrieval backend → exact refine →
//!              gather + pad the golden subset            (retrieval)
//!   XLA (L2/L1): logits + streaming-softmax aggregation + DDIM update
//!              (`golden_step` / `pca_step_*` / `kamb_step` / `wiener_step`)
//!
//! Retrieval goes through the pluggable `index::backend::RetrievalBackend`
//! the engine shares across its denoisers; [`XlaDenoiser::step_group`] runs
//! **one** batched coarse retrieval for a whole batcher group before any
//! dispatch happens, so a tick of B GoldDiff sequences pays a single
//! proxy-table pass (with the batched backend) instead of B. Since the
//! kernel refactor that pass runs as register tiles over the dataset's
//! structure-of-arrays proxy blocks (`index::kernel`), and the exact refine
//! behind `blended_golden_rows_batch` is the batched ladder: the group's
//! candidate-pool union is scanned once, with one bounded heap per
//! sequence, instead of one refine pass per sequence.
//!
//! Full-scan methods (Optimal / PCA / Kamb baselines) keep their padded
//! candidate matrix *device-resident* (uploaded once, reused every step) —
//! without that, the baselines would be benchmarked on memcpy instead of
//! compute. GoldDiff uploads only its k_t-bucket gather each step, which is
//! exactly the paper's complexity story.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::dataset::Dataset;
use crate::data::gauss::GaussMoments;
use crate::denoiser::golddiff::{
    blended_golden_rows_batch_warm, corrector_golden_rows_batch, WarmStart,
};
use crate::denoiser::{DenoiseResult, Denoiser, DenoiserKind, PosteriorStats, StepContext};
use crate::index::backend::{BackendOpts, RetrievalBackend, RetrievalBackendKind};
use crate::runtime::{DeviceTensor, Runtime, StepOutput};
use crate::schedule::budget::BudgetSchedule;

/// Per-step retrieval/dispatch telemetry the engine scrapes after each call.
#[derive(Debug, Clone, Copy, Default)]
pub struct XlaStepTelemetry {
    pub k_bucket: usize,
    pub m_used: usize,
    pub k_used: usize,
    pub scan_secs: f64,
    pub dispatch_secs: f64,
    /// this step was served by the Gaussian moment tier (zero retrieval)
    pub gauss: bool,
}

pub struct XlaDenoiser {
    rt: Rc<Runtime>,
    pub kind: DenoiserKind,
    preset: String,
    budget: BudgetSchedule,
    /// pluggable coarse-retrieval backend (shared engine-wide)
    backend: Arc<dyn RetrievalBackend>,
    /// concentration warm-start: previous sampling point's golden subsets
    /// seed the next coarse screen (exact — see `golddiff::WarmStart`)
    warm_start: bool,
    warm: WarmStart,
    /// device-resident full-scan candidates (+ mask), lazily built
    resident_full: Option<(usize, Rc<DeviceTensor>, Rc<DeviceTensor>)>,
    /// device-resident Wiener stats
    resident_wiener: Option<(Rc<DeviceTensor>, Rc<DeviceTensor>)>,
    /// sampling points `0..gauss_switch` are served closed-form from the
    /// corpus moment tier (`denoiser::gaussian`) — 0 disables the tier;
    /// stands down per tick when the dataset carries no moments
    gauss_switch: usize,
    /// bound-driven per-class switching: when set, each tick resolves its
    /// own switch point from the class moment spread at this tolerance
    /// (overrides the fixed `gauss_switch`)
    gauss_tol: Option<f64>,
    /// device-resident per-class Gaussian moment tensors, reusing the
    /// `wiener_step` executable (uploaded once per class, like
    /// `resident_wiener` — the tier's steady state uploads only x_t)
    resident_gauss: HashMap<Option<u32>, (Rc<DeviceTensor>, Rc<DeviceTensor>)>,
    /// per-sequence posterior means of the newest Gaussian tick, pending
    /// the warm handoff into the first retrieval tick's screen
    gauss_handoff: Option<Vec<Vec<f32>>>,
    /// sequence-ticks served by the Gaussian tier (drained by the engine)
    pub gauss_ticks: u64,
    /// coarse screens (and their refines) the tier made unnecessary
    pub screens_skipped: u64,
    /// the last tick group's golden-subset union, offered to a
    /// higher-order solver's corrector pass then consumed
    reuse_pool: Vec<u32>,
    /// corrector sequence-evals served through retrieval (drained by the
    /// engine, like the gauss counters)
    pub corrector_refines: u64,
    /// corrector evals that rode the predictor's pool — masked refine
    /// only, no coarse screen
    pub screens_reused: u64,
    /// gather scratch (kept across calls — zero-alloc steady state)
    gather_buf: Vec<f32>,
    mask_buf: Vec<f32>,
    pub telemetry: XlaStepTelemetry,
}

impl XlaDenoiser {
    pub fn new(rt: Rc<Runtime>, ds: &Dataset, kind: DenoiserKind) -> Result<XlaDenoiser> {
        let buckets = rt.manifest.buckets("golden_step", &ds.name);
        anyhow::ensure!(
            !buckets.is_empty(),
            "no golden_step artifacts for preset {} — rerun `make artifacts`",
            ds.name
        );
        // env-sensitive defaults: the CI scalar leg flips GOLDDIFF_KERNEL,
        // the sharded leg flips GOLDDIFF_SHARDS — both route every
        // default-constructed denoiser through the matching path. The
        // engine normally replaces this with its shared backend.
        let kernel = crate::config::env_flag("GOLDDIFF_KERNEL", true);
        let opts = BackendOpts {
            kernel,
            refine_kernel: kernel,
            quant: crate::config::env_flag("GOLDDIFF_QUANT", false),
            simd: crate::config::env_flag("GOLDDIFF_SIMD", true),
            shards: crate::config::env_usize("GOLDDIFF_SHARDS", 1),
            ..BackendOpts::default()
        };
        let backend: Arc<dyn RetrievalBackend> = RetrievalBackendKind::Flat.build(ds, opts);
        Ok(XlaDenoiser {
            rt,
            kind,
            preset: ds.name.clone(),
            budget: BudgetSchedule::paper_defaults(ds.n, &buckets),
            backend,
            warm_start: crate::config::env_flag("GOLDDIFF_WARM_START", true),
            warm: WarmStart::new(),
            resident_full: None,
            resident_wiener: None,
            gauss_switch: 0,
            gauss_tol: None,
            resident_gauss: HashMap::new(),
            gauss_handoff: None,
            gauss_ticks: 0,
            screens_skipped: 0,
            reuse_pool: Vec::new(),
            corrector_refines: 0,
            screens_reused: 0,
            gather_buf: Vec::new(),
            mask_buf: Vec::new(),
            telemetry: XlaStepTelemetry::default(),
        })
    }

    /// Override the budget schedule (hyperparameter sweeps, Fig. 6).
    pub fn with_budget(mut self, budget: BudgetSchedule) -> Self {
        self.budget = budget;
        self
    }

    /// Swap the coarse-retrieval backend (the engine shares one instance
    /// across all its denoisers so telemetry aggregates in one place).
    pub fn with_retrieval(mut self, backend: Arc<dyn RetrievalBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// Toggle the concentration warm-start (`EngineConfig::warm_start`).
    /// Exactness is preserved either way.
    pub fn with_warm_start(mut self, on: bool) -> Self {
        self.warm_start = on;
        self
    }

    /// Serve the first `switch` sampling points of GoldDiff trajectories
    /// from the Gaussian moment tier (0 = off). Gaussian ticks never
    /// consult the retrieval backend, so the retrieval segment from
    /// `switch` onward is byte-identical to a run with the tier off.
    pub fn with_gauss(mut self, switch: usize) -> Self {
        self.gauss_switch = switch;
        self
    }

    /// Bound-driven per-class Gaussian switching: each tick resolves its
    /// own switch point from the error bound at this tolerance, using the
    /// **class** moment spread for conditional sequences
    /// (`GaussMoments::spread_for`) — tighter classes hand off later.
    /// Overrides any fixed `with_gauss` prefix.
    pub fn with_gauss_auto(mut self, tol: f64) -> Self {
        self.gauss_tol = Some(tol);
        self
    }

    /// Drain the Gaussian-tier counters — the engine folds them into
    /// `EngineStats` after every tick group (the backend snapshot knows
    /// nothing about ticks the backend never saw).
    pub fn take_gauss_counts(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.gauss_ticks),
            std::mem::take(&mut self.screens_skipped),
        )
    }

    /// Drain the few-step solver counters (corrector evals, pool reuses)
    /// — same engine-folded discipline as the gauss counters.
    pub fn take_fewstep_counts(&mut self) -> (u64, u64) {
        (
            std::mem::take(&mut self.corrector_refines),
            std::mem::take(&mut self.screens_reused),
        )
    }

    /// Whether this tick falls in its Gaussian prefix AND the dataset's
    /// moment tier is available to serve it (a corrupt or absent tier
    /// stands the fast path down to full retrieval, never to an error).
    /// With `gauss_tol` set the prefix is resolved per class.
    fn gauss_serves<'a>(&self, ctx: &StepContext<'a>) -> Option<&'a GaussMoments> {
        if !self.is_golddiff() {
            return None;
        }
        match self.gauss_tol {
            // fixed prefix: never touch the (lazily built) moment tier
            // unless the tier is actually on
            None if ctx.step < self.gauss_switch => ctx.ds.gauss_moments(),
            None => None,
            Some(tol) => {
                let gm = ctx.ds.gauss_moments()?;
                let switch = crate::denoiser::gaussian::resolve_switch_for(
                    crate::denoiser::gaussian::GaussSwitch::Auto,
                    ctx.sched,
                    gm,
                    tol,
                    ctx.class,
                );
                (ctx.step < switch).then_some(gm)
            }
        }
    }

    /// One Gaussian tick: the closed-form moment score through the
    /// `wiener_step` executable, with the class (or global) moment
    /// tensors lazily pinned device-resident. Zero screens, zero refines.
    fn gauss_dispatch(
        &mut self,
        x_t: &[f32],
        ctx: &StepContext,
        gm: &GaussMoments,
    ) -> Result<StepOutput> {
        let ds = ctx.ds;
        let preset = self.preset.clone();
        let t_disp = std::time::Instant::now();
        let alphas = self
            .rt
            .upload(&[ctx.alpha_bar(), ctx.sched.alpha_prev(ctx.step)], &[2])?;
        let bx = self.rt.upload(x_t, &[ds.d])?;
        if !self.resident_gauss.contains_key(&ctx.class) {
            let (mean, var) = gm.moments_for(ctx.class);
            let pair = (
                Rc::new(self.rt.upload(mean, &[ds.d])?),
                Rc::new(self.rt.upload(var, &[ds.d])?),
            );
            self.resident_gauss.insert(ctx.class, pair);
        }
        let (mean, var) = self.resident_gauss.get(&ctx.class).unwrap();
        let (mean, var) = (Rc::clone(mean), Rc::clone(var));
        let out = self
            .rt
            .run_step(&format!("wiener_step__{preset}"), &[&bx, &mean, &var, &alphas])?;
        self.telemetry = XlaStepTelemetry {
            k_bucket: 0,
            m_used: 0,
            k_used: 0,
            scan_secs: 0.0,
            dispatch_secs: t_disp.elapsed().as_secs_f64(),
            gauss: true,
        };
        self.gauss_ticks += 1;
        self.screens_skipped += 1;
        Ok(out)
    }

    /// The gauss→retrieval handoff: seed the first retrieval tick's warm
    /// screen with the corpus neighbourhood of the Gaussian posterior
    /// means — the member rows of the k-means clusters nearest each mean,
    /// nearest cluster first, until the screen budget m is covered. Seeds
    /// are only ever an accelerator (the warm screen is exact and falls
    /// back cold when they cannot fill the heap), so this engages only
    /// over exact backends and never changes the retrieved subsets.
    fn maybe_warm_handoff(&mut self, ctx: &StepContext) {
        let Some(means) = self.gauss_handoff.take() else {
            return;
        };
        if !self.warm_start || !self.backend.is_exact() || ctx.step == 0 {
            return;
        }
        let ds = ctx.ds;
        let ncl = if ds.d > 0 { ds.centroids.len() / ds.d } else { 0 };
        if ncl == 0 {
            return;
        }
        let m = self.budget.at(ctx.sched, ctx.step).m;
        let mut cluster_rows: Vec<Vec<u32>> = vec![Vec::new(); ncl];
        for (row, &cl) in ds.assignments.iter().enumerate() {
            cluster_rows[cl as usize].push(row as u32);
        }
        let mut seeds: HashSet<u32> = HashSet::new();
        for q in &means {
            let mut order: Vec<usize> = (0..ncl).collect();
            let dist = |cl: usize| -> f32 {
                ds.centroids[cl * ds.d..(cl + 1) * ds.d]
                    .iter()
                    .zip(q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum()
            };
            order.sort_by(|&a, &b| dist(a).total_cmp(&dist(b)));
            let mut got = 0usize;
            for cl in order {
                got += cluster_rows[cl].len();
                seeds.extend(cluster_rows[cl].iter().copied());
                if got >= m {
                    break;
                }
            }
        }
        if !seeds.is_empty() {
            let seed_rows: Vec<u32> = seeds.into_iter().collect();
            self.warm.record(ctx.step - 1, &[seed_rows]);
        }
    }

    fn full_bucket(&self) -> usize {
        self.rt
            .manifest
            .preset(&self.preset)
            .map(|p| p.full_bucket)
            .unwrap_or(0)
    }

    /// Device-resident full candidate matrix (unconditional full scans).
    fn resident_full(
        &mut self,
        ds: &Dataset,
    ) -> Result<(usize, Rc<DeviceTensor>, Rc<DeviceTensor>)> {
        if self.resident_full.is_none() {
            let bucket = self.full_bucket();
            let mut data = vec![0.0f32; bucket * ds.d];
            // staged shard-at-a-time through the row source: a streamed
            // corpus fills the upload buffer off the LRU (budget-bounded
            // host residency beyond this one staging buffer) with the
            // exact bytes the resident copy would supply
            ds.copy_all_rows_into(&mut data[..ds.n * ds.d]);
            let mut mask = vec![0.0f32; bucket];
            mask[..ds.n].fill(1.0);
            let cand = Rc::new(self.rt.upload(&data, &[bucket, ds.d])?);
            let maskt = Rc::new(self.rt.upload(&mask, &[bucket])?);
            self.resident_full = Some((bucket, cand, maskt));
        }
        let (b, c, m) = self.resident_full.as_ref().unwrap();
        Ok((*b, Rc::clone(c), Rc::clone(m)))
    }

    /// Gather rows into a padded device tensor at `bucket`.
    fn upload_gather(
        &mut self,
        ds: &Dataset,
        rows: &[u32],
        bucket: usize,
    ) -> Result<(DeviceTensor, DeviceTensor)> {
        ds.gather_rows(rows, bucket, &mut self.gather_buf, &mut self.mask_buf);
        let cand = self.rt.upload(&self.gather_buf, &[bucket, ds.d])?;
        let mask = self.rt.upload(&self.mask_buf, &[bucket])?;
        Ok((cand, mask))
    }

    /// PCA basis tensors for the query's nearest cluster.
    fn upload_basis(&self, ds: &Dataset, q: &[f32]) -> Result<(DeviceTensor, DeviceTensor)> {
        let cluster = ds.nearest_cluster(q);
        let (basis, center) = ds.pca_basis(cluster);
        let r = basis.len() / ds.d;
        Ok((
            self.rt.upload(basis, &[r, ds.d])?,
            self.rt.upload(center, &[ds.d])?,
        ))
    }

    fn variant(&self) -> &'static str {
        match self.kind {
            DenoiserKind::Wiener => "wiener_step",
            DenoiserKind::Optimal | DenoiserKind::GoldDiff => "golden_step",
            DenoiserKind::Pca | DenoiserKind::GoldDiffWss => "pca_step_wss",
            DenoiserKind::PcaUnbiased | DenoiserKind::GoldDiffPca => "pca_step_ss",
            DenoiserKind::Kamb | DenoiserKind::GoldDiffKamb => "kamb_step",
        }
    }

    fn is_golddiff(&self) -> bool {
        matches!(
            self.kind,
            DenoiserKind::GoldDiff
                | DenoiserKind::GoldDiffPca
                | DenoiserKind::GoldDiffWss
                | DenoiserKind::GoldDiffKamb
        )
    }

    /// Bucket a retrieved row set for the compiled ladder and record the
    /// retrieval telemetry.
    fn bucket_plan(
        &mut self,
        mut rows: Vec<u32>,
        m: usize,
        k: usize,
    ) -> Result<(Vec<u32>, usize)> {
        let variant = self.variant();
        let bucket = self
            .rt
            .manifest
            .bucket_for(variant, &self.preset, rows.len())
            .with_context(|| format!("no {variant} bucket for {}", self.preset))?;
        rows.truncate(bucket); // kamb ladder may be coarser than k_t
        self.telemetry.m_used = m;
        self.telemetry.k_used = rows.len().min(k);
        Ok((rows, bucket))
    }

    /// The retrieval phase (L3) for one sequence: produces the candidate
    /// plan the dispatch phase uploads, or `None` for resident full scans.
    fn plan(&mut self, x_t: &[f32], ctx: &StepContext) -> Result<Option<(Vec<u32>, usize)>> {
        let ds = ctx.ds;
        if self.kind == DenoiserKind::Wiener {
            return Ok(None);
        }
        if self.is_golddiff() {
            let b = self.budget.at(ctx.sched, ctx.step);
            let warm = self.warm_start.then_some(&mut self.warm);
            let rows = blended_golden_rows_batch_warm(
                self.backend.as_ref(),
                &[ctx],
                &[x_t],
                b.m,
                b.k,
                ds.h,
                ds.w,
                ds.c,
                warm,
            )
            .pop()
            .unwrap_or_default();
            // stash this tick's golden subset for a higher-order solver's
            // corrector pass (consumed by `corrector_group`)
            let mut pool = rows.clone();
            pool.sort_unstable();
            pool.dedup();
            self.reuse_pool = pool;
            return Ok(Some(self.bucket_plan(rows, b.m, b.k)?));
        }
        if let Some(y) = ctx.class {
            // conditional full scan: the class shard is the support
            let rows = ds.class_rows[y as usize].clone();
            let bucket = self
                .rt
                .manifest
                .bucket_for(self.variant(), &self.preset, rows.len())
                .context("no bucket")?;
            self.telemetry.k_used = rows.len().min(bucket);
            return Ok(Some((rows, bucket)));
        }
        self.telemetry.k_used = ds.n;
        Ok(None) // resident full scan
    }

    /// The dispatch phase (L2/L1 via PJRT) for one sequence.
    fn dispatch(
        &mut self,
        x_t: &[f32],
        ctx: &StepContext,
        plan: Option<(Vec<u32>, usize)>,
    ) -> Result<StepOutput> {
        let ds = ctx.ds;
        let preset = self.preset.clone();
        let variant = self.variant();
        let t_disp = std::time::Instant::now();
        let alphas = self
            .rt
            .upload(&[ctx.alpha_bar(), ctx.sched.alpha_prev(ctx.step)], &[2])?;
        let bx = self.rt.upload(x_t, &[ds.d])?;

        let out = if self.kind == DenoiserKind::Wiener {
            if self.resident_wiener.is_none() {
                self.resident_wiener = Some((
                    Rc::new(self.rt.upload(&ds.mean, &[ds.d])?),
                    Rc::new(self.rt.upload(&ds.var, &[ds.d])?),
                ));
            }
            let (mean, var) = self.resident_wiener.as_ref().unwrap();
            let (mean, var) = (Rc::clone(mean), Rc::clone(var));
            self.rt
                .run_step(&format!("wiener_step__{preset}"), &[&bx, &mean, &var, &alphas])?
        } else {
            // candidate tensors: resident or fresh gather
            let (bucket, cand, mask): (usize, Rc<DeviceTensor>, Rc<DeviceTensor>) = match plan
            {
                None => self.resident_full(ds)?,
                Some((rows, bucket)) => {
                    let (c, m) = self.upload_gather(ds, &rows, bucket)?;
                    (bucket, Rc::new(c), Rc::new(m))
                }
            };
            self.telemetry.k_bucket = bucket;
            match variant {
                "kamb_step" => {
                    let p = if ctx.sched.g(ctx.step) > 0.5 { 7 } else { 3 };
                    let name = format!("kamb_step__{preset}__k{bucket}__p{p}");
                    self.rt.run_step(&name, &[&bx, &cand, &mask, &alphas])?
                }
                "pca_step_ss" | "pca_step_wss" => {
                    let q = crate::denoiser::descale(x_t, ctx.alpha_bar());
                    let (basis, center) = self.upload_basis(ds, &q)?;
                    let name = format!("{variant}__{preset}__k{bucket}");
                    self.rt
                        .run_step(&name, &[&bx, &cand, &mask, &basis, &center, &alphas])?
                }
                _ => {
                    let name = format!("golden_step__{preset}__k{bucket}");
                    self.rt.run_step(&name, &[&bx, &cand, &mask, &alphas])?
                }
            }
        };
        self.telemetry.dispatch_secs = t_disp.elapsed().as_secs_f64();
        Ok(out)
    }

    /// One full step dispatch: returns (x_prev, f_hat, stats) from the graph.
    pub fn step(&mut self, x_t: &[f32], ctx: &StepContext) -> Result<StepOutput> {
        if let Some(gm) = self.gauss_serves(ctx) {
            let out = self.gauss_dispatch(x_t, ctx, gm)?;
            self.gauss_handoff = Some(vec![out.f_hat.clone()]);
            return Ok(out);
        }
        self.maybe_warm_handoff(ctx);
        self.telemetry.gauss = false;
        let t_scan = std::time::Instant::now();
        let plan = self.plan(x_t, ctx)?;
        self.telemetry.scan_secs = t_scan.elapsed().as_secs_f64();
        self.dispatch(x_t, ctx, plan)
    }

    /// One scheduler-tick group: all sequences share (method, step,
    /// k-bucket), so GoldDiff methods run **one** batched coarse retrieval
    /// for the whole group before dispatching each sequence. Returns one
    /// (output, telemetry) pair per sequence; the group's retrieval time is
    /// amortised evenly over the per-sequence `scan_secs`.
    pub fn step_group(
        &mut self,
        xs: &[&[f32]],
        ctxs: &[&StepContext],
    ) -> Result<Vec<(StepOutput, XlaStepTelemetry)>> {
        assert_eq!(xs.len(), ctxs.len());
        if xs.len() <= 1 || !self.is_golddiff() {
            let mut outs = Vec::with_capacity(xs.len());
            for (x_t, ctx) in xs.iter().zip(ctxs) {
                let out = self.step(x_t, ctx)?;
                outs.push((out, self.telemetry));
            }
            return Ok(outs);
        }

        let ds = ctxs[0].ds;
        // gauss-served sequences are closed-form: zero coarse screens,
        // zero refines, no backend contact at all. With the per-class
        // bound (`with_gauss_auto`) a group sharing one sampling point can
        // straddle its classes' switch points, so partition rather than
        // gate the whole group.
        let served: Vec<bool> = ctxs.iter().map(|ctx| self.gauss_serves(ctx).is_some()).collect();
        let mut outs: Vec<Option<(StepOutput, XlaStepTelemetry)>> =
            (0..xs.len()).map(|_| None).collect();
        let mut means = Vec::new();
        for i in (0..xs.len()).filter(|&i| served[i]) {
            let gm = self
                .gauss_serves(ctxs[i])
                .expect("partitioned above; groups share one dataset");
            let out = self.gauss_dispatch(xs[i], ctxs[i], gm)?;
            means.push(out.f_hat.clone());
            outs[i] = Some((out, self.telemetry));
        }
        let retrieval: Vec<usize> = (0..xs.len()).filter(|&i| !served[i]).collect();
        if retrieval.is_empty() {
            if !means.is_empty() {
                self.gauss_handoff = Some(means);
            }
            return Ok(outs.into_iter().map(|o| o.unwrap()).collect());
        }
        // a handoff stashed by an earlier (gauss) tick seeds this tick's
        // warm screen; this tick's own gauss means (mixed group) are
        // stashed afterwards so they seed the *next* retrieval tick
        self.maybe_warm_handoff(ctxs[retrieval[0]]);
        if !means.is_empty() {
            self.gauss_handoff = Some(means);
        }
        self.telemetry.gauss = false;
        let t_scan = std::time::Instant::now();
        let b = self.budget.at(ctxs[0].sched, ctxs[0].step);
        let warm = self.warm_start.then_some(&mut self.warm);
        let r_xs: Vec<&[f32]> = retrieval.iter().map(|&i| xs[i]).collect();
        let r_ctxs: Vec<&StepContext> = retrieval.iter().map(|&i| ctxs[i]).collect();
        let rows_batch = blended_golden_rows_batch_warm(
            self.backend.as_ref(),
            &r_ctxs,
            &r_xs,
            b.m,
            b.k,
            ds.h,
            ds.w,
            ds.c,
            warm,
        );
        let scan_each = t_scan.elapsed().as_secs_f64() / retrieval.len() as f64;

        // stash the group's golden-subset union for a higher-order
        // solver's corrector pass (consumed by `corrector_group`)
        let mut pool: Vec<u32> = rows_batch.iter().flatten().copied().collect();
        pool.sort_unstable();
        pool.dedup();
        self.reuse_pool = pool;

        for (&i, rows) in retrieval.iter().zip(rows_batch) {
            let plan = self.bucket_plan(rows, b.m, b.k)?;
            self.telemetry.scan_secs = scan_each;
            let out = self.dispatch(xs[i], ctxs[i], Some(plan))?;
            outs[i] = Some((out, self.telemetry));
        }
        Ok(outs.into_iter().map(|o| o.unwrap()).collect())
    }

    /// The corrector pass of a higher-order solver tick
    /// (`sampler::Solver::{Heun, Dpm2}`): one batched **refine-only**
    /// retrieval over the predictor tick group's stashed golden-subset
    /// union — no coarse screen when the reuse engages — then the usual
    /// per-sequence bucket + dispatch. Returns each sequence's corrector
    /// f̂; the engine combines predictor and corrector slopes on the host
    /// (the compiled graph's x_prev only knows adjacent grid steps).
    ///
    /// All contexts must share one sampling point (the corrector point:
    /// the tick's target for Heun, the doubled-grid midpoint for Dpm2).
    /// Non-GoldDiff methods pay a full second evaluation — they have no
    /// screen to reuse.
    pub fn corrector_group(
        &mut self,
        xs: &[&[f32]],
        ctxs: &[&StepContext],
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(xs.len(), ctxs.len());
        if xs.is_empty() {
            return Ok(Vec::new());
        }
        if !self.is_golddiff() {
            let mut f_hats = Vec::with_capacity(xs.len());
            for (x_t, ctx) in xs.iter().zip(ctxs) {
                f_hats.push(self.step(x_t, ctx)?.f_hat);
            }
            return Ok(f_hats);
        }
        let ds = ctxs[0].ds;
        let b = self.budget.at(ctxs[0].sched, ctxs[0].step);
        // consume the predictor pool — a stale pool must never serve a
        // second corrector (empty → the exactness-preserving fallback)
        let pool = std::mem::take(&mut self.reuse_pool);
        let t_scan = std::time::Instant::now();
        let (rows_batch, reused) = corrector_golden_rows_batch(
            self.backend.as_ref(),
            ctxs,
            xs,
            &pool,
            b.m,
            b.k,
            ds.h,
            ds.w,
            ds.c,
        );
        let scan_each = t_scan.elapsed().as_secs_f64() / xs.len() as f64;
        self.corrector_refines += xs.len() as u64;
        if reused {
            self.screens_reused += xs.len() as u64;
        }
        let mut f_hats = Vec::with_capacity(xs.len());
        for ((x_t, ctx), rows) in xs.iter().zip(ctxs).zip(rows_batch) {
            let plan = self.bucket_plan(rows, b.m, b.k)?;
            self.telemetry.scan_secs = scan_each;
            let out = self.dispatch(x_t, ctx, Some(plan))?;
            f_hats.push(out.f_hat);
        }
        Ok(f_hats)
    }
}

impl Denoiser for XlaDenoiser {
    fn name(&self) -> String {
        format!("xla:{}", self.kind.name())
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let out = self
            .step(x_t, ctx)
            .expect("XLA dispatch failed — artifacts stale? rerun `make artifacts`");
        DenoiseResult {
            f_hat: out.f_hat,
            stats: PosteriorStats {
                max_logit: out.stats.max_logit,
                logsumexp: out.stats.logsumexp,
                entropy: out.stats.entropy,
                top1_weight: out.stats.top1_weight,
            },
            support: if self.telemetry.gauss {
                0 // no rows aggregated — the moment tier is closed-form
            } else {
                self.telemetry.k_used.max(1)
            },
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        match self.kind {
            DenoiserKind::Wiener => 2 * ds.d as u64 * 4,
            DenoiserKind::GoldDiff
            | DenoiserKind::GoldDiffPca
            | DenoiserKind::GoldDiffWss
            | DenoiserKind::GoldDiffKamb => {
                (ds.n * ds.proxy_d + self.budget.m_max * ds.d) as u64 * 4
            }
            _ => (self.full_bucket() * ds.d) as u64 * 4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::index::backend::BatchedScan;
    use crate::schedule::noise::{NoiseSchedule, ScheduleKind};

    fn setup() -> Option<(Rc<Runtime>, Dataset, NoiseSchedule)> {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        let rt = Rc::new(Runtime::new(dir).unwrap());
        let spec = preset("moons").unwrap().clone();
        let ds = Dataset::synthesize(&spec, 11);
        Some((rt, ds, NoiseSchedule::new(ScheduleKind::DdpmLinear, 10)))
    }

    #[test]
    fn xla_optimal_matches_cpu_optimal() {
        let Some((rt, ds, sched)) = setup() else { return };
        let mut xla = XlaDenoiser::new(rt, &ds, DenoiserKind::Optimal).unwrap();
        let mut cpu = crate::denoiser::optimal::OptimalDenoiser::new();
        for step in [0usize, 5, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let x_t = vec![0.3f32, -0.2];
            let fx = xla.denoise(&x_t, &ctx).f_hat;
            let fc = cpu.denoise(&x_t, &ctx).f_hat;
            for j in 0..ds.d {
                assert!(
                    (fx[j] - fc[j]).abs() < 1e-3,
                    "step {step} dim {j}: {} vs {}",
                    fx[j],
                    fc[j]
                );
            }
        }
    }

    #[test]
    fn xla_golddiff_matches_cpu_golddiff() {
        let Some((rt, ds, sched)) = setup() else { return };
        let buckets = rt.manifest.buckets("golden_step", &ds.name);
        let mut xla = XlaDenoiser::new(rt, &ds, DenoiserKind::GoldDiff).unwrap();
        let mut cpu = crate::denoiser::golddiff::GoldDiff::new(
            &ds,
            BudgetSchedule::paper_defaults(ds.n, &buckets),
            crate::denoiser::golddiff::BaseWeighting::Golden,
        );
        for step in [0usize, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let x_t = vec![-0.6f32, 0.8];
            let fx = xla.denoise(&x_t, &ctx).f_hat;
            let fc = cpu.denoise(&x_t, &ctx).f_hat;
            for j in 0..ds.d {
                assert!(
                    (fx[j] - fc[j]).abs() < 1e-3,
                    "step {step} dim {j}: {} vs {}",
                    fx[j],
                    fc[j]
                );
            }
        }
    }

    #[test]
    fn telemetry_follows_budget_schedule() {
        let Some((rt, ds, sched)) = setup() else { return };
        let mut xla = XlaDenoiser::new(rt, &ds, DenoiserKind::GoldDiff).unwrap();
        let x_t = vec![0.1f32, 0.1];
        let ctx0 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 0,
            class: None,
        };
        xla.denoise(&x_t, &ctx0);
        let k0 = xla.telemetry.k_used;
        let ctx9 = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        xla.denoise(&x_t, &ctx9);
        let k9 = xla.telemetry.k_used;
        assert!(k9 < k0, "k must shrink: {k0} -> {k9}");
        assert!(xla.telemetry.k_bucket >= k9);
    }

    #[test]
    fn resident_buffers_reused_across_steps() {
        let Some((rt, ds, sched)) = setup() else { return };
        let mut xla = XlaDenoiser::new(Rc::clone(&rt), &ds, DenoiserKind::Optimal).unwrap();
        let x_t = vec![0.0f32, 0.0];
        for step in 0..3 {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            xla.denoise(&x_t, &ctx);
        }
        // exactly one full-bucket executable compiled & one resident upload
        assert!(xla.resident_full.is_some());
    }

    #[test]
    fn gauss_prefix_is_closed_form_and_retrieval_segment_is_unchanged() {
        // ticks below the switch serve the CPU closed form (zero screens,
        // zero refines, gauss telemetry), and every tick at/after the
        // switch is byte-identical to a denoiser with the tier off
        let Some((rt, ds, sched)) = setup() else { return };
        let backend: Arc<dyn RetrievalBackend> = Arc::new(BatchedScan::new(2));
        let switch = 3usize;
        let mut on = XlaDenoiser::new(Rc::clone(&rt), &ds, DenoiserKind::GoldDiff)
            .unwrap()
            .with_retrieval(Arc::clone(&backend))
            .with_gauss(switch);
        let mut off = XlaDenoiser::new(Rc::clone(&rt), &ds, DenoiserKind::GoldDiff)
            .unwrap()
            .with_retrieval(Arc::clone(&backend));
        let gm = ds.gauss_moments().expect("resident corpora build lazily");
        let xs_data: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * i as f32, -0.3]).collect();
        for step in 0..sched.steps {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
            let g_on = on.step_group(&xs, &ctxs).unwrap();
            let g_off = off.step_group(&xs, &ctxs).unwrap();
            for (i, x) in xs.iter().enumerate() {
                if step < switch {
                    assert!(g_on[i].1.gauss, "step {step} seq {i}");
                    assert_eq!(g_on[i].1.m_used, 0, "gauss ticks screen nothing");
                    assert_eq!(g_on[i].1.k_used, 0, "gauss ticks refine nothing");
                    let want = crate::denoiser::gaussian::closed_form_f_hat(
                        gm,
                        x,
                        ctx.alpha_bar(),
                        None,
                    );
                    for j in 0..ds.d {
                        assert!(
                            (g_on[i].0.f_hat[j] - want[j]).abs() < 1e-3,
                            "step {step} seq {i} dim {j}"
                        );
                    }
                } else {
                    assert!(!g_on[i].1.gauss);
                    assert_eq!(
                        g_on[i].0.f_hat, g_off[i].0.f_hat,
                        "retrieval segment diverged at step {step} seq {i}"
                    );
                    assert_eq!(g_on[i].0.x_prev, g_off[i].0.x_prev, "step {step} seq {i}");
                }
            }
        }
        let (ticks, skipped) = on.take_gauss_counts();
        assert_eq!(ticks, (switch * xs_data.len()) as u64);
        assert_eq!(skipped, (switch * xs_data.len()) as u64);
        assert_eq!(on.take_gauss_counts(), (0, 0), "counters drain on take");
        assert_eq!(off.gauss_ticks, 0);
    }

    #[test]
    fn step_group_matches_per_sequence_steps() {
        // the batched group path must be numerically identical to stepping
        // every sequence on its own (same backend, same sampling point)
        let Some((rt, ds, sched)) = setup() else { return };
        let backend: Arc<dyn RetrievalBackend> = Arc::new(BatchedScan::new(2));
        let mut xla = XlaDenoiser::new(Rc::clone(&rt), &ds, DenoiserKind::GoldDiff)
            .unwrap()
            .with_retrieval(Arc::clone(&backend));
        let xs_data: Vec<Vec<f32>> = (0..4).map(|i| vec![0.1 * i as f32, -0.2]).collect();
        for step in [0usize, 9] {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step,
                class: None,
            };
            let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
            let ctxs: Vec<&StepContext> = xs.iter().map(|_| &ctx).collect();
            let grouped = xla.step_group(&xs, &ctxs).unwrap();
            assert_eq!(grouped.len(), xs.len());
            for (i, x) in xs.iter().enumerate() {
                let solo = xla.step(x, &ctx).unwrap();
                assert_eq!(grouped[i].0.f_hat, solo.f_hat, "step {step} seq {i}");
                assert_eq!(grouped[i].0.x_prev, solo.x_prev, "step {step} seq {i}");
            }
        }
    }

    #[test]
    fn grouped_corrector_reuses_the_group_screen() {
        // a predictor tick group stashes its golden-subset union; the
        // corrector pass refines over it (no coarse screen) and consumes
        // it, so a second corrector falls back to the full cold path
        let Some((rt, ds, sched)) = setup() else { return };
        let backend: Arc<dyn RetrievalBackend> = Arc::new(BatchedScan::new(2));
        let mut xla = XlaDenoiser::new(Rc::clone(&rt), &ds, DenoiserKind::GoldDiff)
            .unwrap()
            .with_retrieval(Arc::clone(&backend));
        let xs_data: Vec<Vec<f32>> = (0..3).map(|i| vec![0.05 * i as f32, -0.25]).collect();
        let xs: Vec<&[f32]> = xs_data.iter().map(|x| x.as_slice()).collect();
        let pred = StepContext {
            ds: &ds,
            sched: &sched,
            step: 6,
            class: None,
        };
        let corr = StepContext {
            ds: &ds,
            sched: &sched,
            step: 7,
            class: None,
        };
        let pred_ctxs: Vec<&StepContext> = xs.iter().map(|_| &pred).collect();
        let corr_ctxs: Vec<&StepContext> = xs.iter().map(|_| &corr).collect();
        xla.step_group(&xs, &pred_ctxs).unwrap();
        let reused_f = xla.corrector_group(&xs, &corr_ctxs).unwrap();
        assert_eq!(
            xla.take_fewstep_counts(),
            (3, 3),
            "pool reuse engages for the whole group"
        );
        let cold_f = xla.corrector_group(&xs, &corr_ctxs).unwrap();
        assert_eq!(
            xla.take_fewstep_counts(),
            (3, 0),
            "a stale pool never serves a second corrector"
        );
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(reused_f[i].len(), ds.d);
            let solo = xla.step(x, &corr).unwrap();
            assert_eq!(cold_f[i], solo.f_hat, "seq {i}: cold fallback == full path");
        }
    }
}
