//! Bounded MPMC submission queue with backpressure.
//!
//! `submit` blocks while the queue is at capacity (backpressure towards the
//! client); `try_submit` fails fast (the TCP server's 429-equivalent);
//! `pop_batch` drains up to `max` entries for one admission round and
//! `close` wakes all waiters for shutdown.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    capacity: usize,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    Full,
    Closed,
}

impl<T> BoundedQueue<T> {
    pub fn new(capacity: usize) -> Self {
        BoundedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Blocking submit (backpressure). Errors only when closed.
    pub fn submit(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        while g.items.len() >= self.capacity && !g.closed {
            g = self.not_full.wait(g).unwrap();
        }
        if g.closed {
            return Err(SubmitError::Closed);
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking submit.
    pub fn try_submit(&self, item: T) -> Result<(), SubmitError> {
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.items.len() >= self.capacity {
            return Err(SubmitError::Full);
        }
        g.items.push_back(item);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Drain up to `max` items; blocks until ≥1 item or closed-and-empty
    /// (returns empty vec). With `max == 0` returns immediately.
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        if max == 0 {
            return Vec::new();
        }
        let mut g = self.inner.lock().unwrap();
        while g.items.is_empty() && !g.closed {
            g = self.not_empty.wait(g).unwrap();
        }
        let take = max.min(g.items.len());
        let out: Vec<T> = g.items.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    /// Non-blocking drain of up to `max` items.
    pub fn try_pop_batch(&self, max: usize) -> Vec<T> {
        let mut g = self.inner.lock().unwrap();
        let take = max.min(g.items.len());
        let out: Vec<T> = g.items.drain(..take).collect();
        if !out.is_empty() {
            self.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    #[test]
    fn fifo_order_preserved() {
        let q = BoundedQueue::new(16);
        for i in 0..10 {
            q.try_submit(i).unwrap();
        }
        assert_eq!(q.pop_batch(4), vec![0, 1, 2, 3]);
        assert_eq!(q.pop_batch(100), vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn try_submit_full() {
        let q = BoundedQueue::new(2);
        q.try_submit(1).unwrap();
        q.try_submit(2).unwrap();
        assert_eq!(q.try_submit(3), Err(SubmitError::Full));
        q.try_pop_batch(1);
        q.try_submit(3).unwrap();
    }

    #[test]
    fn close_unblocks_and_rejects() {
        let q = Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || q2.pop_batch(1));
        thread::sleep(std::time::Duration::from_millis(30));
        q.close();
        assert!(h.join().unwrap().is_empty());
        assert_eq!(q.try_submit(1), Err(SubmitError::Closed));
        assert_eq!(q.submit(1), Err(SubmitError::Closed));
    }

    #[test]
    fn blocking_submit_applies_backpressure() {
        let q = Arc::new(BoundedQueue::new(1));
        q.submit(0u32).unwrap();
        let q2 = Arc::clone(&q);
        let h = thread::spawn(move || {
            q2.submit(1u32).unwrap(); // blocks until pop
            true
        });
        thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(q.len(), 1, "second submit must still be blocked");
        assert_eq!(q.pop_batch(1), vec![0]);
        assert!(h.join().unwrap());
        assert_eq!(q.pop_batch(1), vec![1]);
    }

    #[test]
    fn concurrent_producers_lose_nothing() {
        let q = Arc::new(BoundedQueue::new(8));
        let mut handles = Vec::new();
        for t in 0..4u32 {
            let q = Arc::clone(&q);
            handles.push(thread::spawn(move || {
                for i in 0..50u32 {
                    q.submit(t * 1000 + i).unwrap();
                }
            }));
        }
        let q2 = Arc::clone(&q);
        let consumer = thread::spawn(move || {
            let mut got = Vec::new();
            while got.len() < 200 {
                got.extend(q2.pop_batch(16));
            }
            got
        });
        for h in handles {
            h.join().unwrap();
        }
        let got = consumer.join().unwrap();
        assert_eq!(got.len(), 200);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 200, "duplicates or losses");
    }
}
