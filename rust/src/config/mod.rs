//! Typed configuration for the serving engine and experiments, with JSON
//! round-trip (config files + CLI overrides).

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use anyhow::Result;

use crate::util::json::{parse, Json};

/// Parse a boolean-ish flag value (CLI `--kernel off`, env `GOLDDIFF_*`).
pub fn parse_flag(v: &str) -> bool {
    parse_flag_strict(v).unwrap_or(false)
}

/// Strict flag parse: `None` for anything that is not a recognised
/// spelling, so callers can tell "explicitly off" from "mistyped". The
/// empty string counts as off (an `VAR=` export conventionally clears).
pub fn parse_flag_strict(v: &str) -> Option<bool> {
    match v {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" | "" => Some(false),
        _ => None,
    }
}

/// Warn to stderr about a malformed env knob — once per variable name per
/// process, so a misspelt `GOLDDIFF_SHARDS=four` surfaces loudly instead
/// of silently serving the default, without spamming every config read.
fn warn_env_once(name: &str, value: &str, expected: &str, fallback: &str) {
    static WARNED: OnceLock<Mutex<HashSet<String>>> = OnceLock::new();
    let mut seen = WARNED
        .get_or_init(|| Mutex::new(HashSet::new()))
        .lock()
        .unwrap();
    if seen.insert(name.to_string()) {
        eprintln!(
            "warning: ignoring {name}={value:?} — expected {expected}; \
             using the default ({fallback})"
        );
    }
}

/// Boolean default with an environment override — the CI scalar-matrix leg
/// runs the whole suite under `GOLDDIFF_KERNEL=0 GOLDDIFF_WARM_START=0` so
/// every default-constructed path exercises the scalar references. A set
/// but unrecognisable value warns once to stderr and serves the default.
pub fn env_flag(name: &str, default: bool) -> bool {
    match std::env::var(name) {
        Ok(v) => parse_flag_strict(&v).unwrap_or_else(|| {
            let fallback = if default { "on" } else { "off" };
            warn_env_once(name, &v, "a flag (1/true/on/yes or 0/false/off/no)", fallback);
            default
        }),
        Err(_) => default,
    }
}

/// Numeric default with an environment override — the CI `tier1-sharded`
/// leg runs the suite under `GOLDDIFF_SHARDS=4` so every
/// default-constructed retrieval path exercises the shard-parallel merge
/// layer end to end. A set but unparsable value warns once to stderr and
/// serves the default.
pub fn env_usize(name: &str, default: usize) -> usize {
    match std::env::var(name) {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            warn_env_once(name, &v, "an unsigned integer", &default.to_string());
            default
        }),
        Err(_) => default,
    }
}

/// Float default with an environment override — the CI `tier1-faults` leg
/// runs the suite under `GOLDDIFF_FAULT_RATE=0.05` so every streamed read
/// exercises the transient-fault retry path. A set but unparsable value
/// warns once to stderr and serves the default.
pub fn env_f64(name: &str, default: f64) -> f64 {
    match std::env::var(name) {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            warn_env_once(name, &v, "a number", &default.to_string());
            default
        }),
        Err(_) => default,
    }
}

/// Switch-point policy string with an environment override —
/// `GOLDDIFF_GAUSS_SWITCH` accepts `auto` (bound-driven) or an explicit
/// unsigned tick count. A set but unrecognisable value warns once to
/// stderr and serves the default, per the strict env-knob contract.
pub fn env_gauss_switch(name: &str, default: &str) -> String {
    match std::env::var(name) {
        Ok(v) => {
            if v == "auto" || v.parse::<usize>().is_ok() {
                v
            } else {
                warn_env_once(name, &v, "`auto` or an unsigned tick count", default);
                default.to_string()
            }
        }
        Err(_) => default.to_string(),
    }
}

/// Solver name with an environment override — `GOLDDIFF_SOLVER` accepts
/// `ddim`, `heun`, or `dpm2` (the `sampler::Solver` names). A set but
/// unrecognisable value warns once to stderr and serves the default, per
/// the strict env-knob contract.
pub fn env_solver(name: &str, default: &str) -> String {
    match std::env::var(name) {
        Ok(v) => {
            if matches!(v.as_str(), "ddim" | "heun" | "dpm2") {
                v
            } else {
                warn_env_once(name, &v, "`ddim`, `heun`, or `dpm2`", default);
                default.to_string()
            }
        }
        Err(_) => default.to_string(),
    }
}

/// u64 default with an environment override — `GOLDDIFF_FAULT_SEED` keys
/// the deterministic fault schedule. A set but unparsable value warns once
/// to stderr and serves the default.
pub fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Ok(v) => v.parse().unwrap_or_else(|_| {
            warn_env_once(name, &v, "an unsigned integer", &default.to_string());
            default
        }),
        Err(_) => default,
    }
}

/// Engine-level configuration (the launcher's config file).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// dataset preset to serve
    pub preset: String,
    /// directory holding the `.gds` stores
    pub data_dir: PathBuf,
    /// directory holding AOT artifacts + manifest.json
    pub artifacts_dir: PathBuf,
    /// denoising steps per request (paper default 10)
    pub steps: usize,
    /// noise schedule family
    pub schedule: String,
    /// worker threads for the dispatch loop
    pub workers: usize,
    /// scan threads inside the coarse index
    pub scan_threads: usize,
    /// bounded request-queue depth (backpressure)
    pub queue_depth: usize,
    /// m_min/m_max/k_min/k_max as fractions of N (paper: 1/10, 1/4, 1/20, 1/10)
    pub m_min_frac: f64,
    pub m_max_frac: f64,
    pub k_min_frac: f64,
    pub k_max_frac: f64,
    /// base denoiser the GoldDiff wrapper drives ("golden", "pca", "kamb")
    pub method: String,
    /// coarse retrieval backend ("flat", "batched", "cluster")
    pub backend: String,
    /// IVF lists for the cluster-pruned backend
    pub clusters: usize,
    /// cluster-pruned probe cap; 0 = exact centroid-bound pruning only
    pub nprobe: usize,
    /// route proxy scans through the register-tiled kernel (scalar paths
    /// remain available for reference runs / debugging)
    pub kernel: bool,
    /// route the exact refine through the pre-blocked kernel ladder
    /// (row-major reference behind `false`; moot when `kernel` is off)
    pub refine_kernel: bool,
    /// quantised screen/refine tiers: coarse screens and the refine
    /// pre-rung run on int8 blocks with sound distance bounds, every
    /// survivor is rescored in exact f32 — end results stay byte-identical
    /// to the pure-f32 path (moot when `kernel` is off)
    pub quant: bool,
    /// explicit SIMD lanes in the tiled scan kernel (runtime-dispatched
    /// AVX2, bit-identical to the scalar reference; scalar fallback
    /// elsewhere)
    pub simd: bool,
    /// heap-aware block ordering for the batched / cluster scans
    pub ordering: bool,
    /// concentration warm-start: seed each tick group's coarse screen from
    /// the previous sampling point's golden subsets (exactness preserved)
    pub warm_start: bool,
    /// Gaussian-score fast path: high-noise tick groups above the switch
    /// point are served closed-form from the corpus moment tier (zero
    /// coarse screens, zero refines) before retrieval takes over. Stands
    /// down to full retrieval when the store carries no usable moment tier
    pub gauss: bool,
    /// switch-point policy: `auto` picks the longest high-noise prefix
    /// whose per-tick error bound stays within `gauss_tol`; an explicit
    /// unsigned integer pins the prefix length (pinning tests, forced
    /// A/B runs)
    pub gauss_switch: String,
    /// per-tick error-bound tolerance the `auto` switch policy enforces
    pub gauss_tol: f64,
    /// reverse-diffusion solver: `ddim` (first order, the byte-identical
    /// default), `heun` (trapezoidal corrector), or `dpm2` (midpoint).
    /// Higher-order correctors re-screen only the predictor's golden
    /// subset, so a second-order step costs ~1 coarse screen, not 2
    pub solver: String,
    /// retrieval-segment tick budget for the few-step plan: `0` (default)
    /// keeps the full grid; a positive budget places that many ticks over
    /// the retrieval segment by churn, coasting across the gaps
    pub step_budget: usize,
    /// queries per kernel register tile (clamped to 1..=8 at build)
    pub kernel_tile_q: usize,
    /// corpus shards: `> 1` scans shard-parallel with exact heap merges
    /// (`index::shard`); `1` keeps the monolithic backends
    pub shards: usize,
    /// memory budget (MiB) for resident cold-shard row blocks; `0` =
    /// unbounded. With `shards > 1` a positive budget implies the
    /// out-of-core mode: the engine serves the corpus data-free off the
    /// `.gds` store (see `resident`)
    pub mem_budget_mb: usize,
    /// keep the full-resolution corpus resident (default). `false` — or
    /// `shards > 1 && mem_budget_mb > 0`, which implies it — serves
    /// data-free: the store is opened via `store::open_streaming` and rows
    /// stream shard-at-a-time through the LRU budget, byte-identically
    pub resident: bool,
    /// loopback shard workers the engine spawns at start: `> 0` routes
    /// retrieval through the distributed tier (`index::remote`) with the
    /// workers in-process over 127.0.0.1 — the CI distributed leg, and the
    /// smallest honest deployment. `0` (default) keeps retrieval
    /// in-process — the byte-identical degenerate case
    pub remote_workers: usize,
    /// comma-separated `host:port` list of already-running external
    /// `shard-worker` processes; non-empty wins over `remote_workers`
    pub worker_addrs: String,
    /// when a worker's retry budget is exhausted, stand the remote tier
    /// down to the in-process path (byte-identical) instead of failing
    /// requests; `false` surfaces the loss as request errors
    pub remote_fallback: bool,
    /// per-op ceiling (ms) a worker is given when the tick group carries
    /// no tighter request deadline
    pub remote_op_timeout_ms: u64,
    /// rng seed
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            preset: "cifar-sim".into(),
            data_dir: PathBuf::from("data"),
            artifacts_dir: PathBuf::from("artifacts"),
            steps: 10,
            schedule: "ddpm".into(),
            workers: crate::util::threadpool::default_threads(),
            scan_threads: crate::util::threadpool::default_threads(),
            queue_depth: 256,
            m_min_frac: 0.10,
            m_max_frac: 0.25,
            k_min_frac: 0.05,
            k_max_frac: 0.10,
            method: "golden".into(),
            backend: "batched".into(),
            clusters: 64,
            nprobe: 0,
            kernel: env_flag("GOLDDIFF_KERNEL", true),
            refine_kernel: env_flag("GOLDDIFF_KERNEL", true),
            quant: env_flag("GOLDDIFF_QUANT", false),
            simd: env_flag("GOLDDIFF_SIMD", true),
            ordering: true,
            warm_start: env_flag("GOLDDIFF_WARM_START", true),
            gauss: env_flag("GOLDDIFF_GAUSS", false),
            gauss_switch: env_gauss_switch("GOLDDIFF_GAUSS_SWITCH", "auto"),
            gauss_tol: env_f64("GOLDDIFF_GAUSS_TOL", 0.05),
            solver: env_solver("GOLDDIFF_SOLVER", "ddim"),
            step_budget: env_usize("GOLDDIFF_STEP_BUDGET", 0),
            kernel_tile_q: crate::index::kernel::TILE_Q,
            shards: env_usize("GOLDDIFF_SHARDS", 1),
            mem_budget_mb: env_usize("GOLDDIFF_MEM_BUDGET_MB", 0),
            resident: env_flag("GOLDDIFF_RESIDENT", true),
            remote_workers: env_usize("GOLDDIFF_REMOTE_WORKERS", 0),
            worker_addrs: String::new(),
            remote_fallback: true,
            remote_op_timeout_ms: 30_000,
            seed: 0,
        }
    }
}

impl EngineConfig {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("preset", self.preset.as_str())
            .set("data_dir", self.data_dir.to_string_lossy().to_string())
            .set(
                "artifacts_dir",
                self.artifacts_dir.to_string_lossy().to_string(),
            )
            .set("steps", self.steps)
            .set("schedule", self.schedule.as_str())
            .set("workers", self.workers)
            .set("scan_threads", self.scan_threads)
            .set("queue_depth", self.queue_depth)
            .set("m_min_frac", self.m_min_frac)
            .set("m_max_frac", self.m_max_frac)
            .set("k_min_frac", self.k_min_frac)
            .set("k_max_frac", self.k_max_frac)
            .set("method", self.method.as_str())
            .set("backend", self.backend.as_str())
            .set("clusters", self.clusters)
            .set("nprobe", self.nprobe)
            .set("kernel", self.kernel)
            .set("refine_kernel", self.refine_kernel)
            .set("quant", self.quant)
            .set("simd", self.simd)
            .set("ordering", self.ordering)
            .set("warm_start", self.warm_start)
            .set("gauss", self.gauss)
            .set("gauss_switch", self.gauss_switch.as_str())
            .set("gauss_tol", self.gauss_tol)
            .set("solver", self.solver.as_str())
            .set("step_budget", self.step_budget)
            .set("kernel_tile_q", self.kernel_tile_q)
            .set("shards", self.shards)
            .set("mem_budget_mb", self.mem_budget_mb)
            .set("resident", self.resident)
            .set("remote_workers", self.remote_workers)
            .set("worker_addrs", self.worker_addrs.as_str())
            .set("remote_fallback", self.remote_fallback)
            .set("remote_op_timeout_ms", self.remote_op_timeout_ms)
            .set("seed", self.seed);
        j
    }

    pub fn from_json(j: &Json) -> Result<EngineConfig> {
        let def = EngineConfig::default();
        let s = |key: &str, d: &str| -> String {
            j.get(key)
                .and_then(Json::as_str)
                .unwrap_or(d)
                .to_string()
        };
        let n = |key: &str, d: f64| j.get(key).and_then(Json::as_f64).unwrap_or(d);
        Ok(EngineConfig {
            preset: s("preset", &def.preset),
            data_dir: PathBuf::from(s("data_dir", &def.data_dir.to_string_lossy())),
            artifacts_dir: PathBuf::from(s(
                "artifacts_dir",
                &def.artifacts_dir.to_string_lossy(),
            )),
            steps: n("steps", def.steps as f64) as usize,
            schedule: s("schedule", &def.schedule),
            workers: n("workers", def.workers as f64) as usize,
            scan_threads: n("scan_threads", def.scan_threads as f64) as usize,
            queue_depth: n("queue_depth", def.queue_depth as f64) as usize,
            m_min_frac: n("m_min_frac", def.m_min_frac),
            m_max_frac: n("m_max_frac", def.m_max_frac),
            k_min_frac: n("k_min_frac", def.k_min_frac),
            k_max_frac: n("k_max_frac", def.k_max_frac),
            method: s("method", &def.method),
            backend: s("backend", &def.backend),
            clusters: n("clusters", def.clusters as f64) as usize,
            nprobe: n("nprobe", def.nprobe as f64) as usize,
            kernel: j
                .get("kernel")
                .and_then(Json::as_bool)
                .unwrap_or(def.kernel),
            refine_kernel: j
                .get("refine_kernel")
                .and_then(Json::as_bool)
                .unwrap_or(def.refine_kernel),
            quant: j.get("quant").and_then(Json::as_bool).unwrap_or(def.quant),
            simd: j.get("simd").and_then(Json::as_bool).unwrap_or(def.simd),
            ordering: j
                .get("ordering")
                .and_then(Json::as_bool)
                .unwrap_or(def.ordering),
            warm_start: j
                .get("warm_start")
                .and_then(Json::as_bool)
                .unwrap_or(def.warm_start),
            gauss: j.get("gauss").and_then(Json::as_bool).unwrap_or(def.gauss),
            gauss_switch: s("gauss_switch", &def.gauss_switch),
            gauss_tol: n("gauss_tol", def.gauss_tol),
            solver: s("solver", &def.solver),
            step_budget: n("step_budget", def.step_budget as f64) as usize,
            kernel_tile_q: n("kernel_tile_q", def.kernel_tile_q as f64) as usize,
            shards: n("shards", def.shards as f64) as usize,
            mem_budget_mb: n("mem_budget_mb", def.mem_budget_mb as f64) as usize,
            resident: j
                .get("resident")
                .and_then(Json::as_bool)
                .unwrap_or(def.resident),
            remote_workers: n("remote_workers", def.remote_workers as f64) as usize,
            worker_addrs: s("worker_addrs", &def.worker_addrs),
            remote_fallback: j
                .get("remote_fallback")
                .and_then(Json::as_bool)
                .unwrap_or(def.remote_fallback),
            remote_op_timeout_ms: n("remote_op_timeout_ms", def.remote_op_timeout_ms as f64)
                as u64,
            seed: n("seed", def.seed as f64) as u64,
        })
    }

    pub fn load(path: &Path) -> Result<EngineConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    /// Apply CLI overrides (`--preset`, `--steps`, …).
    pub fn apply_args(&mut self, args: &crate::util::cli::Args) {
        if let Some(p) = args.get("preset") {
            self.preset = p.to_string();
        }
        if let Some(p) = args.get("data-dir") {
            self.data_dir = PathBuf::from(p);
        }
        if let Some(p) = args.get("artifacts-dir") {
            self.artifacts_dir = PathBuf::from(p);
        }
        if let Some(p) = args.get("method") {
            self.method = p.to_string();
        }
        if let Some(p) = args.get("schedule") {
            self.schedule = p.to_string();
        }
        if let Some(p) = args.get("backend") {
            self.backend = p.to_string();
        }
        self.clusters = args.usize_or("clusters", self.clusters);
        self.nprobe = args.usize_or("nprobe", self.nprobe);
        if let Some(v) = args.get("kernel") {
            self.kernel = parse_flag(v);
        }
        if let Some(v) = args.get("refine-kernel") {
            self.refine_kernel = parse_flag(v);
        }
        if let Some(v) = args.get("quant") {
            self.quant = parse_flag(v);
        }
        if let Some(v) = args.get("simd") {
            self.simd = parse_flag(v);
        }
        if let Some(v) = args.get("ordering") {
            self.ordering = parse_flag(v);
        }
        if let Some(v) = args.get("warm-start") {
            self.warm_start = parse_flag(v);
        }
        if let Some(v) = args.get("gauss") {
            self.gauss = parse_flag(v);
        }
        if let Some(v) = args.get("gauss-switch") {
            self.gauss_switch = v.to_string();
        }
        self.gauss_tol = args.f64_or("gauss-tol", self.gauss_tol);
        if let Some(v) = args.get("solver") {
            self.solver = v.to_string();
        }
        self.step_budget = args.usize_or("step-budget", self.step_budget);
        self.kernel_tile_q = args.usize_or("kernel-tile-q", self.kernel_tile_q);
        self.shards = args.usize_or("shards", self.shards);
        self.mem_budget_mb = args.usize_or("mem-budget-mb", self.mem_budget_mb);
        if let Some(v) = args.get("resident") {
            self.resident = parse_flag(v);
        }
        self.remote_workers = args.usize_or("remote-workers", self.remote_workers);
        if let Some(v) = args.get("worker-addrs") {
            self.worker_addrs = v.to_string();
        }
        if let Some(v) = args.get("remote-fallback") {
            self.remote_fallback = parse_flag(v);
        }
        self.remote_op_timeout_ms = args.u64_or("remote-op-timeout-ms", self.remote_op_timeout_ms);
        self.steps = args.usize_or("steps", self.steps);
        self.workers = args.usize_or("workers", self.workers);
        self.scan_threads = args.usize_or("scan-threads", self.scan_threads);
        self.queue_depth = args.usize_or("queue-depth", self.queue_depth);
        self.seed = args.u64_or("seed", self.seed);
        self.m_min_frac = args.f64_or("m-min-frac", self.m_min_frac);
        self.m_max_frac = args.f64_or("m-max-frac", self.m_max_frac);
        self.k_min_frac = args.f64_or("k-min-frac", self.k_min_frac);
        self.k_max_frac = args.f64_or("k-max-frac", self.k_max_frac);
    }

    /// The retrieval-backend build knobs this config selects.
    pub fn backend_opts(&self) -> crate::index::backend::BackendOpts {
        crate::index::backend::BackendOpts {
            threads: self.scan_threads,
            clusters: self.clusters,
            nprobe: self.nprobe,
            seed: self.seed,
            kernel: self.kernel,
            refine_kernel: self.refine_kernel,
            quant: self.quant,
            simd: self.simd,
            ordering: self.ordering,
            tile_q: self.kernel_tile_q,
            shards: self.shards,
            mem_budget_mb: self.mem_budget_mb,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut c = EngineConfig::default();
        c.preset = "afhq-sim".into();
        c.steps = 25;
        c.k_min_frac = 0.025;
        c.backend = "cluster".into();
        c.clusters = 128;
        c.nprobe = 4;
        c.kernel = false;
        c.refine_kernel = false;
        c.quant = true;
        c.simd = false;
        c.ordering = false;
        c.warm_start = false;
        c.gauss = true;
        c.gauss_switch = "3".into();
        c.gauss_tol = 0.01;
        c.solver = "heun".into();
        c.step_budget = 5;
        c.kernel_tile_q = 2;
        c.shards = 6;
        c.mem_budget_mb = 512;
        c.resident = false;
        c.remote_workers = 3;
        c.worker_addrs = "10.0.0.1:7401,10.0.0.2:7401".into();
        c.remote_fallback = false;
        c.remote_op_timeout_ms = 1500;
        let rt = EngineConfig::from_json(&parse(&c.to_json().to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(rt, c);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("golddiff_cfg_test");
        let path = dir.join("engine.json");
        let c = EngineConfig::default();
        c.save(&path).unwrap();
        assert_eq!(EngineConfig::load(&path).unwrap(), c);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cli_overrides() {
        let mut c = EngineConfig::default();
        let raw: Vec<String> = ["--preset", "moons", "--steps", "50", "--k-min-frac", "0.01"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        c.apply_args(&crate::util::cli::Args::parse(&raw));
        assert_eq!(c.preset, "moons");
        assert_eq!(c.steps, 50);
        assert!((c.k_min_frac - 0.01).abs() < 1e-12);
    }

    #[test]
    fn backend_knobs_default_and_override() {
        let c = EngineConfig::default();
        assert_eq!(c.backend, "batched");
        assert_eq!(c.clusters, 64);
        assert_eq!(c.nprobe, 0);
        // kernel / warm-start defaults follow the env so the CI scalar leg
        // can flip every default-constructed path at once
        assert_eq!(c.kernel, env_flag("GOLDDIFF_KERNEL", true));
        assert_eq!(c.refine_kernel, env_flag("GOLDDIFF_KERNEL", true));
        assert_eq!(c.warm_start, env_flag("GOLDDIFF_WARM_START", true));
        assert!(c.ordering, "heap-aware ordering is on by default");
        assert_eq!(c.kernel_tile_q, crate::index::kernel::TILE_Q);
        // shard count / budget / residency follow the env so the CI
        // sharded and streamed legs can flip every default-constructed
        // retrieval path at once
        assert_eq!(c.shards, env_usize("GOLDDIFF_SHARDS", 1));
        assert_eq!(c.mem_budget_mb, env_usize("GOLDDIFF_MEM_BUDGET_MB", 0));
        assert_eq!(c.resident, env_flag("GOLDDIFF_RESIDENT", true));
        // the distributed tier follows the env so the CI tier1-distrib leg
        // can route every default-constructed engine through loopback
        // shard workers at once
        assert_eq!(c.remote_workers, env_usize("GOLDDIFF_REMOTE_WORKERS", 0));
        assert!(c.worker_addrs.is_empty());
        assert!(c.remote_fallback, "lost workers degrade, not fail");
        assert_eq!(c.remote_op_timeout_ms, 30_000);
        // quant / simd follow the env so the CI tier1-quant leg can flip
        // every default-constructed retrieval path at once
        assert_eq!(c.quant, env_flag("GOLDDIFF_QUANT", false));
        assert_eq!(c.simd, env_flag("GOLDDIFF_SIMD", true));
        // the Gaussian fast path follows the env so the CI tier1-gauss leg
        // can flip every default-constructed engine at once
        assert_eq!(c.gauss, env_flag("GOLDDIFF_GAUSS", false));
        assert_eq!(c.gauss_switch, env_gauss_switch("GOLDDIFF_GAUSS_SWITCH", "auto"));
        assert_eq!(c.gauss_tol, env_f64("GOLDDIFF_GAUSS_TOL", 0.05));
        // the few-step solver and budget follow the env so the CI
        // tier1-fewstep leg can flip every default-constructed engine at
        // once
        assert_eq!(c.solver, env_solver("GOLDDIFF_SOLVER", "ddim"));
        assert_eq!(c.step_budget, env_usize("GOLDDIFF_STEP_BUDGET", 0));
        assert!(crate::index::backend::RetrievalBackendKind::parse(&c.backend).is_some());
        let mut c = EngineConfig::default();
        let raw: Vec<String> = [
            "--backend", "cluster", "--clusters", "32", "--nprobe", "2", "--kernel", "off",
            "--refine-kernel", "off", "--ordering", "off", "--warm-start", "off",
            "--kernel-tile-q", "4", "--shards", "8", "--mem-budget-mb", "256",
            "--resident", "off", "--quant", "on", "--simd", "off",
            "--remote-workers", "2", "--worker-addrs", "127.0.0.1:7401",
            "--remote-fallback", "off", "--remote-op-timeout-ms", "500",
            "--gauss", "on", "--gauss-switch", "4", "--gauss-tol", "0.02",
            "--solver", "heun", "--step-budget", "6",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        c.apply_args(&crate::util::cli::Args::parse(&raw));
        assert_eq!(c.backend, "cluster");
        assert_eq!(c.clusters, 32);
        assert_eq!(c.nprobe, 2);
        assert!(!c.kernel && !c.refine_kernel && !c.ordering && !c.warm_start);
        assert_eq!(c.kernel_tile_q, 4);
        assert_eq!(c.shards, 8);
        assert_eq!(c.mem_budget_mb, 256);
        assert!(!c.resident, "--resident off flips the out-of-core mode");
        assert!(c.quant, "--quant on enables the quantised tiers");
        assert!(!c.simd, "--simd off pins the scalar kernel lanes");
        assert_eq!(c.remote_workers, 2);
        assert_eq!(c.worker_addrs, "127.0.0.1:7401");
        assert!(!c.remote_fallback);
        assert_eq!(c.remote_op_timeout_ms, 500);
        assert!(c.gauss, "--gauss on enables the Gaussian fast path");
        assert_eq!(c.gauss_switch, "4");
        assert!((c.gauss_tol - 0.02).abs() < 1e-12);
        assert_eq!(c.solver, "heun");
        assert_eq!(c.step_budget, 6);
        let opts = c.backend_opts();
        assert!(!opts.kernel && !opts.refine_kernel && !opts.ordering);
        assert!(opts.quant && !opts.simd);
        assert_eq!(opts.tile_q, 4);
        assert_eq!(opts.clusters, 32);
        assert_eq!(opts.shards, 8);
        assert_eq!(opts.mem_budget_mb, 256);
    }

    #[test]
    fn flag_parsing_accepts_the_usual_spellings() {
        for v in ["1", "true", "on", "yes"] {
            assert!(parse_flag(v), "{v}");
        }
        for v in ["0", "false", "off", "no", ""] {
            assert!(!parse_flag(v), "{v}");
        }
        // unset → the default wins
        assert!(env_flag("GOLDDIFF_TEST_FLAG_THAT_IS_NEVER_SET", true));
        assert!(!env_flag("GOLDDIFF_TEST_FLAG_THAT_IS_NEVER_SET", false));
        // set → the env wins over either default (a var name only this
        // test touches, so parallel tests cannot race on it)
        std::env::set_var("GOLDDIFF_TEST_FLAG_PARSE_ONLY", "off");
        assert!(!env_flag("GOLDDIFF_TEST_FLAG_PARSE_ONLY", true));
        std::env::set_var("GOLDDIFF_TEST_FLAG_PARSE_ONLY", "on");
        assert!(env_flag("GOLDDIFF_TEST_FLAG_PARSE_ONLY", false));
        std::env::remove_var("GOLDDIFF_TEST_FLAG_PARSE_ONLY");
        // numeric env override (again a var only this test touches)
        assert_eq!(env_usize("GOLDDIFF_TEST_USIZE_THAT_IS_NEVER_SET", 3), 3);
        std::env::set_var("GOLDDIFF_TEST_USIZE_PARSE_ONLY", "7");
        assert_eq!(env_usize("GOLDDIFF_TEST_USIZE_PARSE_ONLY", 1), 7);
        std::env::set_var("GOLDDIFF_TEST_USIZE_PARSE_ONLY", "not-a-number");
        assert_eq!(env_usize("GOLDDIFF_TEST_USIZE_PARSE_ONLY", 1), 1);
        std::env::remove_var("GOLDDIFF_TEST_USIZE_PARSE_ONLY");
    }

    #[test]
    fn malformed_env_values_warn_and_serve_the_default() {
        // Satellite: a mistyped knob (`GOLDDIFF_SHARDS=four`) must not
        // silently pick a side — it warns once to stderr (not capturable
        // here; the behavioural contract is the fallback) and serves the
        // default. Recognised spellings never take the fallback path.
        assert_eq!(parse_flag_strict("yes"), Some(true));
        assert_eq!(parse_flag_strict("no"), Some(false));
        assert_eq!(parse_flag_strict(""), Some(false), "VAR= clears");
        assert_eq!(parse_flag_strict("four"), None);
        assert_eq!(parse_flag_strict("ON"), None, "spellings are exact");
        // vars only this test touches, so parallel tests cannot race
        std::env::set_var("GOLDDIFF_TEST_BAD_FLAG_ONLY", "maybe");
        assert!(env_flag("GOLDDIFF_TEST_BAD_FLAG_ONLY", true));
        assert!(!env_flag("GOLDDIFF_TEST_BAD_FLAG_ONLY", false));
        std::env::remove_var("GOLDDIFF_TEST_BAD_FLAG_ONLY");
        std::env::set_var("GOLDDIFF_TEST_BAD_USIZE_ONLY", "four");
        assert_eq!(env_usize("GOLDDIFF_TEST_BAD_USIZE_ONLY", 4), 4);
        std::env::set_var("GOLDDIFF_TEST_BAD_USIZE_ONLY", "-3");
        assert_eq!(env_usize("GOLDDIFF_TEST_BAD_USIZE_ONLY", 2), 2);
        std::env::remove_var("GOLDDIFF_TEST_BAD_USIZE_ONLY");
        // GOLDDIFF_REMOTE_WORKERS / GOLDDIFF_MEM_BUDGET_MB route through
        // `env_usize` above, so the strict warn-once-and-serve-default
        // contract covers them without dedicated plumbing.
    }

    #[test]
    fn gauss_switch_env_accepts_auto_or_ticks_and_falls_back() {
        // unset → default wins
        assert_eq!(
            env_gauss_switch("GOLDDIFF_TEST_GSWITCH_NEVER_SET", "auto"),
            "auto"
        );
        // vars only this test touches, so parallel tests cannot race
        std::env::set_var("GOLDDIFF_TEST_GSWITCH_ONLY", "auto");
        assert_eq!(env_gauss_switch("GOLDDIFF_TEST_GSWITCH_ONLY", "auto"), "auto");
        std::env::set_var("GOLDDIFF_TEST_GSWITCH_ONLY", "5");
        assert_eq!(env_gauss_switch("GOLDDIFF_TEST_GSWITCH_ONLY", "auto"), "5");
        // malformed → warns once, serves the default
        std::env::set_var("GOLDDIFF_TEST_GSWITCH_ONLY", "sometimes");
        assert_eq!(env_gauss_switch("GOLDDIFF_TEST_GSWITCH_ONLY", "auto"), "auto");
        std::env::set_var("GOLDDIFF_TEST_GSWITCH_ONLY", "-2");
        assert_eq!(env_gauss_switch("GOLDDIFF_TEST_GSWITCH_ONLY", "auto"), "auto");
        std::env::remove_var("GOLDDIFF_TEST_GSWITCH_ONLY");
    }

    #[test]
    fn solver_env_accepts_known_names_and_falls_back() {
        // unset → default wins
        assert_eq!(env_solver("GOLDDIFF_TEST_SOLVER_NEVER_SET", "ddim"), "ddim");
        // vars only this test touches, so parallel tests cannot race
        for name in ["ddim", "heun", "dpm2"] {
            std::env::set_var("GOLDDIFF_TEST_SOLVER_ONLY", name);
            assert_eq!(env_solver("GOLDDIFF_TEST_SOLVER_ONLY", "ddim"), name);
        }
        // malformed → warns once, serves the default
        std::env::set_var("GOLDDIFF_TEST_SOLVER_ONLY", "euler-maruyama");
        assert_eq!(env_solver("GOLDDIFF_TEST_SOLVER_ONLY", "ddim"), "ddim");
        std::env::set_var("GOLDDIFF_TEST_SOLVER_ONLY", "HEUN");
        assert_eq!(
            env_solver("GOLDDIFF_TEST_SOLVER_ONLY", "ddim"),
            "ddim",
            "spellings are exact"
        );
        std::env::remove_var("GOLDDIFF_TEST_SOLVER_ONLY");
    }

    #[test]
    fn env_f64_and_u64_parse_and_fall_back() {
        // unset → defaults win
        assert_eq!(env_f64("GOLDDIFF_TEST_F64_THAT_IS_NEVER_SET", 0.25), 0.25);
        assert_eq!(env_u64("GOLDDIFF_TEST_U64_THAT_IS_NEVER_SET", 7), 7);
        // vars only this test touches, so parallel tests cannot race
        std::env::set_var("GOLDDIFF_TEST_F64_PARSE_ONLY", "0.05");
        assert_eq!(env_f64("GOLDDIFF_TEST_F64_PARSE_ONLY", 0.0), 0.05);
        std::env::set_var("GOLDDIFF_TEST_F64_PARSE_ONLY", "not-a-rate");
        assert_eq!(env_f64("GOLDDIFF_TEST_F64_PARSE_ONLY", 0.5), 0.5);
        std::env::remove_var("GOLDDIFF_TEST_F64_PARSE_ONLY");
        std::env::set_var("GOLDDIFF_TEST_U64_PARSE_ONLY", "42");
        assert_eq!(env_u64("GOLDDIFF_TEST_U64_PARSE_ONLY", 0), 42);
        std::env::set_var("GOLDDIFF_TEST_U64_PARSE_ONLY", "-1");
        assert_eq!(env_u64("GOLDDIFF_TEST_U64_PARSE_ONLY", 9), 9);
        std::env::remove_var("GOLDDIFF_TEST_U64_PARSE_ONLY");
    }

    #[test]
    fn paper_default_fractions() {
        let c = EngineConfig::default();
        assert_eq!(c.m_min_frac, 0.10);
        assert_eq!(c.m_max_frac, 0.25);
        assert_eq!(c.k_min_frac, 0.05);
        assert_eq!(c.k_max_frac, 0.10);
        assert_eq!(c.steps, 10);
    }
}
