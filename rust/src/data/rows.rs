//! `RowSource` — pluggable full-resolution row storage behind [`Dataset`].
//!
//! The engine's oldest assumption was that the whole corpus lives in one
//! resident `Vec<f32>`; memory, not compute, capped the dataset size. This
//! module inverts that: row storage is a two-variant source —
//!
//! * [`RowSource::Resident`] — the seed behaviour: the flat `[n × d]`
//!   corpus in RAM, zero-copy row borrows, the monolithic pre-blocked
//!   refine table built lazily on top.
//! * [`RowSource::Streamed`] — the out-of-core mode: the `.gds` store is
//!   the corpus. Rows are served shard-at-a-time as [`RowBlocks`] through
//!   an LRU bounded by `mem_budget_mb`; a cold shard streams off disk via
//!   [`ShardReader`], a hot shard is a cache hit, and the budget (not the
//!   corpus) is the resident ceiling.
//!
//! **Exactness contract.** Streaming changes *where* a row's bytes come
//! from, never their values: the store holds the exact little-endian f32s
//! the resident corpus would, the blocked transpose is a verbatim copy,
//! and every consumer visits rows in the same order either way — so a
//! `mem_budget_mb`-bounded engine produces byte-identical output to the
//! resident one (pinned by the `resident ∈ {true, false}` axis of the
//! determinism matrix in `tests/integration_pipeline.rs`).
//!
//! Consumers never read the source directly; they go through the
//! [`Dataset`] surface (`row` for resident-only borrows, [`RowCursor`] /
//! `visit_rows` / `gather_rows` for source-agnostic access, and
//! `build_range_blocks` / `shard_blocks` for the blocked refine tables).
//!
//! The quantised refine pre-rung (`Dataset::quant_rows`, preloaded from a
//! v4 store's `quant_*` sections) narrows candidate pools *before* the
//! exact rungs touch this source, so on a streamed corpus it directly
//! reduces how many shards the refine ladder has to page in — bound
//! rejects here are disk reads that never happen.
//!
//! [`Dataset`]: crate::data::dataset::Dataset

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::shard::ShardPlan;
use crate::data::store::{ChecksumMismatch, ShardReader};
use crate::index::kernel::RowBlocks;
use crate::util::fault::FaultInjector;

/// Retry budget for a transient streamed-read failure: the first attempt
/// plus six retries, with doubling backoff (1 ms → 16 ms cap). Exhausting
/// the budget panics — a streamed corpus has no resident fallback, and
/// corrupt rows must never be served (the engine's per-request
/// `catch_unwind` turns the panic into an `"internal"` error reply).
const MAX_READ_ATTEMPTS: u32 = 7;

/// Transient = worth re-reading: interrupted-style IO errors (real or
/// injected), and checksum mismatches — in-flight corruption re-reads
/// clean, while persistent on-disk corruption keeps failing and exhausts
/// the retry budget.
fn is_transient(err: &anyhow::Error) -> bool {
    if err.downcast_ref::<ChecksumMismatch>().is_some() {
        return true;
    }
    err.downcast_ref::<std::io::Error>().is_some_and(|io| {
        matches!(
            io.kind(),
            std::io::ErrorKind::Interrupted
                | std::io::ErrorKind::WouldBlock
                | std::io::ErrorKind::TimedOut
        )
    })
}

/// Full-resolution row storage: resident corpus or disk-streamed shards.
#[derive(Debug, Clone)]
pub enum RowSource {
    /// the flat `[n × d]` corpus resident in RAM (the seed behaviour)
    Resident(Vec<f32>),
    /// disk-backed: shard-at-a-time row blocks through a bounded LRU.
    /// Shared (`Arc`) so the retrieval layer can delegate its own shard
    /// residency to the one source LRU — one budget, no double caching.
    Streamed(Arc<StreamedRows>),
}

/// Snapshot of a streamed source's residency telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RowSourceStats {
    /// shards currently resident in the LRU
    pub resident_shards: usize,
    /// bytes of resident row blocks right now
    pub resident_bytes: u64,
    /// high-water mark of `resident_bytes` over the source's lifetime
    pub peak_row_bytes: u64,
    /// full-resolution rows read off disk (cold loads + re-streams)
    pub rows_streamed: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// transient read failures recovered by the bounded retry
    pub retries: u64,
    /// shard checksum mismatches observed (each is retried; persistent
    /// corruption exhausts the retry budget and fails hard)
    pub checksum_failures: u64,
    /// faults the configured [`FaultInjector`] injected (0 without one)
    pub faults_injected: u64,
}

#[derive(Debug, Default)]
struct BlockLru {
    resident: HashMap<usize, Arc<RowBlocks>>,
    /// front = least recently used
    order: VecDeque<usize>,
    bytes: u64,
}

/// The streamed row source: a `.gds`-backed corpus served shard-at-a-time
/// under a byte budget. All methods are `&self` (internally synchronised)
/// so one source can feed shard-parallel refines.
#[derive(Debug)]
pub struct StreamedRows {
    n: usize,
    d: usize,
    plan: ShardPlan,
    /// LRU budget in bytes for resident row blocks; 0 = unbounded
    budget_bytes: u64,
    reader: Mutex<ShardReader>,
    lru: Mutex<BlockLru>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    rows_streamed: AtomicU64,
    peak_bytes: AtomicU64,
    retries: AtomicU64,
    checksum_failures: AtomicU64,
    /// shared with the reader so stats can report `faults_injected`
    fault: Option<Arc<FaultInjector>>,
}

impl StreamedRows {
    /// Wrap an open [`ShardReader`]: the reader's plan is the shard
    /// granularity rows stream at, `mem_budget_mb` bounds the resident
    /// blocked working set (0 = unbounded).
    pub fn new(reader: ShardReader, n: usize, d: usize, mem_budget_mb: usize) -> StreamedRows {
        StreamedRows {
            n,
            d,
            plan: reader.plan().clone(),
            budget_bytes: mem_budget_mb as u64 * 1024 * 1024,
            fault: reader.fault().cloned(),
            reader: Mutex::new(reader),
            lru: Mutex::new(BlockLru::default()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            rows_streamed: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            checksum_failures: AtomicU64::new(0),
        }
    }

    /// Run one reader operation under the transient-retry policy: up to
    /// [`MAX_READ_ATTEMPTS`] attempts with doubling backoff. The reader
    /// lock is held only for the op itself — never across a backoff sleep
    /// or the final panic — so concurrent readers keep moving and a fatal
    /// failure cannot poison the mutex out from under the panic handler's
    /// telemetry. Lock acquisition itself is poison-tolerant for the same
    /// reason (the data under the mutex is a seek cursor, not an invariant).
    fn read_with_retry<T>(
        &self,
        what: &str,
        op: impl Fn(&mut ShardReader) -> anyhow::Result<T>,
    ) -> T {
        let mut backoff_ms = 1u64;
        for attempt in 1..=MAX_READ_ATTEMPTS {
            let result = {
                let mut rd = self.reader.lock().unwrap_or_else(|p| p.into_inner());
                op(&mut rd)
            };
            match result {
                Ok(v) => return v,
                Err(err) => {
                    if err.downcast_ref::<ChecksumMismatch>().is_some() {
                        self.checksum_failures.fetch_add(1, Ordering::Relaxed);
                    }
                    if attempt == MAX_READ_ATTEMPTS || !is_transient(&err) {
                        panic!(
                            "streamed corpus: {what} failed after {attempt} attempt(s): {err:#}"
                        );
                    }
                    self.retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(std::time::Duration::from_millis(backoff_ms));
                    backoff_ms = (backoff_ms * 2).min(16);
                }
            }
        }
        unreachable!("the retry loop either returns or panics")
    }

    #[inline]
    pub fn n_rows(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.d
    }

    #[inline]
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// The LRU budget in bytes (0 = unbounded) — consumers deciding
    /// whether to delegate their residency here compare against it.
    #[inline]
    pub fn budget_bytes(&self) -> u64 {
        self.budget_bytes
    }

    /// Shard `shard`'s rows as a pre-blocked kernel table with global row
    /// ids: LRU hit, or a cold stream off the store. The returned `Arc`
    /// keeps the blocks alive past any eviction, so callers may hold it
    /// across a whole scan.
    ///
    /// Transient read failures (interrupted-style IO errors, checksum
    /// mismatches) retry with bounded backoff; anything else — or an
    /// exhausted retry budget — panics: a streamed corpus has no resident
    /// fallback, and serving corrupt rows is never an option (the engine's
    /// per-request `catch_unwind` converts the panic to an `"internal"`
    /// reply instead of killing the worker).
    pub fn shard_blocks(&self, shard: usize) -> Arc<RowBlocks> {
        if let Some(rb) = self.touch(shard) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return rb;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // stream + transpose OUTSIDE the lru lock so shard-parallel
        // refines fault cold shards concurrently; a racing builder may
        // duplicate the (deterministic) work — first insert wins
        let (s, e) = self.plan.range(shard);
        let table = self.read_with_retry(&format!("reading shard {shard}"), |rd| {
            rd.read_shard_rows(shard)
        });
        self.rows_streamed.fetch_add((e - s) as u64, Ordering::Relaxed);
        let ids: Vec<u32> = (s as u32..e as u32).collect();
        let built = Arc::new(RowBlocks::build_local(&table, self.d, ids));
        drop(table);

        let mut lru = self.lru.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(rb) = lru.resident.get(&shard) {
            return Arc::clone(rb); // lost the race — byte-identical copy
        }
        let incoming = built.bytes();
        if self.budget_bytes > 0 {
            // evict BEFORE inserting so resident bytes never exceed the
            // budget — the invariant the debug assert below pins. A shard
            // larger than the whole budget still gets its one slot.
            while lru.bytes + incoming > self.budget_bytes && !lru.order.is_empty() {
                let victim = lru.order.pop_front().unwrap();
                if let Some(old) = lru.resident.remove(&victim) {
                    lru.bytes -= old.bytes();
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        lru.bytes += incoming;
        lru.resident.insert(shard, Arc::clone(&built));
        lru.order.push_back(shard);
        self.peak_bytes.fetch_max(lru.bytes, Ordering::Relaxed);
        debug_assert!(
            self.budget_bytes == 0
                || lru.bytes <= self.budget_bytes
                || lru.resident.len() == 1,
            "streamed residency {} exceeds the {}-byte budget with {} shards resident",
            lru.bytes,
            self.budget_bytes,
            lru.resident.len()
        );
        built
    }

    /// Cache lookup: on a hit, move the shard to the MRU position.
    fn touch(&self, shard: usize) -> Option<Arc<RowBlocks>> {
        let mut lru = self.lru.lock().unwrap_or_else(|p| p.into_inner());
        let rb = Arc::clone(lru.resident.get(&shard)?);
        if let Some(pos) = lru.order.iter().position(|&x| x == shard) {
            lru.order.remove(pos);
        }
        lru.order.push_back(shard);
        Some(rb)
    }

    /// Read an arbitrary row range `[s, e)` straight off the store,
    /// bypassing the LRU (plan-mismatched consumers — e.g. a backend
    /// sharded at a different count than the source).
    pub fn read_range(&self, s: usize, e: usize) -> Vec<f32> {
        let table = self.read_with_retry(&format!("reading rows {s}..{e}"), |rd| {
            rd.read_row_range(s, e)
        });
        self.rows_streamed.fetch_add((e - s) as u64, Ordering::Relaxed);
        table
    }

    pub fn stats(&self) -> RowSourceStats {
        let lru = self.lru.lock().unwrap_or_else(|p| p.into_inner());
        RowSourceStats {
            resident_shards: lru.resident.len(),
            resident_bytes: lru.bytes,
            peak_row_bytes: self.peak_bytes.load(Ordering::Relaxed),
            rows_streamed: self.rows_streamed.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            checksum_failures: self.checksum_failures.load(Ordering::Relaxed),
            faults_injected: self.fault.as_ref().map_or(0, |f| f.injected()),
        }
    }

    /// Zero the monotonic counters (bench harness hook); resident blocks,
    /// the peak high-water mark and the injector's own fault tally stay.
    pub fn reset_counters(&self) {
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
        self.rows_streamed.store(0, Ordering::Relaxed);
        self.retries.store(0, Ordering::Relaxed);
        self.checksum_failures.store(0, Ordering::Relaxed);
    }
}

/// Source-agnostic sequential row access: resident rows borrow straight
/// from the corpus; streamed rows pin their shard's blocks (one `Arc` held
/// at a time, so consecutive ids in one shard pay a single LRU probe) and
/// copy the lane out into an internal scratch row.
///
/// The returned slice is valid until the next `row` call — exactly the
/// shape every scan loop already has.
pub struct RowCursor<'a> {
    source: &'a RowSource,
    d: usize,
    cached: Option<(usize, Arc<RowBlocks>)>,
    scratch: Vec<f32>,
}

impl<'a> RowCursor<'a> {
    pub(crate) fn new(source: &'a RowSource, d: usize) -> RowCursor<'a> {
        RowCursor {
            source,
            d,
            cached: None,
            scratch: Vec::new(),
        }
    }

    /// Row `gid`'s full-resolution values. Bit-identical across sources.
    #[inline]
    pub fn row(&mut self, gid: u32) -> &[f32] {
        match self.source {
            RowSource::Resident(data) => {
                let i = gid as usize * self.d;
                &data[i..i + self.d]
            }
            RowSource::Streamed(src) => {
                let sh = src.plan().shard_of(gid as usize);
                if !matches!(&self.cached, Some((cached, _)) if *cached == sh) {
                    self.cached = Some((sh, src.shard_blocks(sh)));
                }
                let (start, _) = src.plan().range(sh);
                let (_, blocks) = self.cached.as_ref().unwrap();
                self.scratch.resize(self.d, 0.0);
                blocks.copy_row_into(gid as usize - start, &mut self.scratch);
                &self.scratch
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::store;
    use crate::data::synthetic::preset;

    fn saved(n: usize, seed: u64, shards: usize, dir: &str) -> (Dataset, std::path::PathBuf) {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        let ds = Dataset::synthesize(&spec, seed);
        let dir = std::env::temp_dir().join(dir);
        std::fs::remove_dir_all(&dir).ok();
        let path = store::store_path(&dir, "cifar-sim");
        store::save_sharded(&ds, &path, shards).unwrap();
        (ds, path)
    }

    #[test]
    fn cursor_serves_identical_rows_across_sources() {
        let (ds, path) = saved(90, 3, 4, "golddiff_rows_cursor_test");
        let streamed = store::open_streaming(&path, 4, 0).unwrap();
        assert!(!streamed.is_resident() && ds.is_resident());
        let mut cur = streamed.row_cursor();
        // in-order, out-of-order and repeated ids all match the resident row
        for gid in [0u32, 1, 89, 3, 45, 45, 88, 0] {
            assert_eq!(cur.row(gid), ds.row(gid as usize), "row {gid}");
        }
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn lru_respects_budget_and_tracks_peak() {
        // cifar-sim rows are 3072 f32s; 200 rows ≈ 2.4 MiB across 4 shards,
        // so a 1 MiB budget must evict while serving every shard
        let (ds, path) = saved(200, 7, 4, "golddiff_rows_lru_test");
        let streamed = store::open_streaming(&path, 4, 1).unwrap();
        let src = streamed.streamed().expect("streamed source");
        let shard_bytes = src.shard_blocks(0).bytes();
        for round in 0..2 {
            for sh in 0..4 {
                let blocks = src.shard_blocks(sh);
                let (s, e) = src.plan().range(sh);
                assert_eq!(blocks.rows, e - s, "round {round} shard {sh}");
            }
        }
        let st = src.stats();
        assert!(st.evictions > 0, "1 MiB budget must evict: {st:?}");
        assert!(st.resident_bytes <= 1024 * 1024, "budget holds: {st:?}");
        assert!(
            st.peak_row_bytes >= shard_bytes && st.peak_row_bytes <= 1024 * 1024,
            "peak within (shard, budget): {st:?}"
        );
        assert!(st.rows_streamed >= ds.n as u64, "cold loads stream rows");
        assert!(st.hits + st.misses >= 8, "every touch is accounted");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn unbounded_budget_keeps_every_shard_and_hits() {
        let (_ds, path) = saved(80, 11, 3, "golddiff_rows_unbounded_test");
        let streamed = store::open_streaming(&path, 3, 0).unwrap();
        let src = streamed.streamed().unwrap();
        for sh in 0..3 {
            let a = src.shard_blocks(sh);
            let b = src.shard_blocks(sh);
            assert!(Arc::ptr_eq(&a, &b), "second touch is the same copy");
        }
        let st = src.stats();
        assert_eq!(st.misses, 3);
        assert_eq!(st.hits, 3);
        assert_eq!(st.evictions, 0);
        assert_eq!(st.resident_shards, 3);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn transient_faults_retry_and_stay_byte_identical() {
        // Tentpole: with the deterministic injector faulting the first 5
        // positioned reads (5 < the 6-retry budget, so every read
        // eventually lands), streamed rows are byte-identical to the
        // resident corpus and the retry telemetry accounts every fault
        let (ds, path) = saved(90, 19, 3, "golddiff_rows_fault_transient_test");
        let fault = Arc::new(FaultInjector::transient(42, 1.0).with_limit(5));
        let streamed = store::open_streaming_with(&path, 3, 0, Some(Arc::clone(&fault))).unwrap();
        let mut cur = streamed.row_cursor();
        for i in 0..ds.n {
            assert_eq!(cur.row(i as u32), ds.row(i), "row {i}");
        }
        let st = streamed.source_stats().unwrap();
        assert_eq!(st.faults_injected, 5);
        assert_eq!(fault.injected(), 5);
        assert_eq!(st.retries, 5, "every injected fault cost one retry");
        assert_eq!(st.checksum_failures, 0, "transient faults corrupt nothing");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn bit_flips_are_caught_by_checksums_and_rereads_stay_byte_identical() {
        // Tentpole: a flipped bit in a streamed buffer trips the shard
        // checksum (v5 store, matching plan, unbounded LRU → every read is
        // a verified first touch), the retry re-reads clean, and rows stay
        // byte-identical to the resident corpus
        let (ds, path) = saved(90, 23, 3, "golddiff_rows_fault_bitflip_test");
        let fault = Arc::new(FaultInjector::bit_flips(7, 1.0).with_limit(2));
        let streamed = store::open_streaming_with(&path, 3, 0, Some(fault)).unwrap();
        let mut cur = streamed.row_cursor();
        for i in 0..ds.n {
            assert_eq!(cur.row(i as u32), ds.row(i), "row {i}");
        }
        let st = streamed.source_stats().unwrap();
        assert_eq!(st.faults_injected, 2);
        assert_eq!(st.checksum_failures, 2, "every flip tripped the checksum");
        assert_eq!(st.retries, 2, "every flip cost one re-read");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn persistent_on_disk_corruption_exhausts_retries_and_fails_hard() {
        // checksum mismatches retry (in-flight corruption re-reads clean),
        // but corruption that is actually on the medium keeps failing —
        // after MAX_READ_ATTEMPTS the source refuses to serve, naming the
        // checksum, instead of handing out corrupt rows
        let (_ds, path) = saved(60, 29, 2, "golddiff_rows_fault_persist_test");
        let mut bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        // `data` is the first section: its payload starts right after the
        // header, so this lands inside shard 0's rows
        bytes[8 + hlen + 101] ^= 0x40;
        std::fs::write(&path, bytes).unwrap();
        // injector pinned to None: the exact attempt counts below must not
        // wobble when the suite runs under the GOLDDIFF_FAULT_* env leg
        let streamed = store::open_streaming_with(&path, 2, 0, None).unwrap();
        let src = streamed.streamed().unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            src.shard_blocks(0)
        }))
        .expect_err("corrupt shard must not serve");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".to_string());
        assert!(msg.contains("checksum"), "panic must name the cause: {msg}");
        let st = src.stats();
        assert_eq!(st.checksum_failures, MAX_READ_ATTEMPTS as u64);
        assert_eq!(st.retries, (MAX_READ_ATTEMPTS - 1) as u64);
        // shard 1 is clean and still serves after the failure
        let (s, e) = src.plan().range(1);
        assert_eq!(src.shard_blocks(1).rows, e - s);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn read_range_matches_resident_rows_across_shard_boundaries() {
        let (ds, path) = saved(70, 13, 4, "golddiff_rows_range_test");
        let streamed = store::open_streaming(&path, 4, 0).unwrap();
        let src = streamed.streamed().unwrap();
        for (s, e) in [(0usize, 5usize), (10, 40), (0, 70), (69, 70)] {
            let got = src.read_range(s, e);
            let mut want = Vec::new();
            for i in s..e {
                want.extend_from_slice(ds.row(i));
            }
            assert_eq!(got, want, "range {s}..{e}");
        }
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
