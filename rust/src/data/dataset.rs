//! The in-memory dataset: raw corpus + every derived structure the serving
//! engine needs (proxy table, class shards, clusters, local PCA bases,
//! global Gaussian stats, and the population GMM for the oracle).

use std::sync::OnceLock;

use super::cluster::{kmeans, local_pca};
use super::gmm::GmmSpec;
use super::synthetic::{build_population, proxy_embed_all, PresetSpec};
use crate::index::kernel::{ProxyBlocks, RowBlocks};
use crate::util::rng::Pcg64;

/// Number of local-PCA clusters.
pub const N_CLUSTERS: usize = 16;
/// Rank of the local PCA bases (matches python/compile/presets.PCA_RANK).
pub const PCA_RANK: usize = 32;

/// An IVF k-means partition of the proxy table, keyed by `(lists, seed)`.
///
/// Computed once (deterministically) and persisted in the `.gds` store so a
/// `ClusterPruned` engine start can skip k-means entirely when the stored
/// partition matches the config. Old stores without the section simply load
/// `ivf: None` and trigger a rebuild.
#[derive(Debug, Clone, PartialEq)]
pub struct IvfPartition {
    /// number of IVF lists the partition was built with
    pub lists: usize,
    /// rng seed the k-means ran under
    pub seed: u64,
    /// centroids [lists × proxy_d]
    pub centroids: Vec<f32>,
    /// list assignment per row [n]
    pub assignments: Vec<u32>,
}

impl IvfPartition {
    /// Deterministic k-means over the proxy table — the single source of
    /// truth for the IVF substrate (`ClusterPruned` reuses this verbatim,
    /// so a persisted partition is bit-identical to a fresh one).
    pub fn compute(ds: &Dataset, lists: usize, seed: u64) -> IvfPartition {
        let lists = lists.clamp(1, ds.n.max(1));
        let mut rng = Pcg64::with_stream(seed, 0x1f5);
        let (centroids, assignments) = kmeans(&ds.proxies, ds.n, ds.proxy_d, lists, 8, &mut rng);
        IvfPartition {
            lists,
            seed,
            centroids,
            assignments,
        }
    }

    /// Does this partition serve a `(lists, seed)` config verbatim?
    pub fn matches(&self, lists: usize, seed: u64) -> bool {
        self.lists == lists && self.seed == seed
    }
}

#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub d: usize,
    pub proxy_d: usize,
    pub classes: usize,
    pub conditional: bool,

    /// flat corpus [n × d]
    pub data: Vec<f32>,
    /// class labels [n]
    pub labels: Vec<u32>,
    /// s=1/4 proxy table [n × proxy_d]
    pub proxies: Vec<f32>,
    /// the proxy table transposed into cache-friendly SoA row blocks — the
    /// resident layout the tiled scan kernel reads (built once here so
    /// every backend shares one copy)
    pub proxy_blocks: ProxyBlocks,
    /// the full-resolution corpus in the same dim-major block layout — the
    /// table the pre-blocked exact refine ladder scans (the row-major
    /// `data` stays the reference the scalar refine reads). Built lazily on
    /// first use via [`Dataset::row_blocks`] so scalar-only runs (the
    /// `refine_kernel = false` reference paths) never pay the duplicated
    /// corpus residency.
    pub(crate) row_blocks: OnceLock<RowBlocks>,
    /// per-class row indices (conditional scans)
    pub class_rows: Vec<Vec<u32>>,
    /// persisted IVF partition, if the `.gds` store carried one
    pub ivf: Option<IvfPartition>,

    /// global Gaussian stats (Wiener)
    pub mean: Vec<f32>,
    pub var: Vec<f32>,

    /// k-means centroids [N_CLUSTERS × d] + assignment [n]
    pub centroids: Vec<f32>,
    pub assignments: Vec<u32>,
    /// local PCA: bases [N_CLUSTERS × PCA_RANK × d], centers [N_CLUSTERS × d]
    pub pca_bases: Vec<f32>,
    pub pca_centers: Vec<f32>,

    /// the known population law (closed-form oracle)
    pub gmm: GmmSpec,
}

impl Dataset {
    /// Synthesise a dataset from its preset (generation + all derived
    /// structures). Deterministic in (preset, seed).
    pub fn synthesize(spec: &PresetSpec, seed: u64) -> Dataset {
        let gmm = build_population(spec, seed);
        let mut rng = Pcg64::with_stream(seed, 0xda7a);
        let (data, labels) = gmm.sample_n(spec.n, &mut rng);
        Self::from_parts(spec, data, labels, gmm, seed)
    }

    pub fn from_parts(
        spec: &PresetSpec,
        data: Vec<f32>,
        labels: Vec<u32>,
        gmm: GmmSpec,
        seed: u64,
    ) -> Dataset {
        let n = spec.n;
        let d = spec.d();
        assert_eq!(data.len(), n * d);
        let proxies = proxy_embed_all(&data, n, spec.h, spec.w, spec.c);
        let proxy_blocks = ProxyBlocks::build(&proxies, n, spec.proxy_d());

        let mut mean = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                mean[j] += data[i * d + j];
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f32);
        let mut var = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                let dv = data[i * d + j] - mean[j];
                var[j] += dv * dv;
            }
        }
        var.iter_mut().for_each(|v| *v = (*v / n as f32).max(1e-6));

        let mut class_rows = vec![Vec::new(); spec.classes];
        for (i, &y) in labels.iter().enumerate() {
            class_rows[y as usize].push(i as u32);
        }

        // clusters + local PCA on a bounded subsample for speed
        let mut crng = Pcg64::with_stream(seed, 0xc1u64);
        let ncl = N_CLUSTERS.min(n);
        let (centroids, assignments) = kmeans(&data, n, d, ncl, 6, &mut crng);
        let rank = PCA_RANK.min(d);
        let mut pca_bases = vec![0.0f32; ncl * rank * d];
        let mut pca_centers = vec![0.0f32; ncl * d];
        // per-cluster row lists (bounded subsample for the PCA fit)
        let cluster_rows: Vec<Vec<usize>> = (0..ncl)
            .map(|cl| {
                let mut rows: Vec<usize> = assignments
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a as usize == cl)
                    .map(|(i, _)| i)
                    .collect();
                if rows.is_empty() {
                    rows.push(crng.below(n));
                }
                if rows.len() > 1200 {
                    crng.shuffle(&mut rows);
                    rows.truncate(1200);
                }
                rows
            })
            .collect();
        // fit all cluster bases in parallel (dominant cost of dataset build)
        let fits = crate::util::threadpool::parallel_chunks(ncl, ncl, |_, s, e| {
            let mut out = Vec::with_capacity(e - s);
            for cl in s..e {
                let mut rng = Pcg64::with_stream(seed ^ cl as u64, 0x9ca);
                out.push(local_pca(&data, d, &cluster_rows[cl], rank, 5, &mut rng));
            }
            out
        });
        for (cl, (basis, center)) in fits.into_iter().flatten().enumerate() {
            pca_bases[cl * rank * d..cl * rank * d + basis.len()].copy_from_slice(&basis);
            pca_centers[cl * d..(cl + 1) * d].copy_from_slice(&center);
        }

        Dataset {
            name: spec.name.to_string(),
            n,
            h: spec.h,
            w: spec.w,
            c: spec.c,
            d,
            proxy_d: spec.proxy_d(),
            classes: spec.classes,
            conditional: spec.conditional,
            data,
            labels,
            proxies,
            proxy_blocks,
            row_blocks: OnceLock::new(),
            class_rows,
            ivf: None,
            mean,
            var,
            centroids,
            assignments,
            pca_bases,
            pca_centers,
            gmm,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn proxy_row(&self, i: usize) -> &[f32] {
        &self.proxies[i * self.proxy_d..(i + 1) * self.proxy_d]
    }

    /// The pre-blocked full-resolution corpus, transposed on first use
    /// (thread-safe; every subsequent call returns the same resident copy).
    pub fn row_blocks(&self) -> &RowBlocks {
        self.row_blocks
            .get_or_init(|| RowBlocks::build(&self.data, self.n, self.d))
    }

    /// Gather rows into a caller-provided padded buffer [bucket × d]; rows
    /// beyond `idx.len()` are zero-filled. Returns the validity mask length.
    pub fn gather_rows(&self, idx: &[u32], bucket: usize, out: &mut Vec<f32>, mask: &mut Vec<f32>) {
        out.clear();
        out.resize(bucket * self.d, 0.0);
        mask.clear();
        mask.resize(bucket, 0.0);
        for (slot, &i) in idx.iter().take(bucket).enumerate() {
            out[slot * self.d..(slot + 1) * self.d].copy_from_slice(self.row(i as usize));
            mask[slot] = 1.0;
        }
    }

    /// Index of the nearest k-means cluster to a query (PCA basis pick).
    pub fn nearest_cluster(&self, q: &[f32]) -> usize {
        let ncl = self.centroids.len() / self.d;
        let mut best = 0;
        let mut best_d = f32::INFINITY;
        for cl in 0..ncl {
            let c = &self.centroids[cl * self.d..(cl + 1) * self.d];
            let dd: f32 = c.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
            if dd < best_d {
                best_d = dd;
                best = cl;
            }
        }
        best
    }

    pub fn pca_basis(&self, cluster: usize) -> (&[f32], &[f32]) {
        let rank = PCA_RANK.min(self.d);
        let b = &self.pca_bases[cluster * rank * self.d..(cluster + 1) * rank * self.d];
        let c = &self.pca_centers[cluster * self.d..(cluster + 1) * self.d];
        (b, c)
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() + self.proxies.len() + self.mean.len() + self.var.len()) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    fn tiny() -> Dataset {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 300;
        Dataset::synthesize(&spec, 42)
    }

    #[test]
    fn synthesis_produces_consistent_shapes() {
        let ds = tiny();
        assert_eq!(ds.data.len(), 300 * 256);
        assert_eq!(ds.proxies.len(), 300 * 16);
        assert_eq!(ds.labels.len(), 300);
        assert_eq!(ds.class_rows.iter().map(Vec::len).sum::<usize>(), 300);
        assert!(ds.labels.iter().all(|&y| (y as usize) < ds.classes));
    }

    #[test]
    fn synthesis_is_deterministic() {
        let spec = {
            let mut s = preset("moons").unwrap().clone();
            s.n = 100;
            s
        };
        let a = Dataset::synthesize(&spec, 7);
        let b = Dataset::synthesize(&spec, 7);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthesize(&spec, 8);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn gather_pads_and_masks() {
        let ds = tiny();
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        ds.gather_rows(&[3, 5], 4, &mut buf, &mut mask);
        assert_eq!(buf.len(), 4 * ds.d);
        assert_eq!(mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&buf[..ds.d], ds.row(3));
        assert!(buf[2 * ds.d..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nearest_cluster_self_consistent() {
        let ds = tiny();
        // a centroid's nearest cluster is itself
        let cl = 3.min(ds.centroids.len() / ds.d - 1);
        let q = ds.centroids[cl * ds.d..(cl + 1) * ds.d].to_vec();
        assert_eq!(ds.nearest_cluster(&q), cl);
    }

    #[test]
    fn variance_is_positive() {
        let ds = tiny();
        assert!(ds.var.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn proxy_blocks_mirror_the_proxy_table() {
        use crate::index::kernel::BLOCK_ROWS;
        let ds = tiny();
        assert_eq!(ds.proxy_blocks.rows, ds.n);
        assert_eq!(ds.proxy_blocks.dim, ds.proxy_d);
        for i in [0usize, 1, 31, 32, 299] {
            let (b, lane) = (i / BLOCK_ROWS, i % BLOCK_ROWS);
            assert_eq!(ds.proxy_blocks.id(b, lane), i as u32);
            for j in 0..ds.proxy_d {
                assert_eq!(
                    ds.proxy_blocks.block(b)[j * BLOCK_ROWS + lane],
                    ds.proxy_row(i)[j],
                    "row {i} dim {j}"
                );
            }
        }
    }

    #[test]
    fn row_blocks_mirror_the_full_resolution_corpus() {
        use crate::index::kernel::BLOCK_ROWS;
        let ds = tiny();
        // lazy: nothing resident until the first accessor call
        assert!(ds.row_blocks.get().is_none(), "row blocks must build lazily");
        let rb = ds.row_blocks();
        assert_eq!(rb.rows, ds.n);
        assert_eq!(rb.dim, ds.d);
        for i in [0usize, 31, 32, 63, 299] {
            let (b, lane) = (i / BLOCK_ROWS, i % BLOCK_ROWS);
            assert_eq!(rb.id(b, lane), i as u32);
            for j in (0..ds.d).step_by(17) {
                assert_eq!(
                    rb.block(b)[j * BLOCK_ROWS + lane],
                    ds.row(i)[j],
                    "row {i} dim {j}"
                );
            }
        }
        // the accessor memoises one copy
        assert!(std::ptr::eq(rb, ds.row_blocks()));
    }

    #[test]
    fn ivf_partition_is_deterministic_and_clamped() {
        let ds = tiny();
        let a = IvfPartition::compute(&ds, 8, 5);
        let b = IvfPartition::compute(&ds, 8, 5);
        assert_eq!(a, b);
        assert!(a.matches(8, 5) && !a.matches(8, 6) && !a.matches(9, 5));
        assert_eq!(a.assignments.len(), ds.n);
        assert_eq!(a.centroids.len(), 8 * ds.proxy_d);
        // lists clamp to n (tiny corpus so the degenerate k-means is cheap)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 40;
        let small = Dataset::synthesize(&spec, 2);
        let huge = IvfPartition::compute(&small, 10_000, 1);
        assert_eq!(huge.lists, small.n, "lists clamp to n");
    }
}
