//! The dataset: full-resolution rows behind a pluggable [`RowSource`]
//! (resident corpus or `.gds`-streamed shards) + every derived structure
//! the serving engine needs (proxy table, class shards, clusters, local
//! PCA bases, global Gaussian stats, and the population GMM for the
//! oracle). Everything except the rows themselves is always resident —
//! the streamed mode trades only the `n × d` corpus for an LRU budget.

use std::sync::OnceLock;

use super::cluster::{kmeans, local_pca};
use super::gmm::GmmSpec;
use super::rows::{RowCursor, RowSource, RowSourceStats, StreamedRows};
use super::shard::ShardPlan;
use super::synthetic::{build_population, proxy_embed_all, PresetSpec};
use crate::index::kernel::{ProxyBlocks, QuantBlocks, QuantRows, RowBlocks};
use crate::util::rng::Pcg64;

/// Number of local-PCA clusters.
pub const N_CLUSTERS: usize = 16;
/// Rank of the local PCA bases (matches python/compile/presets.PCA_RANK).
pub const PCA_RANK: usize = 32;

/// An IVF k-means partition of the proxy table, keyed by `(lists, seed)`.
///
/// Computed once (deterministically) and persisted in the `.gds` store so a
/// `ClusterPruned` engine start can skip k-means entirely when the stored
/// partition matches the config. Old stores without the section simply load
/// `ivf: None` and trigger a rebuild.
#[derive(Debug, Clone, PartialEq)]
pub struct IvfPartition {
    /// number of IVF lists the partition was built with
    pub lists: usize,
    /// rng seed the k-means ran under
    pub seed: u64,
    /// centroids [lists × proxy_d]
    pub centroids: Vec<f32>,
    /// list assignment per row [n]
    pub assignments: Vec<u32>,
}

impl IvfPartition {
    /// Deterministic k-means over the proxy table — the single source of
    /// truth for the IVF substrate (`ClusterPruned` reuses this verbatim,
    /// so a persisted partition is bit-identical to a fresh one).
    pub fn compute(ds: &Dataset, lists: usize, seed: u64) -> IvfPartition {
        let lists = lists.clamp(1, ds.n.max(1));
        let mut rng = Pcg64::with_stream(seed, 0x1f5);
        let (centroids, assignments) = kmeans(&ds.proxies, ds.n, ds.proxy_d, lists, 8, &mut rng);
        IvfPartition {
            lists,
            seed,
            centroids,
            assignments,
        }
    }

    /// Does this partition serve a `(lists, seed)` config verbatim?
    pub fn matches(&self, lists: usize, seed: u64) -> bool {
        self.lists == lists && self.seed == seed
    }
}

/// The *per-shard* IVF partitions of a sharded cluster engine, keyed by
/// `(shards, lists-per-shard, seed)` and persisted in the `.gds` store
/// (v3 `ivf_shard_i_*` sections) so a sharded cluster engine start stops
/// paying per-shard k-means every time. Assignments are shard-local row
/// indices; shard `i` of a 1-shard plan reproduces the global
/// [`IvfPartition`] k-means verbatim (same rng stream discipline).
#[derive(Debug, Clone, PartialEq)]
pub struct ShardIvfPartition {
    /// shard count the partitions were built for
    pub shards: usize,
    /// per-shard list budget (the `⌈clusters/shards⌉` figure; each shard
    /// clamps it to its own row count)
    pub lists: usize,
    /// rng seed every shard's k-means stream derives from
    pub seed: u64,
    /// per shard: centroids `[lists_i × proxy_d]`
    pub centroids: Vec<Vec<f32>>,
    /// per shard: list assignment per *local* row `[rows_i]`
    pub assignments: Vec<Vec<u32>>,
}

impl ShardIvfPartition {
    /// Deterministic per-shard k-means over the proxy table — the single
    /// source of truth the sharded cluster backend reuses verbatim
    /// (`index::shard::build_shard_ivf` derives members/radii/blocks from
    /// these assignments, so a persisted partition is bit-identical to a
    /// fresh one).
    pub fn compute(ds: &Dataset, shards: usize, lists: usize, seed: u64) -> ShardIvfPartition {
        let plan = ShardPlan::new(ds.n, shards);
        let pd = ds.proxy_d;
        let mut centroids = Vec::with_capacity(plan.count());
        let mut assignments = Vec::with_capacity(plan.count());
        for sh in 0..plan.count() {
            let (s, e) = plan.range(sh);
            let rows = e - s;
            if rows == 0 {
                centroids.push(Vec::new());
                assignments.push(Vec::new());
                continue;
            }
            let k = lists.clamp(1, rows);
            let mut rng = Pcg64::with_stream(
                seed ^ (sh as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                0x1f5,
            );
            let (c, a) = kmeans(&ds.proxies[s * pd..e * pd], rows, pd, k, 8, &mut rng);
            centroids.push(c);
            assignments.push(a);
        }
        ShardIvfPartition {
            shards: plan.count(),
            lists,
            seed,
            centroids,
            assignments,
        }
    }

    /// Does this partition serve a `(shards, lists, seed)` config verbatim?
    pub fn matches(&self, shards: usize, lists: usize, seed: u64) -> bool {
        self.shards == shards && self.lists == lists && self.seed == seed
    }
}

#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub d: usize,
    pub proxy_d: usize,
    pub classes: usize,
    pub conditional: bool,

    /// full-resolution row storage — resident corpus or `.gds`-streamed
    /// shards. Nothing outside the source/store internals touches the raw
    /// rows; every consumer goes through [`Dataset::row`],
    /// [`Dataset::row_cursor`] / [`Dataset::visit_rows`] /
    /// [`Dataset::gather_rows`], or the blocked accessors.
    pub(crate) rows: RowSource,
    /// class labels [n]
    pub labels: Vec<u32>,
    /// s=1/4 proxy table [n × proxy_d]
    pub proxies: Vec<f32>,
    /// the proxy table transposed into cache-friendly SoA row blocks — the
    /// resident layout the tiled scan kernel reads (built once here so
    /// every backend shares one copy)
    pub proxy_blocks: ProxyBlocks,
    /// the full-resolution corpus in the same dim-major block layout — the
    /// table the pre-blocked exact refine ladder scans (the row-major
    /// `data` stays the reference the scalar refine reads). Built lazily on
    /// first use via [`Dataset::row_blocks`] so scalar-only runs (the
    /// `refine_kernel = false` reference paths) never pay the duplicated
    /// corpus residency.
    pub(crate) row_blocks: OnceLock<RowBlocks>,
    /// int8 twin of `proxy_blocks` (per-row scales + correction norms),
    /// built lazily on the first quantised screen — proxies are always
    /// resident, so this tier is available for every residency mode
    pub(crate) quant_proxy: OnceLock<QuantBlocks>,
    /// row-tier int8 codes for the quantised refine pre-rung: preloaded
    /// from the `.gds` `quant_*` sections when the store carries them
    /// (both residencies — same bytes), else built from the resident
    /// corpus on first use; `None` on a streamed legacy store, which
    /// makes the pre-rung stand down
    pub(crate) quant_row_tier: OnceLock<Option<QuantRows>>,
    /// per-class + global diagonal moment summary for the Gaussian
    /// high-noise fast path: preloaded from the `.gds` v6 `gauss_*`
    /// sections when the store carries them (both residencies — same
    /// bytes), else rebuilt from the corpus on the first resident use;
    /// `None` on a streamed legacy store, which makes the Gaussian
    /// tier stand down
    pub(crate) gauss_moment_tier: OnceLock<Option<super::gauss::GaussMoments>>,
    /// per-class row indices (conditional scans)
    pub class_rows: Vec<Vec<u32>>,
    /// persisted IVF partition, if the `.gds` store carried one
    pub ivf: Option<IvfPartition>,
    /// persisted per-shard IVF partitions, if the `.gds` store carried them
    pub shard_ivf: Option<ShardIvfPartition>,
    /// optional tiers that stood down at load because their sections were
    /// present but unreadable (truncated / checksum-corrupt): `"quant"`,
    /// `"ivf"`, `"shard_ivf"`. Empty on a clean or legacy load; the engine
    /// surfaces these through the `health` op
    pub degraded: Vec<String>,
    /// checksum mismatches seen while loading optional sections (required-
    /// section mismatches fail the load instead of counting here)
    pub checksum_failures: u64,

    /// global Gaussian stats (Wiener)
    pub mean: Vec<f32>,
    pub var: Vec<f32>,

    /// k-means centroids [N_CLUSTERS × d] + assignment [n]
    pub centroids: Vec<f32>,
    pub assignments: Vec<u32>,
    /// local PCA: bases [N_CLUSTERS × PCA_RANK × d], centers [N_CLUSTERS × d]
    pub pca_bases: Vec<f32>,
    pub pca_centers: Vec<f32>,

    /// the known population law (closed-form oracle)
    pub gmm: GmmSpec,
}

impl Dataset {
    /// Synthesise a dataset from its preset (generation + all derived
    /// structures). Deterministic in (preset, seed).
    pub fn synthesize(spec: &PresetSpec, seed: u64) -> Dataset {
        let gmm = build_population(spec, seed);
        let mut rng = Pcg64::with_stream(seed, 0xda7a);
        let (data, labels) = gmm.sample_n(spec.n, &mut rng);
        Self::from_parts(spec, data, labels, gmm, seed)
    }

    pub fn from_parts(
        spec: &PresetSpec,
        data: Vec<f32>,
        labels: Vec<u32>,
        gmm: GmmSpec,
        seed: u64,
    ) -> Dataset {
        let n = spec.n;
        let d = spec.d();
        assert_eq!(data.len(), n * d);
        let proxies = proxy_embed_all(&data, n, spec.h, spec.w, spec.c);
        let proxy_blocks = ProxyBlocks::build(&proxies, n, spec.proxy_d());

        let mut mean = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                mean[j] += data[i * d + j];
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f32);
        let mut var = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                let dv = data[i * d + j] - mean[j];
                var[j] += dv * dv;
            }
        }
        var.iter_mut().for_each(|v| *v = (*v / n as f32).max(1e-6));

        let mut class_rows = vec![Vec::new(); spec.classes];
        for (i, &y) in labels.iter().enumerate() {
            class_rows[y as usize].push(i as u32);
        }

        // clusters + local PCA on a bounded subsample for speed
        let mut crng = Pcg64::with_stream(seed, 0xc1u64);
        let ncl = N_CLUSTERS.min(n);
        let (centroids, assignments) = kmeans(&data, n, d, ncl, 6, &mut crng);
        let rank = PCA_RANK.min(d);
        let mut pca_bases = vec![0.0f32; ncl * rank * d];
        let mut pca_centers = vec![0.0f32; ncl * d];
        // per-cluster row lists (bounded subsample for the PCA fit)
        let cluster_rows: Vec<Vec<usize>> = (0..ncl)
            .map(|cl| {
                let mut rows: Vec<usize> = assignments
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a as usize == cl)
                    .map(|(i, _)| i)
                    .collect();
                if rows.is_empty() {
                    rows.push(crng.below(n));
                }
                if rows.len() > 1200 {
                    crng.shuffle(&mut rows);
                    rows.truncate(1200);
                }
                rows
            })
            .collect();
        // fit all cluster bases in parallel (dominant cost of dataset build)
        let fits = crate::util::threadpool::parallel_chunks(ncl, ncl, |_, s, e| {
            let mut out = Vec::with_capacity(e - s);
            for cl in s..e {
                let mut rng = Pcg64::with_stream(seed ^ cl as u64, 0x9ca);
                out.push(local_pca(&data, d, &cluster_rows[cl], rank, 5, &mut rng));
            }
            out
        });
        for (cl, (basis, center)) in fits.into_iter().flatten().enumerate() {
            pca_bases[cl * rank * d..cl * rank * d + basis.len()].copy_from_slice(&basis);
            pca_centers[cl * d..(cl + 1) * d].copy_from_slice(&center);
        }

        Dataset {
            name: spec.name.to_string(),
            n,
            h: spec.h,
            w: spec.w,
            c: spec.c,
            d,
            proxy_d: spec.proxy_d(),
            classes: spec.classes,
            conditional: spec.conditional,
            rows: RowSource::Resident(data),
            labels,
            proxies,
            proxy_blocks,
            row_blocks: OnceLock::new(),
            quant_proxy: OnceLock::new(),
            quant_row_tier: OnceLock::new(),
            gauss_moment_tier: OnceLock::new(),
            class_rows,
            ivf: None,
            shard_ivf: None,
            degraded: Vec::new(),
            checksum_failures: 0,
            mean,
            var,
            centroids,
            assignments,
            pca_bases,
            pca_centers,
            gmm,
        }
    }

    /// Zero-copy borrow of row `i` — **resident sources only**. Production
    /// paths that may serve a streamed corpus use [`Dataset::row_cursor`] /
    /// [`Dataset::visit_rows`] instead; this accessor stays for the
    /// synthesis/ingest path, tests and bench harnesses, and panics loudly
    /// if a streamed path ever slips through to it.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        match &self.rows {
            RowSource::Resident(data) => &data[i * self.d..(i + 1) * self.d],
            RowSource::Streamed(_) => panic!(
                "Dataset::row({i}) on a streamed corpus — route row access \
                 through Dataset::row_cursor / visit_rows / gather_rows"
            ),
        }
    }

    /// Is the full-resolution corpus resident in RAM?
    pub fn is_resident(&self) -> bool {
        matches!(self.rows, RowSource::Resident(_))
    }

    /// The flat resident corpus, when there is one (`None` when streamed).
    pub fn resident_rows(&self) -> Option<&[f32]> {
        match &self.rows {
            RowSource::Resident(data) => Some(data),
            RowSource::Streamed(_) => None,
        }
    }

    /// The streamed row source, when the corpus is disk-backed.
    pub fn streamed(&self) -> Option<&std::sync::Arc<StreamedRows>> {
        match &self.rows {
            RowSource::Resident(_) => None,
            RowSource::Streamed(src) => Some(src),
        }
    }

    /// Residency telemetry of a streamed source (`None` when resident).
    pub fn source_stats(&self) -> Option<RowSourceStats> {
        self.streamed().map(|src| src.stats())
    }

    /// Source-agnostic sequential row access (see [`RowCursor`]): resident
    /// rows borrow straight from the corpus, streamed rows pin one shard's
    /// blocks at a time through the LRU.
    pub fn row_cursor(&self) -> RowCursor<'_> {
        RowCursor::new(&self.rows, self.d)
    }

    /// Visit rows `ids` **in the given order**, calling `f(gid, row)` for
    /// each. Bit-identical values across sources; on a streamed corpus
    /// consecutive ids inside one shard share a single LRU probe, so
    /// ascending visits degrade gracefully to shard-at-a-time passes.
    pub fn visit_rows(
        &self,
        ids: impl IntoIterator<Item = u32>,
        mut f: impl FnMut(u32, &[f32]),
    ) {
        let mut cur = self.row_cursor();
        for gid in ids {
            f(gid, cur.row(gid));
        }
    }

    #[inline]
    pub fn proxy_row(&self, i: usize) -> &[f32] {
        &self.proxies[i * self.proxy_d..(i + 1) * self.proxy_d]
    }

    /// The pre-blocked full-resolution corpus, transposed on first use
    /// (thread-safe; every subsequent call returns the same resident copy).
    /// Resident sources only — a streamed corpus never materialises the
    /// whole blocked table; its consumers go shard-at-a-time through
    /// [`StreamedRows::shard_blocks`] instead.
    pub fn row_blocks(&self) -> &RowBlocks {
        self.row_blocks.get_or_init(|| match &self.rows {
            RowSource::Resident(data) => RowBlocks::build(data, self.n, self.d),
            RowSource::Streamed(_) => panic!(
                "Dataset::row_blocks on a streamed corpus — refine paths \
                 stream per-shard blocks through the row source instead"
            ),
        })
    }

    /// The int8 twin of the proxy block table, quantised on the first
    /// quantised screen (thread-safe; every subsequent call returns the
    /// same resident copy). Proxies are always resident, so this tier is
    /// available in both residency modes.
    pub fn quant_proxy_blocks(&self) -> &QuantBlocks {
        self.quant_proxy
            .get_or_init(|| QuantBlocks::from_blocks(&self.proxy_blocks))
    }

    /// Row-tier int8 codes for the quantised refine pre-rung. Preloaded
    /// from the `.gds` `quant_*` sections when the store carries them
    /// (see `data::store`); otherwise built from the resident corpus on
    /// first use. Returns `None` on a streamed legacy store that predates
    /// the quant sections — the pre-rung stands down and the refine ladder
    /// runs exactly as before.
    pub fn quant_rows(&self) -> Option<&QuantRows> {
        self.quant_row_tier
            .get_or_init(|| match &self.rows {
                RowSource::Resident(data) => Some(QuantRows::build(data, self.n, self.d)),
                RowSource::Streamed(_) => None,
            })
            .as_ref()
    }

    /// Per-class + global diagonal moments for the Gaussian high-noise
    /// fast path. Preloaded from the `.gds` v6 `gauss_*` sections when
    /// the store carries them (see `data::store`); otherwise rebuilt
    /// with one streamed corpus pass on a **resident** legacy open.
    /// Returns `None` on a streamed legacy store — the Gaussian tier
    /// stands down and every tick runs full retrieval, per the
    /// degradation discipline (a serve-time whole-corpus read off disk
    /// is exactly what streamed serving exists to avoid).
    pub fn gauss_moments(&self) -> Option<&super::gauss::GaussMoments> {
        self.gauss_moment_tier
            .get_or_init(|| match &self.rows {
                RowSource::Resident(_) => Some(super::gauss::GaussMoments::build(self)),
                RowSource::Streamed(_) => None,
            })
            .as_ref()
    }

    /// Rows `[s, e)` as a pre-blocked kernel table harvesting global ids —
    /// the build a (possibly evicted) corpus shard rebuilds from. Resident:
    /// gathered from the corpus; streamed: read off the store (bit-identical
    /// either way).
    pub fn build_range_blocks(&self, s: usize, e: usize) -> RowBlocks {
        let ids: Vec<u32> = (s as u32..e as u32).collect();
        match &self.rows {
            RowSource::Resident(data) => RowBlocks::build_subset(data, self.d, &ids),
            RowSource::Streamed(src) => {
                RowBlocks::build_local(&src.read_range(s, e), self.d, ids)
            }
        }
    }

    /// Fill `out` (`n × d`) with the whole corpus, shard-at-a-time through
    /// the row source — the staging path for whole-corpus device uploads.
    /// A streamed source never holds more than the LRU budget beyond `out`
    /// itself; the bytes are identical to the resident copy.
    pub fn copy_all_rows_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n * self.d);
        match &self.rows {
            RowSource::Resident(data) => out.copy_from_slice(data),
            RowSource::Streamed(src) => {
                for sh in 0..src.plan().count() {
                    let (s, e) = src.plan().range(sh);
                    let blocks = src.shard_blocks(sh);
                    for r in s..e {
                        blocks
                            .copy_row_into(r - s, &mut out[r * self.d..(r + 1) * self.d]);
                    }
                }
            }
        }
    }

    /// Gather rows into a caller-provided padded buffer [bucket × d]; rows
    /// beyond `idx.len()` are zero-filled. Routed through the row source,
    /// so streamed corpora gather through the shard LRU.
    pub fn gather_rows(&self, idx: &[u32], bucket: usize, out: &mut Vec<f32>, mask: &mut Vec<f32>) {
        out.clear();
        out.resize(bucket * self.d, 0.0);
        mask.clear();
        mask.resize(bucket, 0.0);
        let mut cur = self.row_cursor();
        for (slot, &i) in idx.iter().take(bucket).enumerate() {
            out[slot * self.d..(slot + 1) * self.d].copy_from_slice(cur.row(i));
            mask[slot] = 1.0;
        }
    }

    /// Shard-aware ingest: a copy of this dataset with rows permuted so
    /// proxy-space k-means cluster members are contiguous. Contiguous
    /// shards then become spatially coherent, which is what lets the warm
    /// screen's whole-shard covering-radius bound actually skip shards on
    /// real corpora. Deterministic in `(lists, seed)`; ingest-time only
    /// (requires a resident corpus). Row-order-keyed derived structures
    /// (labels, class rows, per-row cluster assignments, proxy blocks) are
    /// permuted/rebuilt; order-free global stats (mean/var, PCA bases,
    /// GMM) carry over; persisted IVF partitions are dropped (keyed to the
    /// old order).
    pub fn with_clustered_rows(&self, lists: usize, seed: u64) -> Dataset {
        let data = self
            .resident_rows()
            .expect("clustered ingest needs a resident corpus");
        let part = IvfPartition::compute(self, lists, seed);
        let mut order: Vec<usize> = (0..self.n).collect();
        order.sort_by_key(|&i| (part.assignments[i], i as u32));
        let (d, pd) = (self.d, self.proxy_d);
        let mut new_data = vec![0.0f32; self.n * d];
        let mut new_proxies = vec![0.0f32; self.n * pd];
        let mut new_labels = vec![0u32; self.n];
        let mut new_assign = vec![0u32; self.n];
        for (new, &old) in order.iter().enumerate() {
            new_data[new * d..(new + 1) * d].copy_from_slice(&data[old * d..(old + 1) * d]);
            new_proxies[new * pd..(new + 1) * pd]
                .copy_from_slice(&self.proxies[old * pd..(old + 1) * pd]);
            new_labels[new] = self.labels[old];
            new_assign[new] = self.assignments[old];
        }
        let mut class_rows = vec![Vec::new(); self.classes];
        for (i, &y) in new_labels.iter().enumerate() {
            class_rows[y as usize].push(i as u32);
        }
        Dataset {
            name: self.name.clone(),
            n: self.n,
            h: self.h,
            w: self.w,
            c: self.c,
            d,
            proxy_d: pd,
            classes: self.classes,
            conditional: self.conditional,
            rows: RowSource::Resident(new_data),
            labels: new_labels,
            proxy_blocks: ProxyBlocks::build(&new_proxies, self.n, pd),
            proxies: new_proxies,
            row_blocks: OnceLock::new(),
            quant_proxy: OnceLock::new(),
            quant_row_tier: OnceLock::new(),
            gauss_moment_tier: OnceLock::new(),
            class_rows,
            ivf: None,
            shard_ivf: None,
            degraded: Vec::new(),
            checksum_failures: 0,
            mean: self.mean.clone(),
            var: self.var.clone(),
            centroids: self.centroids.clone(),
            assignments: new_assign,
            pca_bases: self.pca_bases.clone(),
            pca_centers: self.pca_centers.clone(),
            gmm: self.gmm.clone(),
        }
    }

    /// Index of the nearest k-means cluster to a query (PCA basis pick).
    pub fn nearest_cluster(&self, q: &[f32]) -> usize {
        let ncl = self.centroids.len() / self.d;
        let mut best = 0;
        let mut best_d = f32::INFINITY;
        for cl in 0..ncl {
            let c = &self.centroids[cl * self.d..(cl + 1) * self.d];
            let dd: f32 = c.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
            if dd < best_d {
                best_d = dd;
                best = cl;
            }
        }
        best
    }

    pub fn pca_basis(&self, cluster: usize) -> (&[f32], &[f32]) {
        let rank = PCA_RANK.min(self.d);
        let b = &self.pca_bases[cluster * rank * self.d..(cluster + 1) * rank * self.d];
        let c = &self.pca_centers[cluster * self.d..(cluster + 1) * self.d];
        (b, c)
    }

    /// Logical corpus bytes (the paper's Memory-column attribution): the
    /// full `n × d` rows plus the resident side tables, independent of
    /// whether the rows are actually resident or streamed.
    pub fn bytes(&self) -> u64 {
        (self.n * self.d + self.proxies.len() + self.mean.len() + self.var.len()) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    fn tiny() -> Dataset {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 300;
        Dataset::synthesize(&spec, 42)
    }

    #[test]
    fn synthesis_produces_consistent_shapes() {
        let ds = tiny();
        assert!(ds.is_resident());
        assert_eq!(ds.resident_rows().unwrap().len(), 300 * 256);
        assert_eq!(ds.proxies.len(), 300 * 16);
        assert_eq!(ds.labels.len(), 300);
        assert_eq!(ds.class_rows.iter().map(Vec::len).sum::<usize>(), 300);
        assert!(ds.labels.iter().all(|&y| (y as usize) < ds.classes));
    }

    #[test]
    fn synthesis_is_deterministic() {
        let spec = {
            let mut s = preset("moons").unwrap().clone();
            s.n = 100;
            s
        };
        let a = Dataset::synthesize(&spec, 7);
        let b = Dataset::synthesize(&spec, 7);
        assert_eq!(a.resident_rows(), b.resident_rows());
        assert_eq!(a.labels, b.labels);
        let c = Dataset::synthesize(&spec, 8);
        assert_ne!(a.resident_rows(), c.resident_rows());
    }

    #[test]
    fn gather_pads_and_masks() {
        let ds = tiny();
        let mut buf = Vec::new();
        let mut mask = Vec::new();
        ds.gather_rows(&[3, 5], 4, &mut buf, &mut mask);
        assert_eq!(buf.len(), 4 * ds.d);
        assert_eq!(mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(&buf[..ds.d], ds.row(3));
        assert!(buf[2 * ds.d..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn nearest_cluster_self_consistent() {
        let ds = tiny();
        // a centroid's nearest cluster is itself
        let cl = 3.min(ds.centroids.len() / ds.d - 1);
        let q = ds.centroids[cl * ds.d..(cl + 1) * ds.d].to_vec();
        assert_eq!(ds.nearest_cluster(&q), cl);
    }

    #[test]
    fn variance_is_positive() {
        let ds = tiny();
        assert!(ds.var.iter().all(|&v| v > 0.0));
    }

    #[test]
    fn proxy_blocks_mirror_the_proxy_table() {
        use crate::index::kernel::BLOCK_ROWS;
        let ds = tiny();
        assert_eq!(ds.proxy_blocks.rows, ds.n);
        assert_eq!(ds.proxy_blocks.dim, ds.proxy_d);
        for i in [0usize, 1, 31, 32, 299] {
            let (b, lane) = (i / BLOCK_ROWS, i % BLOCK_ROWS);
            assert_eq!(ds.proxy_blocks.id(b, lane), i as u32);
            for j in 0..ds.proxy_d {
                assert_eq!(
                    ds.proxy_blocks.block(b)[j * BLOCK_ROWS + lane],
                    ds.proxy_row(i)[j],
                    "row {i} dim {j}"
                );
            }
        }
    }

    #[test]
    fn row_blocks_mirror_the_full_resolution_corpus() {
        use crate::index::kernel::BLOCK_ROWS;
        let ds = tiny();
        // lazy: nothing resident until the first accessor call
        assert!(ds.row_blocks.get().is_none(), "row blocks must build lazily");
        let rb = ds.row_blocks();
        assert_eq!(rb.rows, ds.n);
        assert_eq!(rb.dim, ds.d);
        for i in [0usize, 31, 32, 63, 299] {
            let (b, lane) = (i / BLOCK_ROWS, i % BLOCK_ROWS);
            assert_eq!(rb.id(b, lane), i as u32);
            for j in (0..ds.d).step_by(17) {
                assert_eq!(
                    rb.block(b)[j * BLOCK_ROWS + lane],
                    ds.row(i)[j],
                    "row {i} dim {j}"
                );
            }
        }
        // the accessor memoises one copy
        assert!(std::ptr::eq(rb, ds.row_blocks()));
    }

    #[test]
    fn visit_rows_preserves_order_and_values() {
        let ds = tiny();
        let ids = [7u32, 0, 299, 7, 150];
        let mut seen = Vec::new();
        ds.visit_rows(ids.iter().copied(), |gid, row| {
            assert_eq!(row, ds.row(gid as usize));
            seen.push(gid);
        });
        assert_eq!(seen, ids, "visit order must be the given order");
        let mut cur = ds.row_cursor();
        assert_eq!(cur.row(42), ds.row(42));
    }

    #[test]
    fn copy_all_rows_matches_resident_corpus() {
        let ds = tiny();
        let mut out = vec![0.0f32; ds.n * ds.d];
        ds.copy_all_rows_into(&mut out);
        assert_eq!(out.as_slice(), ds.resident_rows().unwrap());
        let rb = ds.build_range_blocks(10, 45);
        assert_eq!(rb.rows, 35);
        assert_eq!(rb.id(0, 0), 10);
        let mut row = vec![0.0f32; ds.d];
        rb.copy_row_into(5, &mut row);
        assert_eq!(row.as_slice(), ds.row(15));
    }

    #[test]
    fn clustered_ingest_permutes_coherently() {
        // Satellite: shard-aware ingest — cluster members become contiguous
        // while every row-keyed structure stays consistent
        let ds = tiny();
        let cl = ds.with_clustered_rows(8, 5);
        assert_eq!(cl.n, ds.n);
        // same multiset of rows: sort both corpora row-wise via first dims
        let key = |d: &Dataset, i: usize| -> Vec<u32> {
            d.row(i).iter().take(4).map(|v| v.to_bits()).collect()
        };
        let mut a: Vec<Vec<u32>> = (0..ds.n).map(|i| key(&ds, i)).collect();
        let mut b: Vec<Vec<u32>> = (0..cl.n).map(|i| key(&cl, i)).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b, "ingest must permute, not alter, the rows");
        // proxies/labels/class_rows follow their row
        for i in [0usize, 1, 150, 299] {
            assert_eq!(
                &cl.proxies[i * cl.proxy_d..(i + 1) * cl.proxy_d],
                crate::data::synthetic::proxy_embed(cl.row(i), cl.h, cl.w, cl.c).as_slice(),
                "proxy row {i} must match its permuted row"
            );
        }
        assert_eq!(cl.class_rows.iter().map(Vec::len).sum::<usize>(), cl.n);
        for (y, rows) in cl.class_rows.iter().enumerate() {
            assert!(rows.iter().all(|&i| cl.labels[i as usize] == y as u32));
        }
        // the permutation is exactly "sorted by (cluster assignment, id)" of
        // the same deterministic partition — cluster members are contiguous
        let part = IvfPartition::compute(&ds, 8, 5);
        let mut order: Vec<usize> = (0..ds.n).collect();
        order.sort_by_key(|&i| (part.assignments[i], i as u32));
        for (new, &old) in order.iter().enumerate().step_by(37) {
            assert_eq!(cl.row(new), ds.row(old), "row {new} must come from {old}");
            assert_eq!(cl.labels[new], ds.labels[old]);
        }
        let permuted_assign: Vec<u32> = order.iter().map(|&i| part.assignments[i]).collect();
        assert!(
            permuted_assign.windows(2).all(|w| w[0] <= w[1]),
            "cluster members must be contiguous after ingest ordering"
        );
        // determinism + ivf caches dropped
        let again = ds.with_clustered_rows(8, 5);
        assert_eq!(cl.resident_rows(), again.resident_rows());
        assert!(cl.ivf.is_none() && cl.shard_ivf.is_none());
    }

    #[test]
    fn shard_ivf_partition_is_deterministic_and_keyed() {
        let ds = tiny();
        let a = ShardIvfPartition::compute(&ds, 4, 3, 9);
        let b = ShardIvfPartition::compute(&ds, 4, 3, 9);
        assert_eq!(a, b);
        assert!(a.matches(4, 3, 9) && !a.matches(4, 3, 10) && !a.matches(5, 3, 9));
        assert_eq!(a.centroids.len(), 4);
        let plan = ShardPlan::new(ds.n, 4);
        for sh in 0..4 {
            assert_eq!(a.assignments[sh].len(), plan.rows_in(sh));
            assert_eq!(a.centroids[sh].len() % ds.proxy_d, 0);
        }
    }

    #[test]
    fn ivf_partition_is_deterministic_and_clamped() {
        let ds = tiny();
        let a = IvfPartition::compute(&ds, 8, 5);
        let b = IvfPartition::compute(&ds, 8, 5);
        assert_eq!(a, b);
        assert!(a.matches(8, 5) && !a.matches(8, 6) && !a.matches(9, 5));
        assert_eq!(a.assignments.len(), ds.n);
        assert_eq!(a.centroids.len(), 8 * ds.proxy_d);
        // lists clamp to n (tiny corpus so the degenerate k-means is cheap)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 40;
        let small = Dataset::synthesize(&spec, 2);
        let huge = IvfPartition::compute(&small, 10_000, 1);
        assert_eq!(huge.lists, small.n, "lists clamp to n");
    }
}
