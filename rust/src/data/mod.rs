//! Datasets: the synthetic hierarchical-GMM image generator (the paper's
//! benchmark stand-ins, DESIGN.md §3), the known population mixture each
//! dataset is drawn from (which powers the closed-form oracle), clustering
//! + local PCA bases for the PCA baseline, and the `.gds` binary store.

pub mod cluster;
pub mod dataset;
pub mod gauss;
pub mod gmm;
pub mod rows;
pub mod shard;
pub mod store;
pub mod synthetic;

pub use dataset::{Dataset, IvfPartition, ShardIvfPartition};
pub use gauss::GaussMoments;
pub use gmm::GmmSpec;
pub use rows::{RowCursor, RowSource, RowSourceStats, StreamedRows};
pub use shard::{CorpusShards, ShardCacheStats, ShardPlan};
pub use store::ShardReader;
