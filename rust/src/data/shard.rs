//! The sharded corpus layer: `CorpusShards` splits a dataset's rows, proxy
//! table and (lazily) full-resolution row blocks into `shards` independent,
//! contiguous shards so retrieval can scan shard-parallel and the blocked
//! working set can be memory-bounded.
//!
//! * [`ShardPlan`] is the pure partition — near-equal contiguous row ranges
//!   (the same `split_ranges` discipline the thread-sharded scans already
//!   use), deterministic in `(n, shards)` so a store writer and a reader
//!   always agree on shard boundaries.
//! * Each shard owns its proxy rows as a pre-blocked kernel table
//!   ([`ProxyBlocks`] with global row ids at harvest), plus a shard-level
//!   centroid + covering radius (the substrate for whole-shard exact skips
//!   in the warm-started screen) and per-class row counts (so conditional
//!   scans skip shards with no support outright).
//! * Full-resolution [`RowBlocks`] are built per shard on first refine use
//!   and cached in an LRU bounded by `mem_budget` bytes: cold shards are
//!   evicted least-recently-used and rebuilt on the next touch through the
//!   dataset's [`RowSource`](crate::data::rows::RowSource) — re-gathered
//!   from the resident corpus, or streamed off the `.gds` store when the
//!   corpus is disk-backed. When the dataset's streamed source shares this
//!   layer's shard plan, residency **delegates** to the source's own LRU
//!   outright: one budget, one cache, no duplicated blocks.
//!
//! On every exact path the layer never changes *what* is computed — every
//! consumer (`index::shard::ShardedBackend`) merges per-shard results
//! exactly — so shard count and memory budget are pure
//! performance/residency knobs. The one exception is the cluster
//! backend's approximate mode (`nprobe > 0`, `is_exact() == false`),
//! whose per-shard IVF partitions necessarily depend on the plan.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::data::dataset::Dataset;
use crate::data::rows::StreamedRows;
use crate::index::kernel::{ProxyBlocks, QuantBlocks, RowBlocks};
use crate::util::threadpool::split_ranges;

/// The pure corpus partition: near-equal contiguous row ranges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// total corpus rows
    pub n: usize,
    ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Split `n` rows into (up to) `shards` contiguous ranges. `shards`
    /// clamps to `n` (no shard is ever empty when rows exist); `n == 0`
    /// yields one empty shard so every consumer keeps its single-shard
    /// shape on an empty corpus, mirroring `split_ranges`.
    pub fn new(n: usize, shards: usize) -> ShardPlan {
        ShardPlan {
            n,
            ranges: split_ranges(n, shards.max(1)),
        }
    }

    #[inline]
    pub fn count(&self) -> usize {
        self.ranges.len()
    }

    /// Half-open global row range `[start, end)` of shard `i`.
    #[inline]
    pub fn range(&self, i: usize) -> (usize, usize) {
        self.ranges[i]
    }

    #[inline]
    pub fn rows_in(&self, i: usize) -> usize {
        let (s, e) = self.ranges[i];
        e - s
    }

    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// Shard owning global row `row` (ranges are contiguous ascending).
    pub fn shard_of(&self, row: usize) -> usize {
        debug_assert!(row < self.n.max(1));
        self.ranges
            .binary_search_by(|&(s, e)| {
                if row < s {
                    std::cmp::Ordering::Greater
                } else if row >= e {
                    std::cmp::Ordering::Less
                } else {
                    std::cmp::Ordering::Equal
                }
            })
            .unwrap_or(self.ranges.len() - 1)
    }
}

/// One shard's resident coarse-screen structures.
#[derive(Debug)]
pub struct ShardProxy {
    /// the shard's proxy rows as a pre-blocked kernel table; lanes harvest
    /// global row ids
    pub blocks: ProxyBlocks,
    /// mean of the shard's proxy rows
    pub centroid: Vec<f32>,
    /// max member→centroid Euclidean distance — `(d(q, c) − r)²` lower-
    /// bounds every member's distance, so a full heap can skip the shard
    pub radius: f32,
    /// rows per class inside the shard (conditional-scan skip test)
    pub class_counts: Vec<u32>,
    /// int8 twin of `blocks` (per-row scales + correction norms), built
    /// lazily on the shard's first quantised screen
    quant: OnceLock<QuantBlocks>,
}

impl ShardProxy {
    /// The shard's quantised proxy tier, built on first use (thread-safe;
    /// subsequent calls return the same resident copy).
    pub fn quant(&self) -> &QuantBlocks {
        self.quant
            .get_or_init(|| QuantBlocks::from_blocks(&self.blocks))
    }
}

#[derive(Debug, Default)]
struct Lru {
    resident: HashMap<usize, Arc<RowBlocks>>,
    /// front = least recently used
    order: VecDeque<usize>,
    bytes: u64,
}

/// Snapshot of the row-block cache (telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardCacheStats {
    pub shards: usize,
    pub resident: usize,
    pub resident_bytes: u64,
    /// high-water mark of `resident_bytes` over the cache's lifetime
    pub peak_row_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// row-block builds fed from the `.gds` store (streamed path)
    pub streamed_loads: u64,
    /// full-resolution rows read off disk (0 for a resident corpus)
    pub rows_streamed: u64,
    /// transient streamed-read failures recovered by the bounded retry
    pub retries: u64,
    /// shard checksum mismatches the streamed source observed
    pub checksum_failures: u64,
    /// faults the configured injector put into streamed reads
    pub faults_injected: u64,
}

/// The sharded corpus: per-shard proxy tables (resident) plus LRU-cached,
/// optionally disk-streamed full-resolution row blocks.
#[derive(Debug)]
pub struct CorpusShards {
    plan: ShardPlan,
    proxy: Vec<ShardProxy>,
    /// LRU budget in bytes for resident row blocks; 0 = unbounded
    budget_bytes: u64,
    lru: Mutex<Lru>,
    /// the dataset's streamed row source when its shard plan matches ours —
    /// row-block residency then delegates to the source's LRU (one budget,
    /// no double caching). `None` for resident corpora and for the rare
    /// plan-mismatched streamed case (which builds through its own LRU via
    /// range reads instead).
    source: Option<Arc<StreamedRows>>,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    streamed_loads: AtomicU64,
    peak_bytes: AtomicU64,
}

impl CorpusShards {
    /// Build the shard plan + per-shard proxy structures (one pass over the
    /// proxy table). Row blocks stay cold until [`CorpusShards::row_blocks`].
    pub fn build(ds: &Dataset, shards: usize, mem_budget_mb: usize) -> CorpusShards {
        let plan = ShardPlan::new(ds.n, shards);
        let pd = ds.proxy_d;
        let nclass = ds.classes.max(1);
        let proxy = plan
            .ranges()
            .iter()
            .map(|&(s, e)| {
                let rows = e - s;
                let ids: Vec<u32> = (s as u32..e as u32).collect();
                let blocks = ProxyBlocks::build_subset(&ds.proxies, pd, &ids);
                let mut centroid = vec![0.0f32; pd];
                for r in s..e {
                    for (c, &v) in centroid.iter_mut().zip(ds.proxy_row(r)) {
                        *c += v;
                    }
                }
                centroid.iter_mut().for_each(|c| *c /= rows.max(1) as f32);
                let mut worst = 0.0f32;
                for r in s..e {
                    let d2: f32 = ds
                        .proxy_row(r)
                        .iter()
                        .zip(&centroid)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    worst = worst.max(d2);
                }
                let mut class_counts = vec![0u32; nclass];
                for r in s..e {
                    class_counts[ds.labels[r] as usize] += 1;
                }
                ShardProxy {
                    blocks,
                    centroid,
                    radius: worst.sqrt(),
                    class_counts,
                    quant: OnceLock::new(),
                }
            })
            .collect();
        // delegate row-block residency to a plan-matched streamed source:
        // the dataset's LRU (and budget) is then the single cache. Only
        // sound when the source's budget honours ours (in the engine both
        // knobs are cfg.mem_budget_mb, so delegation always engages); a
        // direct-API mismatch keeps this layer's own bounded LRU — still
        // streamed, via range reads — so `mem_budget_mb` always binds.
        let own_budget = mem_budget_mb as u64 * 1024 * 1024;
        let source = ds
            .streamed()
            .filter(|src| *src.plan() == plan)
            .filter(|src| {
                own_budget == 0
                    || (src.budget_bytes() > 0 && src.budget_bytes() <= own_budget)
            })
            .cloned();
        CorpusShards {
            plan,
            proxy,
            budget_bytes: mem_budget_mb as u64 * 1024 * 1024,
            lru: Mutex::new(Lru::default()),
            source,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            streamed_loads: AtomicU64::new(0),
            peak_bytes: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    #[inline]
    pub fn proxy(&self, shard: usize) -> &ShardProxy {
        &self.proxy[shard]
    }

    /// Does row-block residency delegate to the dataset's streamed source?
    pub fn is_streamed(&self) -> bool {
        self.source.is_some()
    }

    /// The shard's full-resolution row blocks: served by the dataset's
    /// streamed source when its plan matches (one shared LRU), otherwise
    /// LRU-cached here — built on first touch through the dataset's row
    /// source and evicted least-recently-used once resident bytes exceed
    /// the budget.
    pub fn row_blocks(&self, shard: usize, ds: &Dataset) -> Arc<RowBlocks> {
        if let Some(src) = &self.source {
            return src.shard_blocks(shard);
        }
        if let Some(rb) = self.touch(shard) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return rb;
        }
        // build OUTSIDE the lock so shard-parallel refines construct cold
        // shards concurrently instead of convoying on the cache mutex; a
        // racing builder may duplicate the (deterministic) work, in which
        // case the first insert wins and the duplicate is dropped
        self.misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(self.build_row_blocks(shard, ds));
        let mut lru = self.lru.lock().unwrap();
        if let Some(rb) = lru.resident.get(&shard) {
            return Arc::clone(rb); // lost the race — byte-identical copy
        }
        lru.bytes += built.bytes();
        lru.resident.insert(shard, Arc::clone(&built));
        lru.order.push_back(shard);
        self.peak_bytes.fetch_max(lru.bytes, Ordering::Relaxed);
        if self.budget_bytes > 0 {
            // keep at least the shard just requested resident — a budget
            // smaller than one shard must not thrash the current user
            while lru.bytes > self.budget_bytes && lru.order.len() > 1 {
                let victim = lru.order.pop_front().unwrap();
                if victim == shard {
                    lru.order.push_back(victim);
                    continue;
                }
                if let Some(old) = lru.resident.remove(&victim) {
                    lru.bytes -= old.bytes();
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        built
    }

    /// Cache lookup: on a hit, move the shard to the MRU position.
    fn touch(&self, shard: usize) -> Option<Arc<RowBlocks>> {
        let mut lru = self.lru.lock().unwrap();
        let rb = Arc::clone(lru.resident.get(&shard)?);
        if let Some(pos) = lru.order.iter().position(|&x| x == shard) {
            lru.order.remove(pos);
        }
        lru.order.push_back(shard);
        Some(rb)
    }

    fn build_row_blocks(&self, shard: usize, ds: &Dataset) -> RowBlocks {
        // route the rebuild through the dataset's row source: resident
        // corpora gather in RAM, a (plan-mismatched) streamed corpus reads
        // the row range off the store
        if !ds.is_resident() {
            self.streamed_loads.fetch_add(1, Ordering::Relaxed);
        }
        let (s, e) = self.plan.range(shard);
        ds.build_range_blocks(s, e)
    }

    pub fn cache_stats(&self) -> ShardCacheStats {
        if let Some(src) = &self.source {
            // delegated residency: the source's LRU is the cache
            let s = src.stats();
            return ShardCacheStats {
                shards: self.plan.count(),
                resident: s.resident_shards,
                resident_bytes: s.resident_bytes,
                peak_row_bytes: s.peak_row_bytes,
                hits: s.hits,
                misses: s.misses,
                evictions: s.evictions,
                // every cold load of a streamed source comes off disk
                streamed_loads: s.misses,
                rows_streamed: s.rows_streamed,
                retries: s.retries,
                checksum_failures: s.checksum_failures,
                faults_injected: s.faults_injected,
            };
        }
        let lru = self.lru.lock().unwrap();
        ShardCacheStats {
            shards: self.plan.count(),
            resident: lru.resident.len(),
            resident_bytes: lru.bytes,
            peak_row_bytes: self.peak_bytes.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            streamed_loads: self.streamed_loads.load(Ordering::Relaxed),
            rows_streamed: 0,
            retries: 0,
            checksum_failures: 0,
            faults_injected: 0,
        }
    }

    /// Zero the monotonic cache counters (bench harness hook); resident
    /// blocks stay resident.
    pub fn reset_counters(&self) {
        if let Some(src) = &self.source {
            src.reset_counters();
        }
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.evictions.store(0, Ordering::Relaxed);
        self.streamed_loads.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store;
    use crate::data::synthetic::preset;
    use crate::index::kernel::BLOCK_ROWS;
    use crate::util::prop::{forall, gen};

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    #[test]
    fn plan_degenerate_splits() {
        // Satellite: n < shards clamps to n single-row shards; n == 0
        // yields exactly one empty shard; shards == 0 behaves like 1.
        let p = ShardPlan::new(3, 16);
        assert_eq!(p.count(), 3);
        assert_eq!(p.ranges(), &[(0, 1), (1, 2), (2, 3)]);
        let empty = ShardPlan::new(0, 4);
        assert_eq!(empty.count(), 1);
        assert_eq!(empty.range(0), (0, 0));
        assert_eq!(empty.rows_in(0), 0);
        assert_eq!(ShardPlan::new(5, 0).count(), 1);
        let single = ShardPlan::new(1, 7);
        assert_eq!(single.count(), 1);
        assert_eq!(single.range(0), (0, 1));
    }

    #[test]
    fn plan_partitions_exactly_and_shard_of_agrees() {
        forall(41, 40, |rng| {
            let n = gen::usize_in(rng, 1, 500);
            let shards = gen::usize_in(rng, 1, 20);
            let p = ShardPlan::new(n, shards);
            let total: usize = p.ranges().iter().map(|(s, e)| e - s).sum();
            crate::prop_assert!(total == n, "partition covers all rows");
            crate::prop_assert!(p.count() == shards.min(n), "count clamps");
            for i in 0..p.count() {
                let (s, e) = p.range(i);
                crate::prop_assert!(s < e, "no empty shard when n > 0");
                for row in [s, e - 1] {
                    crate::prop_assert!(p.shard_of(row) == i, "shard_of({row}) != {i}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn shard_proxies_mirror_the_table_with_global_ids() {
        let ds = tiny(130, 3);
        let cs = CorpusShards::build(&ds, 4, 0);
        assert_eq!(cs.plan().count(), 4);
        let mut seen = 0usize;
        for sh in 0..cs.plan().count() {
            let (s, e) = cs.plan().range(sh);
            let sp = cs.proxy(sh);
            assert_eq!(sp.blocks.rows, e - s);
            for local in 0..(e - s) {
                let gid = s + local;
                let (b, lane) = (local / BLOCK_ROWS, local % BLOCK_ROWS);
                assert_eq!(sp.blocks.id(b, lane), gid as u32);
                for j in 0..ds.proxy_d {
                    assert_eq!(
                        sp.blocks.block(b)[j * BLOCK_ROWS + lane],
                        ds.proxy_row(gid)[j],
                        "shard {sh} row {gid} dim {j}"
                    );
                }
                seen += 1;
            }
            // covering radius actually covers every member
            for r in s..e {
                let d2: f32 = ds
                    .proxy_row(r)
                    .iter()
                    .zip(&sp.centroid)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(d2.sqrt() <= sp.radius + 1e-4, "shard {sh} row {r}");
            }
            assert_eq!(
                sp.class_counts.iter().sum::<u32>() as usize,
                e - s,
                "class counts partition the shard"
            );
        }
        assert_eq!(seen, ds.n);
    }

    #[test]
    fn row_blocks_match_corpus_and_cache_hits() {
        let ds = tiny(100, 5);
        let cs = CorpusShards::build(&ds, 3, 0);
        for sh in 0..3 {
            let rb = cs.row_blocks(sh, &ds);
            let (s, e) = cs.plan().range(sh);
            assert_eq!(rb.rows, e - s);
            for local in 0..(e - s) {
                let gid = s + local;
                let (b, lane) = (local / BLOCK_ROWS, local % BLOCK_ROWS);
                assert_eq!(rb.id(b, lane), gid as u32);
                for j in (0..ds.d).step_by(13) {
                    assert_eq!(rb.block(b)[j * BLOCK_ROWS + lane], ds.row(gid)[j]);
                }
            }
            // second touch is a hit on the same resident copy
            let again = cs.row_blocks(sh, &ds);
            assert!(Arc::ptr_eq(&rb, &again));
        }
        let st = cs.cache_stats();
        assert_eq!(st.misses, 3);
        assert_eq!(st.hits, 3);
        assert_eq!(st.evictions, 0, "unbounded budget never evicts");
        assert_eq!(st.resident, 3);
    }

    #[test]
    fn lru_evicts_cold_shards_under_budget_and_rebuilds_identically() {
        let ds = tiny(200, 7);
        // budget of ~1 shard: every new shard touch evicts the coldest
        let shard_bytes = {
            let probe = CorpusShards::build(&ds, 4, 0);
            probe.row_blocks(0, &ds).bytes()
        };
        let budget_mb = (shard_bytes as usize).div_ceil(1024 * 1024); // ≥ 1 shard
        let cs = CorpusShards::build(&ds, 4, budget_mb.max(1));
        let first = cs.row_blocks(0, &ds);
        let b0 = first.block(0).to_vec();
        for sh in 0..4 {
            let _ = cs.row_blocks(sh, &ds);
        }
        let st = cs.cache_stats();
        assert!(st.evictions > 0, "tiny budget must evict cold shards");
        assert!(
            st.resident < 4,
            "resident set stays bounded: {} shards",
            st.resident
        );
        // an evicted shard rebuilds byte-identically
        let rebuilt = cs.row_blocks(0, &ds);
        assert_eq!(rebuilt.block(0), b0.as_slice());
        assert!(cs.cache_stats().misses > 4, "rebuild counts as a miss");
    }

    #[test]
    fn streamed_row_blocks_equal_resident_builds() {
        // a streamed dataset's shard layer delegates to the source LRU and
        // serves byte-identical blocks to the resident build
        let ds = tiny(90, 11);
        let dir = std::env::temp_dir().join("golddiff_shard_stream_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = store::store_path(&dir, "cifar-sim");
        store::save_sharded(&ds, &path, 3).unwrap();
        let ds_streamed = store::open_streaming(&path, 3, 0).unwrap();
        let streamed = CorpusShards::build(&ds_streamed, 3, 0);
        let resident = CorpusShards::build(&ds, 3, 0);
        assert!(streamed.is_streamed(), "plan-matched source must delegate");
        assert!(!resident.is_streamed());
        for sh in 0..3 {
            let a = streamed.row_blocks(sh, &ds_streamed);
            let b = resident.row_blocks(sh, &ds);
            assert_eq!(a.rows, b.rows, "shard {sh}");
            for blk in 0..a.n_blocks() {
                assert_eq!(a.block(blk), b.block(blk), "shard {sh} block {blk}");
            }
        }
        let st = streamed.cache_stats();
        assert_eq!(st.streamed_loads, 3, "every cold shard streams");
        assert_eq!(st.rows_streamed, ds.n as u64);
        assert!(st.peak_row_bytes > 0);
        // the delegated cache and the source are one — same counters
        assert_eq!(st.misses, ds_streamed.source_stats().unwrap().misses);

        // plan mismatch: the shard layer keeps its own LRU but still reads
        // through the source's range reader, byte-identically
        let mismatched = CorpusShards::build(&ds_streamed, 2, 0);
        assert!(!mismatched.is_streamed());
        let resident2 = CorpusShards::build(&ds, 2, 0);
        for sh in 0..2 {
            let a = mismatched.row_blocks(sh, &ds_streamed);
            let b = resident2.row_blocks(sh, &ds);
            for blk in 0..a.n_blocks() {
                assert_eq!(a.block(blk), b.block(blk), "mismatch shard {sh}");
            }
        }
        assert_eq!(mismatched.cache_stats().streamed_loads, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
