//! `.gds` — the GoldDiff dataset store.
//!
//! Layout: magic `GDS1` · u32 header length · JSON header · raw
//! little-endian sections. The header lists every section with byte offset
//! and element count, so readers can seek directly; all tensors are f32 or
//! u32. The population GMM rides along so the closed-form oracle can be
//! reconstructed from the file alone.
//!
//! Version 2 optionally appends the IVF k-means partition
//! (`ivf_centroids` / `ivf_assign` sections, keyed by the `ivf_lists` /
//! `ivf_seed` header fields) so a `ClusterPruned` engine start can skip
//! k-means. Readers ignore unknown sections and treat a missing partition
//! as "rebuild", so version-1 stores keep loading unchanged.
//!
//! Version 3 adds the **sharded layout**: when a store is saved for a
//! sharded corpus ([`save_sharded`]), the header carries a `shards` count
//! and the sections list gains per-shard *alias* sections
//! (`data_shard_i` / `proxies_shard_i`) whose offsets point into the
//! contiguous `data` / `proxies` payloads — no bytes are duplicated, but a
//! [`ShardReader`] can seek straight to one shard's rows and stream them
//! on demand (the memory-bounded serving path). Older stores (or stores
//! saved with a different shard count) still stream: shard offsets are
//! derived from the `data` section and the deterministic
//! [`ShardPlan`](crate::data::shard::ShardPlan), so v1/v2 stores load —
//! and shard — exactly as a single-section v3 store would.
//!
//! Two optional v3 additions ride the same ignore-unknown-sections rule:
//! per-shard IVF partitions (`ivf_shard_i_centroids` / `ivf_shard_i_assign`
//! keyed by the `shard_ivf_*` header fields — a sharded cluster engine
//! start skips per-shard k-means), and the **data-free open path**
//! ([`open_streaming`]): every section except `data` loads, the section
//! table is bounds-validated up front, and rows stream through a
//! budget-bounded [`StreamedRows`] source instead of materialising.
//!
//! Version 4 appends the **quantised row tier**: `quant_codes` (per-row
//! int8 codes packed four-per-u32, little-endian), `quant_scale` and
//! `quant_err` (per-row f32 scale and correction norm). Both the resident
//! and the streaming open preload these into the dataset's
//! [`QuantRows`] tier so the quantised refine pre-rung works even when the
//! corpus never materialises. The sections are optional under the same
//! ignore-unknown rule: a v1–v3 store loads unchanged, a resident open
//! rebuilds the tier from the corpus on first use, and a streamed legacy
//! open simply reports no tier (the pre-rung stands down).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::dataset::{Dataset, IvfPartition, ShardIvfPartition};
use super::gmm::GmmSpec;
use super::rows::{RowSource, StreamedRows};
use crate::data::shard::ShardPlan;
use crate::index::kernel::{ProxyBlocks, QuantRows};
use crate::util::json::{parse, Json};

const MAGIC: &[u8; 4] = b"GDS1";
/// Header format version: 2 added the optional IVF partition sections; 3
/// added the per-shard alias sections + `shards` header field; 4 added the
/// optional quantised row tier (`quant_codes` / `quant_scale` /
/// `quant_err`). Readers never gate on this — unknown sections are ignored
/// and missing ones degrade per-feature — so it is documentation, not a
/// compatibility switch.
const VERSION: usize = 4;

/// Pack int8 codes four-per-u32 (little-endian) so the quant tier rides
/// the store's uniform 4-byte-element section machinery; the tail word is
/// zero-padded.
fn pack_i8(codes: &[i8]) -> Vec<u32> {
    codes
        .chunks(4)
        .map(|c| {
            let mut b = [0u8; 4];
            for (dst, &v) in b.iter_mut().zip(c) {
                *dst = v as u8;
            }
            u32::from_le_bytes(b)
        })
        .collect()
}

/// Inverse of [`pack_i8`]: the first `n` int8 codes out of the packed
/// words (padding bytes dropped).
fn unpack_i8(words: &[u32], n: usize) -> Vec<i8> {
    words
        .iter()
        .flat_map(|w| w.to_le_bytes())
        .take(n)
        .map(|b| b as i8)
        .collect()
}

/// Serialise a dataset (with its population GMM) to `path`.
///
/// The write is atomic: sections stream into a sibling `.tmp` file that is
/// renamed over `path` only after a successful flush, so a crash mid-save
/// (or an engine start rewriting the store to persist its IVF partition
/// while another process loads it) can never leave a torn store behind.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    save_sharded(ds, path, 1)
}

/// [`save`] with an explicit shard count: the v3 header records the shard
/// plan and per-shard alias sections so a [`ShardReader`] can stream one
/// shard's rows without touching the rest of the file.
pub fn save_sharded(ds: &Dataset, path: &Path, shards: usize) -> Result<()> {
    anyhow::ensure!(
        ds.is_resident(),
        "cannot save a streamed dataset — the full corpus is not resident \
         (the store it streams from already is the persisted form)"
    );
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("gds.tmp");
    write_store(ds, &tmp, shards)?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    Ok(())
}

fn write_store(ds: &Dataset, path: &Path, shards: usize) -> Result<()> {
    let mut header = Json::obj();
    header
        .set("name", ds.name.as_str())
        .set("version", VERSION)
        .set("n", ds.n)
        .set("h", ds.h)
        .set("w", ds.w)
        .set("c", ds.c)
        .set("d", ds.d)
        .set("proxy_d", ds.proxy_d)
        .set("classes", ds.classes)
        .set("conditional", ds.conditional)
        .set("gmm_components", ds.gmm.n_components());
    if let Some(ivf) = &ds.ivf {
        // the seed rides as a string so u64 values survive the f64 JSON
        // number path losslessly
        header
            .set("ivf_lists", ivf.lists)
            .set("ivf_seed", ivf.seed.to_string());
    }
    if let Some(si) = &ds.shard_ivf {
        header
            .set("shard_ivf_shards", si.shards)
            .set("shard_ivf_lists", si.lists)
            .set("shard_ivf_seed", si.seed.to_string());
    }

    // We need section offsets before writing the header, so write sections
    // to a temp buffer plan first: compute sizes, then emit.
    // Simpler: write header placeholder of fixed size after collecting
    // section metadata — do a two-pass over an in-memory plan of slices.
    let gmm_weights: Vec<f32> = ds.gmm.components.iter().map(|c| c.weight).collect();
    let gmm_classes: Vec<u32> = ds.gmm.components.iter().map(|c| c.class).collect();
    let mut gmm_means = Vec::with_capacity(ds.gmm.n_components() * ds.d);
    let mut gmm_vars = Vec::with_capacity(ds.gmm.n_components() * ds.d);
    for comp in &ds.gmm.components {
        gmm_means.extend_from_slice(&comp.mean);
        gmm_vars.extend_from_slice(&comp.var);
    }

    enum Sec<'a> {
        F(String, &'a [f32]),
        U(String, &'a [u32]),
    }
    let data = ds
        .resident_rows()
        .expect("write_store is resident-gated by save_sharded");
    // v4: the quantised row tier is recomputed at save (deterministic in
    // the corpus bytes) rather than borrowed from the dataset's lazy cache,
    // so every saved store carries it regardless of what the writer touched
    let quant = QuantRows::build(data, ds.n, ds.d);
    let quant_codes = pack_i8(quant.codes_flat());
    let mut plan = vec![
        Sec::F("data".into(), data),
        Sec::U("labels".into(), &ds.labels),
        Sec::F("proxies".into(), &ds.proxies),
        Sec::F("mean".into(), &ds.mean),
        Sec::F("var".into(), &ds.var),
        Sec::F("centroids".into(), &ds.centroids),
        Sec::U("assignments".into(), &ds.assignments),
        Sec::F("pca_bases".into(), &ds.pca_bases),
        Sec::F("pca_centers".into(), &ds.pca_centers),
        Sec::F("gmm_weights".into(), &gmm_weights),
        Sec::U("gmm_classes".into(), &gmm_classes),
        Sec::F("gmm_means".into(), &gmm_means),
        Sec::F("gmm_vars".into(), &gmm_vars),
        Sec::U("quant_codes".into(), &quant_codes),
        Sec::F("quant_scale".into(), quant.scales_flat()),
        Sec::F("quant_err".into(), quant.errs_flat()),
    ];
    if let Some(ivf) = &ds.ivf {
        plan.push(Sec::F("ivf_centroids".into(), &ivf.centroids));
        plan.push(Sec::U("ivf_assign".into(), &ivf.assignments));
    }
    if let Some(si) = &ds.shard_ivf {
        // per-shard IVF partitions (v3): a sharded cluster engine start
        // reuses these instead of paying per-shard k-means every time
        for (i, (c, a)) in si.centroids.iter().zip(&si.assignments).enumerate() {
            plan.push(Sec::F(format!("ivf_shard_{i}_centroids"), c));
            plan.push(Sec::U(format!("ivf_shard_{i}_assign"), a));
        }
    }

    // First pass: build section metadata assuming offsets start at 0 (we
    // prepend magic + header later, storing offsets relative to data start).
    let mut sections = Vec::new();
    let mut offset = 0u64;
    let mut data_offset = 0u64;
    let mut proxies_offset = 0u64;
    for sec in &plan {
        let (name, dtype, len) = match sec {
            Sec::F(n, v) => (n.as_str(), "f32", v.len()),
            Sec::U(n, v) => (n.as_str(), "u32", v.len()),
        };
        match name {
            "data" => data_offset = offset,
            "proxies" => proxies_offset = offset,
            _ => {}
        }
        let mut meta = Json::obj();
        meta.set("name", name)
            .set("dtype", dtype)
            .set("offset", offset)
            .set("len", len);
        sections.push(meta);
        offset += len as u64 * 4;
    }
    // v3: per-shard alias sections into the contiguous data/proxies
    // payloads — rows of shard i live at data_offset + start·d·4 — so a
    // ShardReader seeks one shard without re-deriving the layout; no
    // payload bytes are duplicated. Today the reader cross-checks
    // `data_shard_i` against the plan-derived offset (and proxy streaming
    // is not wired yet — `proxies_shard_i` is declared for the planned
    // corpus-non-resident mode), so the aliases are a forward-compat
    // surface, not load-bearing for current stores.
    if shards > 1 {
        let splan = ShardPlan::new(ds.n, shards);
        header.set("shards", splan.count());
        for i in 0..splan.count() {
            let (s, e) = splan.range(i);
            let rows = e - s;
            let mut meta = Json::obj();
            meta.set("name", format!("data_shard_{i}"))
                .set("dtype", "f32")
                .set("offset", data_offset + (s * ds.d) as u64 * 4)
                .set("len", rows * ds.d);
            sections.push(meta);
            let mut meta = Json::obj();
            meta.set("name", format!("proxies_shard_{i}"))
                .set("dtype", "f32")
                .set("offset", proxies_offset + (s * ds.proxy_d) as u64 * 4)
                .set("len", rows * ds.proxy_d);
            sections.push(meta);
        }
    }
    header.set("sections", Json::Arr(sections));
    let header_bytes = header.to_string_compact().into_bytes();

    let file = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut out = BufWriter::new(file);
    out.write_all(MAGIC)?;
    out.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
    out.write_all(&header_bytes)?;
    for sec in &plan {
        match sec {
            Sec::F(_, v) => {
                for x in *v {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
            Sec::U(_, v) => {
                for x in *v {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    out.flush()?;
    Ok(())
}

/// Parsed store header + bounds-checked section readers — shared by
/// [`load`] (full read) and [`open_streaming`] (data-free read).
struct StoreFile {
    rd: BufReader<File>,
    header: Json,
    data_start: u64,
    file_len: u64,
    path: std::path::PathBuf,
}

impl StoreFile {
    fn open(path: &Path) -> Result<StoreFile> {
        let file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut rd = BufReader::new(file);
        let mut magic = [0u8; 4];
        rd.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a GDS1 file");
        }
        let mut len4 = [0u8; 4];
        rd.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        rd.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?)?;
        header
            .get("sections")
            .and_then(Json::as_arr)
            .context("missing sections")?;
        Ok(StoreFile {
            rd,
            header,
            data_start: 8 + hlen as u64,
            file_len,
            path: path.to_path_buf(),
        })
    }

    /// Locate a section, bounds-checked against the real file size before
    /// any seek, so a truncated store fails with the section's name instead
    /// of a raw IO error from deep inside the byte loop.
    fn locate(&self, name: &str) -> Result<(u64, usize)> {
        let sections = self.header.get("sections").and_then(Json::as_arr).unwrap();
        let sec = sections
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some(name))
            .with_context(|| format!("section {name} missing"))?;
        let off = sec.num_field("offset")? as u64;
        let len = sec.num_field("len")? as usize;
        let end = self.data_start + off + len as u64 * 4;
        if end > self.file_len {
            bail!(
                "{:?}: section `{name}` (offset {off}, {len} elements) \
                 ends at byte {end} past the {}-byte file — \
                 truncated or corrupt store",
                self.path,
                self.file_len
            );
        }
        Ok((off, len))
    }

    fn read_bytes(&mut self, name: &str) -> Result<Vec<u8>> {
        let (off, len) = self.locate(name)?;
        self.rd.seek(SeekFrom::Start(self.data_start + off))?;
        let mut bytes = vec![0u8; len * 4];
        self.rd.read_exact(&mut bytes)?;
        Ok(bytes)
    }

    fn read_f32(&mut self, name: &str) -> Result<Vec<f32>> {
        Ok(self
            .read_bytes(name)?
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn read_u32(&mut self, name: &str) -> Result<Vec<u32>> {
        Ok(self
            .read_bytes(name)?
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Load a dataset from a `.gds` file (fully resident, the seed behaviour).
pub fn load(path: &Path) -> Result<Dataset> {
    let mut sf = StoreFile::open(path)?;
    let data = sf.read_f32("data")?;
    finish_dataset(sf, RowSource::Resident(data))
}

/// Open a `.gds` store **without materialising the corpus**: headers,
/// proxies, shard bounds and stats load as usual, but the `data` section
/// stays on disk and rows stream shard-at-a-time through a
/// `mem_budget_mb`-bounded LRU ([`StreamedRows`]). The section table is
/// still fully bounds-validated up front, so a truncated or corrupt store
/// fails here — loudly, naming the section — not mid-serve.
///
/// Any valid store streams under any `shards` count: v3 stores saved with
/// a matching plan seek via their per-shard alias sections, everything
/// else derives offsets from the contiguous `data` section (see
/// [`ShardReader`]).
pub fn open_streaming(path: &Path, shards: usize, mem_budget_mb: usize) -> Result<Dataset> {
    let sf = StoreFile::open(path)?;
    let n = sf.header.num_field("n")? as usize;
    let d = sf.header.num_field("d")? as usize;
    // validate the data section's bounds without reading a byte of it
    let (_, data_len) = sf.locate("data")?;
    anyhow::ensure!(
        data_len == n * d,
        "{path:?}: data section holds {data_len} values, expected {n}×{d}"
    );
    let reader = ShardReader::open(path, shards)?;
    let src = std::sync::Arc::new(StreamedRows::new(reader, n, d, mem_budget_mb));
    finish_dataset(sf, RowSource::Streamed(src))
}

/// Everything after the row payload: the shared tail of [`load`] and
/// [`open_streaming`] — side tables, stats, GMM, persisted partitions.
fn finish_dataset(mut sf: StoreFile, rows: RowSource) -> Result<Dataset> {
    let n = sf.header.num_field("n")? as usize;
    let d = sf.header.num_field("d")? as usize;
    let labels = sf.read_u32("labels")?;
    let proxies = sf.read_f32("proxies")?;
    let mean = sf.read_f32("mean")?;
    let var = sf.read_f32("var")?;
    let centroids = sf.read_f32("centroids")?;
    let assignments = sf.read_u32("assignments")?;
    let pca_bases = sf.read_f32("pca_bases")?;
    let pca_centers = sf.read_f32("pca_centers")?;
    let gmm_weights = sf.read_f32("gmm_weights")?;
    let gmm_classes = sf.read_u32("gmm_classes")?;
    let gmm_means = sf.read_f32("gmm_means")?;
    let gmm_vars = sf.read_f32("gmm_vars")?;

    let mut gmm = GmmSpec::new(d);
    for (i, (&w, &cls)) in gmm_weights.iter().zip(&gmm_classes).enumerate() {
        gmm.push(
            w,
            gmm_means[i * d..(i + 1) * d].to_vec(),
            gmm_vars[i * d..(i + 1) * d].to_vec(),
            cls,
        );
    }

    let classes = sf.header.num_field("classes")? as usize;
    let mut class_rows = vec![Vec::new(); classes];
    for (i, &y) in labels.iter().enumerate() {
        class_rows[y as usize].push(i as u32);
    }

    let proxy_d = sf.header.num_field("proxy_d")? as usize;

    // version-2 stores may carry the IVF partition; anything older (or a
    // store saved before a cluster engine ran) yields None → k-means rebuild
    let ivf = match (
        sf.header.get("ivf_lists").and_then(Json::as_f64),
        sf.header
            .get("ivf_seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok()),
    ) {
        (Some(lists), Some(seed)) => Some(IvfPartition {
            lists: lists as usize,
            seed,
            centroids: sf.read_f32("ivf_centroids")?,
            assignments: sf.read_u32("ivf_assign")?,
        }),
        _ => None,
    };

    // v3 stores may additionally carry the *per-shard* IVF partitions a
    // sharded cluster engine persisted; legacy stores simply yield None
    let shard_ivf = match (
        sf.header.get("shard_ivf_shards").and_then(Json::as_f64),
        sf.header.get("shard_ivf_lists").and_then(Json::as_f64),
        sf.header
            .get("shard_ivf_seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok()),
    ) {
        (Some(sh), Some(lists), Some(seed)) => {
            let sh = sh as usize;
            let mut centroids = Vec::with_capacity(sh);
            let mut shard_assign = Vec::with_capacity(sh);
            for i in 0..sh {
                centroids.push(sf.read_f32(&format!("ivf_shard_{i}_centroids"))?);
                shard_assign.push(sf.read_u32(&format!("ivf_shard_{i}_assign"))?);
            }
            Some(ShardIvfPartition {
                shards: sh,
                lists: lists as usize,
                seed,
                centroids,
                assignments: shard_assign,
            })
        }
        _ => None,
    };

    // v4 stores carry the quantised row tier; preload it into the
    // dataset's OnceLock so both residencies serve the same persisted
    // bytes. Older stores leave the lock empty: a resident open rebuilds
    // the (identical) tier on first use, a streamed open reports None and
    // the quantised refine pre-rung stands down.
    let quant_row_tier = std::sync::OnceLock::new();
    if sf.locate("quant_codes").is_ok()
        && sf.locate("quant_scale").is_ok()
        && sf.locate("quant_err").is_ok()
    {
        let codes = unpack_i8(&sf.read_u32("quant_codes")?, n * d);
        let scales = sf.read_f32("quant_scale")?;
        let errs = sf.read_f32("quant_err")?;
        let qr = QuantRows::from_parts(n, d, codes, scales, errs).with_context(|| {
            format!(
                "{:?}: quant sections disagree with the {n}×{d} corpus shape",
                sf.path
            )
        })?;
        let _ = quant_row_tier.set(Some(qr));
    }

    let proxy_blocks = ProxyBlocks::build(&proxies, n, proxy_d);
    Ok(Dataset {
        name: sf.header.str_field("name")?.to_string(),
        n,
        h: sf.header.num_field("h")? as usize,
        w: sf.header.num_field("w")? as usize,
        c: sf.header.num_field("c")? as usize,
        d,
        proxy_d,
        classes,
        conditional: sf
            .header
            .get("conditional")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        rows,
        labels,
        proxies,
        proxy_blocks,
        row_blocks: std::sync::OnceLock::new(),
        quant_proxy: std::sync::OnceLock::new(),
        quant_row_tier,
        class_rows,
        ivf,
        shard_ivf,
        mean,
        var,
        centroids,
        assignments,
        pca_bases,
        pca_centers,
        gmm,
    })
}

// ---------------------------------------------------------------------------
// Shard streaming
// ---------------------------------------------------------------------------

/// Streaming shard access to a `.gds` store: seeks straight to one shard's
/// full-resolution rows without materialising the corpus. Uses the v3
/// per-shard alias sections when the store was saved with the same shard
/// count; otherwise (v1/v2 stores, or a different saved plan) it derives
/// the offsets from the contiguous `data` section and the deterministic
/// [`ShardPlan`] — so *any* valid store streams under *any* shard count.
#[derive(Debug)]
pub struct ShardReader {
    file: File,
    d: usize,
    plan: ShardPlan,
    /// absolute byte offset of each shard's first row
    offsets: Vec<u64>,
    /// absolute byte offset of the contiguous `data` section (row 0) —
    /// arbitrary row-range reads seek from here
    data_abs: u64,
}

impl ShardReader {
    pub fn open(path: &Path, shards: usize) -> Result<ShardReader> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut magic = [0u8; 4];
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a GDS1 file");
        }
        let mut len4 = [0u8; 4];
        file.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        file.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?)?;
        let data_start = 8 + hlen as u64;

        let n = header.num_field("n")? as usize;
        let d = header.num_field("d")? as usize;
        let sections = header
            .get("sections")
            .and_then(Json::as_arr)
            .context("missing sections")?;
        let find = |name: &str| -> Option<(u64, usize)> {
            let sec = sections
                .iter()
                .find(|s| s.get("name").and_then(Json::as_str) == Some(name))?;
            Some((
                sec.num_field("offset").ok()? as u64,
                sec.num_field("len").ok()? as usize,
            ))
        };
        let (data_off, data_len) = find("data").context("section data missing")?;
        anyhow::ensure!(
            data_len == n * d,
            "{path:?}: data section holds {data_len} values, expected {n}×{d}"
        );
        let data_abs = data_start + data_off;
        anyhow::ensure!(
            data_abs + data_len as u64 * 4 <= file_len,
            "{path:?}: data section ends past the {file_len}-byte file — \
             truncated store"
        );

        let plan = ShardPlan::new(n, shards);
        let header_shards = header.get("shards").and_then(Json::as_f64).map(|v| v as usize);
        let mut offsets = Vec::with_capacity(plan.count());
        for i in 0..plan.count() {
            let (s, e) = plan.range(i);
            let rows = e - s;
            let derived = data_start + data_off + (s * d) as u64 * 4;
            let abs = if header_shards == Some(plan.count()) {
                match find(&format!("data_shard_{i}")) {
                    Some((off, len)) if len == rows * d => data_start + off,
                    _ => derived,
                }
            } else {
                derived
            };
            let end = abs + (rows * d) as u64 * 4;
            if end > file_len {
                bail!(
                    "{path:?}: shard {i} rows end at byte {end} past the \
                     {file_len}-byte file — truncated store"
                );
            }
            offsets.push(abs);
        }
        Ok(ShardReader {
            file,
            d,
            plan,
            offsets,
            data_abs,
        })
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Read shard `shard`'s full-resolution rows (`rows × d`, row-major).
    pub fn read_shard_rows(&mut self, shard: usize) -> Result<Vec<f32>> {
        let rows = self.plan.rows_in(shard);
        self.file.seek(SeekFrom::Start(self.offsets[shard]))?;
        let mut bytes = vec![0u8; rows * self.d * 4];
        self.file.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Read an arbitrary global row range `[s, e)` (`(e−s) × d`, row-major)
    /// straight out of the contiguous `data` section — rows are stored
    /// contiguously whatever shard plan the store was saved with, so this
    /// serves plan-agnostic consumers (a backend sharded at a different
    /// count than the source).
    pub fn read_row_range(&mut self, s: usize, e: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(s <= e && e <= self.plan.n, "row range {s}..{e} out of bounds");
        self.file
            .seek(SeekFrom::Start(self.data_abs + (s * self.d) as u64 * 4))?;
        let mut bytes = vec![0u8; (e - s) * self.d * 4];
        self.file.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Conventional on-disk path for a preset's store.
pub fn store_path(dir: &Path, preset: &str) -> std::path::PathBuf {
    dir.join(format!("{preset}.gds"))
}

/// Load a preset from `dir`, synthesising (and saving) it when missing.
pub fn load_or_synthesize(dir: &Path, preset_name: &str, seed: u64) -> Result<Dataset> {
    load_or_synthesize_sharded(dir, preset_name, seed, 1)
}

/// Make sure a preset's store exists on disk (synthesise + save when
/// missing) *without* loading it — the precursor to [`open_streaming`],
/// which then serves the corpus data-free off that file.
pub fn ensure_store(
    dir: &Path,
    preset_name: &str,
    seed: u64,
    shards: usize,
) -> Result<std::path::PathBuf> {
    let path = store_path(dir, preset_name);
    if !path.exists() {
        let spec = super::synthetic::preset(preset_name)
            .with_context(|| format!("unknown preset {preset_name}"))?;
        let ds = Dataset::synthesize(spec, seed);
        save_sharded(&ds, &path, shards)?;
    }
    Ok(path)
}

/// [`load_or_synthesize`] with a shard count: a freshly synthesised store
/// is saved with the v3 per-shard sections so the serving engine can
/// stream shards from it straight away. An existing store loads as-is
/// (shard offsets derive from the plan regardless of how it was saved).
pub fn load_or_synthesize_sharded(
    dir: &Path,
    preset_name: &str,
    seed: u64,
    shards: usize,
) -> Result<Dataset> {
    let path = store_path(dir, preset_name);
    if path.exists() {
        return load(&path);
    }
    let spec = super::synthetic::preset(preset_name)
        .with_context(|| format!("unknown preset {preset_name}"))?;
    let ds = Dataset::synthesize(spec, seed);
    save_sharded(&ds, &path, shards)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    /// The resident corpus of a test dataset (all stores here are saved
    /// from resident synthesis).
    fn corpus(ds: &Dataset) -> &[f32] {
        ds.resident_rows().expect("test datasets are resident")
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 128;
        let ds = Dataset::synthesize(&spec, 9);
        let dir = std::env::temp_dir().join("golddiff_store_test");
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        assert_eq!(rt.name, ds.name);
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.labels, ds.labels);
        assert_eq!(rt.proxies, ds.proxies);
        assert_eq!(rt.gmm.n_components(), ds.gmm.n_components());
        assert_eq!(rt.gmm.components[3].mean, ds.gmm.components[3].mean);
        assert_eq!(rt.class_rows, ds.class_rows);
        // derived block layouts rebuild identically from the sections
        assert_eq!(rt.row_blocks().rows, ds.row_blocks().rows);
        assert_eq!(rt.row_blocks().dim, ds.row_blocks().dim);
        assert_eq!(rt.row_blocks().block(0), ds.row_blocks().block(0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_synthesize_caches() {
        let dir = std::env::temp_dir().join("golddiff_store_test2");
        std::fs::remove_dir_all(&dir).ok();
        // shrink via direct synthesize to keep the test fast: use moons
        let a = load_or_synthesize(&dir, "moons", 1).unwrap();
        assert!(store_path(&dir, "moons").exists());
        let b = load_or_synthesize(&dir, "moons", 999).unwrap(); // seed ignored on cache hit
        assert_eq!(a.resident_rows(), b.resident_rows());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ivf_partition_roundtrips_and_legacy_stores_load_without_it() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 96;
        let mut ds = Dataset::synthesize(&spec, 13);
        let dir = std::env::temp_dir().join("golddiff_store_ivf_test");
        let path = dir.join("moons.gds");

        // "legacy" store: saved without a partition → loads as None
        save(&ds, &path).unwrap();
        assert!(load(&path).unwrap().ivf.is_none());

        // version-2 store with the partition riding along
        ds.ivf = Some(IvfPartition::compute(&ds, 6, 0xdead_beef_0042));
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        let got = rt.ivf.expect("partition must roundtrip");
        let want = ds.ivf.as_ref().unwrap();
        assert_eq!(got.lists, want.lists);
        assert_eq!(got.seed, want.seed, "u64 seed survives the JSON header");
        assert_eq!(got.centroids, want.centroids);
        assert_eq!(got.assignments, want.assignments);
        // the rest of the dataset is untouched by the new sections
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_store_roundtrips_and_reader_streams_every_shard() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 110;
        let ds = Dataset::synthesize(&spec, 21);
        let dir = std::env::temp_dir().join("golddiff_store_v3_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 4).unwrap();

        // the alias sections never disturb a full load
        let rt = load(&path).unwrap();
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);

        // streaming with the saved plan uses the per-shard sections
        let mut rd = ShardReader::open(&path, 4).unwrap();
        assert_eq!(rd.plan().count(), 4);
        for sh in 0..4 {
            let (s, e) = rd.plan().range(sh);
            let rows = rd.read_shard_rows(sh).unwrap();
            assert_eq!(rows, corpus(&ds)[s * ds.d..e * ds.d], "shard {sh}");
        }
        // a different shard count still streams via derived offsets
        let mut rd7 = ShardReader::open(&path, 7).unwrap();
        for sh in 0..rd7.plan().count() {
            let (s, e) = rd7.plan().range(sh);
            let rows = rd7.read_shard_rows(sh).unwrap();
            assert_eq!(rows, corpus(&ds)[s * ds.d..e * ds.d], "shard {sh}/7");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_single_section_store_loads_and_streams_as_shards() {
        // a store saved without shard sections (the v1/v2 shape — `save`
        // writes none) must still load whole AND stream under any plan
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 64;
        let ds = Dataset::synthesize(&spec, 5);
        let dir = std::env::temp_dir().join("golddiff_store_legacy_shard_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        // verify the file really has no shard metadata to fall back on
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        assert!(header.get("shards").is_none(), "save() writes no shard plan");

        assert_eq!(
            load(&path).unwrap().resident_rows(),
            ds.resident_rows(),
            "loads as one corpus"
        );
        let mut rd = ShardReader::open(&path, 3).unwrap();
        for sh in 0..3 {
            let (s, e) = rd.plan().range(sh);
            assert_eq!(rd.read_shard_rows(sh).unwrap(), corpus(&ds)[s * ds.d..e * ds.d]);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_store_fails_with_the_section_name() {
        // Satellite: offsets/lengths are validated against the file size
        // before any seek, so a truncated store names the broken section
        // instead of surfacing a raw IO error
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 48;
        let ds = Dataset::synthesize(&spec, 8);
        let dir = std::env::temp_dir().join("golddiff_store_trunc_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 16).unwrap();
        drop(f);
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(
            err.contains("section") && err.contains("truncated"),
            "error must name the problem: {err}"
        );
        // the last-written section is the one the cut lands in
        assert!(err.contains("quant_err"), "error must name the section: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("golddiff_store_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.gds");
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(load(&path).is_err());
        assert!(open_streaming(&path, 2, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_serves_the_corpus_data_free() {
        // Tentpole: everything except the data section loads; rows stream
        // bit-identically through the source
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 120;
        let mut ds = Dataset::synthesize(&spec, 31);
        ds.ivf = Some(IvfPartition::compute(&ds, 5, 77));
        let dir = std::env::temp_dir().join("golddiff_store_stream_open_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 4).unwrap();

        let st = open_streaming(&path, 4, 0).unwrap();
        assert!(!st.is_resident() && st.resident_rows().is_none());
        // side tables + stats + persisted partitions all load
        assert_eq!(st.labels, ds.labels);
        assert_eq!(st.proxies, ds.proxies);
        assert_eq!(st.mean, ds.mean);
        assert_eq!(st.var, ds.var);
        assert_eq!(st.class_rows, ds.class_rows);
        assert_eq!(st.pca_bases, ds.pca_bases);
        assert_eq!(st.ivf.as_ref().unwrap().centroids, ds.ivf.as_ref().unwrap().centroids);
        // nothing of the corpus is resident until a row is touched
        assert_eq!(st.source_stats().unwrap().rows_streamed, 0);
        assert_eq!(st.source_stats().unwrap().peak_row_bytes, 0);
        // every row streams back byte-identical, via cursor and gather
        let mut cur = st.row_cursor();
        for i in 0..ds.n {
            assert_eq!(cur.row(i as u32), ds.row(i), "row {i}");
        }
        let (mut a, mut am) = (Vec::new(), Vec::new());
        let (mut b, mut bm) = (Vec::new(), Vec::new());
        st.gather_rows(&[5, 99, 0], 4, &mut a, &mut am);
        ds.gather_rows(&[5, 99, 0], 4, &mut b, &mut bm);
        assert_eq!((a, am), (b, bm));
        // a whole-corpus staging pass matches the resident copy
        let mut full = vec![0.0f32; ds.n * ds.d];
        st.copy_all_rows_into(&mut full);
        assert_eq!(full.as_slice(), corpus(&ds));
        assert!(st.source_stats().unwrap().rows_streamed >= ds.n as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_handles_legacy_stores_and_any_shard_count() {
        // a v1-shape store (no shard sections) still streams under any plan
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 64;
        let ds = Dataset::synthesize(&spec, 5);
        let dir = std::env::temp_dir().join("golddiff_store_stream_legacy_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        for shards in [1usize, 3, 7] {
            let st = open_streaming(&path, shards, 0).unwrap();
            assert!(st.shard_ivf.is_none(), "legacy stores carry no partitions");
            let mut cur = st.row_cursor();
            for i in [0usize, 20, 63] {
                assert_eq!(cur.row(i as u32), ds.row(i), "shards={shards} row {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_rejects_truncated_stores_up_front() {
        // Satellite: the section table is validated at open, so a truncated
        // store fails loudly before any serving starts
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 48;
        let ds = Dataset::synthesize(&spec, 8);
        let dir = std::env::temp_dir().join("golddiff_store_stream_trunc_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let full = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(full - 16).unwrap();
        drop(f);
        let err = format!("{:#}", open_streaming(&path, 3, 0).unwrap_err());
        assert!(
            err.contains("section") && err.contains("truncated"),
            "error must name the problem: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_refuses_a_streamed_dataset() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 40;
        let ds = Dataset::synthesize(&spec, 3);
        let dir = std::env::temp_dir().join("golddiff_store_stream_save_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let st = open_streaming(&path, 2, 0).unwrap();
        let err = format!("{:#}", save(&st, &dir.join("copy.gds")).unwrap_err());
        assert!(err.contains("streamed"), "error must explain the gate: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Rewrite a store's header with the `quant_*` sections stripped —
    /// simulates a v1–v3 store (the payload bytes stay; section offsets
    /// are relative to the header end, so a shorter header stays valid).
    fn strip_quant_sections(path: &Path) {
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let mut header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        let kept: Vec<crate::util::json::Json> = header
            .get("sections")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap()
            .iter()
            .filter(|s| {
                !s.get("name")
                    .and_then(crate::util::json::Json::as_str)
                    .is_some_and(|n| n.starts_with("quant_"))
            })
            .cloned()
            .collect();
        header.set("sections", crate::util::json::Json::Arr(kept));
        let hb = header.to_string_compact().into_bytes();
        let mut out = Vec::with_capacity(bytes.len());
        out.extend_from_slice(b"GDS1");
        out.extend_from_slice(&(hb.len() as u32).to_le_bytes());
        out.extend_from_slice(&hb);
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(path, out).unwrap();
    }

    #[test]
    fn quant_tier_roundtrips_resident_and_streaming() {
        // Tentpole: the v4 quant sections reload bit-identical to a fresh
        // build from the corpus, on both open paths
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 77;
        let ds = Dataset::synthesize(&spec, 17);
        let want = QuantRows::build(corpus(&ds), ds.n, ds.d);
        let dir = std::env::temp_dir().join("golddiff_store_quant_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();

        for opened in [load(&path).unwrap(), open_streaming(&path, 3, 0).unwrap()] {
            let got = opened.quant_rows().expect("v4 stores carry the tier");
            assert_eq!(got.codes_flat(), want.codes_flat());
            assert_eq!(got.scales_flat(), want.scales_flat());
            assert_eq!(got.errs_flat(), want.errs_flat());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_store_without_quant_sections_degrades_per_residency() {
        // a v1–v3 shape store: the resident open rebuilds the tier from
        // the corpus (identical bytes), the streamed open reports None
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 60;
        let ds = Dataset::synthesize(&spec, 23);
        let dir = std::env::temp_dir().join("golddiff_store_quant_legacy_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        strip_quant_sections(&path);

        let resident = load(&path).unwrap();
        assert_eq!(resident.resident_rows(), ds.resident_rows());
        let want = QuantRows::build(corpus(&ds), ds.n, ds.d);
        let got = resident.quant_rows().expect("resident opens rebuild");
        assert_eq!(got.codes_flat(), want.codes_flat());
        assert_eq!(got.errs_flat(), want.errs_flat());

        let streamed = open_streaming(&path, 2, 0).unwrap();
        assert!(
            streamed.quant_rows().is_none(),
            "a streamed legacy store has no corpus to quantise from"
        );
        // ...and the rest of the dataset still serves
        let mut cur = streamed.row_cursor();
        assert_eq!(cur.row(7), ds.row(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pack_unpack_i8_roundtrips_ragged_lengths() {
        for n in [0usize, 1, 3, 4, 5, 8, 13] {
            let codes: Vec<i8> = (0..n).map(|i| (i as i8).wrapping_mul(-37)).collect();
            let packed = pack_i8(&codes);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack_i8(&packed, n), codes, "n={n}");
        }
        assert_eq!(unpack_i8(&pack_i8(&[-128, 127, -1, 0, 42]), 5), [-128, 127, -1, 0, 42]);
    }

    #[test]
    fn shard_ivf_partitions_roundtrip_and_legacy_stores_load_without_them() {
        // Satellite: per-shard IVF partitions persist in v3 sections and
        // reload verbatim; stores saved without them yield None
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 90;
        let mut ds = Dataset::synthesize(&spec, 13);
        let dir = std::env::temp_dir().join("golddiff_store_shard_ivf_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();
        assert!(load(&path).unwrap().shard_ivf.is_none());

        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 3, 4, 0xfeed_beef_0099));
        save_sharded(&ds, &path, 3).unwrap();
        let rt = load(&path).unwrap();
        let got = rt.shard_ivf.expect("partitions must roundtrip");
        let want = ds.shard_ivf.as_ref().unwrap();
        assert_eq!(&got, want, "u64 seed + all shards survive the header");
        assert!(got.matches(3, 4, 0xfeed_beef_0099));
        // the streaming open loads them too (it never touches data)
        let st = open_streaming(&path, 3, 0).unwrap();
        assert_eq!(st.shard_ivf.as_ref(), Some(want));
        // the rest of the dataset is untouched by the new sections
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);
        std::fs::remove_dir_all(&dir).ok();
    }
}
