//! `.gds` — the GoldDiff dataset store.
//!
//! Layout: magic `GDS1` · u32 header length · JSON header · raw
//! little-endian sections. The header lists every section with byte offset
//! and element count, so readers can seek directly; all tensors are f32 or
//! u32. The population GMM rides along so the closed-form oracle can be
//! reconstructed from the file alone.
//!
//! Version 2 optionally appends the IVF k-means partition
//! (`ivf_centroids` / `ivf_assign` sections, keyed by the `ivf_lists` /
//! `ivf_seed` header fields) so a `ClusterPruned` engine start can skip
//! k-means. Readers ignore unknown sections and treat a missing partition
//! as "rebuild", so version-1 stores keep loading unchanged.
//!
//! Version 3 adds the **sharded layout**: when a store is saved for a
//! sharded corpus ([`save_sharded`]), the header carries a `shards` count
//! and the sections list gains per-shard *alias* sections
//! (`data_shard_i` / `proxies_shard_i`) whose offsets point into the
//! contiguous `data` / `proxies` payloads — no bytes are duplicated, but a
//! [`ShardReader`] can seek straight to one shard's rows and stream them
//! on demand (the memory-bounded serving path). Older stores (or stores
//! saved with a different shard count) still stream: shard offsets are
//! derived from the `data` section and the deterministic
//! [`ShardPlan`](crate::data::shard::ShardPlan), so v1/v2 stores load —
//! and shard — exactly as a single-section v3 store would.
//!
//! Two optional v3 additions ride the same ignore-unknown-sections rule:
//! per-shard IVF partitions (`ivf_shard_i_centroids` / `ivf_shard_i_assign`
//! keyed by the `shard_ivf_*` header fields — a sharded cluster engine
//! start skips per-shard k-means), and the **data-free open path**
//! ([`open_streaming`]): every section except `data` loads, the section
//! table is bounds-validated up front, and rows stream through a
//! budget-bounded [`StreamedRows`] source instead of materialising.
//!
//! Version 4 appends the **quantised row tier**: `quant_codes` (per-row
//! int8 codes packed four-per-u32, little-endian), `quant_scale` and
//! `quant_err` (per-row f32 scale and correction norm). Both the resident
//! and the streaming open preload these into the dataset's
//! [`QuantRows`] tier so the quantised refine pre-rung works even when the
//! corpus never materialises. The sections are optional under the same
//! ignore-unknown rule: a v1–v3 store loads unchanged, a resident open
//! rebuilds the tier from the corpus on first use, and a streamed legacy
//! open simply reports no tier (the pre-rung stands down).
//!
//! Version 5 adds **integrity**: every section's metadata carries a
//! `crc32` (IEEE) over its on-disk bytes, including the per-shard alias
//! sections (checksummed over their subrange so a [`ShardReader`] can
//! verify one shard without touching the rest). Readers verify a section's
//! checksum on first touch and fail with [`ChecksumMismatch`] naming the
//! section. A corrupt *required* section fails the load; a corrupt
//! *optional* section (`quant_*`, `ivf_*`, per-shard IVF) stands its tier
//! down exactly like a legacy load — serving continues on the exact f32
//! path and the degradation is surfaced in `Dataset::degraded` /
//! `checksum_failures`. Writes were already atomic (`*.tmp` + rename);
//! v5 also fsyncs the payload and the parent directory so the rename is
//! durable. v≤4 stores carry no checksums and load exactly as before.
//!
//! Version 6 appends the **Gaussian moment tier**: `gauss_mean` /
//! `gauss_var` (group-major `(classes + 1) × d` f32 tables, global slot
//! first) and `gauss_counts` (u32 rows per group) — the per-class +
//! global diagonal moment summary the high-noise closed-form score
//! (`denoiser::gaussian`) serves from. The sections are optional under
//! the same rules as the quant tier: a v≤5 store loads unchanged, a
//! resident legacy open rebuilds the (bit-identical) summary with one
//! corpus pass on first use, a streamed legacy open reports no tier
//! (the Gaussian fast path stands down and every tick runs full
//! retrieval), and a present-but-corrupt section degrades the tier
//! per the v5 discipline instead of failing the load.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::dataset::{Dataset, IvfPartition, ShardIvfPartition};
use super::gauss::GaussMoments;
use super::gmm::GmmSpec;
use super::rows::{RowSource, StreamedRows};
use crate::data::shard::ShardPlan;
use crate::index::kernel::{ProxyBlocks, QuantRows};
use crate::util::crc::{crc32, crc32_f32, crc32_u32};
use crate::util::fault::{FaultInjector, FaultKind};
use crate::util::json::{parse, Json};

const MAGIC: &[u8; 4] = b"GDS1";
/// Header format version: 2 added the optional IVF partition sections; 3
/// added the per-shard alias sections + `shards` header field; 4 added the
/// optional quantised row tier (`quant_codes` / `quant_scale` /
/// `quant_err`); 5 added the per-section `crc32` checksums; 6 added the
/// optional Gaussian moment tier (`gauss_mean` / `gauss_var` /
/// `gauss_counts`). Readers never gate on this — unknown sections are
/// ignored, missing ones degrade per-feature, and sections without a
/// `crc32` field simply skip verification — so it is documentation, not
/// a compatibility switch.
const VERSION: usize = 6;

/// A section's stored checksum disagrees with its bytes: the store is
/// corrupt (bit rot, torn write, flaky medium). Carried as the typed root
/// cause under anyhow context so callers can classify integrity failures
/// (`err.downcast_ref::<ChecksumMismatch>()`) apart from plain IO errors —
/// the streamed-read retry treats it as transient (an in-flight corruption
/// re-reads clean), the optional-tier loader counts it in
/// `checksum_failures` telemetry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChecksumMismatch {
    pub section: String,
    pub want: u32,
    pub got: u32,
}

impl std::fmt::Display for ChecksumMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "section `{}` checksum mismatch: stored {:08x}, computed {:08x} — \
             corrupt store",
            self.section, self.want, self.got
        )
    }
}

impl std::error::Error for ChecksumMismatch {}

/// Pack int8 codes four-per-u32 (little-endian) so the quant tier rides
/// the store's uniform 4-byte-element section machinery; the tail word is
/// zero-padded.
fn pack_i8(codes: &[i8]) -> Vec<u32> {
    codes
        .chunks(4)
        .map(|c| {
            let mut b = [0u8; 4];
            for (dst, &v) in b.iter_mut().zip(c) {
                *dst = v as u8;
            }
            u32::from_le_bytes(b)
        })
        .collect()
}

/// Inverse of [`pack_i8`]: the first `n` int8 codes out of the packed
/// words (padding bytes dropped).
fn unpack_i8(words: &[u32], n: usize) -> Vec<i8> {
    words
        .iter()
        .flat_map(|w| w.to_le_bytes())
        .take(n)
        .map(|b| b as i8)
        .collect()
}

/// Serialise a dataset (with its population GMM) to `path`.
///
/// The write is atomic: sections stream into a sibling `.tmp` file that is
/// renamed over `path` only after a successful flush, so a crash mid-save
/// (or an engine start rewriting the store to persist its IVF partition
/// while another process loads it) can never leave a torn store behind.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    save_sharded(ds, path, 1)
}

/// [`save`] with an explicit shard count: the v3 header records the shard
/// plan and per-shard alias sections so a [`ShardReader`] can stream one
/// shard's rows without touching the rest of the file.
pub fn save_sharded(ds: &Dataset, path: &Path, shards: usize) -> Result<()> {
    anyhow::ensure!(
        ds.is_resident(),
        "cannot save a streamed dataset — the full corpus is not resident \
         (the store it streams from already is the persisted form)"
    );
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let tmp = path.with_extension("gds.tmp");
    write_store(ds, &tmp, shards)?;
    std::fs::rename(&tmp, path).with_context(|| format!("rename {tmp:?} -> {path:?}"))?;
    // make the rename itself durable: fsync the parent directory (best
    // effort — not every filesystem supports opening a directory)
    if let Some(parent) = path.parent() {
        if let Ok(dir) = File::open(parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(())
}

fn write_store(ds: &Dataset, path: &Path, shards: usize) -> Result<()> {
    let mut header = Json::obj();
    header
        .set("name", ds.name.as_str())
        .set("version", VERSION)
        .set("n", ds.n)
        .set("h", ds.h)
        .set("w", ds.w)
        .set("c", ds.c)
        .set("d", ds.d)
        .set("proxy_d", ds.proxy_d)
        .set("classes", ds.classes)
        .set("conditional", ds.conditional)
        .set("gmm_components", ds.gmm.n_components());
    if let Some(ivf) = &ds.ivf {
        // the seed rides as a string so u64 values survive the f64 JSON
        // number path losslessly
        header
            .set("ivf_lists", ivf.lists)
            .set("ivf_seed", ivf.seed.to_string());
    }
    if let Some(si) = &ds.shard_ivf {
        header
            .set("shard_ivf_shards", si.shards)
            .set("shard_ivf_lists", si.lists)
            .set("shard_ivf_seed", si.seed.to_string());
    }

    // We need section offsets before writing the header, so write sections
    // to a temp buffer plan first: compute sizes, then emit.
    // Simpler: write header placeholder of fixed size after collecting
    // section metadata — do a two-pass over an in-memory plan of slices.
    let gmm_weights: Vec<f32> = ds.gmm.components.iter().map(|c| c.weight).collect();
    let gmm_classes: Vec<u32> = ds.gmm.components.iter().map(|c| c.class).collect();
    let mut gmm_means = Vec::with_capacity(ds.gmm.n_components() * ds.d);
    let mut gmm_vars = Vec::with_capacity(ds.gmm.n_components() * ds.d);
    for comp in &ds.gmm.components {
        gmm_means.extend_from_slice(&comp.mean);
        gmm_vars.extend_from_slice(&comp.var);
    }

    enum Sec<'a> {
        F(String, &'a [f32]),
        U(String, &'a [u32]),
    }
    let data = ds
        .resident_rows()
        .expect("write_store is resident-gated by save_sharded");
    // v4: the quantised row tier is recomputed at save (deterministic in
    // the corpus bytes) rather than borrowed from the dataset's lazy cache,
    // so every saved store carries it regardless of what the writer touched
    let quant = QuantRows::build(data, ds.n, ds.d);
    let quant_codes = pack_i8(quant.codes_flat());
    // v6: the Gaussian moment tier is likewise recomputed at save
    // (deterministic in the corpus bytes + labels) so every saved store
    // carries the summary the high-noise fast path serves from
    let gauss = GaussMoments::build(ds);
    let mut plan = vec![
        Sec::F("data".into(), data),
        Sec::U("labels".into(), &ds.labels),
        Sec::F("proxies".into(), &ds.proxies),
        Sec::F("mean".into(), &ds.mean),
        Sec::F("var".into(), &ds.var),
        Sec::F("centroids".into(), &ds.centroids),
        Sec::U("assignments".into(), &ds.assignments),
        Sec::F("pca_bases".into(), &ds.pca_bases),
        Sec::F("pca_centers".into(), &ds.pca_centers),
        Sec::F("gmm_weights".into(), &gmm_weights),
        Sec::U("gmm_classes".into(), &gmm_classes),
        Sec::F("gmm_means".into(), &gmm_means),
        Sec::F("gmm_vars".into(), &gmm_vars),
        Sec::U("quant_codes".into(), &quant_codes),
        Sec::F("quant_scale".into(), quant.scales_flat()),
        Sec::F("quant_err".into(), quant.errs_flat()),
        Sec::F("gauss_mean".into(), &gauss.mean),
        Sec::F("gauss_var".into(), &gauss.var),
        Sec::U("gauss_counts".into(), &gauss.counts),
    ];
    if let Some(ivf) = &ds.ivf {
        plan.push(Sec::F("ivf_centroids".into(), &ivf.centroids));
        plan.push(Sec::U("ivf_assign".into(), &ivf.assignments));
    }
    if let Some(si) = &ds.shard_ivf {
        // per-shard IVF partitions (v3): a sharded cluster engine start
        // reuses these instead of paying per-shard k-means every time
        for (i, (c, a)) in si.centroids.iter().zip(&si.assignments).enumerate() {
            plan.push(Sec::F(format!("ivf_shard_{i}_centroids"), c));
            plan.push(Sec::U(format!("ivf_shard_{i}_assign"), a));
        }
    }

    // First pass: build section metadata assuming offsets start at 0 (we
    // prepend magic + header later, storing offsets relative to data start).
    let mut sections = Vec::new();
    let mut offset = 0u64;
    let mut data_offset = 0u64;
    let mut proxies_offset = 0u64;
    for sec in &plan {
        let (name, dtype, len) = match sec {
            Sec::F(n, v) => (n.as_str(), "f32", v.len()),
            Sec::U(n, v) => (n.as_str(), "u32", v.len()),
        };
        match name {
            "data" => data_offset = offset,
            "proxies" => proxies_offset = offset,
            _ => {}
        }
        // v5: checksum over the exact little-endian bytes this section
        // puts on disk, so readers can verify payloads on first touch
        let crc = match sec {
            Sec::F(_, v) => crc32_f32(v),
            Sec::U(_, v) => crc32_u32(v),
        };
        let mut meta = Json::obj();
        meta.set("name", name)
            .set("dtype", dtype)
            .set("offset", offset)
            .set("len", len)
            .set("crc32", crc);
        sections.push(meta);
        offset += len as u64 * 4;
    }
    // v3: per-shard alias sections into the contiguous data/proxies
    // payloads — rows of shard i live at data_offset + start·d·4 — so a
    // ShardReader seeks one shard without re-deriving the layout; no
    // payload bytes are duplicated. Today the reader cross-checks
    // `data_shard_i` against the plan-derived offset (and proxy streaming
    // is not wired yet — `proxies_shard_i` is declared for the planned
    // corpus-non-resident mode), so the aliases are a forward-compat
    // surface, not load-bearing for current stores.
    if shards > 1 {
        let splan = ShardPlan::new(ds.n, shards);
        header.set("shards", splan.count());
        for i in 0..splan.count() {
            let (s, e) = splan.range(i);
            let rows = e - s;
            // v5: alias sections are checksummed over their *subrange* so
            // a ShardReader can verify one shard's bytes in isolation
            let mut meta = Json::obj();
            meta.set("name", format!("data_shard_{i}"))
                .set("dtype", "f32")
                .set("offset", data_offset + (s * ds.d) as u64 * 4)
                .set("len", rows * ds.d)
                .set("crc32", crc32_f32(&data[s * ds.d..e * ds.d]));
            sections.push(meta);
            let mut meta = Json::obj();
            meta.set("name", format!("proxies_shard_{i}"))
                .set("dtype", "f32")
                .set("offset", proxies_offset + (s * ds.proxy_d) as u64 * 4)
                .set("len", rows * ds.proxy_d)
                .set(
                    "crc32",
                    crc32_f32(&ds.proxies[s * ds.proxy_d..e * ds.proxy_d]),
                );
            sections.push(meta);
        }
    }
    header.set("sections", Json::Arr(sections));
    let header_bytes = header.to_string_compact().into_bytes();

    let file = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut out = BufWriter::new(file);
    out.write_all(MAGIC)?;
    out.write_all(&(header_bytes.len() as u32).to_le_bytes())?;
    out.write_all(&header_bytes)?;
    for sec in &plan {
        match sec {
            Sec::F(_, v) => {
                for x in *v {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
            Sec::U(_, v) => {
                for x in *v {
                    out.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    out.flush()?;
    // fsync before the caller renames over the live store: without it a
    // crash could publish a name pointing at unwritten payload bytes
    out.get_ref().sync_all()?;
    Ok(())
}

/// Parsed store header + bounds-checked section readers — shared by
/// [`load`] (full read) and [`open_streaming`] (data-free read).
struct StoreFile {
    rd: BufReader<File>,
    header: Json,
    data_start: u64,
    file_len: u64,
    path: std::path::PathBuf,
}

impl StoreFile {
    fn open(path: &Path) -> Result<StoreFile> {
        let file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut rd = BufReader::new(file);
        let mut magic = [0u8; 4];
        rd.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a GDS1 file");
        }
        let mut len4 = [0u8; 4];
        rd.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        rd.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?)?;
        header
            .get("sections")
            .and_then(Json::as_arr)
            .context("missing sections")?;
        Ok(StoreFile {
            rd,
            header,
            data_start: 8 + hlen as u64,
            file_len,
            path: path.to_path_buf(),
        })
    }

    /// Locate a section, bounds-checked against the real file size before
    /// any seek, so a truncated store fails with the section's name instead
    /// of a raw IO error from deep inside the byte loop.
    fn locate(&self, name: &str) -> Result<(u64, usize)> {
        let sections = self.header.get("sections").and_then(Json::as_arr).unwrap();
        let sec = sections
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some(name))
            .with_context(|| format!("section {name} missing"))?;
        let off = sec.num_field("offset")? as u64;
        let len = sec.num_field("len")? as usize;
        let end = self.data_start + off + len as u64 * 4;
        if end > self.file_len {
            bail!(
                "{:?}: section `{name}` (offset {off}, {len} elements) \
                 ends at byte {end} past the {}-byte file — \
                 truncated or corrupt store",
                self.path,
                self.file_len
            );
        }
        Ok((off, len))
    }

    /// Whether the header lists a section at all (no bounds or checksum
    /// implications — "absent" is the legacy-degrade signal, distinct from
    /// "present but unreadable" which is the corruption-degrade signal).
    fn has_section(&self, name: &str) -> bool {
        let sections = self.header.get("sections").and_then(Json::as_arr).unwrap();
        sections
            .iter()
            .any(|s| s.get("name").and_then(Json::as_str) == Some(name))
    }

    /// The section's stored checksum, when the store carries one (v5+).
    /// v≤4 stores have no `crc32` field → `None` → verification skips,
    /// so legacy stores load exactly as before.
    fn section_crc(&self, name: &str) -> Option<u32> {
        let sections = self.header.get("sections").and_then(Json::as_arr)?;
        let sec = sections
            .iter()
            .find(|s| s.get("name").and_then(Json::as_str) == Some(name))?;
        sec.get("crc32").and_then(Json::as_f64).map(|v| v as u32)
    }

    fn read_bytes(&mut self, name: &str) -> Result<Vec<u8>> {
        let (off, len) = self.locate(name)?;
        self.rd.seek(SeekFrom::Start(self.data_start + off))?;
        let mut bytes = vec![0u8; len * 4];
        self.rd.read_exact(&mut bytes)?;
        // v5: first-touch integrity — every section read through here is
        // read exactly once per open, so this verifies each on first touch
        if let Some(want) = self.section_crc(name) {
            let got = crc32(&bytes);
            if got != want {
                return Err(anyhow::Error::new(ChecksumMismatch {
                    section: name.to_string(),
                    want,
                    got,
                })
                .context(format!("{:?}: verifying section `{name}`", self.path)));
            }
        }
        Ok(bytes)
    }

    fn read_f32(&mut self, name: &str) -> Result<Vec<f32>> {
        Ok(self
            .read_bytes(name)?
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    fn read_u32(&mut self, name: &str) -> Result<Vec<u32>> {
        Ok(self
            .read_bytes(name)?
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Load a dataset from a `.gds` file (fully resident, the seed behaviour).
pub fn load(path: &Path) -> Result<Dataset> {
    let mut sf = StoreFile::open(path)?;
    let data = sf.read_f32("data")?;
    finish_dataset(sf, RowSource::Resident(data))
}

/// Open a `.gds` store **without materialising the corpus**: headers,
/// proxies, shard bounds and stats load as usual, but the `data` section
/// stays on disk and rows stream shard-at-a-time through a
/// `mem_budget_mb`-bounded LRU ([`StreamedRows`]). Every *required*
/// section is still bounds-validated (and checksum-verified, v5+) up
/// front, so a truncated or corrupt store fails here — loudly, naming the
/// section — not mid-serve; unreadable *optional* tiers (`quant_*`,
/// `ivf_*`) stand down instead, exactly as in [`load`].
///
/// Any valid store streams under any `shards` count: v3 stores saved with
/// a matching plan seek via their per-shard alias sections, everything
/// else derives offsets from the contiguous `data` section (see
/// [`ShardReader`]).
pub fn open_streaming(path: &Path, shards: usize, mem_budget_mb: usize) -> Result<Dataset> {
    open_streaming_with(path, shards, mem_budget_mb, FaultInjector::from_env())
}

/// [`open_streaming`] with an explicit fault injector behind the
/// `ShardReader` I/O seam — tests wire a seeded one to prove the retry /
/// checksum / degrade paths fire; `open_streaming` itself passes the
/// env-configured default (`GOLDDIFF_FAULT_RATE` / `GOLDDIFF_FAULT_SEED`,
/// off unless the rate is set nonzero).
pub fn open_streaming_with(
    path: &Path,
    shards: usize,
    mem_budget_mb: usize,
    fault: Option<Arc<FaultInjector>>,
) -> Result<Dataset> {
    let sf = StoreFile::open(path)?;
    let n = sf.header.num_field("n")? as usize;
    let d = sf.header.num_field("d")? as usize;
    // validate the data section's bounds without reading a byte of it
    let (_, data_len) = sf.locate("data")?;
    anyhow::ensure!(
        data_len == n * d,
        "{path:?}: data section holds {data_len} values, expected {n}×{d}"
    );
    let reader = ShardReader::open_with(path, shards, fault)?;
    let src = std::sync::Arc::new(StreamedRows::new(reader, n, d, mem_budget_mb));
    finish_dataset(sf, RowSource::Streamed(src))
}

/// [`open_streaming`] for a **shard worker** (`golddiff shard-worker`):
/// the worker serves only its `assigned` shard subset, so this validates
/// the assignment against the plan and pre-touches each assigned shard
/// once — cold-stream cost (and any per-shard checksum failure) surfaces
/// at open, not on the first remote op. An assignment id at or past the
/// shard count is a coordinator routing bug and fails the open loudly
/// rather than being silently ignored.
pub fn open_worker(
    path: &Path,
    shards: usize,
    mem_budget_mb: usize,
    assigned: &[usize],
) -> Result<Dataset> {
    let ds = open_streaming(path, shards, mem_budget_mb)?;
    let ns = shards.max(1);
    for &sh in assigned {
        anyhow::ensure!(sh < ns, "assigned shard {sh} out of range (store has {ns} shards)");
    }
    if let RowSource::Streamed(src) = &ds.rows {
        for &sh in assigned {
            let _ = src.shard_blocks(sh);
        }
    }
    Ok(ds)
}

/// Classify and log an optional-tier read failure: checksum mismatches
/// count separately in telemetry; either way the tier stands down and
/// serving continues on the exact f32 path.
fn tier_degraded(path: &Path, tier: &str, err: &anyhow::Error, checksum_failures: &mut u64) {
    if err.downcast_ref::<ChecksumMismatch>().is_some() {
        *checksum_failures += 1;
    }
    eprintln!("warning: {path:?}: optional tier `{tier}` stands down — {err:#}");
}

/// Everything after the row payload: the shared tail of [`load`] and
/// [`open_streaming`] — side tables, stats, GMM, persisted partitions.
fn finish_dataset(mut sf: StoreFile, rows: RowSource) -> Result<Dataset> {
    let n = sf.header.num_field("n")? as usize;
    let d = sf.header.num_field("d")? as usize;
    // optional tiers that failed verification stand down instead of
    // failing the load; the engine surfaces them through `health`
    let mut degraded: Vec<String> = Vec::new();
    let mut checksum_failures: u64 = 0;
    let labels = sf.read_u32("labels")?;
    let proxies = sf.read_f32("proxies")?;
    let mean = sf.read_f32("mean")?;
    let var = sf.read_f32("var")?;
    let centroids = sf.read_f32("centroids")?;
    let assignments = sf.read_u32("assignments")?;
    let pca_bases = sf.read_f32("pca_bases")?;
    let pca_centers = sf.read_f32("pca_centers")?;
    let gmm_weights = sf.read_f32("gmm_weights")?;
    let gmm_classes = sf.read_u32("gmm_classes")?;
    let gmm_means = sf.read_f32("gmm_means")?;
    let gmm_vars = sf.read_f32("gmm_vars")?;

    let mut gmm = GmmSpec::new(d);
    for (i, (&w, &cls)) in gmm_weights.iter().zip(&gmm_classes).enumerate() {
        gmm.push(
            w,
            gmm_means[i * d..(i + 1) * d].to_vec(),
            gmm_vars[i * d..(i + 1) * d].to_vec(),
            cls,
        );
    }

    let classes = sf.header.num_field("classes")? as usize;
    let mut class_rows = vec![Vec::new(); classes];
    for (i, &y) in labels.iter().enumerate() {
        class_rows[y as usize].push(i as u32);
    }

    let proxy_d = sf.header.num_field("proxy_d")? as usize;

    // version-2 stores may carry the IVF partition; anything older (or a
    // store saved before a cluster engine ran) yields None → k-means
    // rebuild. A partition that is present but unreadable (truncated or
    // checksum-corrupt sections) degrades to the same None — a cluster
    // engine start pays the k-means rebuild instead of failing the load.
    let ivf = match (
        sf.header.get("ivf_lists").and_then(Json::as_f64),
        sf.header
            .get("ivf_seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok()),
    ) {
        (Some(lists), Some(seed)) => {
            let read = sf
                .read_f32("ivf_centroids")
                .and_then(|c| Ok((c, sf.read_u32("ivf_assign")?)));
            match read {
                Ok((centroids, assignments)) => Some(IvfPartition {
                    lists: lists as usize,
                    seed,
                    centroids,
                    assignments,
                }),
                Err(err) => {
                    tier_degraded(&sf.path, "ivf", &err, &mut checksum_failures);
                    degraded.push("ivf".to_string());
                    None
                }
            }
        }
        _ => None,
    };

    // v3 stores may additionally carry the *per-shard* IVF partitions a
    // sharded cluster engine persisted; legacy stores simply yield None
    let shard_ivf = match (
        sf.header.get("shard_ivf_shards").and_then(Json::as_f64),
        sf.header.get("shard_ivf_lists").and_then(Json::as_f64),
        sf.header
            .get("shard_ivf_seed")
            .and_then(Json::as_str)
            .and_then(|s| s.parse::<u64>().ok()),
    ) {
        (Some(sh), Some(lists), Some(seed)) => {
            let sh = sh as usize;
            let read = (|| -> Result<(Vec<Vec<f32>>, Vec<Vec<u32>>)> {
                let mut centroids = Vec::with_capacity(sh);
                let mut shard_assign = Vec::with_capacity(sh);
                for i in 0..sh {
                    centroids.push(sf.read_f32(&format!("ivf_shard_{i}_centroids"))?);
                    shard_assign.push(sf.read_u32(&format!("ivf_shard_{i}_assign"))?);
                }
                Ok((centroids, shard_assign))
            })();
            match read {
                Ok((centroids, shard_assign)) => Some(ShardIvfPartition {
                    shards: sh,
                    lists: lists as usize,
                    seed,
                    centroids,
                    assignments: shard_assign,
                }),
                Err(err) => {
                    // same degrade contract as the monolithic partition:
                    // the sharded cluster start rebuilds its k-means
                    tier_degraded(&sf.path, "shard_ivf", &err, &mut checksum_failures);
                    degraded.push("shard_ivf".to_string());
                    None
                }
            }
        }
        _ => None,
    };

    // v4 stores carry the quantised row tier; preload it into the
    // dataset's OnceLock so both residencies serve the same persisted
    // bytes. Older stores leave the lock empty: a resident open rebuilds
    // the (identical) tier on first use, a streamed open reports None and
    // the quantised refine pre-rung stands down.
    let quant_row_tier = std::sync::OnceLock::new();
    if sf.has_section("quant_codes")
        && sf.has_section("quant_scale")
        && sf.has_section("quant_err")
    {
        let built = (|| -> Result<QuantRows> {
            let codes = unpack_i8(&sf.read_u32("quant_codes")?, n * d);
            let scales = sf.read_f32("quant_scale")?;
            let errs = sf.read_f32("quant_err")?;
            QuantRows::from_parts(n, d, codes, scales, errs).with_context(|| {
                format!(
                    "{:?}: quant sections disagree with the {n}×{d} corpus shape",
                    sf.path
                )
            })
        })();
        match built {
            Ok(qr) => {
                let _ = quant_row_tier.set(Some(qr));
            }
            Err(err) => {
                tier_degraded(&sf.path, "quant", &err, &mut checksum_failures);
                degraded.push("quant".to_string());
                // pin the tier to None (not "unset"): a resident open
                // would otherwise lazily rebuild from the corpus and mask
                // the corruption of the persisted tier — degrading keeps
                // the failure observable and the behaviour identical
                // across residencies (quant-off, exact f32 path)
                let _ = quant_row_tier.set(None);
            }
        }
    }

    // v6 stores carry the Gaussian moment tier; preload it so both
    // residencies serve the same persisted bytes. Legacy stores leave
    // the lock empty: a resident open rebuilds the (bit-identical)
    // summary with one corpus pass on first use, a streamed open
    // reports None and the Gaussian fast path stands down. A corrupt
    // section pins the tier off, same as quant.
    let gauss_moment_tier = std::sync::OnceLock::new();
    if sf.has_section("gauss_mean")
        && sf.has_section("gauss_var")
        && sf.has_section("gauss_counts")
    {
        let built = (|| -> Result<GaussMoments> {
            let mean = sf.read_f32("gauss_mean")?;
            let var = sf.read_f32("gauss_var")?;
            let counts = sf.read_u32("gauss_counts")?;
            GaussMoments::from_parts(d, classes, n, mean, var, counts).with_context(|| {
                format!(
                    "{:?}: gauss sections disagree with the {n}-row, \
                     {classes}-class corpus shape",
                    sf.path
                )
            })
        })();
        match built {
            Ok(gm) => {
                let _ = gauss_moment_tier.set(Some(gm));
            }
            Err(err) => {
                tier_degraded(&sf.path, "gauss", &err, &mut checksum_failures);
                degraded.push("gauss".to_string());
                let _ = gauss_moment_tier.set(None);
            }
        }
    }

    let proxy_blocks = ProxyBlocks::build(&proxies, n, proxy_d);
    Ok(Dataset {
        name: sf.header.str_field("name")?.to_string(),
        n,
        h: sf.header.num_field("h")? as usize,
        w: sf.header.num_field("w")? as usize,
        c: sf.header.num_field("c")? as usize,
        d,
        proxy_d,
        classes,
        conditional: sf
            .header
            .get("conditional")
            .and_then(Json::as_bool)
            .unwrap_or(false),
        rows,
        labels,
        proxies,
        proxy_blocks,
        row_blocks: std::sync::OnceLock::new(),
        quant_proxy: std::sync::OnceLock::new(),
        quant_row_tier,
        gauss_moment_tier,
        class_rows,
        ivf,
        shard_ivf,
        degraded,
        checksum_failures,
        mean,
        var,
        centroids,
        assignments,
        pca_bases,
        pca_centers,
        gmm,
    })
}

// ---------------------------------------------------------------------------
// Shard streaming
// ---------------------------------------------------------------------------

/// Streaming shard access to a `.gds` store: seeks straight to one shard's
/// full-resolution rows without materialising the corpus. Uses the v3
/// per-shard alias sections when the store was saved with the same shard
/// count; otherwise (v1/v2 stores, or a different saved plan) it derives
/// the offsets from the contiguous `data` section and the deterministic
/// [`ShardPlan`] — so *any* valid store streams under *any* shard count.
#[derive(Debug)]
pub struct ShardReader {
    file: File,
    d: usize,
    plan: ShardPlan,
    /// absolute byte offset of each shard's first row
    offsets: Vec<u64>,
    /// absolute byte offset of the contiguous `data` section (row 0) —
    /// arbitrary row-range reads seek from here
    data_abs: u64,
    /// per-shard stored checksums (v5 stores whose saved plan matches;
    /// `None` entries skip verification — legacy stores, or a plan that
    /// differs from the saved alias sections)
    shard_crcs: Vec<Option<u32>>,
    /// first-touch ledger: a shard is verified on its first *successful*
    /// read, then re-streams skip the checksum pass (hot path stays clean)
    verified: Vec<bool>,
    /// deterministic fault source for every positioned read (tests + the
    /// `GOLDDIFF_FAULT_*` env knobs); `None` = clean I/O
    fault: Option<Arc<FaultInjector>>,
}

impl ShardReader {
    pub fn open(path: &Path, shards: usize) -> Result<ShardReader> {
        Self::open_with(path, shards, None)
    }

    /// [`open`](Self::open) with a fault injector wired into the I/O seam:
    /// every `read_shard_rows` / `read_row_range` consults it once per
    /// positioned read. See [`FaultInjector`] for the fault kinds.
    pub fn open_with(
        path: &Path,
        shards: usize,
        fault: Option<Arc<FaultInjector>>,
    ) -> Result<ShardReader> {
        let mut file = File::open(path).with_context(|| format!("open {path:?}"))?;
        let file_len = file.metadata()?.len();
        let mut magic = [0u8; 4];
        file.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a GDS1 file");
        }
        let mut len4 = [0u8; 4];
        file.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbytes = vec![0u8; hlen];
        file.read_exact(&mut hbytes)?;
        let header = parse(std::str::from_utf8(&hbytes)?)?;
        let data_start = 8 + hlen as u64;

        let n = header.num_field("n")? as usize;
        let d = header.num_field("d")? as usize;
        let sections = header
            .get("sections")
            .and_then(Json::as_arr)
            .context("missing sections")?;
        let find = |name: &str| -> Option<(u64, usize)> {
            let sec = sections
                .iter()
                .find(|s| s.get("name").and_then(Json::as_str) == Some(name))?;
            Some((
                sec.num_field("offset").ok()? as u64,
                sec.num_field("len").ok()? as usize,
            ))
        };
        let (data_off, data_len) = find("data").context("section data missing")?;
        anyhow::ensure!(
            data_len == n * d,
            "{path:?}: data section holds {data_len} values, expected {n}×{d}"
        );
        let data_abs = data_start + data_off;
        anyhow::ensure!(
            data_abs + data_len as u64 * 4 <= file_len,
            "{path:?}: data section ends past the {file_len}-byte file — \
             truncated store"
        );

        // stored per-section checksum, when the store carries one (v5+)
        let find_crc = |name: &str| -> Option<u32> {
            let sec = sections
                .iter()
                .find(|s| s.get("name").and_then(Json::as_str) == Some(name))?;
            sec.get("crc32").and_then(Json::as_f64).map(|v| v as u32)
        };
        let plan = ShardPlan::new(n, shards);
        let header_shards = header.get("shards").and_then(Json::as_f64).map(|v| v as usize);
        let mut offsets = Vec::with_capacity(plan.count());
        let mut shard_crcs = Vec::with_capacity(plan.count());
        for i in 0..plan.count() {
            let (s, e) = plan.range(i);
            let rows = e - s;
            let derived = data_start + data_off + (s * d) as u64 * 4;
            // a shard's checksum only applies when it covers exactly the
            // bytes we will read: the saved alias section with a matching
            // plan, or the whole `data` section under a one-shard plan. A
            // mismatched plan re-slices the contiguous payload, so per-
            // shard verification stands down (reads still go through the
            // retry path, and `store::load` still verifies `data` whole).
            let (abs, crc) = if header_shards == Some(plan.count()) {
                match find(&format!("data_shard_{i}")) {
                    Some((off, len)) if len == rows * d => {
                        (data_start + off, find_crc(&format!("data_shard_{i}")))
                    }
                    _ => (derived, None),
                }
            } else if plan.count() == 1 {
                (derived, find_crc("data"))
            } else {
                (derived, None)
            };
            let end = abs + (rows * d) as u64 * 4;
            if end > file_len {
                bail!(
                    "{path:?}: shard {i} rows end at byte {end} past the \
                     {file_len}-byte file — truncated store"
                );
            }
            offsets.push(abs);
            shard_crcs.push(crc);
        }
        let verified = vec![false; plan.count()];
        Ok(ShardReader {
            file,
            d,
            plan,
            offsets,
            data_abs,
            shard_crcs,
            verified,
            fault,
        })
    }

    /// The injector wired at open (shared with [`StreamedRows`] so its
    /// stats can report `faults_injected`).
    pub fn fault(&self) -> Option<&Arc<FaultInjector>> {
        self.fault.as_ref()
    }

    /// One fault-aware positioned read — the seam every streamed byte
    /// crosses. Injected faults surface exactly like real ones: a
    /// transient error fails before any bytes move, a short read delivers
    /// part of the buffer then fails (the caller's retry must re-seek —
    /// which it does, since every read is absolutely positioned), and a
    /// bit flip corrupts the returned buffer (only the shard checksum can
    /// catch it).
    fn read_at(&mut self, abs: u64, len: usize) -> std::io::Result<Vec<u8>> {
        match self.fault.as_ref().and_then(|f| f.roll()) {
            Some(FaultKind::Transient) => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected transient read failure",
                ));
            }
            Some(FaultKind::ShortRead) => {
                self.file.seek(SeekFrom::Start(abs))?;
                let mut partial = vec![0u8; len / 2];
                self.file.read_exact(&mut partial)?;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "injected short read",
                ));
            }
            Some(FaultKind::BitFlip) => {
                self.file.seek(SeekFrom::Start(abs))?;
                let mut bytes = vec![0u8; len];
                self.file.read_exact(&mut bytes)?;
                if let Some(f) = &self.fault {
                    f.flip_bit(&mut bytes);
                }
                return Ok(bytes);
            }
            None => {}
        }
        self.file.seek(SeekFrom::Start(abs))?;
        let mut bytes = vec![0u8; len];
        self.file.read_exact(&mut bytes)?;
        Ok(bytes)
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    /// Read shard `shard`'s full-resolution rows (`rows × d`, row-major).
    /// The shard's checksum (v5 stores) is verified on the first successful
    /// read — first touch — and skipped on re-streams of an evicted shard;
    /// a mismatch surfaces as [`ChecksumMismatch`], which the streamed-read
    /// retry treats as transient (a clean medium re-reads identical bytes,
    /// in-flight corruption re-reads clean; persistent on-disk corruption
    /// exhausts the retries and hard-fails — corrupt rows are never served).
    pub fn read_shard_rows(&mut self, shard: usize) -> Result<Vec<f32>> {
        let rows = self.plan.rows_in(shard);
        let bytes = self
            .read_at(self.offsets[shard], rows * self.d * 4)
            .with_context(|| format!("reading shard {shard} rows"))?;
        if !self.verified[shard] {
            if let Some(want) = self.shard_crcs[shard] {
                let got = crc32(&bytes);
                if got != want {
                    return Err(anyhow::Error::new(ChecksumMismatch {
                        section: format!("data_shard_{shard}"),
                        want,
                        got,
                    }));
                }
            }
            self.verified[shard] = true;
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Read an arbitrary global row range `[s, e)` (`(e−s) × d`, row-major)
    /// straight out of the contiguous `data` section — rows are stored
    /// contiguously whatever shard plan the store was saved with, so this
    /// serves plan-agnostic consumers (a backend sharded at a different
    /// count than the source).
    /// Arbitrary ranges cross shard boundaries, so no per-shard checksum
    /// applies here — the read still goes through the fault-aware seam
    /// (and therefore the caller's transient retry).
    pub fn read_row_range(&mut self, s: usize, e: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(s <= e && e <= self.plan.n, "row range {s}..{e} out of bounds");
        let bytes = self
            .read_at(
                self.data_abs + (s * self.d) as u64 * 4,
                (e - s) * self.d * 4,
            )
            .with_context(|| format!("reading rows {s}..{e}"))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Conventional on-disk path for a preset's store.
pub fn store_path(dir: &Path, preset: &str) -> std::path::PathBuf {
    dir.join(format!("{preset}.gds"))
}

/// Load a preset from `dir`, synthesising (and saving) it when missing.
pub fn load_or_synthesize(dir: &Path, preset_name: &str, seed: u64) -> Result<Dataset> {
    load_or_synthesize_sharded(dir, preset_name, seed, 1)
}

/// Make sure a preset's store exists on disk (synthesise + save when
/// missing) *without* loading it — the precursor to [`open_streaming`],
/// which then serves the corpus data-free off that file.
pub fn ensure_store(
    dir: &Path,
    preset_name: &str,
    seed: u64,
    shards: usize,
) -> Result<std::path::PathBuf> {
    let path = store_path(dir, preset_name);
    if !path.exists() {
        let spec = super::synthetic::preset(preset_name)
            .with_context(|| format!("unknown preset {preset_name}"))?;
        let ds = Dataset::synthesize(spec, seed);
        save_sharded(&ds, &path, shards)?;
    }
    Ok(path)
}

/// [`load_or_synthesize`] with a shard count: a freshly synthesised store
/// is saved with the v3 per-shard sections so the serving engine can
/// stream shards from it straight away. An existing store loads as-is
/// (shard offsets derive from the plan regardless of how it was saved).
pub fn load_or_synthesize_sharded(
    dir: &Path,
    preset_name: &str,
    seed: u64,
    shards: usize,
) -> Result<Dataset> {
    let path = store_path(dir, preset_name);
    if path.exists() {
        return load(&path);
    }
    let spec = super::synthetic::preset(preset_name)
        .with_context(|| format!("unknown preset {preset_name}"))?;
    let ds = Dataset::synthesize(spec, seed);
    save_sharded(&ds, &path, shards)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    /// The resident corpus of a test dataset (all stores here are saved
    /// from resident synthesis).
    fn corpus(ds: &Dataset) -> &[f32] {
        ds.resident_rows().expect("test datasets are resident")
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 128;
        let ds = Dataset::synthesize(&spec, 9);
        let dir = std::env::temp_dir().join("golddiff_store_test");
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        assert_eq!(rt.name, ds.name);
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.labels, ds.labels);
        assert_eq!(rt.proxies, ds.proxies);
        assert_eq!(rt.gmm.n_components(), ds.gmm.n_components());
        assert_eq!(rt.gmm.components[3].mean, ds.gmm.components[3].mean);
        assert_eq!(rt.class_rows, ds.class_rows);
        // derived block layouts rebuild identically from the sections
        assert_eq!(rt.row_blocks().rows, ds.row_blocks().rows);
        assert_eq!(rt.row_blocks().dim, ds.row_blocks().dim);
        assert_eq!(rt.row_blocks().block(0), ds.row_blocks().block(0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_worker_pre_touches_assigned_shards_and_rejects_bad_ids() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 96;
        let ds = Dataset::synthesize(&spec, 5);
        let dir = std::env::temp_dir().join("golddiff_store_worker_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 4).unwrap();
        let w = open_worker(&path, 4, 8, &[1, 3]).unwrap();
        let st = w.source_stats().expect("worker opens a streamed source");
        assert!(st.rows_streamed > 0, "assigned shards stream at open");
        assert!(st.resident_shards >= 1);
        assert!(
            open_worker(&path, 4, 8, &[4]).is_err(),
            "shard id past the plan fails the open"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_synthesize_caches() {
        let dir = std::env::temp_dir().join("golddiff_store_test2");
        std::fs::remove_dir_all(&dir).ok();
        // shrink via direct synthesize to keep the test fast: use moons
        let a = load_or_synthesize(&dir, "moons", 1).unwrap();
        assert!(store_path(&dir, "moons").exists());
        let b = load_or_synthesize(&dir, "moons", 999).unwrap(); // seed ignored on cache hit
        assert_eq!(a.resident_rows(), b.resident_rows());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ivf_partition_roundtrips_and_legacy_stores_load_without_it() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 96;
        let mut ds = Dataset::synthesize(&spec, 13);
        let dir = std::env::temp_dir().join("golddiff_store_ivf_test");
        let path = dir.join("moons.gds");

        // "legacy" store: saved without a partition → loads as None
        save(&ds, &path).unwrap();
        assert!(load(&path).unwrap().ivf.is_none());

        // version-2 store with the partition riding along
        ds.ivf = Some(IvfPartition::compute(&ds, 6, 0xdead_beef_0042));
        save(&ds, &path).unwrap();
        let rt = load(&path).unwrap();
        let got = rt.ivf.expect("partition must roundtrip");
        let want = ds.ivf.as_ref().unwrap();
        assert_eq!(got.lists, want.lists);
        assert_eq!(got.seed, want.seed, "u64 seed survives the JSON header");
        assert_eq!(got.centroids, want.centroids);
        assert_eq!(got.assignments, want.assignments);
        // the rest of the dataset is untouched by the new sections
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_store_roundtrips_and_reader_streams_every_shard() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 110;
        let ds = Dataset::synthesize(&spec, 21);
        let dir = std::env::temp_dir().join("golddiff_store_v3_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 4).unwrap();

        // the alias sections never disturb a full load
        let rt = load(&path).unwrap();
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);

        // streaming with the saved plan uses the per-shard sections
        let mut rd = ShardReader::open(&path, 4).unwrap();
        assert_eq!(rd.plan().count(), 4);
        for sh in 0..4 {
            let (s, e) = rd.plan().range(sh);
            let rows = rd.read_shard_rows(sh).unwrap();
            assert_eq!(rows, corpus(&ds)[s * ds.d..e * ds.d], "shard {sh}");
        }
        // a different shard count still streams via derived offsets
        let mut rd7 = ShardReader::open(&path, 7).unwrap();
        for sh in 0..rd7.plan().count() {
            let (s, e) = rd7.plan().range(sh);
            let rows = rd7.read_shard_rows(sh).unwrap();
            assert_eq!(rows, corpus(&ds)[s * ds.d..e * ds.d], "shard {sh}/7");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_single_section_store_loads_and_streams_as_shards() {
        // a store saved without shard sections (the v1/v2 shape — `save`
        // writes none) must still load whole AND stream under any plan
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 64;
        let ds = Dataset::synthesize(&spec, 5);
        let dir = std::env::temp_dir().join("golddiff_store_legacy_shard_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        // verify the file really has no shard metadata to fall back on
        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        assert!(header.get("shards").is_none(), "save() writes no shard plan");

        assert_eq!(
            load(&path).unwrap().resident_rows(),
            ds.resident_rows(),
            "loads as one corpus"
        );
        let mut rd = ShardReader::open(&path, 3).unwrap();
        for sh in 0..3 {
            let (s, e) = rd.plan().range(sh);
            assert_eq!(rd.read_shard_rows(sh).unwrap(), corpus(&ds)[s * ds.d..e * ds.d]);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncated_store_fails_with_the_section_name() {
        // Satellite: offsets/lengths are validated against the file size
        // before any seek, so a truncated store names the broken section
        // instead of surfacing a raw IO error — unless the cut only
        // removes *optional* tiers, which stand down instead (v5 degrade
        // contract)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 48;
        let ds = Dataset::synthesize(&spec, 8);
        let dir = std::env::temp_dir().join("golddiff_store_trunc_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        // a 16-byte tail cut lands in the `gauss_*` tail — optional,
        // degrades (the quant tier ahead of it is untouched)
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(pristine.len() as u64 - 16).unwrap();
        drop(f);
        let rt = load(&path).unwrap();
        assert_eq!(rt.degraded, vec!["gauss".to_string()]);
        assert!(rt.gauss_moments().is_none(), "the torn tier must stand down");
        assert!(rt.quant_rows().is_some(), "earlier tiers are untouched");
        assert_eq!(rt.resident_rows(), ds.resident_rows(), "corpus intact");

        // a cut inside a *required* section fails, naming it
        std::fs::write(&path, &pristine).unwrap();
        let (start, len) = section_span(&path, "gmm_vars");
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len((start + len / 2) as u64).unwrap();
        drop(f);
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(
            err.contains("section") && err.contains("truncated"),
            "error must name the problem: {err}"
        );
        assert!(err.contains("gmm_vars"), "error must name the section: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("golddiff_store_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.gds");
        std::fs::write(&path, b"NOPE1234").unwrap();
        assert!(load(&path).is_err());
        assert!(open_streaming(&path, 2, 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_serves_the_corpus_data_free() {
        // Tentpole: everything except the data section loads; rows stream
        // bit-identically through the source
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 120;
        let mut ds = Dataset::synthesize(&spec, 31);
        ds.ivf = Some(IvfPartition::compute(&ds, 5, 77));
        let dir = std::env::temp_dir().join("golddiff_store_stream_open_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 4).unwrap();

        let st = open_streaming(&path, 4, 0).unwrap();
        assert!(!st.is_resident() && st.resident_rows().is_none());
        // side tables + stats + persisted partitions all load
        assert_eq!(st.labels, ds.labels);
        assert_eq!(st.proxies, ds.proxies);
        assert_eq!(st.mean, ds.mean);
        assert_eq!(st.var, ds.var);
        assert_eq!(st.class_rows, ds.class_rows);
        assert_eq!(st.pca_bases, ds.pca_bases);
        assert_eq!(st.ivf.as_ref().unwrap().centroids, ds.ivf.as_ref().unwrap().centroids);
        // nothing of the corpus is resident until a row is touched
        assert_eq!(st.source_stats().unwrap().rows_streamed, 0);
        assert_eq!(st.source_stats().unwrap().peak_row_bytes, 0);
        // every row streams back byte-identical, via cursor and gather
        let mut cur = st.row_cursor();
        for i in 0..ds.n {
            assert_eq!(cur.row(i as u32), ds.row(i), "row {i}");
        }
        let (mut a, mut am) = (Vec::new(), Vec::new());
        let (mut b, mut bm) = (Vec::new(), Vec::new());
        st.gather_rows(&[5, 99, 0], 4, &mut a, &mut am);
        ds.gather_rows(&[5, 99, 0], 4, &mut b, &mut bm);
        assert_eq!((a, am), (b, bm));
        // a whole-corpus staging pass matches the resident copy
        let mut full = vec![0.0f32; ds.n * ds.d];
        st.copy_all_rows_into(&mut full);
        assert_eq!(full.as_slice(), corpus(&ds));
        assert!(st.source_stats().unwrap().rows_streamed >= ds.n as u64);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_handles_legacy_stores_and_any_shard_count() {
        // a v1-shape store (no shard sections) still streams under any plan
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 64;
        let ds = Dataset::synthesize(&spec, 5);
        let dir = std::env::temp_dir().join("golddiff_store_stream_legacy_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        for shards in [1usize, 3, 7] {
            let st = open_streaming(&path, shards, 0).unwrap();
            assert!(st.shard_ivf.is_none(), "legacy stores carry no partitions");
            let mut cur = st.row_cursor();
            for i in [0usize, 20, 63] {
                assert_eq!(cur.row(i as u32), ds.row(i), "shards={shards} row {i}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn open_streaming_rejects_truncated_stores_up_front() {
        // Satellite: required sections are validated at open, so a
        // truncated store fails loudly before any serving starts; a cut
        // that only removes optional tiers degrades instead
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 48;
        let ds = Dataset::synthesize(&spec, 8);
        let dir = std::env::temp_dir().join("golddiff_store_stream_trunc_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        // tail cut into the optional gauss tier: serving continues exact
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(pristine.len() as u64 - 16).unwrap();
        drop(f);
        let st = open_streaming(&path, 3, 0).unwrap();
        assert_eq!(st.degraded, vec!["gauss".to_string()]);
        assert!(st.gauss_moments().is_none());
        assert!(st.quant_rows().is_some(), "earlier tiers are untouched");
        let mut cur = st.row_cursor();
        assert_eq!(cur.row(5), ds.row(5), "rows still stream");

        // cut inside the data payload: hard failure naming the section
        std::fs::write(&path, &pristine).unwrap();
        let (start, len) = section_span(&path, "data");
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len((start + len / 2) as u64).unwrap();
        drop(f);
        let err = format!("{:#}", open_streaming(&path, 3, 0).unwrap_err());
        assert!(
            err.contains("section") && err.contains("truncated"),
            "error must name the problem: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_refuses_a_streamed_dataset() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 40;
        let ds = Dataset::synthesize(&spec, 3);
        let dir = std::env::temp_dir().join("golddiff_store_stream_save_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let st = open_streaming(&path, 2, 0).unwrap();
        let err = format!("{:#}", save(&st, &dir.join("copy.gds")).unwrap_err());
        assert!(err.contains("streamed"), "error must explain the gate: {err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Byte span (start, byte_len) of `section`'s payload within the file.
    fn section_span(path: &Path, section: &str) -> (usize, usize) {
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        let sections = header
            .get("sections")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap();
        let sec = sections
            .iter()
            .find(|s| s.get("name").and_then(crate::util::json::Json::as_str) == Some(section))
            .unwrap_or_else(|| panic!("store has no section `{section}`"));
        let off = sec
            .get("offset")
            .and_then(crate::util::json::Json::as_f64)
            .unwrap() as usize;
        let len = sec
            .get("len")
            .and_then(crate::util::json::Json::as_f64)
            .unwrap() as usize
            * 4;
        (8 + hlen + off, len)
    }

    /// Flip one payload bit in the middle of a named section — the
    /// on-disk corruption the v5 checksums exist to catch.
    fn flip_section_byte(path: &Path, section: &str) {
        let (start, len) = section_span(path, section);
        assert!(len > 0, "cannot corrupt empty section `{section}`");
        let mut bytes = std::fs::read(path).unwrap();
        bytes[start + len / 2] ^= 0x40;
        std::fs::write(path, bytes).unwrap();
    }

    /// Rewrite a store's header with every section matching `prefix`
    /// stripped — simulates an older-version store (the payload bytes
    /// stay; section offsets are relative to the header end, so a
    /// shorter header stays valid).
    fn strip_sections(path: &Path, prefix: &str) {
        let bytes = std::fs::read(path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let mut header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        let kept: Vec<crate::util::json::Json> = header
            .get("sections")
            .and_then(crate::util::json::Json::as_arr)
            .unwrap()
            .iter()
            .filter(|s| {
                !s.get("name")
                    .and_then(crate::util::json::Json::as_str)
                    .is_some_and(|n| n.starts_with(prefix))
            })
            .cloned()
            .collect();
        header.set("sections", crate::util::json::Json::Arr(kept));
        let hb = header.to_string_compact().into_bytes();
        let mut out = Vec::with_capacity(bytes.len());
        out.extend_from_slice(b"GDS1");
        out.extend_from_slice(&(hb.len() as u32).to_le_bytes());
        out.extend_from_slice(&hb);
        out.extend_from_slice(&bytes[8 + hlen..]);
        std::fs::write(path, out).unwrap();
    }

    #[test]
    fn quant_tier_roundtrips_resident_and_streaming() {
        // Tentpole: the v4 quant sections reload bit-identical to a fresh
        // build from the corpus, on both open paths
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 77;
        let ds = Dataset::synthesize(&spec, 17);
        let want = QuantRows::build(corpus(&ds), ds.n, ds.d);
        let dir = std::env::temp_dir().join("golddiff_store_quant_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();

        for opened in [load(&path).unwrap(), open_streaming(&path, 3, 0).unwrap()] {
            let got = opened.quant_rows().expect("v4 stores carry the tier");
            assert_eq!(got.codes_flat(), want.codes_flat());
            assert_eq!(got.scales_flat(), want.scales_flat());
            assert_eq!(got.errs_flat(), want.errs_flat());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_store_without_quant_sections_degrades_per_residency() {
        // a v1–v3 shape store: the resident open rebuilds the tier from
        // the corpus (identical bytes), the streamed open reports None
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 60;
        let ds = Dataset::synthesize(&spec, 23);
        let dir = std::env::temp_dir().join("golddiff_store_quant_legacy_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        strip_sections(&path, "quant_");

        let resident = load(&path).unwrap();
        assert_eq!(resident.resident_rows(), ds.resident_rows());
        let want = QuantRows::build(corpus(&ds), ds.n, ds.d);
        let got = resident.quant_rows().expect("resident opens rebuild");
        assert_eq!(got.codes_flat(), want.codes_flat());
        assert_eq!(got.errs_flat(), want.errs_flat());

        let streamed = open_streaming(&path, 2, 0).unwrap();
        assert!(
            streamed.quant_rows().is_none(),
            "a streamed legacy store has no corpus to quantise from"
        );
        // ...and the rest of the dataset still serves
        let mut cur = streamed.row_cursor();
        assert_eq!(cur.row(7), ds.row(7));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn pack_unpack_i8_roundtrips_ragged_lengths() {
        for n in [0usize, 1, 3, 4, 5, 8, 13] {
            let codes: Vec<i8> = (0..n).map(|i| (i as i8).wrapping_mul(-37)).collect();
            let packed = pack_i8(&codes);
            assert_eq!(packed.len(), n.div_ceil(4));
            assert_eq!(unpack_i8(&packed, n), codes, "n={n}");
        }
        assert_eq!(unpack_i8(&pack_i8(&[-128, 127, -1, 0, 42]), 5), [-128, 127, -1, 0, 42]);
    }

    #[test]
    fn v5_stores_checksum_every_section() {
        // Tentpole: every section the writer emits — including the alias
        // subranges and the optional tiers — carries a crc32 in its header
        // metadata, and a clean store loads with nothing degraded
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 66;
        let mut ds = Dataset::synthesize(&spec, 15);
        ds.ivf = Some(IvfPartition::compute(&ds, 4, 31));
        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 3, 2, 32));
        let dir = std::env::temp_dir().join("golddiff_store_crc_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();

        let bytes = std::fs::read(&path).unwrap();
        let hlen = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        let header = parse(std::str::from_utf8(&bytes[8..8 + hlen]).unwrap()).unwrap();
        assert_eq!(header.get("version").and_then(Json::as_f64), Some(6.0));
        let sections = header.get("sections").and_then(Json::as_arr).unwrap();
        assert!(sections.len() >= 19 + 2 + 6 + 6, "full v1–v6 menu present");
        for sec in sections {
            let name = sec.get("name").and_then(Json::as_str).unwrap();
            let crc = sec.get("crc32").and_then(Json::as_f64);
            assert!(crc.is_some(), "section `{name}` must carry a checksum");
        }
        let rt = load(&path).unwrap();
        assert!(rt.degraded.is_empty() && rt.checksum_failures == 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_required_section_fails_naming_it() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 54;
        let ds = Dataset::synthesize(&spec, 19);
        let dir = std::env::temp_dir().join("golddiff_store_corrupt_req_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        flip_section_byte(&path, "proxies");

        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(
            err.contains("proxies") && err.contains("checksum"),
            "load must fail naming the corrupt section: {err}"
        );
        let err = format!("{:#}", open_streaming(&path, 2, 0).unwrap_err());
        assert!(
            err.contains("proxies") && err.contains("checksum"),
            "the streaming open verifies the same sections: {err}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_quant_tier_degrades_and_serving_continues() {
        // Tentpole acceptance: a corrupt *optional* tier stands down like a
        // legacy load — the exact f32 path serves, the degradation is
        // surfaced on the dataset (and from there through `health`)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 58;
        let ds = Dataset::synthesize(&spec, 29);
        let dir = std::env::temp_dir().join("golddiff_store_corrupt_quant_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();
        flip_section_byte(&path, "quant_err");

        let rt = load(&path).unwrap();
        assert_eq!(rt.degraded, vec!["quant".to_string()]);
        assert_eq!(rt.checksum_failures, 1);
        assert!(
            rt.quant_rows().is_none(),
            "the corrupt tier must pin off, not lazily rebuild from the corpus"
        );
        assert_eq!(rt.resident_rows(), ds.resident_rows(), "exact path intact");

        let st = open_streaming(&path, 3, 0).unwrap();
        assert_eq!(st.degraded, vec!["quant".to_string()]);
        assert_eq!(st.checksum_failures, 1);
        assert!(st.quant_rows().is_none());
        let mut cur = st.row_cursor();
        assert_eq!(cur.row(7), ds.row(7), "rows still stream byte-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gauss_tier_roundtrips_resident_and_streaming() {
        // Tentpole: the v6 gauss sections reload bit-identical to a fresh
        // build from the corpus, on both open paths — the streamed open
        // serves the Gaussian fast path without ever touching `data`
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 84;
        let ds = Dataset::synthesize(&spec, 61);
        let want = GaussMoments::build(&ds);
        let dir = std::env::temp_dir().join("golddiff_store_gauss_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();

        for opened in [load(&path).unwrap(), open_streaming(&path, 3, 0).unwrap()] {
            let got = opened.gauss_moments().expect("v6 stores carry the tier");
            assert_eq!(got, &want, "persisted moments are bit-identical");
        }
        // the streamed open reads zero corpus rows to serve the tier
        let st = open_streaming(&path, 3, 0).unwrap();
        let _ = st.gauss_moments().unwrap();
        assert_eq!(st.source_stats().unwrap().rows_streamed, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_store_without_gauss_sections_degrades_per_residency() {
        // a v≤5 shape store: the resident open rebuilds the summary from
        // the corpus (identical bytes), the streamed open stands down
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 56;
        let ds = Dataset::synthesize(&spec, 67);
        let dir = std::env::temp_dir().join("golddiff_store_gauss_legacy_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        strip_sections(&path, "gauss_");

        let resident = load(&path).unwrap();
        assert!(resident.degraded.is_empty(), "legacy absence is not corruption");
        let want = GaussMoments::build(&ds);
        assert_eq!(
            resident.gauss_moments().expect("resident opens rebuild"),
            &want
        );

        let streamed = open_streaming(&path, 2, 0).unwrap();
        assert!(
            streamed.gauss_moments().is_none(),
            "a streamed legacy store never pays a serve-time corpus pass"
        );
        let mut cur = streamed.row_cursor();
        assert_eq!(cur.row(7), ds.row(7), "rows still serve");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_gauss_tier_degrades_and_serving_continues() {
        // a corrupt *optional* gauss section stands the tier down on both
        // open paths — pinned off (no lazy resident rebuild masking it),
        // surfaced in degraded/checksum telemetry, exact path intact
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 52;
        let ds = Dataset::synthesize(&spec, 71);
        let dir = std::env::temp_dir().join("golddiff_store_corrupt_gauss_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();
        flip_section_byte(&path, "gauss_var");

        let rt = load(&path).unwrap();
        assert_eq!(rt.degraded, vec!["gauss".to_string()]);
        assert_eq!(rt.checksum_failures, 1);
        assert!(
            rt.gauss_moments().is_none(),
            "the corrupt tier must pin off, not lazily rebuild from the corpus"
        );
        assert!(rt.quant_rows().is_some(), "other tiers are untouched");
        assert_eq!(rt.resident_rows(), ds.resident_rows(), "exact path intact");

        let st = open_streaming(&path, 3, 0).unwrap();
        assert_eq!(st.degraded, vec!["gauss".to_string()]);
        assert_eq!(st.checksum_failures, 1);
        assert!(st.gauss_moments().is_none());
        let mut cur = st.row_cursor();
        assert_eq!(cur.row(9), ds.row(9), "rows still stream byte-identical");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gauss_moments_byte_identical_across_residency_shards_and_evictions() {
        // Satellite: the accumulator's one ascending visit_rows pass makes
        // the summary bit-identical whether the corpus is resident or
        // streamed, under any shard count, and under an LRU budget tight
        // enough to force evictions mid-pass
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 180;
        let ds = Dataset::synthesize(&spec, 77);
        let want = GaussMoments::build(&ds);
        let dir = std::env::temp_dir().join("golddiff_store_gauss_equality_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("mnist-sim.gds");
        save_sharded(&ds, &path, 4).unwrap();
        strip_sections(&path, "gauss_"); // force a streamed rebuild path

        for shards in [1usize, 4, 6] {
            // budget 0 = minimum (one block resident at a time): every
            // shard transition evicts, the accumulator must not care
            for budget_mb in [0usize, 1, 64] {
                let st = open_streaming(&path, shards, budget_mb).unwrap();
                let got = GaussMoments::build(&st);
                assert_eq!(
                    got, want,
                    "shards={shards} budget={budget_mb}MiB must be bit-identical"
                );
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_ivf_partition_degrades_to_kmeans_rebuild() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 62;
        let mut ds = Dataset::synthesize(&spec, 37);
        ds.ivf = Some(IvfPartition::compute(&ds, 5, 41));
        let dir = std::env::temp_dir().join("golddiff_store_corrupt_ivf_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        flip_section_byte(&path, "ivf_centroids");

        let rt = load(&path).unwrap();
        assert!(rt.ivf.is_none(), "the corrupt partition must stand down");
        assert!(rt.degraded.contains(&"ivf".to_string()));
        assert_eq!(rt.checksum_failures, 1);
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn truncation_fuzz_every_section_fails_loudly_or_degrades() {
        // Satellite: cut the file mid-payload at EVERY section the header
        // lists (the full v1–v5 menu). Each cut must either fail naming a
        // section, or — when only optional tiers are lost — load with the
        // degradation recorded. No cut may load clean or crash raw.
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 72;
        let mut ds = Dataset::synthesize(&spec, 43);
        ds.ivf = Some(IvfPartition::compute(&ds, 4, 51));
        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 3, 2, 52));
        let dir = std::env::temp_dir().join("golddiff_store_trunc_fuzz_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();
        let pristine = std::fs::read(&path).unwrap();
        let hlen =
            u32::from_le_bytes([pristine[4], pristine[5], pristine[6], pristine[7]]) as usize;
        let header = parse(std::str::from_utf8(&pristine[8..8 + hlen]).unwrap()).unwrap();
        let names: Vec<String> = header
            .get("sections")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|s| s.get("name").and_then(Json::as_str).unwrap().to_string())
            .collect();
        for name in &names {
            std::fs::write(&path, &pristine).unwrap();
            let (start, len) = section_span(&path, name);
            if len == 0 {
                continue;
            }
            let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
            f.set_len((start + len / 2) as u64).unwrap();
            drop(f);
            match load(&path) {
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(
                        msg.contains("section"),
                        "cut in `{name}`: the failure must name a section: {msg}"
                    );
                }
                Ok(rt) => {
                    assert!(
                        !rt.degraded.is_empty(),
                        "cut in `{name}` loaded clean — truncation must fail or degrade"
                    );
                }
            }
        }
        // restored bytes load clean again
        std::fs::write(&path, &pristine).unwrap();
        let rt = load(&path).unwrap();
        assert!(rt.degraded.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tmp_write_never_corrupts_the_live_store() {
        // Satellite: the writer goes `*.tmp` → fsync → rename, so a crash
        // mid-write leaves a stale tmp file and an untouched live store
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 44;
        let ds = Dataset::synthesize(&spec, 53);
        let dir = std::env::temp_dir().join("golddiff_store_torn_write_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save(&ds, &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // simulate the crash: half a store image under the tmp name,
        // rename never reached
        let tmp = path.with_extension("gds.tmp");
        std::fs::write(&tmp, &good[..good.len() / 2]).unwrap();
        let rt = load(&path).unwrap();
        assert_eq!(rt.resident_rows(), ds.resident_rows(), "old store intact");
        assert!(rt.degraded.is_empty() && rt.checksum_failures == 0);

        // the next save publishes atomically over both: the tmp is
        // consumed by the rename and the live store stays loadable
        save(&ds, &path).unwrap();
        assert!(!tmp.exists(), "save consumes its tmp via rename");
        assert_eq!(load(&path).unwrap().resident_rows(), ds.resident_rows());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_ivf_partitions_roundtrip_and_legacy_stores_load_without_them() {
        // Satellite: per-shard IVF partitions persist in v3 sections and
        // reload verbatim; stores saved without them yield None
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 90;
        let mut ds = Dataset::synthesize(&spec, 13);
        let dir = std::env::temp_dir().join("golddiff_store_shard_ivf_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("moons.gds");
        save_sharded(&ds, &path, 3).unwrap();
        assert!(load(&path).unwrap().shard_ivf.is_none());

        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 3, 4, 0xfeed_beef_0099));
        save_sharded(&ds, &path, 3).unwrap();
        let rt = load(&path).unwrap();
        let got = rt.shard_ivf.expect("partitions must roundtrip");
        let want = ds.shard_ivf.as_ref().unwrap();
        assert_eq!(&got, want, "u64 seed + all shards survive the header");
        assert!(got.matches(3, 4, 0xfeed_beef_0099));
        // the streaming open loads them too (it never touches data)
        let st = open_streaming(&path, 3, 0).unwrap();
        assert_eq!(st.shard_ivf.as_ref(), Some(want));
        // the rest of the dataset is untouched by the new sections
        assert_eq!(rt.resident_rows(), ds.resident_rows());
        assert_eq!(rt.proxies, ds.proxies);
        std::fs::remove_dir_all(&dir).ok();
    }
}
