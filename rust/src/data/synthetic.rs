//! Procedural hierarchical-GMM image datasets — the benchmark stand-ins
//! for MNIST/CIFAR/CelebA-HQ/AFHQ/ImageNet-1K (DESIGN.md §3).
//!
//! Each class owns a set of mixture components whose means are multi-scale
//! procedural "images": a smooth class-level low-frequency structure plus
//! component-level mid-frequency detail. Per-pixel variances encode
//! high-frequency texture. This enforces the two properties the paper's
//! mechanisms rely on:
//!
//! 1. a clustered manifold (Posterior Progressive Concentration is
//!    observable: the posterior collapses onto the right component), and
//! 2. *hierarchical consistency* (Sec. 3.4): the s=1/4 downsampling proxy
//!    distance correlates with the full-resolution distance, because class
//!    identity lives in the low-frequency band.

use super::gmm::GmmSpec;
use crate::util::rng::Pcg64;

/// Static description of a dataset preset (mirrors python/compile/presets.py
/// and the manifest; kept in sync by integration tests).
#[derive(Debug, Clone)]
pub struct PresetSpec {
    pub name: &'static str,
    pub paper_name: &'static str,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub classes: usize,
    pub modes_per_class: usize,
    pub conditional: bool,
    /// base per-pixel noise std of each component (texture amplitude)
    pub texture: f32,
}

impl PresetSpec {
    pub fn d(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn proxy_d(&self) -> usize {
        if self.h == 1 {
            self.w * self.c
        } else {
            (self.h / 4) * (self.w / 4) * self.c
        }
    }
}

pub const PRESETS: &[PresetSpec] = &[
    PresetSpec { name: "moons", paper_name: "Moons (Fig. 1)", n: 2000, h: 1, w: 2, c: 1, classes: 2, modes_per_class: 24, conditional: false, texture: 0.05 },
    PresetSpec { name: "mnist-sim", paper_name: "MNIST", n: 8000, h: 16, w: 16, c: 1, classes: 10, modes_per_class: 4, conditional: false, texture: 0.10 },
    PresetSpec { name: "fashion-sim", paper_name: "Fashion-MNIST", n: 8000, h: 16, w: 16, c: 1, classes: 10, modes_per_class: 6, conditional: false, texture: 0.14 },
    PresetSpec { name: "cifar-sim", paper_name: "CIFAR-10", n: 10_000, h: 16, w: 16, c: 3, classes: 10, modes_per_class: 8, conditional: false, texture: 0.16 },
    PresetSpec { name: "celeba-sim", paper_name: "CelebA-HQ", n: 6000, h: 24, w: 24, c: 3, classes: 40, modes_per_class: 2, conditional: false, texture: 0.12 },
    PresetSpec { name: "afhq-sim", paper_name: "AFHQv2", n: 6000, h: 24, w: 24, c: 3, classes: 3, modes_per_class: 24, conditional: false, texture: 0.13 },
    PresetSpec { name: "imagenet-sim", paper_name: "ImageNet-1K", n: 50_000, h: 16, w: 16, c: 3, classes: 1000, modes_per_class: 2, conditional: true, texture: 0.15 },
];

pub fn preset(name: &str) -> Option<&'static PresetSpec> {
    PRESETS.iter().find(|p| p.name == name)
}

/// Build the population mixture for a preset.
pub fn build_population(spec: &PresetSpec, seed: u64) -> GmmSpec {
    if spec.name == "moons" {
        return moons_population(spec);
    }
    let mut rng = Pcg64::with_stream(seed, 0x5e_ed);
    let d = spec.d();
    let mut gmm = GmmSpec::new(d);
    for class in 0..spec.classes {
        // class-level low-frequency field: 3 cosine harmonics with
        // class-determined frequencies & phases
        let class_rng_seed = seed ^ (class as u64).wrapping_mul(0x9e37_79b9);
        let mut crng = Pcg64::with_stream(class_rng_seed, 0xc1a5_5e5);
        let harmonics: Vec<(f32, f32, f32, f32, f32)> = (0..3)
            .map(|_| {
                (
                    0.5 + 1.5 * crng.f32(),          // fx (cycles over image)
                    0.5 + 1.5 * crng.f32(),          // fy
                    crng.f32() * std::f32::consts::TAU, // phase
                    0.4 + 0.6 * crng.f32(),          // amplitude
                    crng.f32() * 2.0 - 1.0,          // channel tilt
                )
            })
            .collect();

        for _mode in 0..spec.modes_per_class {
            // component-level mid-frequency detail
            let detail: Vec<(f32, f32, f32, f32)> = (0..2)
                .map(|_| {
                    (
                        3.0 + 3.0 * rng.f32(),
                        3.0 + 3.0 * rng.f32(),
                        rng.f32() * std::f32::consts::TAU,
                        0.15 + 0.2 * rng.f32(),
                    )
                })
                .collect();
            let brightness = 0.3 * rng.normal();

            let mut mean = vec![0.0f32; d];
            let mut var = vec![0.0f32; d];
            for y in 0..spec.h {
                for x in 0..spec.w {
                    let u = x as f32 / spec.w as f32;
                    let v = y as f32 / spec.h as f32;
                    let mut low = 0.0f32;
                    for &(fx, fy, ph, amp, _) in &harmonics {
                        low += amp
                            * (std::f32::consts::TAU * (fx * u + fy * v) + ph).cos();
                    }
                    let mut mid = 0.0f32;
                    for &(fx, fy, ph, amp) in &detail {
                        mid += amp
                            * (std::f32::consts::TAU * (fx * u + fy * v) + ph).cos();
                    }
                    for ch in 0..spec.c {
                        let tilt = harmonics[ch % harmonics.len()].4;
                        let idx = (y * spec.w + x) * spec.c + ch;
                        mean[idx] = (low * (1.0 + 0.25 * tilt * ch as f32)
                            + mid
                            + brightness)
                            .tanh();
                        // texture: high-frequency variance, stronger where the
                        // mid-band detail is strong (edge-like regions)
                        let t = spec.texture * (1.0 + 0.5 * mid.abs());
                        var[idx] = (t * t).max(1e-4);
                    }
                }
            }
            gmm.push(1.0, mean, var, class as u32);
        }
    }
    gmm
}

/// Moons (Fig. 1): two interleaved half-circles approximated by a chain of
/// small-variance components along each arc — keeps the population an exact
/// GMM so the oracle stays closed-form.
fn moons_population(spec: &PresetSpec) -> GmmSpec {
    let mut gmm = GmmSpec::new(2);
    let m = spec.modes_per_class;
    let v = spec.texture * spec.texture;
    for i in 0..m {
        let th = std::f32::consts::PI * (i as f32 + 0.5) / m as f32;
        // upper moon
        gmm.push(1.0, vec![th.cos(), th.sin()], vec![v, v], 0);
        // lower moon, offset per sklearn's make_moons
        gmm.push(1.0, vec![1.0 - th.cos(), 0.5 - th.sin()], vec![v, v], 1);
    }
    gmm
}

/// s = 1/4 spatial average-pool proxy embedding of one flattened image.
/// For 1-D data (moons) the proxy is the identity.
pub fn proxy_embed(x: &[f32], h: usize, w: usize, c: usize) -> Vec<f32> {
    if h == 1 {
        return x.to_vec();
    }
    let (ph, pw) = (h / 4, w / 4);
    let mut out = vec![0.0f32; ph * pw * c];
    for py in 0..ph {
        for px in 0..pw {
            for ch in 0..c {
                let mut acc = 0.0f32;
                for dy in 0..4 {
                    for dx in 0..4 {
                        let y = py * 4 + dy;
                        let xx = px * 4 + dx;
                        acc += x[(y * w + xx) * c + ch];
                    }
                }
                out[(py * pw + px) * c + ch] = acc / 16.0;
            }
        }
    }
    out
}

/// Proxy-embed every row of a flat [n × d] matrix.
pub fn proxy_embed_all(data: &[f32], n: usize, h: usize, w: usize, c: usize) -> Vec<f32> {
    let d = h * w * c;
    let pd = if h == 1 { d } else { (h / 4) * (w / 4) * c };
    let mut out = vec![0.0f32; n * pd];
    for i in 0..n {
        let row = proxy_embed(&data[i * d..(i + 1) * d], h, w, c);
        out[i * pd..(i + 1) * pd].copy_from_slice(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_cover_paper_datasets() {
        for name in [
            "moons",
            "mnist-sim",
            "fashion-sim",
            "cifar-sim",
            "celeba-sim",
            "afhq-sim",
            "imagenet-sim",
        ] {
            assert!(preset(name).is_some(), "{name} missing");
        }
        assert_eq!(preset("imagenet-sim").unwrap().classes, 1000);
        assert!(preset("imagenet-sim").unwrap().conditional);
    }

    #[test]
    fn population_has_expected_component_count() {
        let spec = preset("cifar-sim").unwrap();
        let gmm = build_population(spec, 7);
        assert_eq!(gmm.n_components(), spec.classes * spec.modes_per_class);
        assert_eq!(gmm.d, spec.d());
    }

    #[test]
    fn component_means_bounded_by_tanh() {
        let spec = preset("mnist-sim").unwrap();
        let gmm = build_population(spec, 7);
        for comp in &gmm.components {
            assert!(comp.mean.iter().all(|m| m.abs() <= 1.0));
        }
    }

    #[test]
    fn moons_is_two_arcs() {
        let spec = preset("moons").unwrap();
        let gmm = build_population(spec, 7);
        assert_eq!(gmm.d, 2);
        assert_eq!(gmm.n_classes(), 2);
        // upper-moon means have y >= 0
        for comp in gmm.components.iter().filter(|c| c.class == 0) {
            assert!(comp.mean[1] >= -1e-6);
        }
    }

    #[test]
    fn proxy_is_sixteen_to_one_average() {
        let (h, w, c) = (8, 8, 1);
        let img = vec![2.0f32; h * w * c];
        let p = proxy_embed(&img, h, w, c);
        assert_eq!(p.len(), 4);
        assert!(p.iter().all(|&v| (v - 2.0).abs() < 1e-6));
    }

    #[test]
    fn hierarchical_consistency_proxy_correlates() {
        // The design property the coarse screen relies on: same-class
        // samples are closer in proxy space than cross-class ones, on
        // average.
        let spec = preset("cifar-sim").unwrap();
        let gmm = build_population(spec, 7);
        let mut rng = Pcg64::new(3);
        let a0 = gmm.sample_component(0, &mut rng);
        let a0b = gmm.sample_component(1, &mut rng); // same class (mode 1)
        let b0 = gmm.sample_component(9 * spec.modes_per_class, &mut rng); // other class
        let (h, w, c) = (spec.h, spec.w, spec.c);
        let d_same: f32 = proxy_embed(&a0, h, w, c)
            .iter()
            .zip(proxy_embed(&a0b, h, w, c))
            .map(|(x, y)| (x - y) * (x - y))
            .sum();
        let d_cross: f32 = proxy_embed(&a0, h, w, c)
            .iter()
            .zip(proxy_embed(&b0, h, w, c))
            .map(|(x, y)| (x - y) * (x - y))
            .sum();
        assert!(
            d_same < d_cross,
            "proxy lost class structure: same {d_same} cross {d_cross}"
        );
    }
}
