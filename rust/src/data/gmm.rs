//! Diagonal-covariance Gaussian mixture: the *known population law* behind
//! every synthetic dataset.
//!
//! Keeping the population explicit is what makes the neural-oracle
//! substitution exact (DESIGN.md §3): the true Bayes denoiser E[x₀ | x_t]
//! under this mixture has a closed form (see `oracle`), which is precisely
//! the object the paper's trained U-Net / EDM approximates.

use crate::util::rng::Pcg64;

/// One mixture component with diagonal covariance.
#[derive(Debug, Clone)]
pub struct Component {
    pub weight: f32,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
    /// class label for conditional generation (ImageNet-sim).
    pub class: u32,
}

/// A diagonal-covariance GMM over ℝ^D.
#[derive(Debug, Clone)]
pub struct GmmSpec {
    pub d: usize,
    pub components: Vec<Component>,
}

impl GmmSpec {
    pub fn new(d: usize) -> GmmSpec {
        GmmSpec {
            d,
            components: Vec::new(),
        }
    }

    pub fn push(&mut self, weight: f32, mean: Vec<f32>, var: Vec<f32>, class: u32) {
        assert_eq!(mean.len(), self.d);
        assert_eq!(var.len(), self.d);
        assert!(var.iter().all(|&v| v > 0.0), "variances must be positive");
        self.components.push(Component {
            weight,
            mean,
            var,
            class,
        });
    }

    pub fn n_components(&self) -> usize {
        self.components.len()
    }

    pub fn n_classes(&self) -> usize {
        self.components
            .iter()
            .map(|c| c.class as usize + 1)
            .max()
            .unwrap_or(0)
    }

    /// Draw one sample; returns (x, class).
    pub fn sample(&self, rng: &mut Pcg64) -> (Vec<f32>, u32) {
        let weights: Vec<f32> = self.components.iter().map(|c| c.weight).collect();
        let ci = rng.categorical(&weights);
        (self.sample_component(ci, rng), self.components[ci].class)
    }

    /// Draw one sample from a fixed component.
    pub fn sample_component(&self, ci: usize, rng: &mut Pcg64) -> Vec<f32> {
        let comp = &self.components[ci];
        (0..self.d)
            .map(|j| comp.mean[j] + comp.var[j].sqrt() * rng.normal())
            .collect()
    }

    /// Draw `n` samples; returns flat data [n × d] and labels.
    pub fn sample_n(&self, n: usize, rng: &mut Pcg64) -> (Vec<f32>, Vec<u32>) {
        let mut data = Vec::with_capacity(n * self.d);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let (x, y) = self.sample(rng);
            data.extend_from_slice(&x);
            labels.push(y);
        }
        (data, labels)
    }

    /// Mixture mean (population) — sanity anchor for high-noise denoising.
    pub fn population_mean(&self) -> Vec<f32> {
        let wsum: f32 = self.components.iter().map(|c| c.weight).sum();
        let mut out = vec![0.0; self.d];
        for c in &self.components {
            for j in 0..self.d {
                out[j] += c.weight / wsum * c.mean[j];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_comp() -> GmmSpec {
        let mut g = GmmSpec::new(2);
        g.push(0.5, vec![-2.0, 0.0], vec![0.01, 0.01], 0);
        g.push(0.5, vec![2.0, 0.0], vec![0.01, 0.01], 1);
        g
    }

    #[test]
    fn samples_follow_components() {
        let g = two_comp();
        let mut rng = Pcg64::new(1);
        let (data, labels) = g.sample_n(2000, &mut rng);
        assert_eq!(data.len(), 4000);
        let mut near = [0usize; 2];
        for i in 0..2000 {
            let x = data[i * 2];
            if x < 0.0 {
                assert_eq!(labels[i], 0);
                near[0] += 1;
            } else {
                assert_eq!(labels[i], 1);
                near[1] += 1;
            }
        }
        assert!(near[0] > 800 && near[1] > 800);
    }

    #[test]
    fn population_mean_weighted() {
        let g = two_comp();
        let m = g.population_mean();
        assert!(m[0].abs() < 1e-6);
        assert_eq!(g.n_classes(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_nonpositive_variance() {
        let mut g = GmmSpec::new(1);
        g.push(1.0, vec![0.0], vec![0.0], 0);
    }
}
