//! Gaussian moment tier: one-pass per-class + global diagonal moment
//! summaries of the full-resolution corpus, feeding the closed-form
//! high-noise score (`denoiser::gaussian`).
//!
//! The accumulator streams the corpus **once, in ascending row order,
//! through [`Dataset::visit_rows`]** — so an out-of-core corpus never
//! materialises (consecutive ids inside one shard share a single LRU
//! probe) and the result is bit-identical across residencies, shard
//! counts, and evictions: the visit order is fixed, the row bytes are
//! identical, and all accumulation happens in f64 before one rounding
//! to f32 at the end.
//!
//! Persistence: the summary is tiny (`(classes + 1) × d` means and
//! variances plus the counts) and rides the `.gds` store as the v6
//! `gauss_mean` / `gauss_var` / `gauss_counts` optional sections —
//! checksummed like every other section, degrading per the PR-7
//! discipline when corrupt (see `data::store`).

use anyhow::{ensure, Result};

use super::dataset::Dataset;

/// Diagonal Gaussian moments of the corpus, per class and global.
///
/// Group layout: slot `0` is the global corpus, slot `1 + y` is class
/// `y` — so `mean`/`var` are `[(classes + 1) × d]` and `counts` is
/// `[classes + 1]` with `counts[0] == n`.
#[derive(Debug, Clone, PartialEq)]
pub struct GaussMoments {
    pub d: usize,
    pub classes: usize,
    /// group-major means `[(classes + 1) × d]`, global first
    pub mean: Vec<f32>,
    /// group-major diagonal variances `[(classes + 1) × d]`, floored at
    /// `1e-6` (matches the global Wiener stats discipline)
    pub var: Vec<f32>,
    /// rows per group `[classes + 1]` (`counts[0] == n`)
    pub counts: Vec<u32>,
}

impl GaussMoments {
    /// One streamed pass over the corpus in ascending row order. f64
    /// accumulation + a single terminal rounding makes the result
    /// bit-identical for any residency / shard count / LRU budget.
    pub fn build(ds: &Dataset) -> GaussMoments {
        let (n, d, classes) = (ds.n, ds.d, ds.classes);
        let groups = classes + 1;
        let mut sum = vec![0.0f64; groups * d];
        let mut sumsq = vec![0.0f64; groups * d];
        let mut counts = vec![0u32; groups];
        ds.visit_rows(0..n as u32, |gid, row| {
            let g = ds.labels[gid as usize] as usize + 1;
            counts[0] += 1;
            counts[g] += 1;
            for (j, &v) in row.iter().enumerate() {
                let v = v as f64;
                sum[j] += v;
                sumsq[j] += v * v;
                sum[g * d + j] += v;
                sumsq[g * d + j] += v * v;
            }
        });
        let mut mean = vec![0.0f32; groups * d];
        let mut var = vec![0.0f32; groups * d];
        for g in 0..groups {
            let c = counts[g] as f64;
            if c == 0.0 {
                continue;
            }
            for j in 0..d {
                let m = sum[g * d + j] / c;
                let v = (sumsq[g * d + j] / c - m * m).max(1e-6);
                mean[g * d + j] = m as f32;
                var[g * d + j] = v as f32;
            }
        }
        GaussMoments {
            d,
            classes,
            mean,
            var,
            counts,
        }
    }

    /// Rehydrate from the flat `.gds` sections, validating the shapes
    /// and the count invariants so a mismatched store fails loudly
    /// instead of serving moments from the wrong corpus.
    pub fn from_parts(
        d: usize,
        classes: usize,
        n: usize,
        mean: Vec<f32>,
        var: Vec<f32>,
        counts: Vec<u32>,
    ) -> Result<GaussMoments> {
        let groups = classes + 1;
        ensure!(
            mean.len() == groups * d && var.len() == groups * d,
            "gauss moment sections have {} / {} values, want {} per table",
            mean.len(),
            var.len(),
            groups * d
        );
        ensure!(
            counts.len() == groups,
            "gauss_counts has {} groups, want {groups}",
            counts.len()
        );
        ensure!(
            counts[0] as usize == n,
            "gauss_counts[0] = {} rows, corpus has {n}",
            counts[0]
        );
        ensure!(
            counts[1..].iter().map(|&c| c as usize).sum::<usize>() == n,
            "per-class gauss counts do not sum to the corpus size"
        );
        Ok(GaussMoments {
            d,
            classes,
            mean,
            var,
            counts,
        })
    }

    /// The moment group a step context should score against: the class
    /// slot when the context is conditional and that class has support,
    /// the global slot otherwise.
    pub fn moments_for(&self, class: Option<u32>) -> (&[f32], &[f32]) {
        let g = match class {
            Some(y) if (y as usize) < self.classes && self.counts[y as usize + 1] > 0 => {
                y as usize + 1
            }
            _ => 0,
        };
        (
            &self.mean[g * self.d..(g + 1) * self.d],
            &self.var[g * self.d..(g + 1) * self.d],
        )
    }

    /// Global diagonal variance — the corpus-spread statistic the
    /// `auto` switch-point bound evaluates against.
    pub fn global_var(&self) -> &[f32] {
        &self.var[..self.d]
    }

    /// Mean per-dimension corpus variance (the scalar "spread" the
    /// switch-point error bound uses).
    pub fn spread(&self) -> f64 {
        self.spread_for(None)
    }

    /// Per-class spread: the mean per-dimension variance of the class
    /// slot, under the same selection rule as [`Self::moments_for`] —
    /// conditional contexts with class support read their class slot,
    /// everything else reads the global one. A class concentrated around
    /// its own mean has a smaller spread than the corpus at large, so the
    /// bound-driven switch (`denoiser::gaussian`) can hold its Gaussian
    /// prefix longer for that class.
    pub fn spread_for(&self, class: Option<u32>) -> f64 {
        if self.d == 0 {
            return 0.0;
        }
        let (_, var) = self.moments_for(class);
        var.iter().map(|&v| v as f64).sum::<f64>() / self.d as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    fn tiny(n: usize) -> Dataset {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, 42)
    }

    #[test]
    fn moments_match_a_direct_two_pass_reference() {
        let ds = tiny(240);
        let gm = GaussMoments::build(&ds);
        assert_eq!(gm.counts[0] as usize, ds.n);
        assert_eq!(
            gm.counts[1..].iter().map(|&c| c as usize).sum::<usize>(),
            ds.n
        );
        // global slot agrees with a direct f64 reference over Dataset::row
        for j in (0..ds.d).step_by(13) {
            let mut s = 0.0f64;
            for i in 0..ds.n {
                s += ds.row(i)[j] as f64;
            }
            let m = s / ds.n as f64;
            assert!((gm.mean[j] as f64 - m).abs() < 1e-5, "mean dim {j}");
            let mut v = 0.0f64;
            for i in 0..ds.n {
                let dv = ds.row(i)[j] as f64 - m;
                v += dv * dv;
            }
            v = (v / ds.n as f64).max(1e-6);
            assert!((gm.var[j] as f64 - v).abs() < 1e-4, "var dim {j}");
        }
        assert!(gm.var.iter().all(|&v| v >= 1e-6), "variance floor holds");
        assert!(gm.spread() > 0.0);
    }

    #[test]
    fn class_slots_select_and_fall_back() {
        let ds = tiny(200);
        let gm = GaussMoments::build(&ds);
        // a populated class serves its own slot
        let y = ds.labels[0];
        let (m, v) = gm.moments_for(Some(y));
        assert_eq!(m, &gm.mean[(y as usize + 1) * gm.d..(y as usize + 2) * gm.d]);
        assert_eq!(v, &gm.var[(y as usize + 1) * gm.d..(y as usize + 2) * gm.d]);
        // unconditional and out-of-range classes serve the global slot
        let (g, _) = gm.moments_for(None);
        assert_eq!(g, &gm.mean[..gm.d]);
        let (g2, _) = gm.moments_for(Some(u32::MAX));
        assert_eq!(g2, g);
        // spread_for follows the same slot rule
        assert_eq!(gm.spread_for(None), gm.spread());
        assert_eq!(gm.spread_for(Some(u32::MAX)), gm.spread());
        let (_, cv) = gm.moments_for(Some(y));
        let want = cv.iter().map(|&v| v as f64).sum::<f64>() / gm.d as f64;
        assert_eq!(gm.spread_for(Some(y)), want);
        assert!(gm.spread_for(Some(y)) > 0.0);
    }

    #[test]
    fn from_parts_validates_shapes_and_counts() {
        let ds = tiny(120);
        let gm = GaussMoments::build(&ds);
        let ok = GaussMoments::from_parts(
            gm.d,
            gm.classes,
            ds.n,
            gm.mean.clone(),
            gm.var.clone(),
            gm.counts.clone(),
        )
        .unwrap();
        assert_eq!(ok, gm, "roundtrip through flat parts is lossless");
        // wrong corpus size fails loudly
        assert!(GaussMoments::from_parts(
            gm.d,
            gm.classes,
            ds.n + 1,
            gm.mean.clone(),
            gm.var.clone(),
            gm.counts.clone(),
        )
        .is_err());
        // truncated table fails loudly
        assert!(GaussMoments::from_parts(
            gm.d,
            gm.classes,
            ds.n,
            gm.mean[..gm.d].to_vec(),
            gm.var.clone(),
            gm.counts.clone(),
        )
        .is_err());
    }
}
