//! K-means clustering + per-cluster truncated PCA (subspace iteration).
//!
//! Substrate for the PCA baseline (Lukoianov et al. 2025): at dataset-build
//! time the corpus is clustered and each cluster gets a rank-R orthonormal
//! basis; at inference the denoiser picks the nearest cluster's basis and
//! computes posterior weights in that local subspace (Eq. 3's P_i).

use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_chunks;

/// K-means over flat [n × d] data. Returns (centroids [k × d], assignment).
pub fn kmeans(
    data: &[f32],
    n: usize,
    d: usize,
    k: usize,
    iters: usize,
    rng: &mut Pcg64,
) -> (Vec<f32>, Vec<u32>) {
    assert!(n >= k && k >= 1);
    // k-means++ style seeding on a subsample for speed
    let mut centroids = vec![0.0f32; k * d];
    let first = rng.below(n);
    centroids[..d].copy_from_slice(&data[first * d..(first + 1) * d]);
    for ci in 1..k {
        // sample proportional to distance to nearest chosen centroid over a
        // bounded candidate set
        let cands = rng.choose_k(n, 256.min(n));
        let mut best_idx = cands[0];
        let mut best_score = -1.0f32;
        for &i in &cands {
            let row = &data[i * d..(i + 1) * d];
            let mut nearest = f32::INFINITY;
            for cj in 0..ci {
                let c = &centroids[cj * d..(cj + 1) * d];
                nearest = nearest.min(sqdist(row, c));
            }
            if nearest > best_score {
                best_score = nearest;
                best_idx = i;
            }
        }
        centroids[ci * d..(ci + 1) * d]
            .copy_from_slice(&data[best_idx * d..(best_idx + 1) * d]);
    }

    let mut assign = vec![0u32; n];
    let threads = crate::util::threadpool::default_threads();
    for _ in 0..iters {
        // assignment step (parallel)
        let parts = parallel_chunks(n, threads, |_, s, e| {
            let mut local = vec![0u32; e - s];
            for i in s..e {
                let row = &data[i * d..(i + 1) * d];
                let mut best = 0u32;
                let mut best_d = f32::INFINITY;
                for cj in 0..k {
                    let dd = sqdist(row, &centroids[cj * d..(cj + 1) * d]);
                    if dd < best_d {
                        best_d = dd;
                        best = cj as u32;
                    }
                }
                local[i - s] = best;
            }
            (s, local)
        });
        for (s, local) in parts {
            assign[s..s + local.len()].copy_from_slice(&local);
        }
        // update step
        let mut counts = vec![0u32; k];
        let mut sums = vec![0.0f64; k * d];
        for i in 0..n {
            let c = assign[i] as usize;
            counts[c] += 1;
            let row = &data[i * d..(i + 1) * d];
            for j in 0..d {
                sums[c * d + j] += row[j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // re-seed empty cluster
                let i = rng.below(n);
                centroids[c * d..(c + 1) * d].copy_from_slice(&data[i * d..(i + 1) * d]);
            } else {
                for j in 0..d {
                    centroids[c * d + j] = (sums[c * d + j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    (centroids, assign)
}

fn sqdist(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Rank-R PCA of the rows in `rows` (indices into data) about their mean,
/// via subspace (block power) iteration: Z ← Xᵀ(X Z), QR-orthonormalise.
/// Returns (basis [r × d] with orthonormal rows, center [d]).
pub fn local_pca(
    data: &[f32],
    d: usize,
    rows: &[usize],
    r: usize,
    iters: usize,
    rng: &mut Pcg64,
) -> (Vec<f32>, Vec<f32>) {
    let m = rows.len();
    assert!(m >= 1);
    let r = r.min(d).min(m.max(1));

    let mut center = vec![0.0f32; d];
    for &i in rows {
        for j in 0..d {
            center[j] += data[i * d + j];
        }
    }
    for v in center.iter_mut() {
        *v /= m as f32;
    }

    // init random basis [r × d]
    let mut basis = vec![0.0f32; r * d];
    rng.fill_normal(&mut basis);
    orthonormalize_rows(&mut basis, r, d);

    let mut proj = vec![0.0f32; m * r];
    for _ in 0..iters {
        // proj = (X - mu) Bᵀ : [m × r]
        for (pi, &i) in rows.iter().enumerate() {
            let row = &data[i * d..(i + 1) * d];
            for rr in 0..r {
                let b = &basis[rr * d..(rr + 1) * d];
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += (row[j] - center[j]) * b[j];
                }
                proj[pi * r + rr] = acc;
            }
        }
        // basis = projᵀ (X - mu) : [r × d], then orthonormalise
        basis.iter_mut().for_each(|v| *v = 0.0);
        for (pi, &i) in rows.iter().enumerate() {
            let row = &data[i * d..(i + 1) * d];
            for rr in 0..r {
                let p = proj[pi * r + rr];
                let b = &mut basis[rr * d..(rr + 1) * d];
                for j in 0..d {
                    b[j] += p * (row[j] - center[j]);
                }
            }
        }
        orthonormalize_rows(&mut basis, r, d);
    }
    (basis, center)
}

/// Modified Gram–Schmidt on the rows of a [r × d] matrix (in place).
pub fn orthonormalize_rows(mat: &mut [f32], r: usize, d: usize) {
    for i in 0..r {
        // subtract projections onto previous rows
        for p in 0..i {
            let (head, tail) = mat.split_at_mut(i * d);
            let prev = &head[p * d..(p + 1) * d];
            let cur = &mut tail[..d];
            let dot: f32 = prev.iter().zip(cur.iter()).map(|(a, b)| a * b).sum();
            for j in 0..d {
                cur[j] -= dot * prev[j];
            }
        }
        let row = &mut mat[i * d..(i + 1) * d];
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for v in row.iter_mut() {
                *v /= norm;
            }
        } else {
            // degenerate direction: re-seed with a unit vector
            for (j, v) in row.iter_mut().enumerate() {
                *v = if j == i % d { 1.0 } else { 0.0 };
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_separates_two_blobs() {
        let mut rng = Pcg64::new(1);
        let n = 400;
        let d = 4;
        let mut data = vec![0.0f32; n * d];
        for i in 0..n {
            let off = if i < n / 2 { -5.0 } else { 5.0 };
            for j in 0..d {
                data[i * d + j] = off + rng.normal() * 0.3;
            }
        }
        let (_, assign) = kmeans(&data, n, d, 2, 8, &mut rng);
        // all of first half same cluster, second half the other
        let a0 = assign[0];
        assert!(assign[..n / 2].iter().all(|&a| a == a0));
        assert!(assign[n / 2..].iter().all(|&a| a != a0));
    }

    #[test]
    fn pca_recovers_dominant_direction() {
        let mut rng = Pcg64::new(2);
        let n = 500;
        let d = 8;
        // variance 25 along e0, 0.01 elsewhere
        let mut data = vec![0.0f32; n * d];
        for i in 0..n {
            let t = rng.normal() * 5.0;
            for j in 0..d {
                data[i * d + j] = if j == 0 { t } else { rng.normal() * 0.1 };
            }
        }
        let rows: Vec<usize> = (0..n).collect();
        let (basis, center) = local_pca(&data, d, &rows, 2, 12, &mut rng);
        assert!(center.iter().all(|c| c.abs() < 0.5));
        // first basis row should align with e0
        assert!(
            basis[0].abs() > 0.99,
            "dominant direction not recovered: {}",
            basis[0]
        );
    }

    #[test]
    fn orthonormal_rows_are_orthonormal() {
        let mut rng = Pcg64::new(3);
        let (r, d) = (4, 16);
        let mut mat = vec![0.0f32; r * d];
        rng.fill_normal(&mut mat);
        orthonormalize_rows(&mut mat, r, d);
        for i in 0..r {
            for j in 0..r {
                let dot: f32 = mat[i * d..(i + 1) * d]
                    .iter()
                    .zip(&mat[j * d..(j + 1) * d])
                    .map(|(a, b)| a * b)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({i},{j}) dot {dot}");
            }
        }
    }
}
