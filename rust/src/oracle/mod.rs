//! The population oracle: exact Bayes denoiser E[x₀ | x_t] under the known
//! GMM data law — the stand-in for the paper's trained U-Net / EDM oracles
//! (DESIGN.md §3).
//!
//! With x_t = √ᾱ x₀ + √(1-ᾱ) ε and x₀ ~ Σ_c w_c N(μ_c, diag(v_c)), the
//! descaled query q = x_t/√ᾱ satisfies q | c ~ N(μ_c, diag(v_c + σ²)) with
//! σ² = (1-ᾱ)/ᾱ, so
//!
//!   responsibilities  r_c ∝ w_c · N(q; μ_c, v_c + σ²)
//!   E[x₀ | q, c]      = μ_c + v_c/(v_c + σ²) · (q − μ_c)
//!   E[x₀ | q]         = Σ_c r_c · E[x₀ | q, c]
//!
//! This is precisely the generalising denoiser the paper's neural oracles
//! approximate; analytical estimators are scored by MSE / r² against it.

use crate::data::gmm::GmmSpec;

/// Closed-form population denoiser over a diagonal GMM.
#[derive(Debug, Clone)]
pub struct GmmOracle {
    gmm: GmmSpec,
    log_weights: Vec<f32>,
}

impl GmmOracle {
    pub fn new(gmm: GmmSpec) -> GmmOracle {
        let wsum: f32 = gmm.components.iter().map(|c| c.weight).sum();
        let log_weights = gmm
            .components
            .iter()
            .map(|c| (c.weight / wsum).ln())
            .collect();
        GmmOracle { gmm, log_weights }
    }

    pub fn d(&self) -> usize {
        self.gmm.d
    }

    /// E[x₀ | x_t] under the population, unconditional.
    pub fn denoise(&self, x_t: &[f32], alpha_bar: f32) -> Vec<f32> {
        self.denoise_filtered(x_t, alpha_bar, None)
    }

    /// Class-conditional E[x₀ | x_t, class] (ImageNet-sim conditional rows).
    pub fn denoise_class(&self, x_t: &[f32], alpha_bar: f32, class: u32) -> Vec<f32> {
        self.denoise_filtered(x_t, alpha_bar, Some(class))
    }

    fn denoise_filtered(&self, x_t: &[f32], alpha_bar: f32, class: Option<u32>) -> Vec<f32> {
        let d = self.gmm.d;
        assert_eq!(x_t.len(), d);
        let a = alpha_bar.clamp(1e-6, 1.0 - 1e-6);
        let sigma2 = (1.0 - a) / a;
        let sa = a.sqrt();

        // log responsibilities
        let mut logr = Vec::with_capacity(self.gmm.components.len());
        let mut max_lr = f32::NEG_INFINITY;
        for (ci, comp) in self.gmm.components.iter().enumerate() {
            if let Some(y) = class {
                if comp.class != y {
                    logr.push(f32::NEG_INFINITY);
                    continue;
                }
            }
            let mut lr = self.log_weights[ci];
            for j in 0..d {
                let q = x_t[j] / sa;
                let s = comp.var[j] + sigma2;
                let diff = q - comp.mean[j];
                lr += -0.5 * (diff * diff / s + s.ln());
            }
            logr.push(lr);
            if lr > max_lr {
                max_lr = lr;
            }
        }
        debug_assert!(max_lr.is_finite(), "no components matched class filter");

        let mut out = vec![0.0f32; d];
        let mut total = 0.0f32;
        for (ci, comp) in self.gmm.components.iter().enumerate() {
            let lr = logr[ci];
            if !lr.is_finite() {
                continue;
            }
            let r = (lr - max_lr).exp();
            if r < 1e-12 {
                continue;
            }
            total += r;
            for j in 0..d {
                let q = x_t[j] / sa;
                let shrink = comp.var[j] / (comp.var[j] + sigma2);
                out[j] += r * (comp.mean[j] + shrink * (q - comp.mean[j]));
            }
        }
        for v in out.iter_mut() {
            *v /= total;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn two_blob() -> GmmOracle {
        let mut g = GmmSpec::new(2);
        g.push(0.5, vec![-3.0, 0.0], vec![0.05, 0.05], 0);
        g.push(0.5, vec![3.0, 0.0], vec![0.05, 0.05], 1);
        GmmOracle::new(g)
    }

    #[test]
    fn high_noise_returns_population_mean() {
        let o = two_blob();
        let f = o.denoise(&[0.3, -0.2], 1e-5);
        assert!(f[0].abs() < 0.2, "expected ~0, got {}", f[0]);
    }

    #[test]
    fn low_noise_near_identity_on_manifold() {
        let o = two_blob();
        let x0 = [-3.02f32, 0.01];
        let a: f32 = 0.999;
        let x_t = [x0[0] * a.sqrt(), x0[1] * a.sqrt()];
        let f = o.denoise(&x_t, a);
        assert!((f[0] - x0[0]).abs() < 0.1, "{f:?}");
    }

    #[test]
    fn moderate_noise_resolves_nearer_component() {
        let o = two_blob();
        let a: f32 = 0.5;
        let x_t = [-2.0 * a.sqrt(), 0.0];
        let f = o.denoise(&x_t, a);
        assert!(f[0] < -2.0, "should commit to left blob: {f:?}");
    }

    #[test]
    fn conditional_restricts_components() {
        let o = two_blob();
        // query near class 0, but condition on class 1
        let f = o.denoise_class(&[-1.0, 0.0], 0.3, 1);
        assert!(f[0] > 0.0, "conditional must use class-1 blob: {f:?}");
    }

    #[test]
    fn oracle_is_smooth_in_alpha() {
        let o = two_blob();
        let mut rng = Pcg64::new(1);
        let x = [rng.normal(), rng.normal()];
        let mut prev = o.denoise(&x, 0.01);
        for a in [0.05f32, 0.1, 0.3, 0.5, 0.8, 0.99] {
            let f = o.denoise(&x, a);
            let jump: f32 = f.iter().zip(&prev).map(|(p, q)| (p - q).abs()).sum();
            assert!(jump < 8.0, "discontinuity at alpha {a}: {jump}");
            prev = f;
        }
    }
}
