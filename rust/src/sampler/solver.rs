//! Pluggable reverse-diffusion solvers over the same analytical score.
//!
//! Every solver advances `x` from one placed sampling point to the next
//! through the η-generalised DDIM map ([`super::ddim_update`]), which is an
//! exponential integrator: exact whenever the posterior mean f̂ is constant
//! across the step. The solvers differ only in which f̂ they feed it:
//!
//! * [`Solver::Ddim`] — f̂ at the step's left endpoint. First order; the
//!   default, and **byte-identical** to the pre-solver sampler (same
//!   denoiser calls, same float op order, same rng draw order).
//! * [`Solver::Heun`] — predictor–corrector: a second score evaluation at
//!   the *next* placed point (on the predictor's provisional state), then
//!   the trapezoid average ½(f̂₁+f̂₂) through the same map. Second order.
//! * [`Solver::Dpm2`] — midpoint: a half-step in noise level onto the
//!   doubled reference grid (see [`mid_schedule`]), one score evaluation
//!   there, and that midpoint f̂ through the map. Second order.
//!
//! The corrector/midpoint evaluation goes through
//! [`Denoiser::corrector_denoise`], which GoldDiff overrides to re-run only
//! the masked refine over the predictor tick's golden-subset union — so a
//! second-order step costs ~1 coarse screen instead of 2. Both higher-order
//! solvers degenerate to the plain DDIM update at the terminal step
//! (ᾱ_prev = 1.0: there is no "next" noise level to evaluate at — the
//! standard Karras-Heun practice at σ = 0) and on closed-form Gaussian
//! ticks (`support == 0`: the coasting score is already smooth and free, a
//! corrector would force a cold screen the coast exists to avoid).

use super::ddim_update;
use crate::data::dataset::Dataset;
use crate::denoiser::{DenoiseResult, Denoiser, StepContext};
use crate::schedule::noise::NoiseSchedule;
use crate::util::rng::Pcg64;

/// Which reverse-diffusion solver advances the trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Solver {
    /// η-generalised DDIM (first order; the byte-identical default)
    Ddim,
    /// predictor–corrector trapezoid in f̂ space (second order)
    Heun,
    /// midpoint on the doubled noise grid (second order)
    Dpm2,
}

impl Solver {
    pub fn parse(s: &str) -> Option<Solver> {
        match s {
            "ddim" => Some(Solver::Ddim),
            "heun" => Some(Solver::Heun),
            "dpm2" => Some(Solver::Dpm2),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Solver::Ddim => "ddim",
            Solver::Heun => "heun",
            Solver::Dpm2 => "dpm2",
        }
    }

    pub fn all() -> &'static [Solver] {
        &[Solver::Ddim, Solver::Heun, Solver::Dpm2]
    }

    /// Local truncation order (global order of convergence).
    pub fn order(&self) -> usize {
        match self {
            Solver::Ddim => 1,
            Solver::Heun | Solver::Dpm2 => 2,
        }
    }

    /// Does this solver need the doubled midpoint grid ([`mid_schedule`])?
    pub fn needs_mid_schedule(&self) -> bool {
        matches!(self, Solver::Dpm2)
    }

    /// Advance `x` from grid point `from` to grid point `to` (`to ==
    /// sched.steps` is the terminal clean point, ᾱ = 1). Returns the
    /// predictor's denoise result (what the trajectory records) and the
    /// advanced state. `to` may skip grid points — the budgeted step plan
    /// (`schedule::steps`) coasts by jumping placed point to placed point.
    ///
    /// `mid` must be `Some(mid_schedule(sched))` for [`Solver::Dpm2`];
    /// the other solvers ignore it.
    #[allow(clippy::too_many_arguments)]
    pub fn advance(
        &self,
        den: &mut dyn Denoiser,
        ds: &Dataset,
        sched: &NoiseSchedule,
        mid: Option<&NoiseSchedule>,
        x: &[f32],
        from: usize,
        to: usize,
        eta: f32,
        class: Option<u32>,
        rng: &mut Pcg64,
    ) -> (DenoiseResult, Vec<f32>) {
        debug_assert!(from < to && to <= sched.steps);
        let ctx = StepContext {
            ds,
            sched,
            step: from,
            class,
        };
        let out = den.denoise(x, &ctx);
        let a = sched.alpha_bar(from);
        let ap = if to < sched.steps {
            sched.alpha_bar(to)
        } else {
            1.0
        };
        // terminal step: no next noise level to evaluate the corrector at;
        // gaussian/empty-support ticks: coast first-order on the closed form
        let first_order = matches!(self, Solver::Ddim) || to >= sched.steps || out.support == 0;
        if first_order {
            let x_new = ddim_update(x, &out.f_hat, a, ap, eta, rng);
            return (out, x_new);
        }
        match self {
            Solver::Heun => {
                // predictor to the next placed point (η = 0: no rng draws),
                // corrector score there, trapezoid average through the map
                let x_pred = ddim_update(x, &out.f_hat, a, ap, 0.0, rng);
                let ctx2 = StepContext {
                    ds,
                    sched,
                    step: to,
                    class,
                };
                let corr = den.corrector_denoise(&x_pred, &ctx2);
                let f_avg: Vec<f32> = out
                    .f_hat
                    .iter()
                    .zip(&corr.f_hat)
                    .map(|(&p, &c)| 0.5 * (p + c))
                    .collect();
                let x_new = ddim_update(x, &f_avg, a, ap, eta, rng);
                (out, x_new)
            }
            Solver::Dpm2 => {
                // half-step onto the doubled grid (index from+to is exactly
                // the stride midpoint of 2·from and 2·to), score there, and
                // the midpoint f̂ carries the whole step
                let ms = mid.expect("Dpm2 requires the doubled midpoint schedule");
                debug_assert_eq!(ms.steps, 2 * sched.steps - 1);
                let a_mid = ms.alpha_bar(from + to);
                let x_half = ddim_update(x, &out.f_hat, a, a_mid, 0.0, rng);
                let ctx_mid = StepContext {
                    ds,
                    sched: ms,
                    step: from + to,
                    class,
                };
                let corr = den.corrector_denoise(&x_half, &ctx_mid);
                let x_new = ddim_update(x, &corr.f_hat, a, ap, eta, rng);
                (out, x_new)
            }
            Solver::Ddim => unreachable!("handled by the first-order path"),
        }
    }
}

/// The doubled noise grid used by [`Solver::Dpm2`]'s midpoint evaluation.
///
/// A `2·steps − 1`-point schedule of the same kind: the DDIM stride picks
/// reference index `round((T_REF−1)·(1 − i/(S−1)))`, and for `S' = 2S − 1`
/// the even indices `i = 2j` give `1 − 2j/(2S−2) = 1 − j/(S−1)` *exactly*
/// (numerator and denominator both scale by 2, which is lossless in binary
/// floating point) — so the doubled grid contains every original sampling
/// point bit-identically, plus a true stride-midpoint between each pair.
pub fn mid_schedule(sched: &NoiseSchedule) -> NoiseSchedule {
    NoiseSchedule::new(sched.kind, 2 * sched.steps - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::noise::ScheduleKind;

    #[test]
    fn parse_names_roundtrip() {
        for &s in Solver::all() {
            assert_eq!(Solver::parse(s.name()), Some(s));
        }
        assert_eq!(Solver::parse("euler"), None);
        assert_eq!(Solver::Ddim.order(), 1);
        assert_eq!(Solver::Heun.order(), 2);
        assert_eq!(Solver::Dpm2.order(), 2);
        assert!(Solver::Dpm2.needs_mid_schedule());
        assert!(!Solver::Heun.needs_mid_schedule());
    }

    #[test]
    fn mid_schedule_contains_the_original_grid_bit_identically() {
        for kind in [
            ScheduleKind::DdpmLinear,
            ScheduleKind::Cosine,
            ScheduleKind::EdmVp,
            ScheduleKind::EdmVe,
        ] {
            for steps in [2usize, 5, 10, 25] {
                let sched = NoiseSchedule::new(kind, steps);
                let mid = mid_schedule(&sched);
                assert_eq!(mid.steps, 2 * steps - 1);
                for i in 0..steps {
                    assert_eq!(
                        mid.alpha_bar(2 * i),
                        sched.alpha_bar(i),
                        "{kind:?} steps={steps} i={i}"
                    );
                }
                // interior midpoints sit strictly between their neighbours
                for i in 0..steps - 1 {
                    let m = mid.alpha_bar(2 * i + 1);
                    assert!(
                        m >= sched.alpha_bar(i) && m <= sched.alpha_bar(i + 1),
                        "{kind:?} steps={steps} midpoint {i} out of bracket"
                    );
                }
            }
        }
    }
}
