//! Reverse-diffusion samplers driving any `Denoiser`: deterministic DDIM
//! (η = 0, the paper's 10-step default) and DDPM-style ancestral sampling
//! (η = 1), with full trajectory recording for the figure harnesses.

use crate::data::dataset::Dataset;
use crate::denoiser::{Denoiser, PosteriorStats, StepContext};
use crate::schedule::noise::NoiseSchedule;
use crate::util::rng::Pcg64;

/// A recorded reverse trajectory.
#[derive(Debug, Clone)]
pub struct Trajectory {
    /// x_t at every sampling point, including the initial noise (len steps+1)
    pub xs: Vec<Vec<f32>>,
    /// posterior-mean estimates f̂ per step (len steps)
    pub fs: Vec<Vec<f32>>,
    /// posterior telemetry per step
    pub stats: Vec<PosteriorStats>,
    /// golden-subset / support sizes per step
    pub supports: Vec<usize>,
    /// wall-clock seconds per step
    pub step_secs: Vec<f64>,
}

impl Trajectory {
    pub fn final_sample(&self) -> &[f32] {
        self.xs.last().unwrap()
    }
}

/// Sampler options.
#[derive(Debug, Clone, Copy)]
pub struct SamplerOpts {
    /// DDIM stochasticity: 0 = deterministic DDIM, 1 = DDPM ancestral
    pub eta: f32,
    /// conditional class
    pub class: Option<u32>,
}

impl Default for SamplerOpts {
    fn default() -> Self {
        SamplerOpts {
            eta: 0.0,
            class: None,
        }
    }
}

/// Draw the initial x_T ~ N(0, I) (ᾱ(0) ≈ 0 so x_T is essentially noise).
pub fn init_noise(d: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut x = vec![0.0f32; d];
    rng.fill_normal(&mut x);
    x
}

/// DDIM update (η-generalised):
///   ε̂ = (x_t − √ᾱ f̂)/√(1−ᾱ)
///   σ = η·√((1−ᾱ_prev)/(1−ᾱ))·√(1−ᾱ/ᾱ_prev)
///   x_prev = √ᾱ_prev f̂ + √(1−ᾱ_prev−σ²) ε̂ + σ z
pub fn ddim_update(
    x_t: &[f32],
    f_hat: &[f32],
    alpha_t: f32,
    alpha_prev: f32,
    eta: f32,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let sa = alpha_t.sqrt();
    let s1a = (1.0 - alpha_t).max(1e-12).sqrt();
    let sigma = if eta > 0.0 && alpha_prev < 1.0 {
        eta * ((1.0 - alpha_prev) / (1.0 - alpha_t)).sqrt()
            * (1.0 - alpha_t / alpha_prev).max(0.0).sqrt()
    } else {
        0.0
    };
    let dir = (1.0 - alpha_prev - sigma * sigma).max(0.0).sqrt();
    let sap = alpha_prev.sqrt();
    x_t.iter()
        .zip(f_hat)
        .map(|(&xt, &f)| {
            let eps = (xt - sa * f) / s1a;
            let noise = if sigma > 0.0 { sigma * rng.normal() } else { 0.0 };
            sap * f + dir * eps + noise
        })
        .collect()
}

/// Run a full reverse trajectory of `den` under `sched`.
pub fn sample(
    den: &mut dyn Denoiser,
    ds: &Dataset,
    sched: &NoiseSchedule,
    seed: u64,
    opts: SamplerOpts,
) -> Trajectory {
    let mut rng = Pcg64::with_stream(seed, 0x5a3);
    let mut x = init_noise(ds.d, &mut rng);
    let mut traj = Trajectory {
        xs: vec![x.clone()],
        fs: Vec::with_capacity(sched.steps),
        stats: Vec::with_capacity(sched.steps),
        supports: Vec::with_capacity(sched.steps),
        step_secs: Vec::with_capacity(sched.steps),
    };
    for step in 0..sched.steps {
        let ctx = StepContext {
            ds,
            sched,
            step,
            class: opts.class,
        };
        let t0 = std::time::Instant::now();
        let out = den.denoise(&x, &ctx);
        traj.step_secs.push(t0.elapsed().as_secs_f64());
        x = ddim_update(
            &x,
            &out.f_hat,
            sched.alpha_bar(step),
            sched.alpha_prev(step),
            opts.eta,
            &mut rng,
        );
        traj.xs.push(x.clone());
        traj.fs.push(out.f_hat);
        traj.stats.push(out.stats);
        traj.supports.push(out.support);
    }
    traj
}

/// Re-noise a clean sample to sampling point `step` (forward process) —
/// used by the efficacy protocol to build evaluation queries on-manifold.
pub fn renoise(x0: &[f32], sched: &NoiseSchedule, step: usize, rng: &mut Pcg64) -> Vec<f32> {
    let a = sched.alpha_bar(step);
    let (sa, s1a) = (a.sqrt(), (1.0 - a).max(0.0).sqrt());
    x0.iter().map(|&v| sa * v + s1a * rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::denoiser::optimal::OptimalDenoiser;
    use crate::schedule::noise::ScheduleKind;

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 400;
        (
            Dataset::synthesize(&spec, 8),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn ddim_deterministic_for_seed() {
        let (ds, sched) = setup();
        let mut a = OptimalDenoiser::new();
        let mut b = OptimalDenoiser::new();
        let ta = sample(&mut a, &ds, &sched, 5, SamplerOpts::default());
        let tb = sample(&mut b, &ds, &sched, 5, SamplerOpts::default());
        assert_eq!(ta.final_sample(), tb.final_sample());
        let tc = sample(&mut a, &ds, &sched, 6, SamplerOpts::default());
        assert_ne!(ta.final_sample(), tc.final_sample());
    }

    #[test]
    fn trajectory_lands_near_the_manifold() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        for seed in 0..8 {
            let t = sample(&mut den, &ds, &sched, seed, SamplerOpts::default());
            let x = t.final_sample();
            // nearest-train-point distance should be tiny for the optimal
            // denoiser (memorisation)
            let mut best = f32::INFINITY;
            for i in 0..ds.n {
                let d: f32 = ds
                    .row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(d);
            }
            assert!(best < 0.1, "seed {seed} landed {best} away");
        }
    }

    #[test]
    fn trajectory_shapes() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let t = sample(&mut den, &ds, &sched, 1, SamplerOpts::default());
        assert_eq!(t.xs.len(), 11);
        assert_eq!(t.fs.len(), 10);
        assert_eq!(t.stats.len(), 10);
        assert_eq!(t.step_secs.len(), 10);
    }

    #[test]
    fn entropy_collapses_along_trajectory() {
        // Posterior Progressive Concentration (Fig. 1/3a): entropy at the
        // last step far below the first step.
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let t = sample(&mut den, &ds, &sched, 2, SamplerOpts::default());
        assert!(
            t.stats.last().unwrap().entropy < t.stats[0].entropy * 0.2,
            "entropy {} -> {}",
            t.stats[0].entropy,
            t.stats.last().unwrap().entropy
        );
    }

    #[test]
    fn eta_one_is_stochastic() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let opts = SamplerOpts {
            eta: 1.0,
            class: None,
        };
        let a = sample(&mut den, &ds, &sched, 3, opts);
        // same seed, same eta → identical (noise comes from the seeded rng)
        let b = sample(&mut den, &ds, &sched, 3, opts);
        assert_eq!(a.final_sample(), b.final_sample());
        // eta=1 differs from eta=0
        let c = sample(&mut den, &ds, &sched, 3, SamplerOpts::default());
        assert_ne!(a.final_sample(), c.final_sample());
    }

    #[test]
    fn renoise_interpolates_signal_and_noise() {
        let (ds, sched) = setup();
        let mut rng = Pcg64::new(1);
        let x0 = ds.row(0).to_vec();
        let deep = renoise(&x0, &sched, 0, &mut rng);
        let shallow = renoise(&x0, &sched, 9, &mut rng);
        let d_deep: f32 = deep.iter().zip(&x0).map(|(a, b)| (a - b) * (a - b)).sum();
        let d_shallow: f32 = shallow
            .iter()
            .zip(&x0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(d_shallow < d_deep);
    }
}
