//! Reverse-diffusion samplers driving any `Denoiser`: deterministic DDIM
//! (η = 0, the paper's 10-step default) and DDPM-style ancestral sampling
//! (η = 1), with full trajectory recording for the figure harnesses.
//! Higher-order solvers (`solver::Solver`) and budgeted step plans
//! (`schedule::steps::StepPlan`) plug into the same loop; the defaults
//! (`ddim`, full grid) are byte-identical to the original sampler.

pub mod solver;

use crate::data::dataset::Dataset;
use crate::denoiser::{Denoiser, PosteriorStats};
use crate::schedule::noise::NoiseSchedule;
use crate::schedule::steps::StepPlan;
use crate::util::rng::Pcg64;

pub use solver::{mid_schedule, Solver};

/// A recorded reverse trajectory.
#[derive(Debug, Clone)]
pub struct Trajectory {
    /// x_t at every placed sampling point, including the initial noise
    /// (len = placed ticks + 1; the full grid gives steps + 1)
    pub xs: Vec<Vec<f32>>,
    /// posterior-mean estimates f̂ per placed tick
    pub fs: Vec<Vec<f32>>,
    /// posterior telemetry per placed tick
    pub stats: Vec<PosteriorStats>,
    /// golden-subset / support sizes per placed tick
    pub supports: Vec<usize>,
    /// wall-clock seconds per placed tick (score eval(s) + solver update)
    pub step_secs: Vec<f64>,
    /// the grid index each recorded tick ran at (0..steps on the full grid)
    pub placed: Vec<usize>,
}

impl Trajectory {
    pub fn final_sample(&self) -> &[f32] {
        self.xs.last().unwrap()
    }
}

/// Sampler options.
#[derive(Debug, Clone, Copy)]
pub struct SamplerOpts {
    /// DDIM stochasticity: 0 = deterministic DDIM, 1 = DDPM ancestral
    pub eta: f32,
    /// conditional class
    pub class: Option<u32>,
    /// reverse-diffusion solver (ddim = the byte-identical default)
    pub solver: Solver,
}

impl Default for SamplerOpts {
    fn default() -> Self {
        SamplerOpts {
            eta: 0.0,
            class: None,
            solver: Solver::Ddim,
        }
    }
}

/// Draw the initial x_T ~ N(0, I) (ᾱ(0) ≈ 0 so x_T is essentially noise).
pub fn init_noise(d: usize, rng: &mut Pcg64) -> Vec<f32> {
    let mut x = vec![0.0f32; d];
    rng.fill_normal(&mut x);
    x
}

/// DDIM update (η-generalised):
///   ε̂ = (x_t − √ᾱ f̂)/√(1−ᾱ)
///   σ = η·√((1−ᾱ_prev)/(1−ᾱ))·√(1−ᾱ/ᾱ_prev)
///   x_prev = √ᾱ_prev f̂ + √(1−ᾱ_prev−σ²) ε̂ + σ z
pub fn ddim_update(
    x_t: &[f32],
    f_hat: &[f32],
    alpha_t: f32,
    alpha_prev: f32,
    eta: f32,
    rng: &mut Pcg64,
) -> Vec<f32> {
    let sa = alpha_t.sqrt();
    let s1a = (1.0 - alpha_t).max(1e-12).sqrt();
    let sigma = if eta > 0.0 && alpha_prev < 1.0 {
        eta * ((1.0 - alpha_prev) / (1.0 - alpha_t)).sqrt()
            * (1.0 - alpha_t / alpha_prev).max(0.0).sqrt()
    } else {
        0.0
    };
    let dir = (1.0 - alpha_prev - sigma * sigma).max(0.0).sqrt();
    let sap = alpha_prev.sqrt();
    x_t.iter()
        .zip(f_hat)
        .map(|(&xt, &f)| {
            let eps = (xt - sa * f) / s1a;
            let noise = if sigma > 0.0 { sigma * rng.normal() } else { 0.0 };
            sap * f + dir * eps + noise
        })
        .collect()
}

/// Run a full reverse trajectory of `den` under `sched` (every grid point
/// placed). With the default `SamplerOpts` this is byte-identical to the
/// pre-solver sampler: same rng stream, same denoiser calls, same float op
/// order in the DDIM update.
pub fn sample(
    den: &mut dyn Denoiser,
    ds: &Dataset,
    sched: &NoiseSchedule,
    seed: u64,
    opts: SamplerOpts,
) -> Trajectory {
    sample_planned(den, ds, sched, seed, opts, &StepPlan::full(sched.steps))
}

/// Run a reverse trajectory over the placed points of `plan`, jumping
/// placed point to placed point (coasted grid points get no tick).
pub fn sample_planned(
    den: &mut dyn Denoiser,
    ds: &Dataset,
    sched: &NoiseSchedule,
    seed: u64,
    opts: SamplerOpts,
    plan: &StepPlan,
) -> Trajectory {
    assert_eq!(plan.steps, sched.steps, "plan cut from a different grid");
    assert_eq!(plan.placed.first(), Some(&0), "trajectories start at point 0");
    let mid = opts
        .solver
        .needs_mid_schedule()
        .then(|| mid_schedule(sched));
    let mut rng = Pcg64::with_stream(seed, 0x5a3);
    let mut x = init_noise(ds.d, &mut rng);
    let ticks = plan.len();
    let mut traj = Trajectory {
        xs: vec![x.clone()],
        fs: Vec::with_capacity(ticks),
        stats: Vec::with_capacity(ticks),
        supports: Vec::with_capacity(ticks),
        step_secs: Vec::with_capacity(ticks),
        placed: Vec::with_capacity(ticks),
    };
    for pos in 0..ticks {
        let from = plan.placed[pos];
        let to = plan.target_of(pos);
        let t0 = std::time::Instant::now();
        let (out, x_new) = opts.solver.advance(
            den,
            ds,
            sched,
            mid.as_ref(),
            &x,
            from,
            to,
            opts.eta,
            opts.class,
            &mut rng,
        );
        traj.step_secs.push(t0.elapsed().as_secs_f64());
        x = x_new;
        traj.xs.push(x.clone());
        traj.fs.push(out.f_hat);
        traj.stats.push(out.stats);
        traj.supports.push(out.support);
        traj.placed.push(from);
    }
    traj
}

/// Re-noise a clean sample to sampling point `step` (forward process) —
/// used by the efficacy protocol to build evaluation queries on-manifold.
pub fn renoise(x0: &[f32], sched: &NoiseSchedule, step: usize, rng: &mut Pcg64) -> Vec<f32> {
    let a = sched.alpha_bar(step);
    let (sa, s1a) = (a.sqrt(), (1.0 - a).max(0.0).sqrt());
    x0.iter().map(|&v| sa * v + s1a * rng.normal()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::denoiser::optimal::OptimalDenoiser;
    use crate::schedule::noise::ScheduleKind;

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 400;
        (
            Dataset::synthesize(&spec, 8),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn ddim_deterministic_for_seed() {
        let (ds, sched) = setup();
        let mut a = OptimalDenoiser::new();
        let mut b = OptimalDenoiser::new();
        let ta = sample(&mut a, &ds, &sched, 5, SamplerOpts::default());
        let tb = sample(&mut b, &ds, &sched, 5, SamplerOpts::default());
        assert_eq!(ta.final_sample(), tb.final_sample());
        let tc = sample(&mut a, &ds, &sched, 6, SamplerOpts::default());
        assert_ne!(ta.final_sample(), tc.final_sample());
    }

    #[test]
    fn trajectory_lands_near_the_manifold() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        for seed in 0..8 {
            let t = sample(&mut den, &ds, &sched, seed, SamplerOpts::default());
            let x = t.final_sample();
            // nearest-train-point distance should be tiny for the optimal
            // denoiser (memorisation)
            let mut best = f32::INFINITY;
            for i in 0..ds.n {
                let d: f32 = ds
                    .row(i)
                    .iter()
                    .zip(x)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                best = best.min(d);
            }
            assert!(best < 0.1, "seed {seed} landed {best} away");
        }
    }

    #[test]
    fn trajectory_shapes() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let t = sample(&mut den, &ds, &sched, 1, SamplerOpts::default());
        assert_eq!(t.xs.len(), 11);
        assert_eq!(t.fs.len(), 10);
        assert_eq!(t.stats.len(), 10);
        assert_eq!(t.step_secs.len(), 10);
        assert_eq!(t.placed, (0..10).collect::<Vec<usize>>());
    }

    #[test]
    fn default_solver_matches_the_legacy_inline_loop() {
        // the tentpole's byte-identity contract: sample() with the default
        // SamplerOpts (ddim, full grid) equals the pre-solver loop exactly
        use crate::denoiser::StepContext;
        let (ds, sched) = setup();
        for eta in [0.0f32, 1.0] {
            let opts = SamplerOpts {
                eta,
                ..SamplerOpts::default()
            };
            let mut den = OptimalDenoiser::new();
            let t = sample(&mut den, &ds, &sched, 11, opts);
            // the seed repo's loop, inlined verbatim
            let mut den2 = OptimalDenoiser::new();
            let mut rng = Pcg64::with_stream(11, 0x5a3);
            let mut x = init_noise(ds.d, &mut rng);
            let mut xs = vec![x.clone()];
            for step in 0..sched.steps {
                let ctx = StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let out = den2.denoise(&x, &ctx);
                x = ddim_update(
                    &x,
                    &out.f_hat,
                    sched.alpha_bar(step),
                    sched.alpha_prev(step),
                    eta,
                    &mut rng,
                );
                xs.push(x.clone());
            }
            assert_eq!(t.xs, xs, "eta={eta}: solver loop must be byte-identical");
        }
    }

    #[test]
    fn higher_order_solvers_converge_faster() {
        // property test on the smooth analytic score: against a fine-grid
        // reference, halving the steps must hurt heun/dpm2 (2nd order) far
        // less than ddim (1st order)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 200;
        let ds = Dataset::synthesize(&spec, 8);
        let finish = |solver: Solver, steps: usize| -> Vec<f32> {
            let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, steps);
            let mut den = OptimalDenoiser::new();
            let opts = SamplerOpts {
                solver,
                ..SamplerOpts::default()
            };
            sample(&mut den, &ds, &sched, 7, opts)
                .final_sample()
                .to_vec()
        };
        // every grid shares its ᾱ endpoints and the same seeded x_T, so
        // all step counts discretise one reverse ODE path
        let reference = finish(Solver::Ddim, 640);
        let err = |solver: Solver, steps: usize| -> f64 {
            finish(solver, steps)
                .iter()
                .zip(&reference)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        for solver in [Solver::Heun, Solver::Dpm2] {
            // 2nd order beats 1st at matched step counts…
            for steps in [10usize, 20] {
                assert!(
                    err(solver, steps) < err(Solver::Ddim, steps),
                    "{} at {steps} steps: {} vs ddim {}",
                    solver.name(),
                    err(solver, steps),
                    err(Solver::Ddim, steps)
                );
            }
            // …and its error decays faster under refinement (asymptotic
            // ratios are ~16 vs ~4 over a 4× refinement; assert loosely)
            let r = err(solver, 5) / err(solver, 20).max(1e-12);
            assert!(r > 3.0, "{}: refinement ratio {r}", solver.name());
        }
        let r_ddim = err(Solver::Ddim, 5) / err(Solver::Ddim, 20).max(1e-12);
        assert!(r_ddim > 1.5, "ddim refinement ratio {r_ddim}");
    }

    #[test]
    fn planned_sampling_ticks_only_the_placed_points() {
        use crate::schedule::{churn_prior, StepPlan};
        let (ds, sched) = setup();
        // the full plan is the default path, byte for byte
        let mut a = OptimalDenoiser::new();
        let full = sample_planned(
            &mut a,
            &ds,
            &sched,
            4,
            SamplerOpts::default(),
            &StepPlan::full(sched.steps),
        );
        let mut b = OptimalDenoiser::new();
        let plain = sample(&mut b, &ds, &sched, 4, SamplerOpts::default());
        assert_eq!(full.xs, plain.xs);
        // a budgeted plan jumps placed point to placed point
        let plan = StepPlan::budgeted(&sched, 4, 0, &churn_prior(&sched));
        assert!(plan.len() < sched.steps);
        let mut c = OptimalDenoiser::new();
        let t = sample_planned(&mut c, &ds, &sched, 4, SamplerOpts::default(), &plan);
        assert_eq!(t.placed, plan.placed);
        assert_eq!(t.xs.len(), plan.len() + 1);
        assert_eq!(t.fs.len(), plan.len());
        // the coasted trajectory still contracts to the manifold: the
        // terminal point is always placed and serves the final precision
        let x = t.final_sample();
        let mut best = f32::INFINITY;
        for i in 0..ds.n {
            let d: f32 = ds
                .row(i)
                .iter()
                .zip(x)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            best = best.min(d);
        }
        assert!(best < 0.25, "coasted trajectory landed {best} away");
    }

    #[test]
    fn entropy_collapses_along_trajectory() {
        // Posterior Progressive Concentration (Fig. 1/3a): entropy at the
        // last step far below the first step.
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let t = sample(&mut den, &ds, &sched, 2, SamplerOpts::default());
        assert!(
            t.stats.last().unwrap().entropy < t.stats[0].entropy * 0.2,
            "entropy {} -> {}",
            t.stats[0].entropy,
            t.stats.last().unwrap().entropy
        );
    }

    #[test]
    fn eta_one_is_stochastic() {
        let (ds, sched) = setup();
        let mut den = OptimalDenoiser::new();
        let opts = SamplerOpts {
            eta: 1.0,
            ..SamplerOpts::default()
        };
        let a = sample(&mut den, &ds, &sched, 3, opts);
        // same seed, same eta → identical (noise comes from the seeded rng)
        let b = sample(&mut den, &ds, &sched, 3, opts);
        assert_eq!(a.final_sample(), b.final_sample());
        // eta=1 differs from eta=0
        let c = sample(&mut den, &ds, &sched, 3, SamplerOpts::default());
        assert_ne!(a.final_sample(), c.final_sample());
    }

    #[test]
    fn renoise_interpolates_signal_and_noise() {
        let (ds, sched) = setup();
        let mut rng = Pcg64::new(1);
        let x0 = ds.row(0).to_vec();
        let deep = renoise(&x0, &sched, 0, &mut rng);
        let shallow = renoise(&x0, &sched, 9, &mut rng);
        let d_deep: f32 = deep.iter().zip(&x0).map(|(a, b)| (a - b) * (a - b)).sum();
        let d_shallow: f32 = shallow
            .iter()
            .zip(&x0)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        assert!(d_shallow < d_deep);
    }
}
