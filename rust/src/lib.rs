//! # GoldDiff — Fast and Scalable Analytical Diffusion
//!
//! Production reproduction of *"Fast and Scalable Analytical Diffusion"*
//! (Shang, Sun, Lin, Shen; 2026): a three-layer rust + JAX + Pallas stack
//! where the rust coordinator owns the serving hot path and all heavy
//! numerics run in AOT-compiled XLA executables (PJRT CPU client).
//!
//! Layer map (see DESIGN.md):
//!
//! * [`util`] — offline-friendly substrates (JSON, RNG, threadpool, CLI, …).
//! * [`config`] — typed configuration for datasets, schedules and the engine.
//! * [`data`] — synthetic hierarchical-GMM datasets, the `.gds` store
//!   (v3: per-shard sections, persisted per-shard IVF partitions, and the
//!   data-free `store::open_streaming` path), the pluggable row source
//!   (`data::rows::RowSource`: resident corpus or `.gds`-streamed shards
//!   under a `mem_budget_mb`-bounded LRU — out-of-core serving with
//!   byte-identical output), and the sharded corpus layer
//!   (`data::shard::CorpusShards`).
//! * [`schedule`] — noise schedules and the paper's counter-monotonic
//!   (m_t, k_t) budget schedules (Eqs. 4 & 6).
//! * [`index`] — Adaptive Coarse Screening behind pluggable
//!   `RetrievalBackend`s: flat per-query scan (reference), batched
//!   multi-query scan (one proxy-table pass per engine tick group), and
//!   IVF-style cluster-pruned screening with exact centroid bounds; all
//!   three scan through the register-tiled SoA kernel (`index::kernel`)
//!   by default, and tick groups refine through the batched union-scan
//!   ladder. `index::shard` wraps any backend kind in the shard-parallel
//!   merge layer: per-shard coarse screens merged exactly by
//!   (distance, row id), shard-local refine and warm-start
//!   (`index/README.md` documents the trait, the kernel layout, the
//!   merge-exactness argument, knobs and guarantees).
//! * [`oracle`] — closed-form population denoiser (the neural-oracle stand-in).
//! * [`denoiser`] — Optimal / Wiener / Kamb / PCA baselines + the GoldDiff
//!   coarse→fine wrapper; streaming softmax (SS) and biased WSS.
//! * [`sampler`] — DDIM / DDPM drivers over any denoiser.
//! * [`runtime`] — PJRT executable cache over `artifacts/*.hlo.txt`.
//! * [`coordinator`] — the serving engine: router, batcher, scheduler,
//!   workers, backpressure, stats.
//! * [`server`] — TCP line-JSON front end.
//! * [`metrics`] — MSE / r² / entropy / spectra + table writers.
//! * [`benchlib`] — per-paper-experiment harnesses shared by `cargo bench`
//!   targets and examples.

// CI runs `cargo clippy -- -D warnings`; these style lints fight the
// deliberately index-oriented numeric kernels (blocked SIMD-friendly loops,
// flat [n × d] matrices) and the wide-but-explicit hot-path signatures.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::manual_memcpy
)]

pub mod benchlib;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod denoiser;
pub mod index;
pub mod metrics;
pub mod oracle;
pub mod runtime;
pub mod sampler;
pub mod schedule;
pub mod server;
pub mod util;

pub use config::EngineConfig;
pub use data::dataset::Dataset;
pub use denoiser::{Denoiser, DenoiserKind};
pub use schedule::noise::{NoiseSchedule, ScheduleKind};
