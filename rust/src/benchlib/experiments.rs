//! One runner per paper table. Every runner regenerates the corresponding
//! table's rows/columns (DESIGN.md §5) and emits markdown + JSON under
//! `out/`.

use anyhow::Result;

use super::{dataset, eval_samples, out_dir, runtime, EvalProtocol, MethodRow};
use crate::coordinator::xla_denoiser::XlaDenoiser;
use crate::data::dataset::Dataset;
use crate::denoiser::DenoiserKind;
use crate::metrics::tables::{fmt_ms, fmt_speedup, Table};
use crate::schedule::budget::BudgetSchedule;
use crate::schedule::noise::{NoiseSchedule, ScheduleKind};
use crate::util::timer::TimingStats;

/// The paper's Table 2 / Table 7 method roster. "golddiff-pca" is the
/// paper's primary GoldDiff configuration (deployed atop the PCA denoiser).
pub const MAIN_METHODS: &[DenoiserKind] = &[
    DenoiserKind::Optimal,
    DenoiserKind::Wiener,
    DenoiserKind::Kamb,
    DenoiserKind::Pca,
    DenoiserKind::GoldDiffPca,
];

pub fn paper_label(kind: DenoiserKind) -> &'static str {
    match kind {
        DenoiserKind::Optimal => "Optimal",
        DenoiserKind::Wiener => "Wiener",
        DenoiserKind::Kamb => "Kamb",
        DenoiserKind::Pca => "PCA",
        DenoiserKind::PcaUnbiased => "PCA (Unbiased)",
        DenoiserKind::GoldDiffPca => "GoldDiff (Ours)",
        DenoiserKind::GoldDiff => "GoldDiff (Ours)",
        DenoiserKind::GoldDiffWss => "GoldDiff + WSS",
        DenoiserKind::GoldDiffKamb => "Kamb + GoldDiff",
    }
}

/// Score a set of methods on one dataset through the XLA-backed path.
pub fn eval_methods(
    ds: &Dataset,
    sched: &NoiseSchedule,
    methods: &[DenoiserKind],
    n_samples: usize,
    classes: &[u32],
    seed: u64,
) -> Result<Vec<MethodRow>> {
    let rt = runtime()?;
    let protocol = EvalProtocol::build(ds, sched, n_samples, classes, seed);
    let mut rows = Vec::new();
    for &kind in methods {
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), ds, kind)?;
        let mut row = protocol.eval(ds, &mut den);
        row.name = paper_label(kind).to_string();
        rows.push(row);
        eprintln!(
            "  [{}] {}: mse={:.4} r2={:.3} t/step={}",
            ds.name,
            rows.last().unwrap().name,
            rows.last().unwrap().mse,
            rows.last().unwrap().r2,
            fmt_ms(rows.last().unwrap().time_per_step),
        );
    }
    Ok(rows)
}

fn table_from_rows(title: &str, per_dataset: &[(String, Vec<MethodRow>)]) -> Table {
    let mut columns = Vec::new();
    for (ds, _) in per_dataset {
        columns.push(format!("{ds} MSE↓"));
        columns.push(format!("{ds} r²↑"));
        columns.push(format!("{ds} Time"));
        columns.push(format!("{ds} Mem(GB)"));
    }
    let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut t = Table::new(title, &col_refs);
    let n_methods = per_dataset[0].1.len();
    for mi in 0..n_methods {
        let mut cells = Vec::new();
        for (_, rows) in per_dataset {
            cells.extend(rows[mi].cells());
        }
        t.row(&per_dataset[0].1[mi].name.clone(), cells);
    }
    t
}

/// Append the "vs PCA" speedup row the paper prints under Table 2.
fn add_speedup_row(t: &mut Table, per_dataset: &[(String, Vec<MethodRow>)]) {
    let mut cells = Vec::new();
    for (_, rows) in per_dataset {
        let pca = rows.iter().find(|r| r.name == "PCA");
        let ours = rows.iter().find(|r| r.name.contains("Ours"));
        match (pca, ours) {
            (Some(p), Some(o)) => {
                cells.push(format!(
                    "↑{:.1}%",
                    (p.mse - o.mse) / p.mse.max(1e-12) * 100.0
                ));
                cells.push(format!("↑{:.1}%", (o.r2 - p.r2) * 100.0));
                cells.push(fmt_speedup(p.time_per_step, o.time_per_step));
                cells.push("-".into());
            }
            _ => cells.extend(["-", "-", "-", "-"].map(String::from)),
        }
    }
    t.row("vs. PCA", cells);
}

// ---------------------------------------------------------------------------
// Table 1 — empirical complexity scaling (per-step time vs N)
// ---------------------------------------------------------------------------

/// CPU-path scaling sweep: per-step cost vs dataset size for each method,
/// plus the fitted log-log slope (≈1 ⇒ O(N), ≈0 ⇒ O(1), GoldDiff in between
/// because only the O(N·d_proxy) coarse scan touches N).
pub fn run_table1(sizes: &[usize], seed: u64) -> Result<Table> {
    use crate::data::synthetic::preset;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let methods: &[DenoiserKind] = &[
        DenoiserKind::Optimal,
        DenoiserKind::Wiener,
        DenoiserKind::Kamb,
        DenoiserKind::Pca,
        DenoiserKind::GoldDiff,
    ];
    let mut per_method: Vec<(String, Vec<f64>)> = methods
        .iter()
        .map(|k| (paper_label(*k).to_string(), Vec::new()))
        .collect();

    for &n in sizes {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        let ds = Dataset::synthesize(&spec, seed);
        let queries = 6;
        for (mi, &kind) in methods.iter().enumerate() {
            let mut den = kind.build(&ds, &sched);
            let mut timing = TimingStats::new();
            for qi in 0..queries {
                let step = (qi * sched.steps) / queries;
                let mut rng = crate::util::rng::Pcg64::new(seed + qi as u64);
                let x = crate::sampler::init_noise(ds.d, &mut rng);
                let ctx = crate::denoiser::StepContext {
                    ds: &ds,
                    sched: &sched,
                    step,
                    class: None,
                };
                let t0 = std::time::Instant::now();
                let _ = den.denoise(&x, &ctx);
                timing.record(t0.elapsed());
            }
            per_method[mi].1.push(timing.mean());
            eprintln!("  [N={n}] {}: {}", per_method[mi].0, fmt_ms(timing.mean()));
        }
    }

    let mut columns: Vec<String> = sizes.iter().map(|n| format!("N={n}")).collect();
    columns.push("log-log slope".into());
    let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Table 1 — empirical per-step cost vs dataset size (paper: complexity comparison)",
        &col_refs,
    );
    for (name, times) in &per_method {
        let slope = loglog_slope(sizes, times);
        let mut cells: Vec<String> = times.iter().map(|&s| fmt_ms(s)).collect();
        cells.push(format!("{slope:.2}"));
        t.row(name, cells);
    }
    t.emit(&out_dir(), "table1_scaling")?;
    Ok(t)
}

pub fn loglog_slope(sizes: &[usize], times: &[f64]) -> f64 {
    let xs: Vec<f64> = sizes.iter().map(|&n| (n as f64).ln()).collect();
    let ys: Vec<f64> = times.iter().map(|&t| t.max(1e-9).ln()).collect();
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let num: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den.max(1e-12)
}

// ---------------------------------------------------------------------------
// Table 2 — small-scale efficacy/efficiency (CIFAR / CelebA / AFHQ)
// ---------------------------------------------------------------------------

pub fn run_table2(seed: u64) -> Result<Table> {
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let n = eval_samples(16);
    let mut per_dataset = Vec::new();
    for preset in ["cifar-sim", "celeba-sim", "afhq-sim"] {
        let ds = dataset(preset, seed)?;
        let rows = eval_methods(&ds, &sched, MAIN_METHODS, n, &[], seed)?;
        per_dataset.push((short_name(preset), rows));
    }
    let mut t = table_from_rows(
        "Table 2 — Quantitative comparison of analytical denoisers (CIFAR-10 / CelebA-HQ / AFHQ stand-ins)",
        &per_dataset,
    );
    add_speedup_row(&mut t, &per_dataset);
    t.emit(&out_dir(), "table2_smallscale")?;
    Ok(t)
}

pub fn short_name(preset: &str) -> String {
    match preset {
        "cifar-sim" => "CIFAR-10".into(),
        "celeba-sim" => "CelebA-HQ".into(),
        "afhq-sim" => "AFHQ".into(),
        "mnist-sim" => "MNIST".into(),
        "fashion-sim" => "F-MNIST".into(),
        "imagenet-sim" => "ImageNet-1K".into(),
        other => other.into(),
    }
}

// ---------------------------------------------------------------------------
// Table 3 — ImageNet-1K scale, unconditional + conditional, T ∈ {10, 100}
// ---------------------------------------------------------------------------

pub fn run_table3(seed: u64) -> Result<Table> {
    let ds = dataset("imagenet-sim", seed)?;
    let methods = [
        DenoiserKind::Pca,
        DenoiserKind::PcaUnbiased,
        DenoiserKind::GoldDiffPca,
    ];
    let n = eval_samples(4);
    let classes: Vec<u32> = (0..n as u32).map(|i| (i * 37) % 1000).collect();

    let mut columns = Vec::new();
    for t in ["T=10", "T=100"] {
        for c in ["Uncond MSE↓", "Uncond r²↑", "Uncond Time", "Cond MSE↓", "Cond r²↑", "Cond Time"] {
            columns.push(format!("{t} {c}"));
        }
    }
    let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut table = Table::new(
        "Table 3 — ImageNet-1K (sim): unconditional + conditional",
        &col_refs,
    );

    let mut cells_per_method: Vec<Vec<String>> = vec![Vec::new(); methods.len()];
    for steps in [10usize, 100] {
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, steps);
        let uncond = eval_methods(&ds, &sched, &methods, n, &[], seed)?;
        let cond = eval_methods(&ds, &sched, &methods, n, &classes, seed)?;
        for (mi, _) in methods.iter().enumerate() {
            cells_per_method[mi].push(format!("{:.4}", uncond[mi].mse));
            cells_per_method[mi].push(format!("{:.3}", uncond[mi].r2));
            cells_per_method[mi].push(fmt_ms(uncond[mi].time_per_step));
            cells_per_method[mi].push(format!("{:.4}", cond[mi].mse));
            cells_per_method[mi].push(format!("{:.3}", cond[mi].r2));
            cells_per_method[mi].push(fmt_ms(cond[mi].time_per_step));
        }
    }
    for (mi, &kind) in methods.iter().enumerate() {
        table.row(paper_label(kind), cells_per_method[mi].clone());
    }
    table.emit(&out_dir(), "table3_imagenet")?;
    Ok(table)
}

// ---------------------------------------------------------------------------
// Table 4 — EDM-VP / EDM-VE oracles
// ---------------------------------------------------------------------------

pub fn run_table4(seed: u64) -> Result<Table> {
    let n = eval_samples(12);
    let mut per_block = Vec::new(); // (schedule, dataset, rows)
    for kind in [ScheduleKind::EdmVp, ScheduleKind::EdmVe] {
        let sched = NoiseSchedule::new(kind, 10);
        for preset in ["cifar-sim", "afhq-sim"] {
            let ds = dataset(preset, seed)?;
            let rows = eval_methods(&ds, &sched, MAIN_METHODS, n, &[], seed)?;
            per_block.push((kind.name().to_string(), short_name(preset), rows));
        }
    }
    let mut columns = Vec::new();
    for (sname, dsname, _) in &per_block {
        columns.push(format!("{sname}/{dsname} MSE↓"));
        columns.push(format!("{sname}/{dsname} r²↑"));
    }
    let col_refs: Vec<&str> = columns.iter().map(String::as_str).collect();
    let mut t = Table::new("Table 4 — validation on diverse neural denoisers (EDM-VP / EDM-VE)", &col_refs);
    for mi in 0..MAIN_METHODS.len() {
        let mut cells = Vec::new();
        for (_, _, rows) in &per_block {
            cells.push(format!("{:.4}", rows[mi].mse));
            cells.push(format!("{:.3}", rows[mi].r2));
        }
        t.row(paper_label(MAIN_METHODS[mi]), cells);
    }
    t.emit(&out_dir(), "table4_neural")?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 5 — orthogonality: GoldDiff plugged into Optimal and Kamb
// ---------------------------------------------------------------------------

pub fn run_table5(seed: u64) -> Result<Table> {
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let methods = [
        DenoiserKind::Optimal,
        DenoiserKind::GoldDiff, // golddiff over pixel logits = "+GoldDiff" on Optimal
        DenoiserKind::Kamb,
        DenoiserKind::GoldDiffKamb,
    ];
    let n = eval_samples(10);
    let mut per_dataset = Vec::new();
    for preset in ["celeba-sim", "afhq-sim"] {
        let ds = dataset(preset, seed)?;
        let mut rows = eval_methods(&ds, &sched, &methods, n, &[], seed)?;
        rows[1].name = "Optimal + GoldDiff".into();
        rows[3].name = "Kamb + GoldDiff".into();
        per_dataset.push((short_name(preset), rows));
    }
    let mut t = table_from_rows(
        "Table 5 — orthogonality to existing analytical denoisers",
        &per_dataset,
    );
    t.title = t.title.clone();
    t.emit(&out_dir(), "table5_orthogonal")?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 6 — biased (WSS) vs unbiased (SS) weight estimation inside GoldDiff
// ---------------------------------------------------------------------------

pub fn run_table6(seed: u64) -> Result<Table> {
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let methods = [DenoiserKind::GoldDiffWss, DenoiserKind::GoldDiffPca];
    let n = eval_samples(12);
    let mut per_dataset = Vec::new();
    for preset in ["celeba-sim", "afhq-sim"] {
        let ds = dataset(preset, seed)?;
        let mut rows = eval_methods(&ds, &sched, &methods, n, &[], seed)?;
        rows[0].name = "GoldDiff + WSS (biased)".into();
        rows[1].name = "GoldDiff + SS (unbiased)".into();

        // Fig. 2 quantification: high-frequency energy retention of samples
        let rt = runtime()?;
        for (mi, &kind) in methods.iter().enumerate() {
            let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind)?;
            let mut ratio = 0.0;
            let count = 4;
            for s in 0..count {
                let traj = crate::sampler::sample(
                    &mut den,
                    &ds,
                    &sched,
                    seed + s,
                    crate::sampler::SamplerOpts::default(),
                );
                ratio += crate::metrics::highfreq_energy_ratio(
                    traj.final_sample(),
                    ds.h,
                    ds.w,
                    ds.c,
                );
            }
            eprintln!(
                "  [{}] {} high-freq energy ratio: {:.4}",
                ds.name,
                rows[mi].name,
                ratio / count as f64
            );
        }
        per_dataset.push((short_name(preset), rows));
    }
    let t = {
        let mut t = table_from_rows("Table 6 — biased (WSS) vs unbiased (SS) weight estimation", &per_dataset);
        t.title += " [+ Fig. 2 high-frequency retention printed above]";
        t
    };
    t.emit(&out_dir(), "table6_softmax")?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table 7 — MNIST / Fashion-MNIST
// ---------------------------------------------------------------------------

pub fn run_table7(seed: u64) -> Result<Table> {
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let n = eval_samples(16);
    let mut per_dataset = Vec::new();
    for preset in ["mnist-sim", "fashion-sim"] {
        let ds = dataset(preset, seed)?;
        let rows = eval_methods(&ds, &sched, MAIN_METHODS, n, &[], seed)?;
        per_dataset.push((short_name(preset), rows));
    }
    let mut t = table_from_rows("Table 7 — MNIST / Fashion-MNIST stand-ins", &per_dataset);
    add_speedup_row(&mut t, &per_dataset);
    t.emit(&out_dir(), "table7_grayscale")?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Fig. 6 — hyperparameter sensitivity (m_max, k_min)
// ---------------------------------------------------------------------------

pub fn run_fig6(seed: u64) -> Result<(Table, Table)> {
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let n = eval_samples(8);
    let presets = ["mnist-sim", "cifar-sim", "afhq-sim"];
    let rt = runtime()?;

    // (a) m_max sweep at paper-default k
    let m_fracs = [1.0, 0.5, 1.0 / 3.0, 0.25, 0.2];
    let mut ta = Table::new(
        "Fig. 6a — coarse candidate size m_max sweep (r² vs oracle)",
        &presets.map(short_name).iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for &mf in &m_fracs {
        let mut cells = Vec::new();
        for preset in presets {
            let ds = dataset(preset, seed)?;
            let protocol = EvalProtocol::build(&ds, &sched, n, &[], seed);
            let buckets = rt.manifest.buckets("golden_step", &ds.name);
            let budget = BudgetSchedule::new(
                ds.n,
                ds.n / 10,
                ((ds.n as f64 * mf) as usize).max(ds.n / 10),
                ds.n / 20,
                ds.n / 10,
                &buckets,
            );
            let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, DenoiserKind::GoldDiffPca)?
                .with_budget(budget);
            let row = protocol.eval(&ds, &mut den);
            cells.push(format!("{:.3}", row.r2));
        }
        ta.row(&format!("m_max = N×{mf:.2}"), cells);
    }
    ta.emit(&out_dir(), "fig6a_mmax")?;

    // (b) k_min sweep at paper-default m
    let k_fracs = [0.25, 0.1, 0.05, 1.0 / 30.0, 0.025];
    let mut tb = Table::new(
        "Fig. 6b — golden subset size k_min sweep (r² vs oracle)",
        &presets.map(short_name).iter().map(String::as_str).collect::<Vec<_>>(),
    );
    for &kf in &k_fracs {
        let mut cells = Vec::new();
        for preset in presets {
            let ds = dataset(preset, seed)?;
            let protocol = EvalProtocol::build(&ds, &sched, n, &[], seed);
            let buckets = rt.manifest.buckets("golden_step", &ds.name);
            let k_min = ((ds.n as f64 * kf) as usize).max(1);
            let budget = BudgetSchedule::new(
                ds.n,
                ds.n / 10,
                ds.n / 4,
                k_min,
                k_min.max(ds.n / 10),
                &buckets,
            );
            let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, DenoiserKind::GoldDiffPca)?
                .with_budget(budget);
            let row = protocol.eval(&ds, &mut den);
            cells.push(format!("{:.3}", row.r2));
        }
        tb.row(&format!("k_min = N×{kf:.3}"), cells);
    }
    tb.emit(&out_dir(), "fig6b_kmin")?;
    Ok((ta, tb))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loglog_slope_detects_linear_and_constant() {
        let sizes = [1000usize, 2000, 4000, 8000];
        let linear: Vec<f64> = sizes.iter().map(|&n| n as f64 * 1e-6).collect();
        let constant = vec![0.5f64; 4];
        assert!((loglog_slope(&sizes, &linear) - 1.0).abs() < 0.01);
        assert!(loglog_slope(&sizes, &constant).abs() < 0.01);
    }

    #[test]
    fn labels_cover_all_kinds() {
        for &k in DenoiserKind::all() {
            assert!(!paper_label(k).is_empty());
        }
    }
}
