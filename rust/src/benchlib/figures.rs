//! Figure harnesses: Fig. 1 (posterior progressive concentration on Moons),
//! Fig. 3 (weight evolution + subset-size sensitivity), and the supporting
//! random-subset ablation denoiser.

use anyhow::Result;

use super::{dataset, eval_samples, out_dir, EvalProtocol};
use crate::data::dataset::Dataset;
use crate::denoiser::softmax::exact_softmax;
use crate::denoiser::{descale, sqdist, DenoiseResult, Denoiser, StepContext};
use crate::metrics::tables::Table;
use crate::metrics::{effective_support, entropy, support_at_mass};
use crate::sampler;
use crate::schedule::noise::{NoiseSchedule, ScheduleKind};
use crate::util::rng::Pcg64;

/// Exact posterior weights of the full-scan denoiser at one query.
pub fn full_posterior_weights(ds: &Dataset, x_t: &[f32], sched: &NoiseSchedule, step: usize) -> Vec<f32> {
    let q = descale(x_t, sched.alpha_bar(step));
    let scale = sched.logit_scale(step);
    let logits: Vec<f32> = (0..ds.n)
        .map(|i| -sqdist(&q, ds.row(i)) * scale)
        .collect();
    exact_softmax(&logits)
}

/// Fig. 1 / Fig. 3a: track the posterior weight distribution along oracle
/// trajectories — effective support exp(H), support@90% mass, top-1 weight.
pub fn run_concentration(preset: &str, n_traj: usize, seed: u64) -> Result<Table> {
    let ds = dataset(preset, seed)?;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let oracle = crate::oracle::GmmOracle::new(ds.gmm.clone());

    let mut eff = vec![0.0f64; sched.steps];
    let mut s90 = vec![0.0f64; sched.steps];
    let mut top1 = vec![0.0f64; sched.steps];
    let mut ent = vec![0.0f64; sched.steps];
    for t in 0..n_traj {
        let mut rng = Pcg64::with_stream(seed + t as u64, 0xf19);
        let mut x = sampler::init_noise(ds.d, &mut rng);
        for step in 0..sched.steps {
            let w = full_posterior_weights(&ds, &x, &sched, step);
            eff[step] += effective_support(&w);
            s90[step] += support_at_mass(&w, 0.9) as f64;
            top1[step] += *w
                .iter()
                .max_by(|a, b| a.total_cmp(b))
                .unwrap() as f64;
            ent[step] += entropy(&w);
            let f = oracle.denoise(&x, sched.alpha_bar(step));
            x = sampler::ddim_update(
                &x,
                &f,
                sched.alpha_bar(step),
                sched.alpha_prev(step),
                0.0,
                &mut rng,
            );
        }
    }
    let inv = 1.0 / n_traj as f64;
    let cols: Vec<String> = (0..sched.steps).map(|s| format!("t{}", sched.steps - s)).collect();
    let col_refs: Vec<&str> = cols.iter().map(String::as_str).collect();
    let mut t = Table::new(
        &format!("Posterior Progressive Concentration on {preset} (Fig. 1 / Fig. 3a)"),
        &col_refs,
    );
    t.row(
        "effective support exp(H)",
        eff.iter().map(|v| format!("{:.1}", v * inv)).collect(),
    );
    t.row(
        "support @ 90% mass",
        s90.iter().map(|v| format!("{:.1}", v * inv)).collect(),
    );
    t.row(
        "top-1 weight",
        top1.iter().map(|v| format!("{:.4}", v * inv)).collect(),
    );
    t.row(
        "entropy (nats)",
        ent.iter().map(|v| format!("{:.2}", v * inv)).collect(),
    );
    t.emit(&out_dir(), &format!("concentration_{preset}"))?;
    Ok(t)
}

/// Random-subset denoiser for the Fig. 3b sensitivity ablation: aggregates
/// over a *fixed random* subset of `n_sub` rows (static retrieval — exactly
/// the strawman the paper contrasts with dynamic golden subsets).
pub struct RandomSubsetDenoiser {
    pub rows: Vec<u32>,
}

impl RandomSubsetDenoiser {
    pub fn new(ds: &Dataset, n_sub: usize, seed: u64) -> Self {
        let mut rng = Pcg64::with_stream(seed, 0x5b5);
        RandomSubsetDenoiser {
            rows: rng
                .choose_k(ds.n, n_sub)
                .into_iter()
                .map(|i| i as u32)
                .collect(),
        }
    }
}

impl Denoiser for RandomSubsetDenoiser {
    fn name(&self) -> String {
        format!("random-{}", self.rows.len())
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let ds = ctx.ds;
        let q = descale(x_t, ctx.alpha_bar());
        let scale = ctx.logit_scale();
        let (f_hat, stats) = crate::denoiser::softmax::ss_aggregate(
            ds.d,
            self.rows.iter().map(|&gid| {
                let row = ds.row(gid as usize);
                (-sqdist(&q, row) * scale, row)
            }),
        );
        DenoiseResult {
            f_hat,
            stats,
            support: self.rows.len(),
        }
    }
}

/// Fig. 3b: MSE vs oracle for random subsets of size {10, 100, 1000, 5000}
/// vs the full dataset, split by diffusion stage (early/mid/late thirds).
pub fn run_sensitivity(preset: &str, seed: u64) -> Result<Table> {
    let ds = dataset(preset, seed)?;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let n = eval_samples(12);
    let protocol = EvalProtocol::build(&ds, &sched, n, &[], seed);

    let sizes = [10usize, 100, 1000, 5000.min(ds.n), ds.n];
    let mut t = Table::new(
        &format!("Fig. 3b — sensitivity to subset size on {preset} (MSE vs oracle)"),
        &["early (high noise)", "mid", "late (low noise)", "overall"],
    );
    for &n_sub in &sizes {
        let mut den = RandomSubsetDenoiser::new(&ds, n_sub, seed);
        // split queries by stage
        let mut accs = [
            crate::metrics::EfficacyAccum::new(),
            crate::metrics::EfficacyAccum::new(),
            crate::metrics::EfficacyAccum::new(),
            crate::metrics::EfficacyAccum::new(),
        ];
        for q in &protocol.queries {
            let ctx = StepContext {
                ds: &ds,
                sched: &sched,
                step: q.step,
                class: q.class,
            };
            let out = den.denoise(&q.x_t, &ctx);
            let stage = (q.step * 3) / sched.steps;
            accs[stage].update(&out.f_hat, &q.target);
            accs[3].update(&out.f_hat, &q.target);
        }
        let label = if n_sub == ds.n {
            "full dataset".to_string()
        } else {
            format!("N_sub = {n_sub}")
        };
        t.row(
            &label,
            accs.iter().map(|a| format!("{:.4}", a.mse())).collect(),
        );
    }
    t.emit(&out_dir(), &format!("fig3b_sensitivity_{preset}"))?;
    Ok(t)
}

/// Figs. 4/5: qualitative comparison grids — every method generates from
/// the same initial noise (10-step DDIM, as the paper) and the samples are
/// tiled into one PPM per method under `out/fig4/`, plus an oracle row
/// (the stand-in for the paper's "trained U-Net" reference row).
pub fn run_qualitative(preset: &str, n_samples: usize, seed: u64) -> Result<()> {
    use crate::coordinator::xla_denoiser::XlaDenoiser;
    use crate::denoiser::DenoiserKind;
    use crate::util::pgm::write_grid;

    let ds = dataset(preset, seed)?;
    let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
    let rt = super::runtime()?;
    let dir = out_dir().join("fig4");

    for kind in [
        DenoiserKind::Optimal,
        DenoiserKind::Wiener,
        DenoiserKind::Kamb,
        DenoiserKind::Pca,
        DenoiserKind::GoldDiffPca,
    ] {
        let mut den = XlaDenoiser::new(std::rc::Rc::clone(&rt), &ds, kind)?;
        let samples: Vec<Vec<f32>> = (0..n_samples)
            .map(|s| {
                sampler::sample(&mut den, &ds, &sched, seed + s as u64, Default::default())
                    .final_sample()
                    .to_vec()
            })
            .collect();
        let path = dir.join(format!("{preset}_{}.ppm", kind.name()));
        write_grid(&path, &samples, ds.h, ds.w, ds.c, n_samples.min(8))?;
        eprintln!("  wrote {path:?}");
    }

    // oracle reference row (same seeds)
    let oracle = crate::oracle::GmmOracle::new(ds.gmm.clone());
    let samples: Vec<Vec<f32>> = (0..n_samples)
        .map(|s| {
            let mut rng = Pcg64::with_stream(seed + s as u64, 0x5a3);
            let mut x = sampler::init_noise(ds.d, &mut rng);
            for step in 0..sched.steps {
                let f = oracle.denoise(&x, sched.alpha_bar(step));
                x = sampler::ddim_update(
                    &x,
                    &f,
                    sched.alpha_bar(step),
                    sched.alpha_prev(step),
                    0.0,
                    &mut rng,
                );
            }
            x
        })
        .collect();
    write_grid(
        &dir.join(format!("{preset}_oracle.ppm")),
        &samples,
        ds.h,
        ds.w,
        ds.c,
        n_samples.min(8),
    )?;
    eprintln!("  wrote oracle reference grid");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;

    #[test]
    fn posterior_weights_sum_to_one_and_concentrate() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 300;
        let ds = Dataset::synthesize(&spec, 3);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let x = vec![0.4f32, 0.3];
        let w0 = full_posterior_weights(&ds, &x, &sched, 0);
        let w9 = full_posterior_weights(&ds, &x, &sched, 9);
        assert!((w0.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        assert!((w9.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        assert!(effective_support(&w9) < effective_support(&w0));
    }

    #[test]
    fn random_subset_denoiser_is_deterministic_per_seed() {
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 200;
        let ds = Dataset::synthesize(&spec, 1);
        let a = RandomSubsetDenoiser::new(&ds, 32, 9);
        let b = RandomSubsetDenoiser::new(&ds, 32, 9);
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.rows.len(), 32);
    }
}
