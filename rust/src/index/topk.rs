//! Top-k selection over distance streams.
//!
//! `BoundedMaxHeap` keeps the k smallest values seen (a max-heap rooted at
//! the current worst retained value), so a scan can push N items in
//! O(N log k) without materialising or sorting the full distance vector.

/// Max-heap of (dist, idx) bounded to capacity k; retains the k smallest.
#[derive(Debug, Clone)]
pub struct BoundedMaxHeap {
    k: usize,
    /// binary heap ordered by dist descending at the root
    items: Vec<(f32, u32)>,
}

impl BoundedMaxHeap {
    pub fn new(k: usize) -> Self {
        assert!(k > 0);
        BoundedMaxHeap {
            k,
            items: Vec::with_capacity(k),
        }
    }

    #[inline]
    pub fn worst(&self) -> f32 {
        if self.items.len() < self.k {
            f32::INFINITY
        } else {
            self.items[0].0
        }
    }

    #[inline]
    pub fn push(&mut self, dist: f32, idx: u32) {
        if self.items.len() < self.k {
            self.items.push((dist, idx));
            self.sift_up(self.items.len() - 1);
        } else if dist < self.items[0].0 {
            self.items[0] = (dist, idx);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.items[i].0 > self.items[parent].0 {
                self.items.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut largest = i;
            if l < self.items.len() && self.items[l].0 > self.items[largest].0 {
                largest = l;
            }
            if r < self.items.len() && self.items[r].0 > self.items[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.items.swap(i, largest);
            i = largest;
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Drain into (dist, idx) pairs sorted ascending by distance.
    pub fn into_sorted(mut self) -> Vec<(f32, u32)> {
        self.items.sort_by(|a, b| a.0.total_cmp(&b.0));
        self.items
    }

    /// Merge another heap's contents (used to combine per-shard results).
    pub fn merge(&mut self, other: BoundedMaxHeap) {
        for (d, i) in other.items {
            self.push(d, i);
        }
    }
}

/// Exact top-k smallest of a dense distance slice; returns indices sorted
/// ascending by distance. `idx_map` translates local positions to global
/// row ids (pass `None` for the identity).
pub fn top_k_smallest(dists: &[f32], k: usize, idx_map: Option<&[u32]>) -> Vec<u32> {
    let mut heap = BoundedMaxHeap::new(k.max(1).min(dists.len().max(1)));
    for (i, &d) in dists.iter().enumerate() {
        let gid = idx_map.map(|m| m[i]).unwrap_or(i as u32);
        heap.push(d, gid);
    }
    heap.into_sorted().into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Pcg64;

    #[test]
    fn keeps_k_smallest() {
        let mut heap = BoundedMaxHeap::new(3);
        for (i, d) in [5.0, 1.0, 4.0, 0.5, 9.0, 2.0].iter().enumerate() {
            heap.push(*d, i as u32);
        }
        let got: Vec<u32> = heap.into_sorted().into_iter().map(|(_, i)| i).collect();
        assert_eq!(got, vec![3, 1, 5]); // dists 0.5, 1.0, 2.0
    }

    #[test]
    fn top_k_matches_naive_sort() {
        forall(11, 100, |rng| {
            let n = gen::usize_in(rng, 1, 500);
            let k = gen::usize_in(rng, 1, n);
            let dists: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let got = top_k_smallest(&dists, k, None);
            let mut naive: Vec<u32> = (0..n as u32).collect();
            naive.sort_by(|&a, &b| dists[a as usize].total_cmp(&dists[b as usize]));
            naive.truncate(k);
            crate::prop_assert!(got == naive, "mismatch n={n} k={k}");
            Ok(())
        });
    }

    #[test]
    fn merge_equals_single_heap() {
        let mut rng = Pcg64::new(4);
        let dists: Vec<f32> = (0..200).map(|_| rng.f32()).collect();
        let mut whole = BoundedMaxHeap::new(10);
        for (i, &d) in dists.iter().enumerate() {
            whole.push(d, i as u32);
        }
        let mut a = BoundedMaxHeap::new(10);
        let mut b = BoundedMaxHeap::new(10);
        for (i, &d) in dists.iter().enumerate() {
            if i < 100 {
                a.push(d, i as u32)
            } else {
                b.push(d, i as u32)
            }
        }
        a.merge(b);
        assert_eq!(a.into_sorted(), whole.into_sorted());
    }

    #[test]
    fn idx_map_translates() {
        let map = [10u32, 20, 30];
        let got = top_k_smallest(&[3.0, 1.0, 2.0], 2, Some(&map));
        assert_eq!(got, vec![20, 30]);
    }
}
