//! Register-tiled multi-query distance kernel over a structure-of-arrays
//! proxy-block layout.
//!
//! The PR 1 batched scan amortised *passes* over the proxy table — one
//! traversal per batch group — but the inner loop still walked one `f32` at
//! a time, row-major, and re-derived each query's stride from scratch. This
//! module makes the FLOPs themselves cache- and register-efficient:
//!
//! * [`ProxyBlocks`] transposes the proxy table once at dataset load into
//!   fixed-width row blocks ([`BLOCK_ROWS`] rows each) stored *dim-major*
//!   inside the block, so the values of one dimension for all rows of a
//!   block are contiguous — the shape auto-vectorisers want.
//! * [`KernelScan`] evaluates a [`TILE_Q`]-query × row-block tile per inner
//!   loop: each block column (one dimension, `BLOCK_ROWS` lanes) is loaded
//!   once and broadcast against every query in the group, so the
//!   memory-bandwidth cost of a row is shared by up to 8 queries while the
//!   running distances stay in a 1 KB register/L1 tile.
//! * Between dimension strips ([`STRIP_DIMS`] wide) the kernel checks each
//!   query's best partial distance in the tile against that query's current
//!   worst retained heap distance: partial sums only grow, so when even the
//!   closest row of the block already exceeds the cutoff the whole
//!   (query, block) tile is provably dead and the remaining strips are
//!   skipped — the tile-level generalisation of `scan::sqdist_early_exit`.
//!
//! Exactness: a tile that survives all strips holds full squared distances
//! (each accumulator sums dimensions in index order), and a tile retired
//! early can only drop rows whose distance is already ≥ the heap's worst —
//! the same guarantee the scalar early-exit gives, so kernel and scalar
//! scans retain identical row sets (ties between bit-equal distances are
//! the only divergence surface, as with every backend — see
//! `index/README.md`).
//!
//! The kernel is layout-generic: the whole proxy table (`Dataset`'s
//! resident [`ProxyBlocks`]), an IVF list, a class-filtered member list, or
//! the full-resolution corpus ([`RowBlocks`], the refine ladder's table)
//! all scan through the same code path via the optional row-id map.
//!
//! The tile inner loops run through explicit SIMD lanes when the CPU has
//! them ([`simd`]): the scalar loop accumulates every lane independently
//! (no horizontal reduction), so the AVX2 path performs the identical IEEE
//! operations per lane and is **bit-identical** to the scalar fallback —
//! the `simd` knob is a pure speed toggle.
//!
//! A quantised tier rides on the same layout ([`QuantBlocks`],
//! [`QuantScan`]): int8 symmetric codes with per-row scales and per-row
//! error-norm corrections give provably sound lower/upper distance bounds,
//! so a coarse screen can visit 1-byte columns, exclude most rows with the
//! bound, and re-stream only the bound-cleared survivors through the exact
//! f32 masked tiles — final heap contents are exact f32 distances, so end
//! results match the pure-f32 scan (see `index/README.md`, "Quantised
//! tier").
//!
//! Two further extensions ride on the same layout:
//!
//! * **Heap-aware block ordering** — each block carries its centroid and
//!   covering radius (computed once at build). A scan may visit blocks in
//!   ascending centroid distance to the query group ([`block_order`],
//!   [`KernelScan::top_m_ordered`]): near blocks fill the heaps first, so
//!   the strip early-exit bound is tight for the bulk of the pass instead
//!   of only its tail. Ordering never changes *which* distances are
//!   computed or their values — only the visit order — so results are
//!   identical to the unordered scan (exact f32 ties are the only
//!   divergence surface, as everywhere in `index`).
//! * **Masked refine tiles** ([`refine_scan_masked`]) — the exact refine
//!   stage scans only the blocks that hold candidate rows, with a
//!   per-(row, query) membership bitmask applied at harvest, so the
//!   full-resolution pass reuses the same dim-major column loads and strip
//!   early-exit as the coarse kernel.

use std::collections::HashMap;

use super::topk::BoundedMaxHeap;
use crate::util::threadpool::parallel_chunks;

/// Queries evaluated per register tile (one row-block load is shared by up
/// to this many queries).
pub const TILE_Q: usize = 8;
/// Rows per structure-of-arrays block. 32 rows × 8 queries × 4 B = 1 KB of
/// running accumulators — small enough to live in registers/L1 while one
/// block column streams through.
pub const BLOCK_ROWS: usize = 32;
/// Dimensions accumulated between early-exit checks.
const STRIP_DIMS: usize = 16;

/// Runtime-dispatched SIMD lanes for the tile inner loops.
///
/// The scalar column loops accumulate each of the block's [`BLOCK_ROWS`]
/// lanes independently (`acc[lane] += (qv − v)²`, no horizontal reduction
/// and no fused multiply-add), so the AVX2 paths below perform the exact
/// same IEEE-754 operations per lane in the same order and produce
/// **bit-identical** accumulators. That is what makes the knob safe as a
/// process-wide flag (`EngineConfig::simd` / `GOLDDIFF_SIMD`): toggling it
/// can change speed, never results. Non-x86 targets (and CPUs without
/// AVX2) fall back to the scalar loops transparently.
pub mod simd {
    use std::sync::atomic::{AtomicBool, Ordering};

    static ENABLED: AtomicBool = AtomicBool::new(true);

    /// Toggle the SIMD lanes process-wide. Results are bit-identical
    /// either way, so late or concurrent toggles are harmless.
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Ordering::Relaxed);
    }

    /// Is the knob on (regardless of CPU support)?
    pub fn enabled() -> bool {
        ENABLED.load(Ordering::Relaxed)
    }

    /// Does this CPU expose the AVX2 lanes the kernels target?
    #[cfg(target_arch = "x86_64")]
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// Does this CPU expose the AVX2 lanes the kernels target?
    #[cfg(not(target_arch = "x86_64"))]
    pub fn available() -> bool {
        false
    }

    /// One dispatch decision per block scan (hoisted out of the column
    /// loops; the feature probe is cached by std).
    #[inline]
    pub(super) fn active() -> bool {
        enabled() && available()
    }

    /// `acc[lane] += (qv − col[lane])²` across the block's lanes.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`available`]).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn accum_f32_avx2(
        acc: &mut [f32; super::BLOCK_ROWS],
        qv: f32,
        col: &[f32],
    ) {
        use core::arch::x86_64::*;
        debug_assert!(col.len() >= super::BLOCK_ROWS);
        let q = _mm256_set1_ps(qv);
        let ap = acc.as_mut_ptr();
        let cp = col.as_ptr();
        for i in 0..super::BLOCK_ROWS / 8 {
            // sub/mul/add only — no FMA, so every lane matches the scalar
            // `d = qv − v; a += d·d` bit-for-bit
            let v = _mm256_loadu_ps(cp.add(i * 8));
            let d = _mm256_sub_ps(q, v);
            let a = _mm256_loadu_ps(ap.add(i * 8) as *const f32);
            _mm256_storeu_ps(ap.add(i * 8), _mm256_add_ps(a, _mm256_mul_ps(d, d)));
        }
    }

    /// `acc[lane] += (qv − scales[lane]·codes[lane])²` across the block's
    /// lanes — the int8 column load is a quarter of the f32 footprint.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support ([`available`]).
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn accum_i8_avx2(
        acc: &mut [f32; super::BLOCK_ROWS],
        qv: f32,
        codes: &[i8],
        scales: &[f32],
    ) {
        use core::arch::x86_64::*;
        debug_assert!(codes.len() >= super::BLOCK_ROWS);
        debug_assert!(scales.len() >= super::BLOCK_ROWS);
        let q = _mm256_set1_ps(qv);
        let ap = acc.as_mut_ptr();
        for i in 0..super::BLOCK_ROWS / 8 {
            // widen 8 i8 codes → i32 → f32 (exact), then mul/sub/mul/add
            // mirrors the scalar `d = qv − s·(c as f32); a += d·d`
            // lane-for-lane
            let c8 = _mm_loadl_epi64(codes.as_ptr().add(i * 8) as *const __m128i);
            let c = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(c8));
            let s = _mm256_loadu_ps(scales.as_ptr().add(i * 8));
            let d = _mm256_sub_ps(q, _mm256_mul_ps(s, c));
            let a = _mm256_loadu_ps(ap.add(i * 8) as *const f32);
            _mm256_storeu_ps(ap.add(i * 8), _mm256_add_ps(a, _mm256_mul_ps(d, d)));
        }
    }
}

/// Scalar reference lanes for one f32 column (the `simd` fallback and the
/// bit-identity baseline).
#[inline(always)]
fn accum_f32_scalar(acc: &mut [f32; BLOCK_ROWS], qv: f32, col: &[f32]) {
    for (a, &v) in acc.iter_mut().zip(col) {
        let d = qv - v;
        *a += d * d;
    }
}

/// Scalar reference lanes for one int8 column.
#[inline(always)]
fn accum_i8_scalar(acc: &mut [f32; BLOCK_ROWS], qv: f32, codes: &[i8], scales: &[f32]) {
    for ((a, &c), &s) in acc.iter_mut().zip(codes).zip(scales) {
        let d = qv - s * c as f32;
        *a += d * d;
    }
}

/// One f32 column through the dispatched lanes. `use_simd` is the hoisted
/// per-scan [`simd::active`] decision.
#[inline]
fn accum_f32(use_simd: bool, acc: &mut [f32; BLOCK_ROWS], qv: f32, col: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if use_simd {
        // SAFETY: `use_simd` implies `simd::available()` returned true
        unsafe { simd::accum_f32_avx2(acc, qv, col) };
        return;
    }
    let _ = use_simd;
    accum_f32_scalar(acc, qv, col);
}

/// One int8 column through the dispatched lanes.
#[inline]
fn accum_i8(use_simd: bool, acc: &mut [f32; BLOCK_ROWS], qv: f32, codes: &[i8], scales: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if use_simd {
        // SAFETY: `use_simd` implies `simd::available()` returned true
        unsafe { simd::accum_i8_avx2(acc, qv, codes, scales) };
        return;
    }
    let _ = use_simd;
    accum_i8_scalar(acc, qv, codes, scales);
}

/// The proxy table transposed into fixed-width, dim-major row blocks.
///
/// Block `b` occupies `data[b*dim*BLOCK_ROWS ..]` and stores, for each
/// dimension `j`, the `BLOCK_ROWS` values `data[.. + j*BLOCK_ROWS + lane]`
/// of rows `b*BLOCK_ROWS + lane`. The final block is zero-padded; padded
/// lanes are never harvested. `ids` optionally maps block lanes back to
/// global row ids (IVF lists); `None` means the identity (the whole table).
///
/// Each block also carries its centroid (mean of the valid lanes) and the
/// covering radius (max member→centroid Euclidean distance): the substrate
/// for heap-aware block ordering and for exact per-block lower bounds
/// (`(d(q, c) − r)² ≤ d(q, x)²` for every member x).
#[derive(Debug, Clone, Default)]
pub struct ProxyBlocks {
    /// valid rows (excluding padding)
    pub rows: usize,
    /// values per row
    pub dim: usize,
    ids: Option<Vec<u32>>,
    data: Vec<f32>,
    /// per-block centroids [n_blocks × dim]
    centroids: Vec<f32>,
    /// per-block covering radii [n_blocks]
    radii: Vec<f32>,
}

/// The full-resolution corpus in the same dim-major block layout — what the
/// pre-blocked refine ladder scans (`Dataset::row_blocks`).
pub type RowBlocks = ProxyBlocks;

impl ProxyBlocks {
    /// Block the whole `rows × dim` table with identity row ids.
    pub fn build(table: &[f32], rows: usize, dim: usize) -> ProxyBlocks {
        assert_eq!(table.len(), rows * dim);
        Self::build_inner(table, dim, rows, None, false)
    }

    /// Block a row subset (e.g. an IVF member list); lane `l` of the result
    /// holds `table` row `ids[l]` and harvests as global id `ids[l]`.
    pub fn build_subset(table: &[f32], dim: usize, ids: &[u32]) -> ProxyBlocks {
        Self::build_inner(table, dim, ids.len(), Some(ids.to_vec()), true)
    }

    /// Block a *local* `ids.len() × dim` table whose lane `l` harvests as
    /// global id `ids[l]` — the layout a streamed corpus shard builds from
    /// rows read off disk: the table holds exactly the shard's rows in
    /// shard order, but results must carry global row ids.
    pub fn build_local(table: &[f32], dim: usize, ids: Vec<u32>) -> ProxyBlocks {
        assert_eq!(table.len(), ids.len() * dim);
        Self::build_inner(table, dim, ids.len(), Some(ids), false)
    }

    fn build_inner(
        table: &[f32],
        dim: usize,
        rows: usize,
        ids: Option<Vec<u32>>,
        gather_by_ids: bool,
    ) -> ProxyBlocks {
        let nb = rows.div_ceil(BLOCK_ROWS);
        let mut data = vec![0.0f32; nb * dim * BLOCK_ROWS];
        for r in 0..rows {
            let src_row = match &ids {
                Some(map) if gather_by_ids => map[r] as usize,
                _ => r,
            };
            let src = &table[src_row * dim..(src_row + 1) * dim];
            let base = (r / BLOCK_ROWS) * dim * BLOCK_ROWS + (r % BLOCK_ROWS);
            for (j, &v) in src.iter().enumerate() {
                data[base + j * BLOCK_ROWS] = v;
            }
        }
        let mut out = ProxyBlocks {
            rows,
            dim,
            ids,
            data,
            centroids: vec![0.0f32; nb * dim],
            radii: vec![0.0f32; nb],
        };
        for b in 0..nb {
            let n_valid = out.rows_in(b);
            let block = &out.data[b * dim * BLOCK_ROWS..(b + 1) * dim * BLOCK_ROWS];
            for j in 0..dim {
                let col = &block[j * BLOCK_ROWS..j * BLOCK_ROWS + n_valid];
                out.centroids[b * dim + j] = col.iter().sum::<f32>() / n_valid.max(1) as f32;
            }
            let c = &out.centroids[b * dim..(b + 1) * dim];
            let mut worst = 0.0f32;
            for lane in 0..n_valid {
                let d2: f32 = (0..dim)
                    .map(|j| {
                        let d = block[j * BLOCK_ROWS + lane] - c[j];
                        d * d
                    })
                    .sum();
                worst = worst.max(d2);
            }
            out.radii[b] = worst.sqrt();
        }
        out
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.rows.div_ceil(BLOCK_ROWS)
    }

    /// The dim-major slice of block `b` (`dim * BLOCK_ROWS` values).
    #[inline]
    pub fn block(&self, b: usize) -> &[f32] {
        let w = self.dim * BLOCK_ROWS;
        &self.data[b * w..(b + 1) * w]
    }

    /// Valid (non-padding) rows in block `b`.
    #[inline]
    pub fn rows_in(&self, b: usize) -> usize {
        (self.rows - b * BLOCK_ROWS).min(BLOCK_ROWS)
    }

    /// Global row id of lane `lane` in block `b`.
    #[inline]
    pub fn id(&self, b: usize, lane: usize) -> u32 {
        let r = b * BLOCK_ROWS + lane;
        match &self.ids {
            Some(map) => map[r],
            None => r as u32,
        }
    }

    /// Centroid of block `b` (mean of its valid lanes).
    #[inline]
    pub fn centroid(&self, b: usize) -> &[f32] {
        &self.centroids[b * self.dim..(b + 1) * self.dim]
    }

    /// Covering radius of block `b`: max member→centroid Euclidean distance.
    #[inline]
    pub fn radius(&self, b: usize) -> f32 {
        self.radii[b]
    }

    /// Copy local row `r` (block-lane addressed) out of the dim-major
    /// layout into `out[..dim]` — the streamed row source's path from a
    /// blocked shard back to a flat row. The values are the exact f32s the
    /// build transposed in, so a blocked roundtrip is bit-identical to the
    /// row-major original.
    #[inline]
    pub fn copy_row_into(&self, r: usize, out: &mut [f32]) {
        debug_assert!(r < self.rows);
        debug_assert!(out.len() >= self.dim);
        let (b, lane) = (r / BLOCK_ROWS, r % BLOCK_ROWS);
        let block = self.block(b);
        for (j, o) in out.iter_mut().enumerate().take(self.dim) {
            *o = block[j * BLOCK_ROWS + lane];
        }
    }

    /// Resident bytes of the blocked copy (telemetry / working-set math).
    pub fn bytes(&self) -> u64 {
        (self.data.len() + self.centroids.len() + self.radii.len()) as u64 * 4
    }
}

/// Heap-aware visit order: block ids sorted ascending by squared centroid
/// distance to `q` (ties broken by block id so the order is deterministic).
/// Scanning near blocks first fills the per-query heaps with small
/// distances early, so the strip early-exit retires far tiles sooner.
pub fn block_order(blocks: &ProxyBlocks, q: &[f32]) -> Vec<u32> {
    let mut order: Vec<(f32, u32)> = (0..blocks.n_blocks())
        .map(|b| {
            let c = blocks.centroid(b);
            let d: f32 = c.iter().zip(q).map(|(a, b)| (a - b) * (a - b)).sum();
            (d, b as u32)
        })
        .collect();
    order.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    order.into_iter().map(|(_, b)| b).collect()
}

/// Cumulative kernel counters for one scan (merged across shards).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelStats {
    /// (query-group × block) tiles evaluated
    pub tiles: u64,
    /// valid rows whose distances were produced (padding excluded)
    pub rows: u64,
    /// (query, block) pairs retired by the strip early-exit bound
    pub strip_exits: u64,
    /// (query, row) distance evaluations cut short by those retirements —
    /// the work the early exit actually saved (heap-aware ordering exists
    /// to push this number up)
    pub exit_gain_rows: u64,
}

impl KernelStats {
    pub fn add(&mut self, other: &KernelStats) {
        self.tiles += other.tiles;
        self.rows += other.rows;
        self.strip_exits += other.strip_exits;
        self.exit_gain_rows += other.exit_gain_rows;
    }
}

/// One tiled scan: a group of ≤ [`TILE_Q`] queries against a block table.
///
/// `classes[qi]` restricts query `qi` to rows whose `labels[gid]` matches —
/// the distance is still computed tile-wide (the row load is shared), the
/// filter applies at harvest. Pass `labels: None` when the blocks are
/// already class-filtered (per-class IVF lists) or every query is
/// unconditional.
pub struct KernelScan<'a> {
    pub blocks: &'a ProxyBlocks,
    pub queries: &'a [&'a [f32]],
    pub classes: &'a [Option<u32>],
    pub labels: Option<&'a [u32]>,
}

impl KernelScan<'_> {
    /// Scan blocks `[b0, b1)` pushing exact squared distances into one
    /// bounded heap per query. The heaps' current worst retained distances
    /// drive the per-tile early-exit bound.
    pub fn scan_into(
        &self,
        b0: usize,
        b1: usize,
        heaps: &mut [BoundedMaxHeap],
        stats: &mut KernelStats,
    ) {
        self.check_group(heaps);
        for b in b0..b1 {
            self.scan_block(b, heaps, stats);
        }
    }

    /// Scan an explicit block visit list (heap-aware ordering, IVF lists).
    /// Identical distances to [`scan_into`] — only the visit order differs.
    pub fn scan_list_into(
        &self,
        list: &[u32],
        heaps: &mut [BoundedMaxHeap],
        stats: &mut KernelStats,
    ) {
        self.check_group(heaps);
        for &b in list {
            self.scan_block(b as usize, heaps, stats);
        }
    }

    fn check_group(&self, heaps: &[BoundedMaxHeap]) {
        let nq = self.queries.len();
        assert!(nq > 0 && nq <= TILE_Q, "query group of {nq} exceeds TILE_Q");
        assert_eq!(nq, heaps.len());
        assert_eq!(nq, self.classes.len());
        debug_assert!(self.queries.iter().all(|q| q.len() == self.blocks.dim));
    }

    fn scan_block(&self, b: usize, heaps: &mut [BoundedMaxHeap], stats: &mut KernelStats) {
        let nq = self.queries.len();
        let dim = self.blocks.dim;
        let rows = self.blocks.rows_in(b);
        let data = self.blocks.block(b);
        let use_simd = simd::active();
        let mut acc = [[0.0f32; BLOCK_ROWS]; TILE_Q];
        let mut alive = [false; TILE_Q];
        alive[..nq].fill(true);
        let mut n_alive = nq;

        let mut j = 0;
        while j < dim {
            let jend = (j + STRIP_DIMS).min(dim);
            for jj in j..jend {
                let col = &data[jj * BLOCK_ROWS..(jj + 1) * BLOCK_ROWS];
                for (qi, q) in self.queries.iter().enumerate() {
                    if !alive[qi] {
                        continue;
                    }
                    // one column load serves every live query: the
                    // lane update is contiguous and branch-free, either
                    // auto-vectorised (scalar path) or explicit AVX2
                    accum_f32(use_simd, &mut acc[qi], q[jj], col);
                }
            }
            j = jend;
            if j >= dim {
                break;
            }
            // partial sums only grow: once even the nearest row of the
            // tile exceeds a query's worst retained distance, no row of
            // this block can enter that query's heap
            for qi in 0..nq {
                if !alive[qi] {
                    continue;
                }
                let cutoff = heaps[qi].worst();
                if !cutoff.is_finite() {
                    continue;
                }
                let best = acc[qi][..rows]
                    .iter()
                    .fold(f32::INFINITY, |m, &v| m.min(v));
                if best >= cutoff {
                    alive[qi] = false;
                    n_alive -= 1;
                    stats.strip_exits += 1;
                    stats.exit_gain_rows += rows as u64;
                }
            }
            if n_alive == 0 {
                break;
            }
        }
        stats.tiles += 1;
        stats.rows += rows as u64;

        // harvest: only queries that survived every strip hold full
        // distances; retired queries provably gain nothing here
        for qi in 0..nq {
            if !alive[qi] {
                continue;
            }
            let heap = &mut heaps[qi];
            let class = self.classes[qi];
            for (lane, &d) in acc[qi][..rows].iter().enumerate() {
                let gid = self.blocks.id(b, lane);
                if let (Some(y), Some(labels)) = (class, self.labels) {
                    if labels[gid as usize] != y {
                        continue;
                    }
                }
                heap.push(d, gid);
            }
        }
    }

    /// Full scan of the block table sharded over `threads`: per-shard heaps
    /// of capacity `cap` merged in shard order (the same merge discipline
    /// the scalar backends use). Returns ids sorted ascending by distance
    /// per query, plus the merged kernel counters.
    pub fn top_m(&self, cap: usize, threads: usize) -> (Vec<Vec<u32>>, KernelStats) {
        let cap = cap.max(1);
        let nb = self.blocks.n_blocks();
        let shards = parallel_chunks(nb, threads.max(1), |_, s, e| {
            let mut heaps = self.fresh_heaps(cap);
            let mut st = KernelStats::default();
            self.scan_into(s, e, &mut heaps, &mut st);
            (heaps, st)
        });
        self.merge_shards(cap, shards)
    }

    /// [`top_m`] under an explicit block visit order (see [`block_order`]):
    /// shards take contiguous chunks of the ordered list, so the shard that
    /// owns the nearest blocks tightens its bounds first. Results are
    /// identical to the unordered scan — same rows, same distances, only
    /// the visit (and therefore exit) pattern changes.
    pub fn top_m_ordered(
        &self,
        cap: usize,
        threads: usize,
        order: &[u32],
    ) -> (Vec<Vec<u32>>, KernelStats) {
        let cap = cap.max(1);
        let shards = parallel_chunks(order.len(), threads.max(1), |_, s, e| {
            let mut heaps = self.fresh_heaps(cap);
            let mut st = KernelStats::default();
            self.scan_list_into(&order[s..e], &mut heaps, &mut st);
            (heaps, st)
        });
        self.merge_shards(cap, shards)
    }

    fn fresh_heaps(&self, cap: usize) -> Vec<BoundedMaxHeap> {
        (0..self.queries.len())
            .map(|_| BoundedMaxHeap::new(cap))
            .collect()
    }

    fn merge_shards(
        &self,
        cap: usize,
        shards: Vec<(Vec<BoundedMaxHeap>, KernelStats)>,
    ) -> (Vec<Vec<u32>>, KernelStats) {
        let mut merged = self.fresh_heaps(cap);
        let mut stats = KernelStats::default();
        for (heaps, st) in shards {
            stats.add(&st);
            for (m, h) in merged.iter_mut().zip(heaps) {
                m.merge(h);
            }
        }
        (
            merged
                .into_iter()
                .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
                .collect(),
            stats,
        )
    }
}

// ---------------------------------------------------------------------------
// Masked refine tiles (the pre-blocked exact refine ladder)
// ---------------------------------------------------------------------------

/// One work item of a masked refine scan: a block of the full-resolution
/// [`RowBlocks`] plus the candidate lanes inside it. `lanes[i] = (lane,
/// bits)` where bit `qi` of `bits` says lane `lane` belongs to query `qi`'s
/// candidate pool (≤ [`TILE_Q`] queries per plan).
#[derive(Debug, Clone)]
pub struct MaskedBlock {
    pub block: u32,
    pub lanes: Vec<(u8, u8)>,
}

/// Group `(row id, query bits)` pairs — ascending distinct row ids — into
/// per-block work items for [`refine_scan_masked`].
pub fn build_refine_plan(rows: &[(u32, u8)]) -> Vec<MaskedBlock> {
    let mut plan: Vec<MaskedBlock> = Vec::new();
    for &(gid, bits) in rows {
        let block = gid / BLOCK_ROWS as u32;
        let lane = (gid % BLOCK_ROWS as u32) as u8;
        match plan.last_mut() {
            Some(mb) if mb.block == block => mb.lanes.push((lane, bits)),
            _ => plan.push(MaskedBlock {
                block,
                lanes: vec![(lane, bits)],
            }),
        }
    }
    plan
}

/// The exact refine as register tiles: scan only the blocks that hold
/// candidate rows, sharing each dim-major column load across the tile's
/// queries, and apply the per-(row, query) membership bits at harvest.
///
/// Distances are full squared sums exactly as in [`KernelScan`]; the strip
/// early-exit bounds each query against the minimum partial sum over *its
/// member lanes only* (non-member lanes can never enter that query's heap,
/// so excluding them keeps the bound tight and the retirement provable).
///
/// The plan's row values are *positions* in `blocks` (`pos / BLOCK_ROWS`,
/// `pos % BLOCK_ROWS`); harvested ids come from `blocks.id(..)`. For the
/// identity layout (`Dataset::row_blocks`) positions are global row ids;
/// a corpus shard passes shard-local positions and its id map translates
/// them back to global ids at harvest.
pub fn refine_scan_masked(
    blocks: &RowBlocks,
    queries: &[&[f32]],
    plan: &[MaskedBlock],
    heaps: &mut [BoundedMaxHeap],
    stats: &mut KernelStats,
) {
    let nq = queries.len();
    assert!(nq > 0 && nq <= TILE_Q, "refine tile of {nq} exceeds TILE_Q");
    assert_eq!(nq, heaps.len());
    let dim = blocks.dim;
    debug_assert!(queries.iter().all(|q| q.len() == dim));
    let use_simd = simd::active();

    for mb in plan {
        let b = mb.block as usize;
        let data = blocks.block(b);
        let mut acc = [[0.0f32; BLOCK_ROWS]; TILE_Q];
        let mut member = [0u64; TILE_Q]; // lanes of each query, as counts
        let mut alive = [false; TILE_Q];
        let mut n_alive = 0usize;
        for &(_, bits) in &mb.lanes {
            for (qi, m) in member.iter_mut().enumerate().take(nq) {
                if bits & (1 << qi) != 0 {
                    *m += 1;
                }
            }
        }
        for qi in 0..nq {
            if member[qi] > 0 {
                alive[qi] = true;
                n_alive += 1;
            }
        }

        let mut j = 0;
        while j < dim && n_alive > 0 {
            let jend = (j + STRIP_DIMS).min(dim);
            for jj in j..jend {
                let col = &data[jj * BLOCK_ROWS..(jj + 1) * BLOCK_ROWS];
                for (qi, q) in queries.iter().enumerate() {
                    if !alive[qi] {
                        continue;
                    }
                    // whole-column accumulation stays branch-free; the
                    // membership filter applies at harvest, like the
                    // coarse kernel's class filter
                    accum_f32(use_simd, &mut acc[qi], q[jj], col);
                }
            }
            j = jend;
            if j >= dim {
                break;
            }
            for qi in 0..nq {
                if !alive[qi] {
                    continue;
                }
                let cutoff = heaps[qi].worst();
                if !cutoff.is_finite() {
                    continue;
                }
                let best = mb
                    .lanes
                    .iter()
                    .filter(|&&(_, bits)| bits & (1 << qi) != 0)
                    .fold(f32::INFINITY, |m, &(lane, _)| m.min(acc[qi][lane as usize]));
                if best >= cutoff {
                    alive[qi] = false;
                    n_alive -= 1;
                    stats.strip_exits += 1;
                    stats.exit_gain_rows += member[qi];
                }
            }
        }
        stats.tiles += 1;
        stats.rows += mb.lanes.len() as u64;

        for &(lane, bits) in &mb.lanes {
            let gid = blocks.id(b, lane as usize);
            for (qi, heap) in heaps.iter_mut().enumerate().take(nq) {
                if alive[qi] && bits & (1 << qi) != 0 {
                    heap.push(acc[qi][lane as usize], gid);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantised tier: int8 codes with per-row scales and error corrections.
//
// Each row x is coded symmetrically: `scale = max|x_j| / 127` (1.0 for the
// all-zero row), `code_j = round(x_j / scale)` clamped to ±127, and the
// correction term `err = ‖x − scale·code‖₂` — the exact L2 norm of the
// rounding residual. For any query q, with d̂ = ‖q − scale·code‖₂ the
// triangle inequality gives the sandwich
//
//     max(0, d̂ − err)  ≤  ‖q − x‖₂  ≤  d̂ + err
//
// so squared bounds follow by squaring the non-negative ends. The screen
// rejects a row only when its *lower* bound already exceeds an *upper*
// -bound threshold on the k-th best candidate, so no true top-k member can
// ever be excluded; every survivor is re-scored on the f32 rows, making
// the end-to-end result byte-identical to the f32 path (see
// `index/README.md`, "Quantised tier" for the full argument).
//
// Two f32-arithmetic details keep the exclusions sound in practice, not
// just in reals: bounds are formed through `quant_lb2`/`quant_ub2`, which
// widen the sandwich by the `quant_guard` margin (the d̂² summation, the
// sqrts and the subtraction each round, so a near-tight computed lb can
// otherwise overshoot the true distance by accumulated ulps), and the
// post-merge survivor refilter rejects only on *strict* `lb² > T` — a
// threshold-heap member has lb² ≤ ub² ≤ T by construction, with equality
// exactly when err == 0 (zero, constant and duplicate rows quantise
// exactly), so rejecting on equality would self-reject the very rows the
// threshold is made of.
//
// Scales are per ROW, not per block — strictly tighter than a shared
// block scale (one outlier row cannot inflate its 31 neighbours' grids)
// and layout-independent, so the same codes serve any shard plan.
// ---------------------------------------------------------------------------

/// Quantise one row into `codes`; returns `(scale, err)` where `err` is
/// the L2 norm of the rounding residual.
pub fn quantise_row(row: &[f32], codes: &mut [i8]) -> (f32, f32) {
    assert_eq!(row.len(), codes.len());
    let maxab = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if maxab > 0.0 { maxab / 127.0 } else { 1.0 };
    let mut err2 = 0.0f32;
    for (c, &v) in codes.iter_mut().zip(row) {
        // clamp before the cast: round(v/scale) can land on ±128 when
        // v == ±max|x| and the division rounds up
        let q = (v / scale).round().clamp(-127.0, 127.0);
        *c = q as i8;
        let r = v - scale * q;
        err2 += r * r;
    }
    (scale, err2.sqrt())
}

/// Relative rounding margin for the sandwich bounds. The triangle
/// inequality holds in real arithmetic, but `d̂²` is a `dim`-term f32
/// summation and `d̂`, `err` and the subtraction each round — when a
/// bound is near-tight the computed lb can exceed the true distance by
/// accumulated ulps and wrongly exclude a row. `O(dim·ε)` covers the
/// worst-case relative summation error plus slack for the scalar
/// roundings; near-boundary rows are kept instead of dropped, costing
/// one extra exact f32 rescore and never changing results.
#[inline]
pub(crate) fn quant_guard(dim: usize) -> f32 {
    (dim as f32 + 8.0) * f32::EPSILON
}

/// Guarded squared lower bound from accumulated `d̂²` (full or partial —
/// a partial sum only shrinks the bound) and the row's residual norm:
/// deflate d̂ and inflate err by the margin before subtracting.
#[inline]
pub(crate) fn quant_lb2(acc: f32, err: f32, margin: f32) -> f32 {
    let lb = (acc.sqrt() * (1.0 - margin) - err * (1.0 + margin)).max(0.0);
    lb * lb
}

/// Guarded squared upper bound: inflate the sum by the margin so the
/// threshold side of the sandwich stays an upper bound under rounding.
#[inline]
pub(crate) fn quant_ub2(acc: f32, err: f32, margin: f32) -> f32 {
    let ub = (acc.sqrt() + err) * (1.0 + margin);
    ub * ub
}

/// Int8 twin of a [`ProxyBlocks`] table: same dim-major `BLOCK_ROWS`-lane
/// layout (so the tile kernels walk it with the same stride math), plus
/// per-lane scales and correction norms. Padding lanes carry code 0,
/// scale 1.0, err 0.0 and are never harvested.
#[derive(Debug, Clone, Default)]
pub struct QuantBlocks {
    pub rows: usize,
    pub dim: usize,
    /// `n_blocks × dim × BLOCK_ROWS` codes, dim-major within each block.
    codes: Vec<i8>,
    /// `n_blocks × BLOCK_ROWS` per-lane scales.
    scales: Vec<f32>,
    /// `n_blocks × BLOCK_ROWS` per-lane residual norms.
    errs: Vec<f32>,
}

impl QuantBlocks {
    /// Quantise every row of an existing f32 block table. Rows are read
    /// back through the blocked layout, so this works for identity,
    /// subset and shard-local tables alike (positions, not global ids).
    pub fn from_blocks(blocks: &ProxyBlocks) -> Self {
        let (rows, dim) = (blocks.rows, blocks.dim);
        let nb = blocks.n_blocks();
        let mut codes = vec![0i8; nb * dim * BLOCK_ROWS];
        let mut scales = vec![1.0f32; nb * BLOCK_ROWS];
        let mut errs = vec![0.0f32; nb * BLOCK_ROWS];
        let mut row = vec![0.0f32; dim];
        let mut code = vec![0i8; dim];
        for b in 0..nb {
            let data = blocks.block(b);
            let boff = b * dim * BLOCK_ROWS;
            for lane in 0..blocks.rows_in(b) {
                for (j, r) in row.iter_mut().enumerate() {
                    *r = data[j * BLOCK_ROWS + lane];
                }
                let (s, e) = quantise_row(&row, &mut code);
                scales[b * BLOCK_ROWS + lane] = s;
                errs[b * BLOCK_ROWS + lane] = e;
                for (j, &c) in code.iter().enumerate() {
                    codes[boff + j * BLOCK_ROWS + lane] = c;
                }
            }
        }
        QuantBlocks {
            rows,
            dim,
            codes,
            scales,
            errs,
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.rows.div_ceil(BLOCK_ROWS)
    }

    pub fn rows_in(&self, b: usize) -> usize {
        (self.rows - b * BLOCK_ROWS).min(BLOCK_ROWS)
    }

    /// Dim-major code slab of block `b` (`dim × BLOCK_ROWS` entries).
    pub fn codes(&self, b: usize) -> &[i8] {
        let w = self.dim * BLOCK_ROWS;
        &self.codes[b * w..(b + 1) * w]
    }

    /// Per-lane scales of block `b` (`BLOCK_ROWS` entries).
    pub fn scales(&self, b: usize) -> &[f32] {
        &self.scales[b * BLOCK_ROWS..(b + 1) * BLOCK_ROWS]
    }

    /// Per-lane residual norms of block `b` (`BLOCK_ROWS` entries).
    pub fn errs(&self, b: usize) -> &[f32] {
        &self.errs[b * BLOCK_ROWS..(b + 1) * BLOCK_ROWS]
    }

    pub fn bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.errs.len()) * 4
    }
}

/// Row-major int8 tier over the full-resolution table — the form the
/// `.gds` store persists and the refine pre-rung consumes (random access
/// by global row id, no blocking).
#[derive(Debug, Clone, Default)]
pub struct QuantRows {
    pub n: usize,
    pub d: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
    errs: Vec<f32>,
}

impl QuantRows {
    /// Quantise a resident row-major table.
    pub fn build(table: &[f32], n: usize, d: usize) -> Self {
        assert_eq!(table.len(), n * d);
        let mut codes = vec![0i8; n * d];
        let mut scales = vec![1.0f32; n];
        let mut errs = vec![0.0f32; n];
        for i in 0..n {
            let (s, e) = quantise_row(&table[i * d..(i + 1) * d], &mut codes[i * d..(i + 1) * d]);
            scales[i] = s;
            errs[i] = e;
        }
        QuantRows {
            n,
            d,
            codes,
            scales,
            errs,
        }
    }

    /// Reassemble from persisted sections; `None` when the lengths are
    /// inconsistent (a corrupt or foreign store — caller falls back to
    /// the f32-only path).
    pub fn from_parts(
        n: usize,
        d: usize,
        codes: Vec<i8>,
        scales: Vec<f32>,
        errs: Vec<f32>,
    ) -> Option<Self> {
        if codes.len() != n * d || scales.len() != n || errs.len() != n {
            return None;
        }
        Some(QuantRows {
            n,
            d,
            codes,
            scales,
            errs,
        })
    }

    pub fn codes_row(&self, i: usize) -> &[i8] {
        &self.codes[i * self.d..(i + 1) * self.d]
    }

    pub fn scale(&self, i: usize) -> f32 {
        self.scales[i]
    }

    pub fn err(&self, i: usize) -> f32 {
        self.errs[i]
    }

    /// Flat views for persistence.
    pub fn codes_flat(&self) -> &[i8] {
        &self.codes
    }

    pub fn scales_flat(&self) -> &[f32] {
        &self.scales
    }

    pub fn errs_flat(&self) -> &[f32] {
        &self.errs
    }

    /// Sound squared-distance sandwich `(lb², ub²)` on `‖q − x_gid‖²`,
    /// rounding-guarded (see [`quant_guard`]).
    pub fn bounds2(&self, q: &[f32], gid: u32) -> (f32, f32) {
        let i = gid as usize;
        let d2 = crate::index::scan::quant_sqdist(q, self.codes_row(i), self.scales[i]);
        let m = quant_guard(self.d);
        let err = self.errs[i];
        (quant_lb2(d2, err, m), quant_ub2(d2, err, m))
    }

    pub fn bytes(&self) -> usize {
        self.codes.len() + (self.scales.len() + self.errs.len()) * 4
    }
}

/// Telemetry from the quantised tier (per-query-group, mergeable).
/// Invariant: `rows_screened == bound_rejects + rescore_rows` — every
/// class-eligible row a quant pass touches lands in exactly one bucket.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QuantStats {
    /// Class-eligible rows whose bounds were evaluated on int8 codes.
    pub rows_screened: u64,
    /// Rows the bound could not exclude — re-scored on f32.
    pub rescore_rows: u64,
    /// Rows excluded by the sound lower bound (never touched f32 data).
    pub bound_rejects: u64,
}

impl QuantStats {
    pub fn add(&mut self, o: &QuantStats) {
        self.rows_screened += o.rows_screened;
        self.rescore_rows += o.rescore_rows;
        self.bound_rejects += o.bound_rejects;
    }
}

/// Coarse screen over the int8 tier with exact f32 rescore.
///
/// The screen runs the same 8-query register tile as [`KernelScan`], but on
/// quarter-width int8 columns. Per query it maintains an *upper-bound
/// threshold heap* (capacity = the requested cap) of survivor ub²s; a row
/// is excluded at visit time only when its lb² is already ≥ the heap's
/// worst retained ub². The heap's worst over ingested rows always upper
/// -bounds the cap-th smallest *true* distance over those rows, so an
/// excluded row is provably outside the true top-cap — the exclusion is
/// sound irrespective of visit order or sharding. After the parallel
/// chunks merge, survivors are filtered once more against the merged
/// threshold (strictly — a heap member's lb² equals its own ub² when
/// err == 0 and must still reach the rescore), then re-streamed through
/// [`refine_scan_masked`] on the f32 twin blocks, so harvested distances
/// are *exactly* the f32 kernel's.
///
/// Strip early-exit re-uses the f32 kernel's retirement discipline with
/// the bound made err-aware: partial sums only grow and the full-row
/// residual norm over-covers any dim prefix, so
/// `(√acc_partial − err).max(0)²` lower-bounds the full true distance.
///
/// Conditional queries participate: only class-eligible rows are ingested
/// into a query's threshold heap (mixing classes would tighten the
/// threshold unsoundly for the conditional query).
pub struct QuantScan<'a> {
    /// f32 twin — supplies ids and the exact rescore data.
    pub blocks: &'a ProxyBlocks,
    pub quant: &'a QuantBlocks,
    pub queries: &'a [&'a [f32]],
    pub classes: &'a [Option<u32>],
    pub labels: Option<&'a [u32]>,
}

impl<'a> QuantScan<'a> {
    fn check_group(&self, heaps: &[BoundedMaxHeap]) {
        let nq = self.queries.len();
        assert!(nq > 0 && nq <= TILE_Q, "query group of {nq} exceeds TILE_Q");
        assert_eq!(nq, heaps.len());
        assert_eq!(nq, self.classes.len());
        assert_eq!(self.quant.rows, self.blocks.rows);
        assert_eq!(self.quant.dim, self.blocks.dim);
        debug_assert!(self.queries.iter().all(|q| q.len() == self.blocks.dim));
    }

    /// Class-eligible lanes of block `b` for one query.
    fn eligible_rows(&self, b: usize, rows: usize, class: Option<u32>) -> u64 {
        match (class, self.labels) {
            (Some(y), Some(labels)) => (0..rows)
                .filter(|&lane| labels[self.blocks.id(b, lane) as usize] == y)
                .count() as u64,
            _ => rows as u64,
        }
    }

    /// Quant tile pass over one block: accumulate d̂² per lane, retire
    /// queries whose err-aware lower bound clears their threshold heap,
    /// harvest bounds for the surviving eligible lanes.
    #[allow(clippy::too_many_arguments)]
    fn quant_block(
        &self,
        b: usize,
        use_simd: bool,
        ubheaps: &mut [BoundedMaxHeap],
        surv: &mut [Vec<(u32, f32)>],
        qst: &mut QuantStats,
        kst: &mut KernelStats,
    ) {
        let nq = self.queries.len();
        let dim = self.quant.dim;
        let rows = self.quant.rows_in(b);
        let codes = self.quant.codes(b);
        let scales = self.quant.scales(b);
        let errs = self.quant.errs(b);
        let margin = quant_guard(dim);
        let mut acc = [[0.0f32; BLOCK_ROWS]; TILE_Q];
        let mut alive = [false; TILE_Q];
        alive[..nq].fill(true);
        let mut n_alive = nq;

        let mut j = 0;
        while j < dim {
            let jend = (j + STRIP_DIMS).min(dim);
            for jj in j..jend {
                let ccol = &codes[jj * BLOCK_ROWS..(jj + 1) * BLOCK_ROWS];
                for (qi, q) in self.queries.iter().enumerate() {
                    if !alive[qi] {
                        continue;
                    }
                    accum_i8(use_simd, &mut acc[qi], q[jj], ccol, scales);
                }
            }
            j = jend;
            if j >= dim {
                break;
            }
            for qi in 0..nq {
                if !alive[qi] {
                    continue;
                }
                let cutoff = ubheaps[qi].worst();
                if !cutoff.is_finite() {
                    continue;
                }
                // (√acc − err).max(0)² lower-bounds the full true
                // distance even on a partial sum: acc only grows and the
                // full-row err over-covers any prefix residual (guarded
                // against f32 rounding, see `quant_guard`)
                let best = (0..rows).fold(f32::INFINITY, |best, lane| {
                    best.min(quant_lb2(acc[qi][lane], errs[lane], margin))
                });
                if best >= cutoff {
                    alive[qi] = false;
                    n_alive -= 1;
                    kst.strip_exits += 1;
                    kst.exit_gain_rows += rows as u64;
                    // every eligible row of this block is excluded by
                    // the bound without touching f32 data
                    let n_elig = self.eligible_rows(b, rows, self.classes[qi]);
                    qst.rows_screened += n_elig;
                    qst.bound_rejects += n_elig;
                }
            }
            if n_alive == 0 {
                break;
            }
        }
        kst.tiles += 1;
        kst.rows += rows as u64;

        for qi in 0..nq {
            if !alive[qi] {
                continue;
            }
            let class = self.classes[qi];
            for lane in 0..rows {
                if let (Some(y), Some(labels)) = (class, self.labels) {
                    if labels[self.blocks.id(b, lane) as usize] != y {
                        continue;
                    }
                }
                let a = acc[qi][lane];
                let err = errs[lane];
                let lb2 = quant_lb2(a, err, margin);
                qst.rows_screened += 1;
                if lb2 >= ubheaps[qi].worst() {
                    // cannot beat the cap-th best upper bound: provably
                    // outside the true top-cap (rejection accounted now;
                    // the heap holds only *other* rows at this point and
                    // is full whenever worst() is finite, so ≥ cap rows
                    // are at least as close and a push would be a no-op)
                    qst.bound_rejects += 1;
                } else {
                    let pos = (b * BLOCK_ROWS + lane) as u32;
                    ubheaps[qi].push(quant_ub2(a, err, margin), pos);
                    surv[qi].push((pos, lb2));
                }
            }
        }
    }

    /// Screen all blocks (optionally in an explicit visit `order`) on the
    /// int8 tier, then rescore every survivor on the f32 twin into
    /// `heaps` (fresh, capacity = `cap`). On tie-free data the harvested
    /// ids and distances are byte-identical to [`KernelScan::top_m`].
    #[allow(clippy::too_many_arguments)]
    pub fn screen_into(
        &self,
        cap: usize,
        threads: usize,
        order: Option<&[u32]>,
        heaps: &mut [BoundedMaxHeap],
        qst: &mut QuantStats,
        kst: &mut KernelStats,
    ) {
        self.check_group(heaps);
        let cap = cap.max(1);
        let nq = self.queries.len();
        let nb = self.quant.n_blocks();
        let n_items = order.map_or(nb, <[u32]>::len);
        let use_simd = simd::active();

        let chunks = parallel_chunks(n_items, threads.max(1), |_, s, e| {
            let mut ubheaps: Vec<BoundedMaxHeap> =
                (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
            let mut surv: Vec<Vec<(u32, f32)>> = vec![Vec::new(); nq];
            let mut q = QuantStats::default();
            let mut k = KernelStats::default();
            for pos in s..e {
                let b = order.map_or(pos, |o| o[pos] as usize);
                self.quant_block(b, use_simd, &mut ubheaps, &mut surv, &mut q, &mut k);
            }
            (ubheaps, surv, q, k)
        });

        // merged upper-bound threshold: the cap-th smallest survivor ub²
        // across all chunks still upper-bounds the true cap-th distance,
        // so one more (tighter) filter pass over survivors stays sound
        let mut merged: Vec<BoundedMaxHeap> = (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
        for (ubheaps, _, q, k) in &chunks {
            qst.add(q);
            kst.add(k);
            for (m, h) in merged.iter_mut().zip(ubheaps) {
                m.merge(h.clone());
            }
        }
        let t_final: Vec<f32> = merged.iter().map(BoundedMaxHeap::worst).collect();

        let mut bits: HashMap<u32, u8> = HashMap::new();
        for (_, surv, _, _) in &chunks {
            for qi in 0..nq {
                for &(pos, lb2) in &surv[qi] {
                    // strict: a threshold-heap member has lb² ≤ ub² ≤
                    // t_final (its own ub² sits *in* the merged heap),
                    // with equality exactly when err == 0 — zero,
                    // constant and duplicate rows quantise exactly — so
                    // rejecting on `>=` would self-reject heap members
                    // and could empty the refine plan (e.g. cap = 1 with
                    // an exactly-quantisable nearest row). Keep on
                    // equality, matching quant_prefilter's `lb ≤ T` rule
                    if lb2 > t_final[qi] {
                        qst.bound_rejects += 1;
                    } else {
                        *bits.entry(pos).or_insert(0) |= 1 << qi;
                        qst.rescore_rows += 1;
                    }
                }
            }
        }

        // exact rescore: survivors re-streamed through the f32 masked
        // tiles in ascending position order (= block order), so the
        // harvested distances are the f32 kernel's own
        let mut rows: Vec<(u32, u8)> = bits.into_iter().collect();
        rows.sort_unstable_by_key(|&(pos, _)| pos);
        let plan = build_refine_plan(&rows);
        if plan.is_empty() {
            return;
        }
        let shards = parallel_chunks(plan.len(), threads.max(1), |_, s, e| {
            let mut hs: Vec<BoundedMaxHeap> = (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
            let mut st = KernelStats::default();
            refine_scan_masked(self.blocks, self.queries, &plan[s..e], &mut hs, &mut st);
            (hs, st)
        });
        for (hs, st) in shards {
            kst.add(&st);
            for (h, hh) in heaps.iter_mut().zip(hs) {
                h.merge(hh);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Pcg64;

    /// Sequential-scalar reference top-m (the naive oracle).
    fn naive_top_m(table: &[f32], rows: usize, dim: usize, q: &[f32], m: usize) -> Vec<u32> {
        let mut dists: Vec<(f32, u32)> = (0..rows)
            .map(|i| {
                let d: f32 = table[i * dim..(i + 1) * dim]
                    .iter()
                    .zip(q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                (d, i as u32)
            })
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        dists.truncate(m.min(rows));
        dists.into_iter().map(|(_, i)| i).collect()
    }

    fn random_table(rng: &mut Pcg64, rows: usize, dim: usize) -> Vec<f32> {
        (0..rows * dim).map(|_| rng.normal()).collect()
    }

    #[test]
    fn blocks_layout_roundtrips_every_cell() {
        let mut rng = Pcg64::new(3);
        for (rows, dim) in [(1usize, 1usize), (31, 7), (32, 16), (33, 16), (100, 5)] {
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            assert_eq!(blocks.n_blocks(), rows.div_ceil(BLOCK_ROWS));
            for r in 0..rows {
                let (b, lane) = (r / BLOCK_ROWS, r % BLOCK_ROWS);
                assert_eq!(blocks.id(b, lane), r as u32);
                for j in 0..dim {
                    assert_eq!(
                        blocks.block(b)[j * BLOCK_ROWS + lane],
                        table[r * dim + j],
                        "rows={rows} dim={dim} r={r} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn copy_row_into_roundtrips_the_row_major_table() {
        let mut rng = Pcg64::new(21);
        for (rows, dim) in [(1usize, 5usize), (33, 17), (100, 96)] {
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let mut out = vec![0.0f32; dim];
            for r in [0, rows / 2, rows - 1] {
                blocks.copy_row_into(r, &mut out);
                assert_eq!(out, table[r * dim..(r + 1) * dim], "rows={rows} r={r}");
            }
        }
    }

    #[test]
    fn tiled_matches_naive_across_ragged_dims_and_rows() {
        // Satellite: parity across proxy dims that are and are not
        // multiples of the strip/lane width, and row counts that do and do
        // not fill the last block.
        forall(71, 40, |rng| {
            let dim = [1usize, 7, 15, 16, 17, 31, 32, 33, 48, 100][rng.below(10)];
            let rows = [1usize, 2, 31, 32, 33, 64, 97][rng.below(7)];
            let table = random_table(rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let nq = gen::usize_in(rng, 1, TILE_Q);
            let m = gen::usize_in(rng, 1, rows + 2);
            let qs_data: Vec<Vec<f32>> = (0..nq).map(|_| gen::vec_normal(rng, dim, 1.0)).collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let classes = vec![None; nq];
            let scan = KernelScan {
                blocks: &blocks,
                queries: &qs,
                classes: &classes,
                labels: None,
            };
            let (got, st) = scan.top_m(m.min(rows).max(1), 2);
            crate::prop_assert!(st.rows >= rows as u64, "row accounting");
            for (qi, q) in qs.iter().enumerate() {
                let want = naive_top_m(&table, rows, dim, q, m);
                crate::prop_assert!(
                    got[qi] == want,
                    "dim={dim} rows={rows} nq={nq} m={m} qi={qi}: {:?} vs {:?}",
                    got[qi],
                    want
                );
            }
            Ok(())
        });
    }

    #[test]
    fn strip_early_exit_preserves_exactness_on_self_queries() {
        // self-queries make heap cutoffs tiny after the home block, so most
        // tiles retire early — results must still equal the naive scan
        let mut rng = Pcg64::new(9);
        let (rows, dim) = (200usize, 96usize); // several strips per block
        let table = random_table(&mut rng, rows, dim);
        let blocks = ProxyBlocks::build(&table, rows, dim);
        for r in [0usize, 57, 199] {
            let q = &table[r * dim..(r + 1) * dim];
            let queries = [q];
            let scan = KernelScan {
                blocks: &blocks,
                queries: &queries,
                classes: &[None],
                labels: None,
            };
            let (got, st) = scan.top_m(3, 1);
            assert_eq!(got[0], naive_top_m(&table, rows, dim, q, 3));
            assert_eq!(got[0][0], r as u32);
            assert!(st.strip_exits > 0, "self-query must retire tiles early");
        }
    }

    #[test]
    fn subset_blocks_map_lanes_to_global_ids() {
        let mut rng = Pcg64::new(5);
        let (rows, dim) = (90usize, 24usize);
        let table = random_table(&mut rng, rows, dim);
        let ids: Vec<u32> = (0..rows as u32).filter(|i| i % 3 == 0).collect();
        let blocks = ProxyBlocks::build_subset(&table, dim, &ids);
        assert_eq!(blocks.rows, ids.len());
        let q = gen::vec_normal(&mut rng, dim, 1.0);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, _) = scan.top_m(5, 1);
        // naive over the subset only
        let mut dists: Vec<(f32, u32)> = ids
            .iter()
            .map(|&gid| {
                let row = &table[gid as usize * dim..(gid as usize + 1) * dim];
                let d: f32 = row.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                (d, gid)
            })
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        let want: Vec<u32> = dists.into_iter().take(5).map(|(_, i)| i).collect();
        assert_eq!(got[0], want);
    }

    #[test]
    fn local_blocks_match_subset_blocks() {
        // a shard's streamed build (local table + global id map) must be
        // byte-identical to the resident gather over the full table
        let mut rng = Pcg64::new(17);
        let (rows, dim) = (77usize, 12usize);
        let table = random_table(&mut rng, rows, dim);
        let ids: Vec<u32> = (20u32..53).collect(); // a contiguous shard range
        let local: Vec<f32> = ids
            .iter()
            .flat_map(|&gid| table[gid as usize * dim..(gid as usize + 1) * dim].to_vec())
            .collect();
        let a = ProxyBlocks::build_subset(&table, dim, &ids);
        let b = ProxyBlocks::build_local(&local, dim, ids.clone());
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.n_blocks(), b.n_blocks());
        for blk in 0..a.n_blocks() {
            assert_eq!(a.block(blk), b.block(blk), "block {blk}");
            assert_eq!(a.centroid(blk), b.centroid(blk));
            assert_eq!(a.radius(blk), b.radius(blk));
            for lane in 0..a.rows_in(blk) {
                assert_eq!(a.id(blk, lane), b.id(blk, lane));
            }
        }
    }

    #[test]
    fn conditional_harvest_filters_by_label() {
        let mut rng = Pcg64::new(7);
        let (rows, dim) = (64usize, 8usize);
        let table = random_table(&mut rng, rows, dim);
        let labels: Vec<u32> = (0..rows as u32).map(|i| i % 4).collect();
        let blocks = ProxyBlocks::build(&table, rows, dim);
        let q = gen::vec_normal(&mut rng, dim, 1.0);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[Some(2)],
            labels: Some(&labels),
        };
        let (got, _) = scan.top_m(6, 2);
        assert_eq!(got[0].len(), 6);
        assert!(got[0].iter().all(|&gid| labels[gid as usize] == 2));
    }

    #[test]
    fn empty_and_singleton_tables_are_safe() {
        let blocks = ProxyBlocks::build(&[], 0, 4);
        assert_eq!(blocks.n_blocks(), 0);
        let q = vec![0.5f32; 4];
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, st) = scan.top_m(3, 2);
        assert!(got[0].is_empty());
        assert_eq!(st.rows, 0);

        let table = vec![1.0f32, -2.0, 0.0, 3.0];
        let blocks = ProxyBlocks::build(&table, 1, 4);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, _) = scan.top_m(3, 2);
        assert_eq!(got[0], vec![0]);
    }

    #[test]
    fn block_centroids_cover_their_members() {
        let mut rng = Pcg64::new(13);
        for (rows, dim) in [(1usize, 3usize), (33, 7), (100, 16)] {
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            for b in 0..blocks.n_blocks() {
                let c = blocks.centroid(b);
                let r = blocks.radius(b);
                for lane in 0..blocks.rows_in(b) {
                    let gid = blocks.id(b, lane) as usize;
                    let d: f32 = table[gid * dim..(gid + 1) * dim]
                        .iter()
                        .zip(c)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    assert!(
                        d.sqrt() <= r + 1e-4,
                        "rows={rows} dim={dim} b={b} lane={lane}: {} > {r}",
                        d.sqrt()
                    );
                }
            }
        }
    }

    #[test]
    fn ordered_scan_matches_unordered_scan_exactly() {
        // heap-aware ordering changes the visit pattern, never the result:
        // identical ids AND identical f32 distances for every visit order
        forall(89, 20, |rng| {
            let dim = [3usize, 16, 17, 48][rng.below(4)];
            let rows = gen::usize_in(rng, 1, 140);
            let table = random_table(rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let nq = gen::usize_in(rng, 1, TILE_Q);
            let m = gen::usize_in(rng, 1, rows);
            let qs_data: Vec<Vec<f32>> =
                (0..nq).map(|_| gen::vec_normal(rng, dim, 1.0)).collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let classes = vec![None; nq];
            let scan = KernelScan {
                blocks: &blocks,
                queries: &qs,
                classes: &classes,
                labels: None,
            };
            let (plain, _) = scan.top_m(m, 2);
            // centroid order AND a reversed order must both agree
            let near = block_order(&blocks, qs[0]);
            let far: Vec<u32> = near.iter().rev().copied().collect();
            for order in [&near, &far] {
                let (got, _) = scan.top_m_ordered(m, 2, order);
                for qi in 0..nq {
                    crate::prop_assert!(
                        got[qi] == plain[qi],
                        "rows={rows} dim={dim} qi={qi}: order changed the result"
                    );
                    // rank-by-rank distances bit-identical, not just ids
                    let da: Vec<f32> =
                        got[qi].iter().map(|&g| naive_dist(&table, dim, qs[qi], g)).collect();
                    let db: Vec<f32> =
                        plain[qi].iter().map(|&g| naive_dist(&table, dim, qs[qi], g)).collect();
                    crate::prop_assert!(da == db, "ordered scan changed a distance");
                }
            }
            Ok(())
        });
    }

    fn naive_dist(table: &[f32], dim: usize, q: &[f32], gid: u32) -> f32 {
        table[gid as usize * dim..(gid as usize + 1) * dim]
            .iter()
            .zip(q)
            .map(|(a, b)| (a - b) * (a - b))
            .sum()
    }

    /// Reference refine: exact top-k of a pool (dedup'd), sorted ascending.
    fn naive_refine(table: &[f32], dim: usize, q: &[f32], pool: &[u32], k: usize) -> Vec<u32> {
        let mut distinct: Vec<u32> = pool.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let mut dists: Vec<(f32, u32)> = distinct
            .iter()
            .map(|&gid| (naive_dist(table, dim, q, gid), gid))
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        dists.truncate(k.max(1).min(pool.len().max(1)));
        dists.into_iter().map(|(_, i)| i).collect()
    }

    #[test]
    fn masked_refine_matches_naive_across_ragged_dims_and_pool_edges() {
        // Satellite: pre-blocked refine parity for dims off the strip/lane
        // grid and pool sizes around the powers the masks chunk at —
        // 0/1/63/64/65 — plus duplicate candidate ids (dedup'd like the
        // row-major refine ladder's union mask).
        let mut rng = Pcg64::new(31);
        for &dim in &[1usize, 7, 15, 16, 17, 31, 33, 96] {
            let rows = 130usize;
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            for &pool_len in &[0usize, 1, 63, 64, 65] {
                let nq = 1 + (pool_len % TILE_Q);
                let qs_data: Vec<Vec<f32>> =
                    (0..nq).map(|_| gen::vec_normal(&mut rng, dim, 1.0)).collect();
                let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
                let pools: Vec<Vec<u32>> = (0..nq)
                    .map(|_| {
                        let mut p: Vec<u32> =
                            (0..pool_len).map(|_| rng.below(rows) as u32).collect();
                        if pool_len > 2 {
                            p[1] = p[0]; // force a duplicate id
                        }
                        p
                    })
                    .collect();
                let k = (pool_len / 2).max(1);

                // union mask over the tile's queries
                let mut mask = std::collections::HashMap::new();
                for (qi, pool) in pools.iter().enumerate() {
                    for &gid in pool {
                        *mask.entry(gid).or_insert(0u8) |= 1 << qi;
                    }
                }
                let mut union: Vec<(u32, u8)> = mask.into_iter().collect();
                union.sort_unstable_by_key(|e| e.0);
                let plan = build_refine_plan(&union);
                let mut heaps: Vec<BoundedMaxHeap> = pools
                    .iter()
                    .map(|p| BoundedMaxHeap::new(k.max(1).min(p.len().max(1))))
                    .collect();
                let mut st = KernelStats::default();
                refine_scan_masked(&blocks, &qs, &plan, &mut heaps, &mut st);
                assert_eq!(st.rows, union.len() as u64, "dim={dim} pool={pool_len}");
                for (qi, heap) in heaps.into_iter().enumerate() {
                    let got: Vec<u32> =
                        heap.into_sorted().into_iter().map(|(_, i)| i).collect();
                    let want = if pools[qi].is_empty() {
                        Vec::new()
                    } else {
                        naive_refine(&table, dim, qs[qi], &pools[qi], k)
                    };
                    assert_eq!(got, want, "dim={dim} pool={pool_len} qi={qi}");
                }
            }
        }
    }

    #[test]
    fn masked_refine_early_exits_on_concentrated_pools() {
        // self-query pools with many far rows: the member-lane bound must
        // retire tiles without changing the result
        let mut rng = Pcg64::new(77);
        let (rows, dim) = (128usize, 96usize);
        let table = random_table(&mut rng, rows, dim);
        let blocks = ProxyBlocks::build(&table, rows, dim);
        let q = table[5 * dim..6 * dim].to_vec();
        let pool: Vec<u32> = (0..rows as u32).collect();
        let union: Vec<(u32, u8)> = pool.iter().map(|&gid| (gid, 1u8)).collect();
        let plan = build_refine_plan(&union);
        let queries = [q.as_slice()];
        let mut heaps = vec![BoundedMaxHeap::new(3)];
        let mut st = KernelStats::default();
        refine_scan_masked(&blocks, &queries, &plan, &mut heaps, &mut st);
        let got: Vec<u32> = heaps.remove(0).into_sorted().into_iter().map(|(_, i)| i).collect();
        assert_eq!(got, naive_refine(&table, dim, &q, &pool, 3));
        assert_eq!(got[0], 5);
        assert!(st.strip_exits > 0, "concentrated pool must retire tiles");
        assert!(st.exit_gain_rows > 0, "retirements must bank row gains");
    }

    #[test]
    fn simd_dispatch_is_bit_identical_to_scalar() {
        // the AVX2 lanes perform the same IEEE ops per lane as the scalar
        // loop, so accumulators must match to the bit — on machines
        // without AVX2 this degenerates to scalar vs scalar and still
        // guards the dispatch plumbing. CI sets GOLDDIFF_REQUIRE_SIMD=1
        // on AVX2-capable runners so that degeneration fails loudly there
        // instead of silently skipping the bit-identity check
        if std::env::var("GOLDDIFF_REQUIRE_SIMD").as_deref() == Ok("1") {
            assert!(
                simd::available(),
                "GOLDDIFF_REQUIRE_SIMD=1 but AVX2 is unavailable — SIMD lanes were not exercised"
            );
        }
        let mut rng = Pcg64::new(91);
        for _ in 0..50 {
            let qv = rng.normal() * 10f32.powi(gen::usize_in(&mut rng, 0, 6) as i32 - 3);
            let col: Vec<f32> = (0..BLOCK_ROWS).map(|_| rng.normal()).collect();
            let codes: Vec<i8> = (0..BLOCK_ROWS)
                .map(|_| (rng.below(255) as i32 - 127) as i8)
                .collect();
            let scales: Vec<f32> = (0..BLOCK_ROWS).map(|_| rng.f32() + 0.01).collect();
            let mut a = [0.5f32; BLOCK_ROWS];
            let mut b = [0.5f32; BLOCK_ROWS];
            accum_f32(simd::available(), &mut a, qv, &col);
            accum_f32(false, &mut b, qv, &col);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "f32 lanes diverge from scalar"
            );
            let mut a = [0.25f32; BLOCK_ROWS];
            let mut b = [0.25f32; BLOCK_ROWS];
            accum_i8(simd::available(), &mut a, qv, &codes, &scales);
            accum_i8(false, &mut b, qv, &codes, &scales);
            assert!(
                a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
                "i8 lanes diverge from scalar"
            );
        }
    }

    #[test]
    fn quantise_row_bounds_sandwich_true_distance() {
        // lb ≤ ‖q−x‖ ≤ ub across magnitudes from 1e-6 to 1e6, plus
        // constant and all-zero rows (scale degeneracies)
        let mut rng = Pcg64::new(17);
        for _ in 0..200 {
            let dim = gen::usize_in(&mut rng, 1, 97);
            let mag = 10f32.powi(gen::usize_in(&mut rng, 0, 12) as i32 - 6);
            let row: Vec<f32> = match rng.below(8) {
                0 => vec![0.0; dim],                       // zero row: scale 1, err 0
                1 => vec![mag * rng.normal().signum(); dim], // constant row: err 0
                _ => (0..dim).map(|_| mag * rng.normal()).collect(),
            };
            let mut codes = vec![0i8; dim];
            let (scale, err) = quantise_row(&row, &mut codes);
            assert!(scale > 0.0 && err >= 0.0);
            if row.iter().all(|&v| v == 0.0) {
                assert_eq!(scale, 1.0);
                assert_eq!(err, 0.0);
            }
            if row.iter().all(|&v| v == row[0]) {
                // symmetric grid hits a constant row exactly
                assert!(err <= 1e-3 * row[0].abs().max(1e-30), "constant row err={err}");
            }
            let q: Vec<f32> = (0..dim).map(|_| mag * rng.normal()).collect();
            let true_d: f32 = row
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let dhat: f32 = codes
                .iter()
                .zip(&q)
                .map(|(&c, &b)| {
                    let d = b - scale * c as f32;
                    d * d
                })
                .sum::<f32>()
                .sqrt();
            let lb = (dhat - err).max(0.0);
            let ub = dhat + err;
            // small f32 headroom: the sandwich is exact in reals
            let slack = 1e-4 * (true_d + err + 1e-6);
            assert!(lb <= true_d + slack, "lb={lb} true={true_d} dim={dim} mag={mag}");
            assert!(ub >= true_d - slack, "ub={ub} true={true_d} dim={dim} mag={mag}");
        }
    }

    #[test]
    fn quant_blocks_agree_with_quant_rows() {
        // the blocked twin must carry the exact same codes/scales/errs as
        // the row-major tier — positions through the lane layout
        let mut rng = Pcg64::new(23);
        for (rows, dim) in [(1usize, 3usize), (31, 7), (33, 16), (100, 5)] {
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let qb = QuantBlocks::from_blocks(&blocks);
            let qr = QuantRows::build(&table, rows, dim);
            assert_eq!(qb.n_blocks(), blocks.n_blocks());
            for r in 0..rows {
                let (b, lane) = (r / BLOCK_ROWS, r % BLOCK_ROWS);
                assert_eq!(qb.scales(b)[lane], qr.scale(r), "r={r}");
                assert_eq!(qb.errs(b)[lane], qr.err(r), "r={r}");
                for j in 0..dim {
                    assert_eq!(
                        qb.codes(b)[j * BLOCK_ROWS + lane],
                        qr.codes_row(r)[j],
                        "r={r} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn quant_bound_never_excludes_true_topk() {
        // the refine pre-rung's exclusion rule (lb² > k-th smallest ub²)
        // must keep every true top-k member, across ragged dims, extreme
        // scales and constant rows
        forall(41, 40, |rng| {
            let rows = gen::usize_in(rng, 2, 150);
            let dim = gen::usize_in(rng, 1, 50);
            let mag = 10f32.powi(gen::usize_in(rng, 0, 8) as i32 - 4);
            let mut table = random_table(rng, rows, dim);
            for v in table.iter_mut() {
                *v *= mag;
            }
            if rows > 4 {
                // a few constant rows in the mix
                for r in 0..3 {
                    let c = mag * rng.normal();
                    table[r * dim..(r + 1) * dim].fill(c);
                }
            }
            if rows > 6 {
                // exactly-quantisable degeneracies: an all-zero row and
                // an exact duplicate pair (err == 0 ⇒ lb² == ub², the
                // equality edge the keep-on-`lb ≤ T` rule must survive)
                table[3 * dim..4 * dim].fill(0.0);
                let dup: Vec<f32> = table[4 * dim..5 * dim].to_vec();
                table[5 * dim..6 * dim].copy_from_slice(&dup);
            }
            let qr = QuantRows::build(&table, rows, dim);
            let k = gen::usize_in(rng, 1, rows);
            let q: Vec<f32> = (0..dim).map(|_| mag * rng.normal()).collect();
            let want = naive_top_m(&table, rows, dim, &q, k);

            let mut th = BoundedMaxHeap::new(k);
            let bounds: Vec<(f32, f32)> = (0..rows as u32)
                .map(|gid| {
                    let (lb2, ub2) = qr.bounds2(&q, gid);
                    assert!(lb2 <= ub2);
                    th.push(ub2, gid);
                    (lb2, ub2)
                })
                .collect();
            let t = th.worst();
            for &gid in &want {
                crate::prop_assert!(
                    bounds[gid as usize].0 <= t,
                    "true top-{k} member {gid} excluded: lb2={} > T={t} rows={rows} dim={dim} mag={mag}",
                    bounds[gid as usize].0
                );
            }
            Ok(())
        });
    }

    #[test]
    fn quant_screen_keeps_exactly_quantised_rows() {
        // REVIEW regression: rows with err == 0 (all-zero, constant and
        // exact-duplicate rows quantise exactly) have lb² bit-equal to
        // ub², so with cap = 1 the nearest such row IS the merged
        // threshold — a `>=` survivor refilter self-rejected it,
        // emptying the refine plan and returning nothing at all.
        let dim = 24usize;

        // sharpest form: a 1-row corpus of one exactly-quantisable row
        let one = vec![0.0f32; dim];
        let blocks1 = ProxyBlocks::build(&one, 1, dim);
        let quant1 = QuantBlocks::from_blocks(&blocks1);
        let q1: Vec<&[f32]> = vec![&one[..]];
        let classes1 = vec![None];
        let qscan1 = QuantScan {
            blocks: &blocks1,
            quant: &quant1,
            queries: &q1,
            classes: &classes1,
            labels: None,
        };
        let mut heaps = vec![BoundedMaxHeap::new(1)];
        let mut qst = QuantStats::default();
        let mut kst = KernelStats::default();
        qscan1.screen_into(1, 1, None, &mut heaps, &mut qst, &mut kst);
        let got: Vec<(f32, u32)> = heaps.remove(0).into_sorted();
        assert_eq!(got, vec![(0.0, 0)], "exact-quantisable nearest row self-rejected");

        // mixed corpus: zero row, constant row, an exact duplicate pair
        // straddling blocks, Gaussian filler — queries sit exactly on
        // the err == 0 rows so their own bound is the threshold
        let mut rng = Pcg64::new(71);
        let rows = 3 * BLOCK_ROWS + 5;
        let mut table = random_table(&mut rng, rows, dim);
        table[..dim].fill(0.0);
        table[3 * dim..4 * dim].fill(0.75);
        let dup: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        table[dim..2 * dim].copy_from_slice(&dup);
        let far = (BLOCK_ROWS + 2) * dim;
        table[far..far + dim].copy_from_slice(&dup);

        let blocks = ProxyBlocks::build(&table, rows, dim);
        let quant = QuantBlocks::from_blocks(&blocks);
        let zero = vec![0.0f32; dim];
        let consts = vec![0.75f32; dim];
        let qs: Vec<&[f32]> = vec![&zero[..], &dup[..], &consts[..]];
        let classes = vec![None; qs.len()];
        let f32_scan = KernelScan {
            blocks: &blocks,
            queries: &qs,
            classes: &classes,
            labels: None,
        };
        let qscan = QuantScan {
            blocks: &blocks,
            quant: &quant,
            queries: &qs,
            classes: &classes,
            labels: None,
        };

        // cap = 1 is tie-free per query (first-seen wins among exact
        // duplicates in both paths): compare ids exactly
        let (want1, _) = f32_scan.top_m(1, 1);
        assert_eq!(want1[0], vec![0], "zero query must find the zero row");
        assert_eq!(want1[1], vec![1], "dup query must find the first duplicate");
        assert_eq!(want1[2], vec![3], "const query must find the constant row");

        for cap in [1usize, 2, 5] {
            let (want, _) = f32_scan.top_m(cap, 2);
            for threads in [1usize, 3] {
                let mut heaps: Vec<BoundedMaxHeap> =
                    (0..qs.len()).map(|_| BoundedMaxHeap::new(cap)).collect();
                let mut qst = QuantStats::default();
                let mut kst = KernelStats::default();
                qscan.screen_into(cap, threads, None, &mut heaps, &mut qst, &mut kst);
                let got: Vec<Vec<u32>> = heaps
                    .into_iter()
                    .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
                    .collect();
                for (qi, ids) in got.iter().enumerate() {
                    assert_eq!(
                        ids.len(),
                        cap.min(rows),
                        "cap={cap} threads={threads} qi={qi}: refine plan lost rows"
                    );
                }
                // the duplicate pair ties in distance, so rank order at
                // the tie is heap-shape dependent — compare id *sets*
                // (membership is unambiguous on this corpus)
                let sort = |v: &[Vec<u32>]| -> Vec<Vec<u32>> {
                    v.iter()
                        .map(|ids| {
                            let mut s = ids.clone();
                            s.sort_unstable();
                            s
                        })
                        .collect()
                };
                assert_eq!(sort(&got), sort(&want), "cap={cap} threads={threads}");
                assert_eq!(qst.rows_screened, qst.bound_rejects + qst.rescore_rows);
            }
        }
    }

    #[test]
    fn quant_scan_matches_f32_kernel_byte_for_byte() {
        // end-to-end: int8 screen + exact f32 rescore must reproduce the
        // f32 kernel's ids exactly on tie-free data — unconditional and
        // conditional queries, ordered and natural visit order, 1–2
        // threads, and the telemetry invariant must hold
        let mut rng = Pcg64::new(53);
        for &(rows, dim, nclass) in &[(90usize, 24usize, 0u32), (260, 48, 3), (33, 16, 2)] {
            let table = random_table(&mut rng, rows, dim);
            let labels: Vec<u32> = (0..rows)
                .map(|_| if nclass == 0 { 0 } else { rng.below(nclass as usize) as u32 })
                .collect();
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let quant = QuantBlocks::from_blocks(&blocks);
            let nq = 5usize;
            let qs_data: Vec<Vec<f32>> = (0..nq)
                .map(|_| gen::vec_normal(&mut rng, dim, 1.0))
                .collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let classes: Vec<Option<u32>> = (0..nq)
                .map(|qi| {
                    if nclass > 0 && qi % 2 == 1 {
                        Some((qi as u32) % nclass)
                    } else {
                        None
                    }
                })
                .collect();
            let cap = 9usize;
            let f32_scan = KernelScan {
                blocks: &blocks,
                queries: &qs,
                classes: &classes,
                labels: Some(&labels),
            };
            let (want, _) = f32_scan.top_m(cap, 2);

            let qscan = QuantScan {
                blocks: &blocks,
                quant: &quant,
                queries: &qs,
                classes: &classes,
                labels: Some(&labels),
            };
            let order = block_order(&blocks, qs[0]);
            for threads in [1usize, 2] {
                for ord in [None, Some(order.as_slice())] {
                    let mut heaps: Vec<BoundedMaxHeap> =
                        (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
                    let mut qst = QuantStats::default();
                    let mut kst = KernelStats::default();
                    qscan.screen_into(cap, threads, ord, &mut heaps, &mut qst, &mut kst);
                    let got: Vec<Vec<u32>> = heaps
                        .into_iter()
                        .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
                        .collect();
                    assert_eq!(
                        got, want,
                        "rows={rows} dim={dim} nclass={nclass} threads={threads} ordered={}",
                        ord.is_some()
                    );
                    assert_eq!(
                        qst.rows_screened,
                        qst.bound_rejects + qst.rescore_rows,
                        "telemetry invariant"
                    );
                    assert!(qst.rows_screened > 0);
                    assert!(
                        qst.rescore_rows < qst.rows_screened || rows <= cap,
                        "screen should reject something on rows={rows}"
                    );
                }
            }
        }
    }
}
