//! Register-tiled multi-query distance kernel over a structure-of-arrays
//! proxy-block layout.
//!
//! The PR 1 batched scan amortised *passes* over the proxy table — one
//! traversal per batch group — but the inner loop still walked one `f32` at
//! a time, row-major, and re-derived each query's stride from scratch. This
//! module makes the FLOPs themselves cache- and register-efficient:
//!
//! * [`ProxyBlocks`] transposes the proxy table once at dataset load into
//!   fixed-width row blocks ([`BLOCK_ROWS`] rows each) stored *dim-major*
//!   inside the block, so the values of one dimension for all rows of a
//!   block are contiguous — the shape auto-vectorisers want.
//! * [`KernelScan`] evaluates a [`TILE_Q`]-query × row-block tile per inner
//!   loop: each block column (one dimension, `BLOCK_ROWS` lanes) is loaded
//!   once and broadcast against every query in the group, so the
//!   memory-bandwidth cost of a row is shared by up to 8 queries while the
//!   running distances stay in a 1 KB register/L1 tile.
//! * Between dimension strips ([`STRIP_DIMS`] wide) the kernel checks each
//!   query's best partial distance in the tile against that query's current
//!   worst retained heap distance: partial sums only grow, so when even the
//!   closest row of the block already exceeds the cutoff the whole
//!   (query, block) tile is provably dead and the remaining strips are
//!   skipped — the tile-level generalisation of `scan::sqdist_early_exit`.
//!
//! Exactness: a tile that survives all strips holds full squared distances
//! (each accumulator sums dimensions in index order), and a tile retired
//! early can only drop rows whose distance is already ≥ the heap's worst —
//! the same guarantee the scalar early-exit gives, so kernel and scalar
//! scans retain identical row sets (ties between bit-equal distances are
//! the only divergence surface, as with every backend — see
//! `index/README.md`).
//!
//! The kernel is layout-generic: the whole proxy table (`Dataset`'s
//! resident [`ProxyBlocks`]), an IVF list, or a class-filtered member list
//! all scan through the same code path via the optional row-id map.

use super::topk::BoundedMaxHeap;
use crate::util::threadpool::parallel_chunks;

/// Queries evaluated per register tile (one row-block load is shared by up
/// to this many queries).
pub const TILE_Q: usize = 8;
/// Rows per structure-of-arrays block. 32 rows × 8 queries × 4 B = 1 KB of
/// running accumulators — small enough to live in registers/L1 while one
/// block column streams through.
pub const BLOCK_ROWS: usize = 32;
/// Dimensions accumulated between early-exit checks.
const STRIP_DIMS: usize = 16;

/// The proxy table transposed into fixed-width, dim-major row blocks.
///
/// Block `b` occupies `data[b*dim*BLOCK_ROWS ..]` and stores, for each
/// dimension `j`, the `BLOCK_ROWS` values `data[.. + j*BLOCK_ROWS + lane]`
/// of rows `b*BLOCK_ROWS + lane`. The final block is zero-padded; padded
/// lanes are never harvested. `ids` optionally maps block lanes back to
/// global row ids (IVF lists); `None` means the identity (the whole table).
#[derive(Debug, Clone, Default)]
pub struct ProxyBlocks {
    /// valid rows (excluding padding)
    pub rows: usize,
    /// values per row
    pub dim: usize,
    ids: Option<Vec<u32>>,
    data: Vec<f32>,
}

impl ProxyBlocks {
    /// Block the whole `rows × dim` table with identity row ids.
    pub fn build(table: &[f32], rows: usize, dim: usize) -> ProxyBlocks {
        assert_eq!(table.len(), rows * dim);
        Self::build_inner(table, dim, rows, None)
    }

    /// Block a row subset (e.g. an IVF member list); lane `l` of the result
    /// holds `table` row `ids[l]` and harvests as global id `ids[l]`.
    pub fn build_subset(table: &[f32], dim: usize, ids: &[u32]) -> ProxyBlocks {
        Self::build_inner(table, dim, ids.len(), Some(ids.to_vec()))
    }

    fn build_inner(table: &[f32], dim: usize, rows: usize, ids: Option<Vec<u32>>) -> ProxyBlocks {
        let nb = rows.div_ceil(BLOCK_ROWS);
        let mut data = vec![0.0f32; nb * dim * BLOCK_ROWS];
        for r in 0..rows {
            let src_row = match &ids {
                Some(map) => map[r] as usize,
                None => r,
            };
            let src = &table[src_row * dim..(src_row + 1) * dim];
            let base = (r / BLOCK_ROWS) * dim * BLOCK_ROWS + (r % BLOCK_ROWS);
            for (j, &v) in src.iter().enumerate() {
                data[base + j * BLOCK_ROWS] = v;
            }
        }
        ProxyBlocks {
            rows,
            dim,
            ids,
            data,
        }
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.rows.div_ceil(BLOCK_ROWS)
    }

    /// The dim-major slice of block `b` (`dim * BLOCK_ROWS` values).
    #[inline]
    pub fn block(&self, b: usize) -> &[f32] {
        let w = self.dim * BLOCK_ROWS;
        &self.data[b * w..(b + 1) * w]
    }

    /// Valid (non-padding) rows in block `b`.
    #[inline]
    pub fn rows_in(&self, b: usize) -> usize {
        (self.rows - b * BLOCK_ROWS).min(BLOCK_ROWS)
    }

    /// Global row id of lane `lane` in block `b`.
    #[inline]
    pub fn id(&self, b: usize, lane: usize) -> u32 {
        let r = b * BLOCK_ROWS + lane;
        match &self.ids {
            Some(map) => map[r],
            None => r as u32,
        }
    }

    /// Resident bytes of the blocked copy (telemetry / working-set math).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }
}

/// Cumulative kernel counters for one scan (merged across shards).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KernelStats {
    /// (query-group × block) tiles evaluated
    pub tiles: u64,
    /// valid rows whose distances were produced (padding excluded)
    pub rows: u64,
    /// (query, block) pairs retired by the strip early-exit bound
    pub strip_exits: u64,
}

impl KernelStats {
    pub fn add(&mut self, other: &KernelStats) {
        self.tiles += other.tiles;
        self.rows += other.rows;
        self.strip_exits += other.strip_exits;
    }
}

/// One tiled scan: a group of ≤ [`TILE_Q`] queries against a block table.
///
/// `classes[qi]` restricts query `qi` to rows whose `labels[gid]` matches —
/// the distance is still computed tile-wide (the row load is shared), the
/// filter applies at harvest. Pass `labels: None` when the blocks are
/// already class-filtered (per-class IVF lists) or every query is
/// unconditional.
pub struct KernelScan<'a> {
    pub blocks: &'a ProxyBlocks,
    pub queries: &'a [&'a [f32]],
    pub classes: &'a [Option<u32>],
    pub labels: Option<&'a [u32]>,
}

impl KernelScan<'_> {
    /// Scan blocks `[b0, b1)` pushing exact squared distances into one
    /// bounded heap per query. The heaps' current worst retained distances
    /// drive the per-tile early-exit bound.
    pub fn scan_into(
        &self,
        b0: usize,
        b1: usize,
        heaps: &mut [BoundedMaxHeap],
        stats: &mut KernelStats,
    ) {
        let nq = self.queries.len();
        assert!(nq > 0 && nq <= TILE_Q, "query group of {nq} exceeds TILE_Q");
        assert_eq!(nq, heaps.len());
        assert_eq!(nq, self.classes.len());
        let dim = self.blocks.dim;
        debug_assert!(self.queries.iter().all(|q| q.len() == dim));

        for b in b0..b1 {
            let rows = self.blocks.rows_in(b);
            let data = self.blocks.block(b);
            let mut acc = [[0.0f32; BLOCK_ROWS]; TILE_Q];
            let mut alive = [false; TILE_Q];
            alive[..nq].fill(true);
            let mut n_alive = nq;

            let mut j = 0;
            while j < dim {
                let jend = (j + STRIP_DIMS).min(dim);
                for jj in j..jend {
                    let col = &data[jj * BLOCK_ROWS..(jj + 1) * BLOCK_ROWS];
                    for (qi, q) in self.queries.iter().enumerate() {
                        if !alive[qi] {
                            continue;
                        }
                        let qv = q[jj];
                        // one column load serves every live query: the
                        // lane loop is contiguous and branch-free, so it
                        // vectorises across the block's rows
                        for (a, &v) in acc[qi].iter_mut().zip(col) {
                            let d = qv - v;
                            *a += d * d;
                        }
                    }
                }
                j = jend;
                if j >= dim {
                    break;
                }
                // partial sums only grow: once even the nearest row of the
                // tile exceeds a query's worst retained distance, no row of
                // this block can enter that query's heap
                for qi in 0..nq {
                    if !alive[qi] {
                        continue;
                    }
                    let cutoff = heaps[qi].worst();
                    if !cutoff.is_finite() {
                        continue;
                    }
                    let best = acc[qi][..rows]
                        .iter()
                        .fold(f32::INFINITY, |m, &v| m.min(v));
                    if best >= cutoff {
                        alive[qi] = false;
                        n_alive -= 1;
                        stats.strip_exits += 1;
                    }
                }
                if n_alive == 0 {
                    break;
                }
            }
            stats.tiles += 1;
            stats.rows += rows as u64;

            // harvest: only queries that survived every strip hold full
            // distances; retired queries provably gain nothing here
            for qi in 0..nq {
                if !alive[qi] {
                    continue;
                }
                let heap = &mut heaps[qi];
                let class = self.classes[qi];
                for (lane, &d) in acc[qi][..rows].iter().enumerate() {
                    let gid = self.blocks.id(b, lane);
                    if let (Some(y), Some(labels)) = (class, self.labels) {
                        if labels[gid as usize] != y {
                            continue;
                        }
                    }
                    heap.push(d, gid);
                }
            }
        }
    }

    /// Full scan of the block table sharded over `threads`: per-shard heaps
    /// of capacity `cap` merged in shard order (the same merge discipline
    /// the scalar backends use). Returns ids sorted ascending by distance
    /// per query, plus the merged kernel counters.
    pub fn top_m(&self, cap: usize, threads: usize) -> (Vec<Vec<u32>>, KernelStats) {
        let nq = self.queries.len();
        let cap = cap.max(1);
        let nb = self.blocks.n_blocks();
        let shards = parallel_chunks(nb, threads.max(1), |_, s, e| {
            let mut heaps: Vec<BoundedMaxHeap> = (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
            let mut st = KernelStats::default();
            self.scan_into(s, e, &mut heaps, &mut st);
            (heaps, st)
        });
        let mut merged: Vec<BoundedMaxHeap> = (0..nq).map(|_| BoundedMaxHeap::new(cap)).collect();
        let mut stats = KernelStats::default();
        for (heaps, st) in shards {
            stats.add(&st);
            for (m, h) in merged.iter_mut().zip(heaps) {
                m.merge(h);
            }
        }
        (
            merged
                .into_iter()
                .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
                .collect(),
            stats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Pcg64;

    /// Sequential-scalar reference top-m (the naive oracle).
    fn naive_top_m(table: &[f32], rows: usize, dim: usize, q: &[f32], m: usize) -> Vec<u32> {
        let mut dists: Vec<(f32, u32)> = (0..rows)
            .map(|i| {
                let d: f32 = table[i * dim..(i + 1) * dim]
                    .iter()
                    .zip(q)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                (d, i as u32)
            })
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        dists.truncate(m.min(rows));
        dists.into_iter().map(|(_, i)| i).collect()
    }

    fn random_table(rng: &mut Pcg64, rows: usize, dim: usize) -> Vec<f32> {
        (0..rows * dim).map(|_| rng.normal()).collect()
    }

    #[test]
    fn blocks_layout_roundtrips_every_cell() {
        let mut rng = Pcg64::new(3);
        for (rows, dim) in [(1usize, 1usize), (31, 7), (32, 16), (33, 16), (100, 5)] {
            let table = random_table(&mut rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            assert_eq!(blocks.n_blocks(), rows.div_ceil(BLOCK_ROWS));
            for r in 0..rows {
                let (b, lane) = (r / BLOCK_ROWS, r % BLOCK_ROWS);
                assert_eq!(blocks.id(b, lane), r as u32);
                for j in 0..dim {
                    assert_eq!(
                        blocks.block(b)[j * BLOCK_ROWS + lane],
                        table[r * dim + j],
                        "rows={rows} dim={dim} r={r} j={j}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_matches_naive_across_ragged_dims_and_rows() {
        // Satellite: parity across proxy dims that are and are not
        // multiples of the strip/lane width, and row counts that do and do
        // not fill the last block.
        forall(71, 40, |rng| {
            let dim = [1usize, 7, 15, 16, 17, 31, 32, 33, 48, 100][rng.below(10)];
            let rows = [1usize, 2, 31, 32, 33, 64, 97][rng.below(7)];
            let table = random_table(rng, rows, dim);
            let blocks = ProxyBlocks::build(&table, rows, dim);
            let nq = gen::usize_in(rng, 1, TILE_Q);
            let m = gen::usize_in(rng, 1, rows + 2);
            let qs_data: Vec<Vec<f32>> = (0..nq).map(|_| gen::vec_normal(rng, dim, 1.0)).collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let classes = vec![None; nq];
            let scan = KernelScan {
                blocks: &blocks,
                queries: &qs,
                classes: &classes,
                labels: None,
            };
            let (got, st) = scan.top_m(m.min(rows).max(1), 2);
            crate::prop_assert!(st.rows >= rows as u64, "row accounting");
            for (qi, q) in qs.iter().enumerate() {
                let want = naive_top_m(&table, rows, dim, q, m);
                crate::prop_assert!(
                    got[qi] == want,
                    "dim={dim} rows={rows} nq={nq} m={m} qi={qi}: {:?} vs {:?}",
                    got[qi],
                    want
                );
            }
            Ok(())
        });
    }

    #[test]
    fn strip_early_exit_preserves_exactness_on_self_queries() {
        // self-queries make heap cutoffs tiny after the home block, so most
        // tiles retire early — results must still equal the naive scan
        let mut rng = Pcg64::new(9);
        let (rows, dim) = (200usize, 96usize); // several strips per block
        let table = random_table(&mut rng, rows, dim);
        let blocks = ProxyBlocks::build(&table, rows, dim);
        for r in [0usize, 57, 199] {
            let q = &table[r * dim..(r + 1) * dim];
            let queries = [q];
            let scan = KernelScan {
                blocks: &blocks,
                queries: &queries,
                classes: &[None],
                labels: None,
            };
            let (got, st) = scan.top_m(3, 1);
            assert_eq!(got[0], naive_top_m(&table, rows, dim, q, 3));
            assert_eq!(got[0][0], r as u32);
            assert!(st.strip_exits > 0, "self-query must retire tiles early");
        }
    }

    #[test]
    fn subset_blocks_map_lanes_to_global_ids() {
        let mut rng = Pcg64::new(5);
        let (rows, dim) = (90usize, 24usize);
        let table = random_table(&mut rng, rows, dim);
        let ids: Vec<u32> = (0..rows as u32).filter(|i| i % 3 == 0).collect();
        let blocks = ProxyBlocks::build_subset(&table, dim, &ids);
        assert_eq!(blocks.rows, ids.len());
        let q = gen::vec_normal(&mut rng, dim, 1.0);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, _) = scan.top_m(5, 1);
        // naive over the subset only
        let mut dists: Vec<(f32, u32)> = ids
            .iter()
            .map(|&gid| {
                let row = &table[gid as usize * dim..(gid as usize + 1) * dim];
                let d: f32 = row.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum();
                (d, gid)
            })
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        let want: Vec<u32> = dists.into_iter().take(5).map(|(_, i)| i).collect();
        assert_eq!(got[0], want);
    }

    #[test]
    fn conditional_harvest_filters_by_label() {
        let mut rng = Pcg64::new(7);
        let (rows, dim) = (64usize, 8usize);
        let table = random_table(&mut rng, rows, dim);
        let labels: Vec<u32> = (0..rows as u32).map(|i| i % 4).collect();
        let blocks = ProxyBlocks::build(&table, rows, dim);
        let q = gen::vec_normal(&mut rng, dim, 1.0);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[Some(2)],
            labels: Some(&labels),
        };
        let (got, _) = scan.top_m(6, 2);
        assert_eq!(got[0].len(), 6);
        assert!(got[0].iter().all(|&gid| labels[gid as usize] == 2));
    }

    #[test]
    fn empty_and_singleton_tables_are_safe() {
        let blocks = ProxyBlocks::build(&[], 0, 4);
        assert_eq!(blocks.n_blocks(), 0);
        let q = vec![0.5f32; 4];
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, st) = scan.top_m(3, 2);
        assert!(got[0].is_empty());
        assert_eq!(st.rows, 0);

        let table = vec![1.0f32, -2.0, 0.0, 3.0];
        let blocks = ProxyBlocks::build(&table, 1, 4);
        let queries = [q.as_slice()];
        let scan = KernelScan {
            blocks: &blocks,
            queries: &queries,
            classes: &[None],
            labels: None,
        };
        let (got, _) = scan.top_m(3, 2);
        assert_eq!(got[0], vec![0]);
    }
}
