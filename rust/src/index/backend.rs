//! Pluggable retrieval backends behind one `RetrievalBackend` contract.
//!
//! The coarse half of Adaptive Coarse Screening (Sec. 3.4, Eq. 4) used to be
//! a single hard-wired flat scan that ran once per query — B live sequences
//! in one engine tick paid B full passes over the proxy table. This module
//! turns the retrieval step into a trait with three implementations:
//!
//! * [`FlatScan`] — the original sharded scan, extracted behind the trait.
//!   `FlatScan::scalar` keeps the seed `ProxyIndex` semantics bit-stable;
//!   the default constructor routes through the tiled kernel.
//! * [`BatchedScan`] — a multi-query scan that makes **one** pass over the
//!   proxy table for a whole batch group, keeping one bounded heap per
//!   query. Since the kernel refactor the pass itself runs through
//!   [`kernel::KernelScan`]: the proxy table lives in a structure-of-arrays
//!   block layout and every row-block load is shared by a register tile of
//!   up to [`kernel::TILE_Q`] queries, so the scan is FLOP-efficient as
//!   well as pass-efficient.
//! * [`ClusterPruned`] — an IVF-style backend: k-means over the proxy table
//!   (reused from a persisted [`IvfPartition`] when the `.gds` store has a
//!   matching one) at build time, then per-query pruning of whole clusters
//!   via the exact triangle-inequality lower bound `d(q, x) ≥ d(q, c) −
//!   r_c`. Member lists are kept **per class** as pre-blocked kernel
//!   tables, so conditional scans probe class-filtered lists (with the
//!   tighter per-class radius bound) instead of filtering labels
//!   row-by-row. With `nprobe == 0` results are *exact* (identical to
//!   `FlatScan` up to distance ties); `nprobe > 0` is the approximate
//!   fallback that scans only the nprobe nearest lists.
//!
//! All backends share the exact full-resolution refine (Eq. 5). Groups go
//! through [`RetrievalBackend::refine_top_k_batch`] — the batched refine
//! ladder: the union of the group's candidate pools is scanned once, each
//! full-resolution row is loaded once and scored against every query whose
//! pool contains it, and one bounded heap per query collects the top-k. By
//! default the ladder runs **pre-blocked** ([`batched_refine_kernel`]):
//! candidate blocks of the dataset's resident `row_blocks` stream through
//! the masked register-tile kernel (`kernel::refine_scan_masked`), with the
//! row-major union scan ([`batched_refine`]) kept as the bit-stable
//! reference behind `refine_kernel = false`. The batched and cluster scans
//! also visit proxy blocks in **heap-aware order** (ascending block-centroid
//! distance to the query group) so early-exit bounds tighten early; the
//! `ordering` knob falls back to storage order. Backends expose atomic
//! telemetry counters (`proxy_passes`, `rows_scanned`, `tiles_evaluated`,
//! `clusters_pruned`, `blocks_reordered`, `exit_gain_rows`, …) that the
//! engine's stats and the perf benches scrape. See `index/README.md` for
//! when each backend wins.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::kernel::{
    self, block_order, build_refine_plan, refine_scan_masked, KernelScan, KernelStats,
    ProxyBlocks, QuantScan, QuantStats, RowBlocks,
};
use super::scan::ProxyIndex;
use super::topk::BoundedMaxHeap;
use crate::data::dataset::{Dataset, IvfPartition};
use crate::data::shard::ShardPlan;
use crate::util::threadpool::parallel_chunks;

/// One coarse query of a batch: the s=1/4 proxy embedding plus the optional
/// conditional class restriction.
#[derive(Debug, Clone)]
pub struct ProxyQuery<'a> {
    pub proxy: &'a [f32],
    pub class: Option<u32>,
}

/// Snapshot of a backend's cumulative retrieval telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetrievalStats {
    /// full traversals of the proxy table (a batched scan counts one pass
    /// for the whole group; cluster-pruned scans never do a full pass)
    pub proxy_passes: u64,
    /// individual coarse queries answered
    pub queries: u64,
    /// proxy rows whose distances were evaluated across all queries
    pub rows_scanned: u64,
    /// clusters scanned (ClusterPruned only)
    pub clusters_scanned: u64,
    /// clusters skipped via the centroid lower bound or nprobe cap
    pub clusters_pruned: u64,
    /// (query-group × row-block) tiles the kernel evaluated
    pub tiles_evaluated: u64,
    /// (query, block) tiles retired early by the strip bound
    pub kernel_exits: u64,
    /// full-resolution rows visited by the batched refine ladder
    pub refine_rows: u64,
    /// blocks visited out of storage order by heap-aware scan ordering
    pub blocks_reordered: u64,
    /// (query, row) distance evaluations the strip exits cut short — the
    /// work the ordering exists to grow
    pub exit_gain_rows: u64,
    /// (query, shard) coarse scans executed (sharded backend only; for a
    /// cold sharded screen `shards_scanned + shards_skipped` equals
    /// `queries × shard count`)
    pub shards_scanned: u64,
    /// (query, shard) scans avoided outright — class-absent shards and
    /// whole shards cleared by the warm-start centroid bound
    pub shards_skipped: u64,
    /// cold-shard `RowBlocks` evicted by the corpus LRU under `mem_budget`
    pub shard_evictions: u64,
    /// full-resolution rows read off the `.gds` store (streamed serving;
    /// 0 for a resident corpus)
    pub rows_streamed: u64,
    /// high-water mark of resident row-block bytes under the LRU budget
    pub peak_row_bytes: u64,
    /// class-eligible rows whose distance bounds ran on the int8 tier
    /// (quant screens + refine pre-rungs; 0 with `quant` off)
    pub quant_rows_screened: u64,
    /// quant-screened rows the sound bound could not exclude — re-scored
    /// exactly on f32 (`quant_rows_screened = rescore_rows + bound_rejects`)
    pub rescore_rows: u64,
    /// quant-screened rows excluded by the lower bound without touching
    /// f32 data — the quantised tier's saved work
    pub bound_rejects: u64,
    /// transient streamed-read failures recovered by the bounded retry
    /// (0 for a resident corpus)
    pub retries: u64,
    /// shard checksum mismatches observed on streamed reads (each retried;
    /// persistent corruption fails the request instead of serving rows)
    pub checksum_failures: u64,
    /// faults the deterministic injector put into streamed reads (0
    /// without `GOLDDIFF_FAULT_RATE` or a test-wired injector)
    pub faults_injected: u64,
    /// retrieval ops answered by remote shard workers (0 for the
    /// in-process backends)
    pub remote_ops: u64,
    /// worker round-trips retried after a transient failure (each op
    /// retries with backoff before its worker is declared lost)
    pub remote_retries: u64,
    /// workers whose retry budget was exhausted — the remote tier stood
    /// down to the in-process path (or failed the op, with fallback off)
    pub workers_lost: u64,
    /// sequence-ticks served closed-form by the Gaussian moment tier.
    /// Engine-folded: the backend never sees a Gaussian tick, so backend
    /// snapshots always report 0 and `EngineStats::record_backend` must
    /// not overwrite the folded value.
    pub gauss_ticks: u64,
    /// coarse screens (with their refines) the Gaussian tier made
    /// unnecessary — engine-folded, like `gauss_ticks`
    pub screens_skipped: u64,
    /// corrector score evaluations run by a higher-order solver
    /// (`sampler::Solver::{Heun, Dpm2}`) — engine-folded, like
    /// `gauss_ticks`
    pub corrector_refines: u64,
    /// corrector evaluations that re-used the predictor tick's stashed
    /// golden-subset union instead of paying a second coarse screen —
    /// engine-folded
    pub screens_reused: u64,
    /// sequence-ticks executed under a budgeted step plan
    /// (`schedule::steps::StepPlan`); 0 when every grid point is placed —
    /// engine-folded
    pub ticks_placed: u64,
}

#[derive(Debug, Default)]
pub(crate) struct Counters {
    pub(crate) proxy_passes: AtomicU64,
    pub(crate) queries: AtomicU64,
    pub(crate) rows_scanned: AtomicU64,
    pub(crate) clusters_scanned: AtomicU64,
    pub(crate) clusters_pruned: AtomicU64,
    pub(crate) tiles_evaluated: AtomicU64,
    pub(crate) kernel_exits: AtomicU64,
    pub(crate) refine_rows: AtomicU64,
    pub(crate) blocks_reordered: AtomicU64,
    pub(crate) exit_gain_rows: AtomicU64,
    pub(crate) shards_scanned: AtomicU64,
    pub(crate) shards_skipped: AtomicU64,
    pub(crate) quant_rows_screened: AtomicU64,
    pub(crate) rescore_rows: AtomicU64,
    pub(crate) bound_rejects: AtomicU64,
}

impl Counters {
    pub(crate) fn snapshot(&self) -> RetrievalStats {
        RetrievalStats {
            proxy_passes: self.proxy_passes.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            rows_scanned: self.rows_scanned.load(Ordering::Relaxed),
            clusters_scanned: self.clusters_scanned.load(Ordering::Relaxed),
            clusters_pruned: self.clusters_pruned.load(Ordering::Relaxed),
            tiles_evaluated: self.tiles_evaluated.load(Ordering::Relaxed),
            kernel_exits: self.kernel_exits.load(Ordering::Relaxed),
            refine_rows: self.refine_rows.load(Ordering::Relaxed),
            blocks_reordered: self.blocks_reordered.load(Ordering::Relaxed),
            exit_gain_rows: self.exit_gain_rows.load(Ordering::Relaxed),
            shards_scanned: self.shards_scanned.load(Ordering::Relaxed),
            shards_skipped: self.shards_skipped.load(Ordering::Relaxed),
            shard_evictions: 0,
            rows_streamed: 0,
            peak_row_bytes: 0,
            retries: 0,
            checksum_failures: 0,
            faults_injected: 0,
            remote_ops: 0,
            remote_retries: 0,
            workers_lost: 0,
            gauss_ticks: 0,
            screens_skipped: 0,
            corrector_refines: 0,
            screens_reused: 0,
            ticks_placed: 0,
            quant_rows_screened: self.quant_rows_screened.load(Ordering::Relaxed),
            rescore_rows: self.rescore_rows.load(Ordering::Relaxed),
            bound_rejects: self.bound_rejects.load(Ordering::Relaxed),
        }
    }

    /// Record a quantised-tier pass (coarse screen or refine pre-rung).
    pub(crate) fn record_quant(&self, st: &QuantStats) {
        self.quant_rows_screened
            .fetch_add(st.rows_screened, Ordering::Relaxed);
        self.rescore_rows.fetch_add(st.rescore_rows, Ordering::Relaxed);
        self.bound_rejects.fetch_add(st.bound_rejects, Ordering::Relaxed);
    }

    pub(crate) fn record_kernel(&self, st: &KernelStats) {
        self.rows_scanned.fetch_add(st.rows, Ordering::Relaxed);
        self.tiles_evaluated.fetch_add(st.tiles, Ordering::Relaxed);
        self.kernel_exits.fetch_add(st.strip_exits, Ordering::Relaxed);
        self.exit_gain_rows.fetch_add(st.exit_gain_rows, Ordering::Relaxed);
    }

    /// Record a kernel refine-ladder pass: `refine_rows` keeps its distinct
    /// full-resolution row semantics; `rows_scanned` stays proxy-only.
    pub(crate) fn record_refine(&self, rows: u64, st: &KernelStats) {
        self.refine_rows.fetch_add(rows, Ordering::Relaxed);
        self.tiles_evaluated.fetch_add(st.tiles, Ordering::Relaxed);
        self.kernel_exits.fetch_add(st.strip_exits, Ordering::Relaxed);
        self.exit_gain_rows.fetch_add(st.exit_gain_rows, Ordering::Relaxed);
    }

    /// Record a heap-aware visit order: blocks whose visit position moved.
    pub(crate) fn record_order(&self, order: &[u32]) {
        self.blocks_reordered
            .fetch_add(moved_blocks(order), Ordering::Relaxed);
    }

    pub(crate) fn reset(&self) {
        self.proxy_passes.store(0, Ordering::Relaxed);
        self.queries.store(0, Ordering::Relaxed);
        self.rows_scanned.store(0, Ordering::Relaxed);
        self.clusters_scanned.store(0, Ordering::Relaxed);
        self.clusters_pruned.store(0, Ordering::Relaxed);
        self.tiles_evaluated.store(0, Ordering::Relaxed);
        self.kernel_exits.store(0, Ordering::Relaxed);
        self.refine_rows.store(0, Ordering::Relaxed);
        self.blocks_reordered.store(0, Ordering::Relaxed);
        self.exit_gain_rows.store(0, Ordering::Relaxed);
        self.shards_scanned.store(0, Ordering::Relaxed);
        self.shards_skipped.store(0, Ordering::Relaxed);
        self.quant_rows_screened.store(0, Ordering::Relaxed);
        self.rescore_rows.store(0, Ordering::Relaxed);
        self.bound_rejects.store(0, Ordering::Relaxed);
    }
}

/// The retrieval contract every backend implements. Coarse top-m produces
/// the candidate pool C_t; the exact refine produces the golden subset S_t.
///
/// `Send + Sync` so one backend instance can be shared by the engine's
/// denoisers and scraped for telemetry from other threads.
pub trait RetrievalBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Coarse top-m over the proxy table for a single query. Returns row
    /// ids sorted ascending by proxy distance; class-conditional queries
    /// only see rows of that class.
    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32>;

    /// Coarse top-m for a whole batch group sharing one budget `m`. The
    /// default loops `top_m`; `BatchedScan` overrides it with a one-pass
    /// tiled traversal.
    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        queries
            .iter()
            .map(|q| self.top_m(ds, q.proxy, m, q.class))
            .collect()
    }

    /// Does this backend's coarse screen return the *exact* top-m (every
    /// default does)? `ClusterPruned` with `nprobe > 0` is the approximate
    /// exception. Exactness-preserving shortcuts elsewhere (the warm-start
    /// screen) must not engage over an approximate backend — an exact
    /// result would *differ* from the backend's own.
    fn is_exact(&self) -> bool {
        true
    }

    /// Exact full-resolution top-k inside a candidate pool (Eq. 5). Shared
    /// CPU reference used by every backend.
    ///
    /// Candidate pools are expected to hold distinct row ids (coarse
    /// `top_m` output always does). On duplicate ids the paths differ by
    /// construction: the row-major reference scores each occurrence, while
    /// the ladder/kernel paths collapse duplicates via their membership
    /// masks.
    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        exact_refine(ds, q, cands, k, crate::util::threadpool::default_threads())
    }

    /// Exact refine for a whole tick group: each query keeps its own
    /// candidate pool and budget `k`. The default loops `refine_top_k`;
    /// the batched backends override it with the union-scan refine ladder
    /// ([`batched_refine`]) so each full-resolution row is loaded once per
    /// group instead of once per query.
    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        qs.iter()
            .zip(pools)
            .map(|(q, pool)| self.refine_top_k(ds, q, pool, k))
            .collect()
    }

    /// The seeded exact coarse screen (concentration warm-start): fill a
    /// top-m heap from `seeds` (sorted distinct row ids), then sweep the
    /// proxy blocks nearest-centroid-first, skipping every block whose
    /// exact lower bound `(d(q, c_b) − r_b)²` already exceeds the heap's
    /// worst retained distance. Returns `None` when the class-eligible
    /// seeds cannot fill the heap (the sufficiency precondition for the
    /// bound to engage) — callers fall back to the cold screen.
    ///
    /// Only sound over backends whose own screen is exact
    /// ([`RetrievalBackend::is_exact`]); callers gate on that. The default
    /// sweeps the dataset's global [`ProxyBlocks`]; the sharded backend
    /// overrides it with a shard-local sweep that skips whole shards via
    /// per-shard centroid bounds.
    fn warm_top_m(
        &self,
        ds: &Dataset,
        query_proxy: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
    ) -> Option<Vec<u32>> {
        warm_screen_global(ds, query_proxy, class, m, seeds)
    }

    /// Cumulative telemetry since construction (or the last reset).
    fn stats(&self) -> RetrievalStats;

    /// Zero the telemetry counters (bench harness hook).
    fn reset_stats(&self);

    /// Budget hint for the next retrieval op: the tightest remaining
    /// request deadline in the tick group, or `None` when nothing in the
    /// group carries one. In-process backends ignore it (a local scan
    /// cannot be abandoned mid-flight without losing exactness); the
    /// remote tier forwards it so a worker can refuse an op whose
    /// requester has already expired instead of burning the scan.
    fn set_deadline(&self, _remaining_ms: Option<u64>) {}
}

// ---------------------------------------------------------------------------
// Warm-start screen (shared by the default backends; `index::shard` overrides
// with the shard-local sweep)
// ---------------------------------------------------------------------------

/// Blocks whose visit position moved under a heap-aware order — the one
/// definition of the `blocks_reordered` metric shared by the monolithic
/// and sharded backends.
pub(crate) fn moved_blocks(order: &[u32]) -> u64 {
    order
        .iter()
        .enumerate()
        .filter(|&(i, &b)| i as u32 != b)
        .count() as u64
}

/// The seed pass of a warm screen: score every class-eligible seed row into
/// a fresh heap of capacity `cap`. Returns `None` when the eligible seeds
/// cannot fill the heap — the bound below would never engage, so the caller
/// should run the cold screen instead.
pub(crate) fn warm_seed_heap(
    ds: &Dataset,
    qp: &[f32],
    class: Option<u32>,
    cap: usize,
    seeds: &[u32],
) -> Option<BoundedMaxHeap> {
    let mut heap = BoundedMaxHeap::new(cap);
    let mut eligible = 0usize;
    for &gid in seeds {
        if let Some(y) = class {
            if ds.labels[gid as usize] != y {
                continue;
            }
        }
        eligible += 1;
        heap.push(
            super::scan::sqdist_flat(qp, ds.proxy_row(gid as usize)),
            gid,
        );
    }
    (eligible >= cap).then_some(heap)
}

/// The block sweep of a seeded screen: visit `pb`'s blocks in ascending
/// centroid distance to the query (ties by block id, like
/// [`kernel::block_order`]), skip every block whose exact lower bound
/// `(d(q, c_b) − r_b)²` clears the heap's *current* worst — which only
/// tightens as near blocks land — and score surviving rows (seed rows
/// skipped, classes filtered). One definition of the sweep, shared by the
/// global warm screen and the sharded backend's per-shard sweeps so the
/// two can never silently diverge.
pub(crate) fn warm_sweep_blocks(
    ds: &Dataset,
    pb: &ProxyBlocks,
    qp: &[f32],
    class: Option<u32>,
    seeds: &[u32],
    heap: &mut BoundedMaxHeap,
) {
    let mut order: Vec<(f32, u32)> = (0..pb.n_blocks())
        .map(|b| {
            let c = pb.centroid(b);
            let d2: f32 = c.iter().zip(qp).map(|(a, b)| (a - b) * (a - b)).sum();
            (d2, b as u32)
        })
        .collect();
    order.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    for &(d2, b) in &order {
        let b = b as usize;
        let lb = (d2.sqrt() - pb.radius(b)).max(0.0);
        if lb * lb >= heap.worst() {
            // every member row is provably ≥ the worst retained distance
            continue;
        }
        for lane in 0..pb.rows_in(b) {
            let gid = pb.id(b, lane);
            if seeds.binary_search(&gid).is_ok() {
                continue; // already scored in the seed pass
            }
            if let Some(y) = class {
                if ds.labels[gid as usize] != y {
                    continue;
                }
            }
            let d = super::scan::sqdist_early_exit(qp, ds.proxy_row(gid as usize), heap.worst());
            if d.is_finite() {
                heap.push(d, gid);
            }
        }
    }
}

/// One seeded screen over the dataset's global proxy blocks (the
/// [`RetrievalBackend::warm_top_m`] default). Returns `None` when the
/// class-eligible seeds cannot fill the heap.
pub fn warm_screen_global(
    ds: &Dataset,
    qp: &[f32],
    class: Option<u32>,
    m: usize,
    seeds: &[u32],
) -> Option<Vec<u32>> {
    let cap = m.max(1).min(ds.n.max(1));
    let mut heap = warm_seed_heap(ds, qp, class, cap, seeds)?;
    warm_sweep_blocks(ds, &ds.proxy_blocks, qp, class, seeds, &mut heap);
    Some(heap.into_sorted().into_iter().map(|(_, i)| i).collect())
}

/// Exact top-k of ||q − x_i||² over `cands`, sorted ascending — the
/// row-major reference refine (same algorithm as `ProxyIndex::refine_top_k`;
/// the `refine_kernel = false` knob and the parity property tests pin the
/// backends to this path).
pub fn exact_refine(ds: &Dataset, q: &[f32], cands: &[u32], k: usize, threads: usize) -> Vec<u32> {
    ProxyIndex { threads }.refine_top_k(ds, q, cands, k)
}

/// [`exact_refine`] through the pre-blocked kernel: a one-query masked tile
/// scan of `Dataset::row_blocks`. Duplicate candidate ids collapse via the
/// membership mask (exactly like the refine ladders); `exact_refine` scores
/// a duplicate once per occurrence instead, so hand it distinct pools when
/// comparing the two — coarse `top_m` output always is.
pub fn exact_refine_kernel(
    ds: &Dataset,
    q: &[f32],
    cands: &[u32],
    k: usize,
    threads: usize,
) -> Vec<u32> {
    let (mut out, _, _) = batched_refine_kernel(ds, &[q], &[cands], k, threads);
    out.pop().unwrap_or_default()
}

// ---------------------------------------------------------------------------
// Batched refine ladder
// ---------------------------------------------------------------------------

/// Exact batched refine: scan the union of the group's candidate pools
/// once, scoring each full-resolution row against every query whose pool
/// contains it (queries are chunked into ≤64-wide membership masks). Each
/// query's result is identical to a per-query [`exact_refine`] over its own
/// pool — only the row visit order differs, so exact f32 distance ties are
/// the sole divergence surface, as everywhere else in `index`. Pools must
/// hold distinct row ids (coarse `top_m` output always does).
///
/// Returns the per-query top-k lists plus the number of distinct
/// full-resolution rows visited (the refine ladder's bandwidth telemetry).
pub fn batched_refine(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, u64) {
    assert_eq!(qs.len(), pools.len());
    let mut out = Vec::with_capacity(qs.len());
    let mut rows_visited = 0u64;
    for (qc, pc) in qs.chunks(64).zip(pools.chunks(64)) {
        let (res, rows) = batched_refine_group(ds, qc, pc, k, threads);
        out.extend(
            res.into_iter()
                .map(|l| l.into_iter().map(|(_, i)| i).collect::<Vec<u32>>()),
        );
        rows_visited += rows;
    }
    (out, rows_visited)
}

/// [`batched_refine`] keeping each survivor's exact f32 distance, each
/// list canonicalised to ascending `(distance, row id)` — the form a shard
/// worker ships so the coordinator's merge is deterministic regardless of
/// heap order. Same row sets as [`batched_refine`]; only the order of
/// exact-tie distances can differ (the id tiebreak vs heap order).
pub(crate) fn batched_refine_scored(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<(f32, u32)>>, u64) {
    assert_eq!(qs.len(), pools.len());
    let mut out = Vec::with_capacity(qs.len());
    let mut rows_visited = 0u64;
    for (qc, pc) in qs.chunks(64).zip(pools.chunks(64)) {
        let (res, rows) = batched_refine_group(ds, qc, pc, k, threads);
        out.extend(res.into_iter().map(|mut l| {
            l.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            l
        }));
        rows_visited += rows;
    }
    (out, rows_visited)
}

/// Elementwise mean of a query group — the anchor heap-aware ordering
/// ranks blocks against (tick-group queries share a sampling point, so
/// their mean tracks the shared neighbourhood).
pub(crate) fn group_mean(qs: &[&[f32]], dim: usize) -> Vec<f32> {
    let mut mean = vec![0.0f32; dim];
    for q in qs {
        for (m, &v) in mean.iter_mut().zip(*q) {
            *m += v;
        }
    }
    let n = qs.len().max(1) as f32;
    mean.iter_mut().for_each(|m| *m /= n);
    mean
}

fn batched_refine_group(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<(f32, u32)>>, u64) {
    // union of the pools with a per-row membership mask, in deterministic
    // (ascending row id) order so shard merges stay reproducible
    let mut mask: HashMap<u32, u64> = HashMap::new();
    for (j, pool) in pools.iter().enumerate() {
        for &gid in *pool {
            *mask.entry(gid).or_insert(0) |= 1u64 << j;
        }
    }
    let mut union: Vec<(u32, u64)> = mask.into_iter().collect();
    union.sort_unstable_by_key(|e| e.0);

    // per-query caps mirror the per-query refine's clamp exactly
    let caps = refine_caps(pools, k);
    let threads = refine_threads(union.len(), ds.d, threads);
    let shards = parallel_chunks(union.len(), threads, |_, s, e| {
        let mut heaps: Vec<BoundedMaxHeap> =
            caps.iter().map(|&c| BoundedMaxHeap::new(c)).collect();
        // source-agnostic row access: ascending union ids turn a streamed
        // corpus into shard-at-a-time passes through the LRU
        let mut cur = ds.row_cursor();
        for &(gid, bits) in &union[s..e] {
            let row = cur.row(gid);
            let mut bits = bits;
            while bits != 0 {
                let j = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                let d = super::scan::sqdist_early_exit(qs[j], row, heaps[j].worst());
                if d.is_finite() {
                    heaps[j].push(d, gid);
                }
            }
        }
        heaps
    });
    let mut merged: Vec<BoundedMaxHeap> = caps.iter().map(|&c| BoundedMaxHeap::new(c)).collect();
    for shard in shards {
        for (m, h) in merged.iter_mut().zip(shard) {
            m.merge(h);
        }
    }
    let rows = union.len() as u64;
    // lists stay in `into_sorted` (distance-only) order here so the
    // id-mapping caller reproduces the seed bytes exactly; the scored
    // caller canonicalises to `(distance, row id)` on top
    (merged.into_iter().map(|h| h.into_sorted()).collect(), rows)
}

/// Per-query heap caps for a refine group — the per-query refine's clamp.
pub(crate) fn refine_caps(pools: &[&[u32]], k: usize) -> Vec<usize> {
    pools.iter().map(|p| k.max(1).min(p.len().max(1))).collect()
}

/// Same spawn-overhead threshold as the row-major ladder.
fn refine_threads(union_rows: usize, d: usize, threads: usize) -> usize {
    if union_rows * d < 2_000_000 {
        1
    } else {
        threads.max(1)
    }
}

/// The refine ladder through the pre-blocked kernel: the same union scan as
/// [`batched_refine`], but each visited block of the full-resolution
/// [`kernel::RowBlocks`] streams through [`refine_scan_masked`] — dim-major
/// column loads shared by a register tile of up to [`kernel::TILE_Q`]
/// queries, candidate membership applied at harvest, and the strip
/// early-exit retiring (query, block) tiles whose member lanes are already
/// past the heap bound.
///
/// Per-query results equal [`batched_refine`]'s (and therefore the
/// per-query [`exact_refine`]'s) row sets; the kernel accumulates each
/// distance in dimension order while the row-major path sums 8-lane
/// chunks, so rows whose distances collide within final-ulp rounding are
/// the only divergence surface — same contract as the coarse kernel
/// (`index/README.md`). Returns (per-query top-k, distinct rows visited,
/// merged kernel counters).
pub fn batched_refine_kernel(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, u64, KernelStats) {
    assert_eq!(qs.len(), pools.len());
    if let Some(src) = ds.streamed() {
        // the monolithic ladder needs the whole corpus blocked resident;
        // a streamed corpus refines shard-at-a-time through the source LRU
        // instead — the same masked tiles and exact `(distance, row id)`
        // merge the sharded backend uses, so results stay byte-identical
        // (index/README.md, "Out-of-core corpus")
        return refine_masked_by_shard(
            src.plan(),
            &|sh| src.shard_blocks(sh),
            qs,
            pools,
            k,
            threads,
        );
    }
    let mut out = Vec::with_capacity(qs.len());
    let mut rows_visited = 0u64;
    let mut stats = KernelStats::default();
    // ≤64-wide membership masks, exactly like the row-major ladder; each
    // 64-query group then splits into TILE_Q-wide register tiles
    for (qc, pc) in qs.chunks(64).zip(pools.chunks(64)) {
        let (res, rows, st) = batched_refine_kernel_group(ds, qc, pc, k, threads);
        out.extend(res);
        rows_visited += rows;
        stats.add(&st);
    }
    (out, rows_visited, stats)
}

/// The shard-local masked refine shared by the sharded backend and the
/// streamed monolithic path: each ≤[`kernel::TILE_Q`]-query tile's
/// candidate union is split by owning shard, every touched shard streams
/// its row blocks (however `blocks_for` sources them — the corpus-shard
/// LRU or the streamed row source) through [`refine_scan_masked`], and the
/// per-shard heaps merge **exactly** by ascending `(distance, row id)`.
/// Per-(query, row) distances are pure functions of query and row, so the
/// merged result equals the monolithic ladder's byte-for-byte — the
/// merge-exactness argument of `index/README.md`.
///
/// Returns (per-query top-k, distinct rows visited, merged kernel stats).
pub(crate) fn refine_masked_by_shard(
    plan: &ShardPlan,
    blocks_for: &(dyn Fn(usize) -> Arc<RowBlocks> + Sync),
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, u64, KernelStats) {
    let (scored, rows, stats) =
        refine_masked_by_shard_scored(plan, blocks_for, qs, pools, k, threads);
    (
        scored
            .into_iter()
            .map(|l| l.into_iter().map(|(_, i)| i).collect())
            .collect(),
        rows,
        stats,
    )
}

/// [`refine_masked_by_shard`] keeping each survivor's exact f32 distance —
/// the internal merge is already `(distance, row id)`-ordered, so this is
/// the same computation with the final id projection left to the caller.
/// Shard workers ship these scored lists; the coordinator's cross-worker
/// merge then reproduces the in-process result byte for byte.
pub(crate) fn refine_masked_by_shard_scored(
    plan: &ShardPlan,
    blocks_for: &(dyn Fn(usize) -> Arc<RowBlocks> + Sync),
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<(f32, u32)>>, u64, KernelStats) {
    assert_eq!(qs.len(), pools.len());
    let caps = refine_caps(pools, k);
    let ns = plan.count();
    let mut out: Vec<Vec<(f32, u32)>> = Vec::with_capacity(qs.len());
    // `refine_rows` keeps the monolithic ladder's accounting — distinct
    // rows per ≤64-query group — so resident and streamed/sharded runs of
    // the same tick group report comparable telemetry
    let mut rows_visited = 0u64;
    for pc in pools.chunks(64) {
        let mut ids: Vec<u32> = pc.iter().flat_map(|p| p.iter().copied()).collect();
        ids.sort_unstable();
        ids.dedup();
        rows_visited += ids.len() as u64;
    }
    let mut stats = KernelStats::default();
    for ((qt, pt), ct) in qs
        .chunks(kernel::TILE_Q)
        .zip(pools.chunks(kernel::TILE_Q))
        .zip(caps.chunks(kernel::TILE_Q))
    {
        // union membership mask over the tile's queries — duplicate ids
        // collapse onto one bit, exactly like the refine ladders
        let mut mask: HashMap<u32, u8> = HashMap::new();
        for (j, pool) in pt.iter().enumerate() {
            for &gid in *pool {
                *mask.entry(gid).or_insert(0) |= 1 << j;
            }
        }
        let mut union: Vec<(u32, u8)> = mask.into_iter().collect();
        union.sort_unstable_by_key(|e| e.0);
        // shard-local (position, bits) lists: positions are local so the
        // refine plan tiles the shard's own blocks; harvest maps back to
        // global ids through the blocks' id table
        let mut per_shard: Vec<Vec<(u32, u8)>> = vec![Vec::new(); ns];
        for &(gid, bits) in &union {
            let sh = plan.shard_of(gid as usize);
            let (s, _) = plan.range(sh);
            per_shard[sh].push((gid - s as u32, bits));
        }
        let touched: Vec<usize> =
            (0..ns).filter(|&sh| !per_shard[sh].is_empty()).collect();
        let shard_heaps: Vec<(Vec<BoundedMaxHeap>, KernelStats)> =
            parallel_chunks(touched.len(), threads.max(1), |_, s, e| {
                (s..e)
                    .map(|ti| {
                        let sh = touched[ti];
                        let rb = blocks_for(sh);
                        let block_plan = build_refine_plan(&per_shard[sh]);
                        let mut heaps: Vec<BoundedMaxHeap> =
                            ct.iter().map(|&c| BoundedMaxHeap::new(c)).collect();
                        let mut st = KernelStats::default();
                        refine_scan_masked(&rb, qt, &block_plan, &mut heaps, &mut st);
                        (heaps, st)
                    })
                    .collect::<Vec<_>>()
            })
            .into_iter()
            .flatten()
            .collect();
        let mut shard_lists: Vec<Vec<Vec<(f32, u32)>>> = Vec::with_capacity(shard_heaps.len());
        for (heaps, st) in shard_heaps {
            stats.add(&st);
            shard_lists.push(
                heaps
                    .into_iter()
                    .map(|h| {
                        let mut v = h.into_sorted();
                        v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                        v
                    })
                    .collect(),
            );
        }
        for (qi, &c) in ct.iter().enumerate() {
            let mut all: Vec<(f32, u32)> = shard_lists
                .iter()
                .flat_map(|l| l[qi].iter().copied())
                .collect();
            all.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            all.truncate(c);
            out.push(all);
        }
    }
    (out, rows_visited, stats)
}

fn batched_refine_kernel_group(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    threads: usize,
) -> (Vec<Vec<u32>>, u64, KernelStats) {
    // union of the pools with per-row membership bits, ascending row id —
    // duplicate ids inside a pool collapse onto one bit, like batched_refine
    let mut mask: HashMap<u32, u64> = HashMap::new();
    for (j, pool) in pools.iter().enumerate() {
        for &gid in *pool {
            *mask.entry(gid).or_insert(0) |= 1u64 << j;
        }
    }
    let mut union: Vec<(u32, u64)> = mask.into_iter().collect();
    union.sort_unstable_by_key(|e| e.0);

    let caps = refine_caps(pools, k);
    let threads = refine_threads(union.len(), ds.d, threads);
    // force the lazy blocked corpus once, outside the sharded region
    let row_blocks = ds.row_blocks();
    let mut out: Vec<Vec<u32>> = Vec::with_capacity(qs.len());
    let mut stats = KernelStats::default();
    for (tile, (qt, ct)) in qs.chunks(kernel::TILE_Q).zip(caps.chunks(kernel::TILE_Q)).enumerate() {
        // this tile's slice of the 64-wide masks, as 8-bit lane masks
        let rows: Vec<(u32, u8)> = union
            .iter()
            .filter_map(|&(gid, bits)| {
                let byte = ((bits >> (tile * kernel::TILE_Q)) & 0xff) as u8;
                (byte != 0).then_some((gid, byte))
            })
            .collect();
        let plan = build_refine_plan(&rows);
        let shards = parallel_chunks(plan.len(), threads, |_, s, e| {
            let mut heaps: Vec<BoundedMaxHeap> =
                ct.iter().map(|&c| BoundedMaxHeap::new(c)).collect();
            let mut st = KernelStats::default();
            refine_scan_masked(row_blocks, qt, &plan[s..e], &mut heaps, &mut st);
            (heaps, st)
        });
        let mut merged: Vec<BoundedMaxHeap> =
            ct.iter().map(|&c| BoundedMaxHeap::new(c)).collect();
        for (heaps, st) in shards {
            stats.add(&st);
            for (m, h) in merged.iter_mut().zip(heaps) {
                m.merge(h);
            }
        }
        out.extend(
            merged
                .into_iter()
                .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect::<Vec<u32>>()),
        );
    }
    (out, union.len() as u64, stats)
}

/// Quantised refine pre-rung: drop pool candidates whose int8 **lower**
/// bound strictly exceeds the k-th smallest int8 **upper** bound over the
/// pool — such a row is provably farther than k other candidates, so it
/// cannot be a top-k member under any tie-break, and removing it cannot
/// change the exact refine's result. Pools small enough that nothing can
/// be excluded without shrinking the refine cap (`distinct ≤ k`) pass
/// through untouched; when filtering does happen, at least k distinct
/// candidates always survive (every threshold-heap member's lb ≤ ub ≤ T),
/// so per-query refine caps are identical with the pre-rung on or off.
/// Survivors keep their original order and multiplicity.
///
/// Returns `None` when the dataset carries no row-tier codes (a streamed
/// legacy store) — the caller falls back to the plain f32 ladder.
pub(crate) fn quant_prefilter(
    ds: &Dataset,
    qs: &[&[f32]],
    pools: &[&[u32]],
    k: usize,
    counters: &Counters,
) -> Option<Vec<Vec<u32>>> {
    let qr = ds.quant_rows()?;
    let k = k.max(1);
    let mut qst = QuantStats::default();
    let out = qs
        .iter()
        .zip(pools)
        .map(|(q, pool)| {
            let mut distinct: Vec<u32> = pool.to_vec();
            distinct.sort_unstable();
            distinct.dedup();
            if distinct.len() <= k {
                return pool.to_vec();
            }
            let mut th = BoundedMaxHeap::new(k);
            let bounds: HashMap<u32, f32> = distinct
                .iter()
                .map(|&gid| {
                    let (lb2, ub2) = qr.bounds2(q, gid);
                    th.push(ub2, gid);
                    (gid, lb2)
                })
                .collect();
            let t = th.worst();
            qst.rows_screened += distinct.len() as u64;
            let kept_distinct = distinct.iter().filter(|gid| bounds[gid] <= t).count() as u64;
            qst.rescore_rows += kept_distinct;
            qst.bound_rejects += distinct.len() as u64 - kept_distinct;
            pool.iter().copied().filter(|gid| bounds[gid] <= t).collect()
        })
        .collect();
    counters.record_quant(&qst);
    Some(out)
}

// ---------------------------------------------------------------------------
// FlatScan
// ---------------------------------------------------------------------------

/// The seed's sharded flat scan behind the trait: one full proxy-table pass
/// per query. [`FlatScan::scalar`] keeps the seed `ProxyIndex` semantics —
/// the bit-stable CPU reference all other paths are property-tested
/// against; the default constructor evaluates single-query tiles through
/// the kernel so all default backends share one distance code path.
#[derive(Debug, Default)]
pub struct FlatScan {
    inner: ProxyIndex,
    use_kernel: bool,
    refine_kernel: bool,
    /// int8 screen + refine pre-rung with exact f32 rescore (kernel paths
    /// only; results stay byte-identical to the f32 path)
    quant: bool,
    counters: Counters,
}

impl FlatScan {
    /// Kernel-backed flat scan (the default path).
    pub fn new(threads: usize) -> FlatScan {
        FlatScan {
            inner: ProxyIndex { threads },
            use_kernel: true,
            refine_kernel: true,
            quant: false,
            counters: Counters::default(),
        }
    }

    /// The seed-semantics scalar scan (reference for parity tests and the
    /// `kernel = false` engine knob): row-major coarse scan AND row-major
    /// refine.
    pub fn scalar(threads: usize) -> FlatScan {
        FlatScan {
            use_kernel: false,
            refine_kernel: false,
            ..FlatScan::new(threads)
        }
    }

    /// Route the exact refine through the pre-blocked kernel (default on
    /// the kernel path) or the row-major reference.
    pub fn with_refine_kernel(mut self, on: bool) -> Self {
        self.refine_kernel = on;
        self
    }

    /// Toggle the quantised tier (int8 screen + pre-rung, exact rescore).
    pub fn with_quant(mut self, on: bool) -> Self {
        self.quant = on;
        self
    }

    fn effective_threads(&self, work: usize) -> usize {
        if work < 2_000_000 {
            1
        } else {
            self.inner.threads
        }
    }
}

impl RetrievalBackend for FlatScan {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.counters.proxy_passes.fetch_add(1, Ordering::Relaxed);
        self.counters.queries.fetch_add(1, Ordering::Relaxed);
        // the kernel only pays off when its work matches the scalar scan's:
        // a lone conditional query would tile the WHOLE table and discard
        // non-class rows at harvest, so class queries keep the class-shard
        // scalar scan (BatchedScan's mixed groups are where conditional
        // queries ride the kernel, sharing the pass they'd pay anyway)
        if self.use_kernel && class.is_none() {
            let cap = m.max(1).min(ds.n.max(1));
            let queries = [query_proxy];
            let threads = self.effective_threads(ds.n * ds.proxy_d);
            if self.quant {
                let scan = QuantScan {
                    blocks: &ds.proxy_blocks,
                    quant: ds.quant_proxy_blocks(),
                    queries: &queries,
                    classes: &[None],
                    labels: None,
                };
                let mut heaps = vec![BoundedMaxHeap::new(cap)];
                let mut qst = QuantStats::default();
                let mut kst = KernelStats::default();
                scan.screen_into(cap, threads, None, &mut heaps, &mut qst, &mut kst);
                self.counters.record_kernel(&kst);
                self.counters.record_quant(&qst);
                return heaps
                    .pop()
                    .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
                    .unwrap_or_default();
            }
            let scan = KernelScan {
                blocks: &ds.proxy_blocks,
                queries: &queries,
                classes: &[None],
                labels: None,
            };
            let (mut got, st) = scan.top_m(cap, threads);
            self.counters.record_kernel(&st);
            return got.pop().unwrap_or_default();
        }
        match class {
            Some(y) => {
                self.counters
                    .rows_scanned
                    .fetch_add(ds.class_rows[y as usize].len() as u64, Ordering::Relaxed);
                self.inner.top_m_class(ds, query_proxy, m, y)
            }
            None => {
                self.counters
                    .rows_scanned
                    .fetch_add(ds.n as u64, Ordering::Relaxed);
                self.inner.top_m(ds, query_proxy, m)
            }
        }
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        if self.refine_kernel {
            if self.quant {
                if let Some(filtered) = quant_prefilter(ds, &[q], &[cands], k, &self.counters) {
                    let fp: Vec<&[u32]> = filtered.iter().map(Vec::as_slice).collect();
                    let (out, rows, st) =
                        batched_refine_kernel(ds, &[q], &fp, k, self.inner.threads);
                    self.counters.record_refine(rows, &st);
                    return out.into_iter().next().unwrap_or_default();
                }
            }
            let (out, rows, st) =
                batched_refine_kernel(ds, &[q], &[cands], k, self.inner.threads);
            self.counters.record_refine(rows, &st);
            return out.into_iter().next().unwrap_or_default();
        }
        self.inner.refine_top_k(ds, q, cands, k)
    }

    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        if self.refine_kernel {
            if self.quant {
                if let Some(filtered) = quant_prefilter(ds, qs, pools, k, &self.counters) {
                    let fp: Vec<&[u32]> = filtered.iter().map(Vec::as_slice).collect();
                    let (out, rows, st) = batched_refine_kernel(ds, qs, &fp, k, self.inner.threads);
                    self.counters.record_refine(rows, &st);
                    return out;
                }
            }
            let (out, rows, st) = batched_refine_kernel(ds, qs, pools, k, self.inner.threads);
            self.counters.record_refine(rows, &st);
            return out;
        }
        qs.iter()
            .zip(pools)
            .map(|(q, pool)| self.inner.refine_top_k(ds, q, pool, k))
            .collect()
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// BatchedScan
// ---------------------------------------------------------------------------

/// Multi-query scan: one pass over the proxy table per `top_m_batch` call,
/// one bounded heap per query. Rows stream through the cache once and are
/// scored against every query in the group; with the kernel enabled
/// (default) the pass runs as [`kernel::TILE_Q`]-query register tiles over
/// the dataset's resident [`ProxyBlocks`], so one block-column load feeds
/// the whole query group.
#[derive(Debug)]
pub struct BatchedScan {
    pub threads: usize,
    use_kernel: bool,
    refine_kernel: bool,
    /// heap-aware block ordering: visit proxy blocks in ascending centroid
    /// distance to the query-group mean (default on; kernel path only)
    ordered: bool,
    /// int8 screen + refine pre-rung with exact f32 rescore (kernel paths
    /// only; results stay byte-identical to the f32 path)
    quant: bool,
    tile_q: usize,
    counters: Counters,
}

impl Default for BatchedScan {
    fn default() -> Self {
        BatchedScan::new(crate::util::threadpool::default_threads())
    }
}

impl BatchedScan {
    pub fn new(threads: usize) -> BatchedScan {
        BatchedScan {
            threads,
            use_kernel: true,
            refine_kernel: true,
            ordered: true,
            quant: false,
            tile_q: kernel::TILE_Q,
            counters: Counters::default(),
        }
    }

    /// The PR 1 scalar row-major pass (reference and `kernel = false` knob).
    pub fn scalar(threads: usize) -> BatchedScan {
        BatchedScan {
            use_kernel: false,
            refine_kernel: false,
            ordered: false,
            ..BatchedScan::new(threads)
        }
    }

    /// Override the queries-per-tile width (clamped to 1..=[`kernel::TILE_Q`]).
    pub fn with_tile(mut self, tile_q: usize) -> Self {
        self.tile_q = tile_q.clamp(1, kernel::TILE_Q);
        self
    }

    /// Toggle heap-aware block ordering (order-invariance reference runs).
    pub fn with_ordering(mut self, on: bool) -> Self {
        self.ordered = on;
        self
    }

    /// Route the exact refine through the pre-blocked kernel (default on
    /// the kernel path) or the row-major reference ladder.
    pub fn with_refine_kernel(mut self, on: bool) -> Self {
        self.refine_kernel = on;
        self
    }

    /// Toggle the quantised tier (int8 screen + pre-rung, exact rescore).
    pub fn with_quant(mut self, on: bool) -> Self {
        self.quant = on;
        self
    }

    /// Same spawn-overhead threshold as the flat scan (the batch multiplies
    /// the work, never shrinks it, so single-query sharding stays stable).
    fn effective_threads(&self, work: usize) -> usize {
        if work < 2_000_000 {
            1
        } else {
            self.threads
        }
    }

    /// The tiled pass: queries are split into `tile_q`-wide register
    /// groups; each group shares every block-column load. With ordering on
    /// (default), each group's blocks are visited in ascending centroid
    /// distance to the group-mean proxy, so the per-query heap bounds
    /// tighten while most of the pass is still ahead — the strip early-exit
    /// then retires far tiles after one strip instead of never engaging
    /// until the storage-order scan stumbles onto the neighbourhood.
    fn kernel_top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        let cap = m.max(1).min(ds.n.max(1));
        let threads = self.effective_threads(ds.n * ds.proxy_d);
        let mut out = Vec::with_capacity(queries.len());
        for group in queries.chunks(self.tile_q.clamp(1, kernel::TILE_Q)) {
            let qs: Vec<&[f32]> = group.iter().map(|q| q.proxy).collect();
            let classes: Vec<Option<u32>> = group.iter().map(|q| q.class).collect();
            let order = if self.ordered && ds.proxy_blocks.n_blocks() > 1 {
                let mean = group_mean(&qs, ds.proxy_d);
                let order = block_order(&ds.proxy_blocks, &mean);
                self.counters.record_order(&order);
                Some(order)
            } else {
                None
            };
            if self.quant {
                let scan = QuantScan {
                    blocks: &ds.proxy_blocks,
                    quant: ds.quant_proxy_blocks(),
                    queries: &qs,
                    classes: &classes,
                    labels: Some(&ds.labels),
                };
                let mut heaps: Vec<BoundedMaxHeap> =
                    (0..qs.len()).map(|_| BoundedMaxHeap::new(cap)).collect();
                let mut qst = QuantStats::default();
                let mut kst = KernelStats::default();
                scan.screen_into(cap, threads, order.as_deref(), &mut heaps, &mut qst, &mut kst);
                self.counters.record_kernel(&kst);
                self.counters.record_quant(&qst);
                out.extend(
                    heaps
                        .into_iter()
                        .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect::<Vec<u32>>()),
                );
                continue;
            }
            let scan = KernelScan {
                blocks: &ds.proxy_blocks,
                queries: &qs,
                classes: &classes,
                labels: Some(&ds.labels),
            };
            let (res, st) = match &order {
                Some(order) => scan.top_m_ordered(cap, threads, order),
                None => scan.top_m(cap, threads),
            };
            self.counters.record_kernel(&st);
            out.extend(res);
        }
        out
    }

    /// The PR 1 scalar pass, kept as the `kernel = false` fallback and the
    /// `kernel_scalar` bench baseline.
    fn scalar_top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        let b = queries.len();
        let cap = m.max(1).min(ds.n.max(1));
        self.counters
            .rows_scanned
            .fetch_add(ds.n as u64, Ordering::Relaxed);
        let threads = self.effective_threads(ds.n * ds.proxy_d);
        let conditional = queries.iter().any(|q| q.class.is_some());
        let shards: Vec<Vec<BoundedMaxHeap>> = parallel_chunks(ds.n, threads, |_, s, e| {
            let mut heaps: Vec<BoundedMaxHeap> =
                (0..b).map(|_| BoundedMaxHeap::new(cap)).collect();
            for i in s..e {
                let row = ds.proxy_row(i);
                let label = if conditional { ds.labels[i] } else { 0 };
                for (j, q) in queries.iter().enumerate() {
                    if let Some(y) = q.class {
                        if y != label {
                            continue;
                        }
                    }
                    let heap = &mut heaps[j];
                    let d = super::scan::sqdist_early_exit(q.proxy, row, heap.worst());
                    if d.is_finite() {
                        heap.push(d, i as u32);
                    }
                }
            }
            heaps
        });

        let mut merged: Vec<BoundedMaxHeap> = (0..b).map(|_| BoundedMaxHeap::new(cap)).collect();
        for shard in shards {
            for (j, heap) in shard.into_iter().enumerate() {
                merged[j].merge(heap);
            }
        }
        merged
            .into_iter()
            .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
            .collect()
    }
}

impl RetrievalBackend for BatchedScan {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.top_m_batch(
            ds,
            &[ProxyQuery {
                proxy: query_proxy,
                class,
            }],
            m,
        )
        .pop()
        .unwrap_or_default()
    }

    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        if queries.is_empty() {
            return Vec::new();
        }
        self.counters.proxy_passes.fetch_add(1, Ordering::Relaxed);
        self.counters
            .queries
            .fetch_add(queries.len() as u64, Ordering::Relaxed);
        if self.use_kernel {
            self.kernel_top_m_batch(ds, queries, m)
        } else {
            self.scalar_top_m_batch(ds, queries, m)
        }
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        if self.refine_kernel {
            return self
                .refine_top_k_batch(ds, &[q], &[cands], k)
                .pop()
                .unwrap_or_default();
        }
        exact_refine(ds, q, cands, k, self.threads)
    }

    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        if self.refine_kernel {
            if self.quant {
                if let Some(filtered) = quant_prefilter(ds, qs, pools, k, &self.counters) {
                    let fp: Vec<&[u32]> = filtered.iter().map(Vec::as_slice).collect();
                    let (out, rows, st) = batched_refine_kernel(ds, qs, &fp, k, self.threads);
                    self.counters.record_refine(rows, &st);
                    return out;
                }
            }
            let (out, rows, st) = batched_refine_kernel(ds, qs, pools, k, self.threads);
            self.counters.record_refine(rows, &st);
            return out;
        }
        let (out, rows) = batched_refine(ds, qs, pools, k, self.threads);
        self.counters.refine_rows.fetch_add(rows, Ordering::Relaxed);
        out
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// ClusterPruned
// ---------------------------------------------------------------------------

/// IVF-style backend: the proxy table is k-means-partitioned into `lists`
/// clusters once at build time (reusing the dataset's persisted
/// [`IvfPartition`] when it matches, so engine start skips k-means); a
/// query visits clusters in ascending centroid distance and, once its heap
/// is full, skips any cluster whose triangle-inequality lower bound
/// `(d(q, c) − r_c)²` already exceeds the worst retained distance.
/// Local-structure arguments (Wang & Vastola 2024) say posterior mass
/// concentrates on a few clusters at moderate-to-low noise, so most lists
/// are skipped with *exact* bounds.
///
/// Member lists are materialised twice: whole-list and **per-class** (both
/// as pre-blocked kernel tables), so conditional queries probe
/// class-filtered lists under the tighter per-class radius bound instead of
/// testing labels row-by-row inside each list.
///
/// Knobs:
/// * `nprobe == 0` (default) — exactness: only bound-justified skips, the
///   result equals the flat scan.
/// * `nprobe > 0` — approximate fallback: scan at most `nprobe` nearest
///   clusters (still topping up past the cap if the heap is not yet full,
///   so a class-conditional query always gets its m rows when they exist).
pub struct ClusterPruned {
    pub threads: usize,
    /// number of IVF lists (k-means clusters over the proxy table)
    lists: usize,
    /// 0 = exact bound pruning; >0 = scan at most this many nearest lists
    nprobe: usize,
    /// centroids [lists × proxy_d]
    centroids: Vec<f32>,
    /// member row ids per list
    members: Vec<Vec<u32>>,
    /// member row ids per (list, class)
    class_members: Vec<Vec<Vec<u32>>>,
    /// max Euclidean member→centroid distance per list
    radius: Vec<f32>,
    /// max member→centroid distance per (list, class) — the tighter bound
    /// conditional queries prune with
    class_radius: Vec<Vec<f32>>,
    /// pre-blocked kernel tables per list / per (list, class)
    blocks: Vec<ProxyBlocks>,
    class_blocks: Vec<Vec<ProxyBlocks>>,
    use_kernel: bool,
    refine_kernel: bool,
    /// heap-aware ordering of each scanned list's blocks (kernel path)
    ordered: bool,
    counters: Counters,
}

impl ClusterPruned {
    /// Partition the dataset's proxy table (build once per dataset). When
    /// `ds.ivf` holds a persisted partition for the same `(lists, seed)`,
    /// the k-means step is skipped entirely.
    pub fn build(ds: &Dataset, lists: usize, nprobe: usize, seed: u64) -> ClusterPruned {
        Self::build_with_threads(
            ds,
            lists,
            nprobe,
            seed,
            crate::util::threadpool::default_threads(),
        )
    }

    pub fn build_with_threads(
        ds: &Dataset,
        lists: usize,
        nprobe: usize,
        seed: u64,
        threads: usize,
    ) -> ClusterPruned {
        Self::build_inner(ds, lists, nprobe, seed, threads, true)
    }

    fn build_inner(
        ds: &Dataset,
        lists: usize,
        nprobe: usize,
        seed: u64,
        threads: usize,
        use_kernel: bool,
    ) -> ClusterPruned {
        let lists = lists.clamp(1, ds.n.max(1));
        let part = match &ds.ivf {
            Some(p) if p.matches(lists, seed) => p.clone(),
            _ => IvfPartition::compute(ds, lists, seed),
        };
        let pd = ds.proxy_d;
        let nclass = ds.classes.max(1);
        // with one class the per-class structures would duplicate the
        // whole-list ones verbatim — skip them and fall back at query time
        let per_class = nclass > 1;
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); lists];
        let mut class_members: Vec<Vec<Vec<u32>>> = if per_class {
            vec![vec![Vec::new(); nclass]; lists]
        } else {
            Vec::new()
        };
        for (i, &a) in part.assignments.iter().enumerate() {
            members[a as usize].push(i as u32);
            if per_class {
                class_members[a as usize][ds.labels[i] as usize].push(i as u32);
            }
        }
        let mut radius = vec![0.0f32; lists];
        let mut class_radius: Vec<Vec<f32>> = if per_class {
            vec![vec![0.0f32; nclass]; lists]
        } else {
            Vec::new()
        };
        for (cl, rows) in members.iter().enumerate() {
            let c = &part.centroids[cl * pd..(cl + 1) * pd];
            let mut worst = 0.0f32;
            let mut class_worst = vec![0.0f32; nclass];
            for &i in rows {
                let d = super::scan::sqdist_flat(ds.proxy_row(i as usize), c);
                worst = worst.max(d);
                let y = ds.labels[i as usize] as usize;
                class_worst[y] = class_worst[y].max(d);
            }
            radius[cl] = worst.sqrt();
            if per_class {
                for (r, w) in class_radius[cl].iter_mut().zip(&class_worst) {
                    *r = w.sqrt();
                }
            }
        }
        // block tables exist only for the kernel path — a scalar-only
        // build skips the transposed copies entirely
        let blocks: Vec<ProxyBlocks> = if use_kernel {
            members
                .iter()
                .map(|rows| ProxyBlocks::build_subset(&ds.proxies, pd, rows))
                .collect()
        } else {
            Vec::new()
        };
        let class_blocks: Vec<Vec<ProxyBlocks>> = if use_kernel && per_class {
            class_members
                .iter()
                .map(|per| {
                    per.iter()
                        .map(|rows| ProxyBlocks::build_subset(&ds.proxies, pd, rows))
                        .collect()
                })
                .collect()
        } else {
            Vec::new()
        };
        ClusterPruned {
            threads,
            lists,
            nprobe,
            centroids: part.centroids,
            members,
            class_members,
            radius,
            class_radius,
            blocks,
            class_blocks,
            use_kernel,
            refine_kernel: use_kernel,
            ordered: use_kernel,
            counters: Counters::default(),
        }
    }

    /// Disable the tiled kernel (scalar per-row list scans). Disabling also
    /// frees the pre-blocked tables; re-enabling on a scalar-built instance
    /// is not supported (the default build is kernel-backed).
    pub fn with_kernel(mut self, use_kernel: bool) -> Self {
        self.use_kernel = use_kernel && !self.blocks.is_empty();
        if !self.use_kernel {
            self.blocks = Vec::new();
            self.class_blocks = Vec::new();
            self.refine_kernel = false;
            self.ordered = false;
        }
        self
    }

    /// Toggle heap-aware ordering of each scanned list's blocks.
    pub fn with_ordering(mut self, on: bool) -> Self {
        self.ordered = on && self.use_kernel;
        self
    }

    /// Route the exact refine through the pre-blocked kernel or the
    /// row-major reference ladder.
    pub fn with_refine_kernel(mut self, on: bool) -> Self {
        self.refine_kernel = on;
        self
    }

    pub fn lists(&self) -> usize {
        self.lists
    }
}

impl RetrievalBackend for ClusterPruned {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn is_exact(&self) -> bool {
        // nprobe > 0 caps the scanned lists past what the centroid bound
        // justifies — the approximate knob
        self.nprobe == 0
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        // conditional queries clamp to the class support so the heap can
        // actually fill (and the bound prune can engage) on small classes
        let cap = match class {
            Some(y) => m.max(1).min(ds.class_rows[y as usize].len().max(1)),
            None => m.max(1).min(ds.n.max(1)),
        };
        self.counters.queries.fetch_add(1, Ordering::Relaxed);

        // rank clusters by centroid distance
        let pd = ds.proxy_d;
        let mut order: Vec<(f32, usize)> = (0..self.lists)
            .map(|cl| {
                (
                    super::scan::sqdist_flat(query_proxy, &self.centroids[cl * pd..(cl + 1) * pd]),
                    cl,
                )
            })
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0));

        let mut heap = BoundedMaxHeap::new(cap);
        let mut kstats = KernelStats::default();
        let mut scanned_lists = 0u64;
        let mut pruned_lists = 0u64;
        let mut rows_scanned = 0u64;
        for &(c_d2, cl) in &order {
            // the bound radius tightens to the class subset for
            // conditional queries — still a valid lower bound, so skips
            // stay provably exact (single-class datasets fall back to the
            // whole-list radius, which equals the class radius there)
            let r = match class {
                Some(y) if !self.class_radius.is_empty() => self.class_radius[cl][y as usize],
                _ => self.radius[cl],
            };
            // pruning only ever applies once the heap is full — a query
            // must always receive its m rows when they exist
            if heap.len() >= cap {
                let lb = (c_d2.sqrt() - r).max(0.0);
                if lb * lb >= heap.worst() {
                    pruned_lists += 1;
                    continue;
                }
                if self.nprobe > 0 && scanned_lists >= self.nprobe as u64 {
                    pruned_lists += 1;
                    continue;
                }
            }
            scanned_lists += 1;
            if self.use_kernel {
                let blocks = match class {
                    Some(y) if !self.class_blocks.is_empty() => &self.class_blocks[cl][y as usize],
                    _ => &self.blocks[cl],
                };
                let queries = [query_proxy];
                let scan = KernelScan {
                    blocks,
                    queries: &queries,
                    classes: &[None],
                    labels: None,
                };
                if self.ordered && blocks.n_blocks() > 1 {
                    // lists are already visited nearest-first; ordering the
                    // blocks *inside* each list lets the strip bound retire
                    // the list's far tail too
                    let order = block_order(blocks, query_proxy);
                    self.counters.record_order(&order);
                    scan.scan_list_into(&order, std::slice::from_mut(&mut heap), &mut kstats);
                } else {
                    scan.scan_into(
                        0,
                        blocks.n_blocks(),
                        std::slice::from_mut(&mut heap),
                        &mut kstats,
                    );
                }
            } else {
                let rows = match class {
                    Some(y) if !self.class_members.is_empty() => &self.class_members[cl][y as usize],
                    _ => &self.members[cl],
                };
                for &gid in rows {
                    rows_scanned += 1;
                    let row = ds.proxy_row(gid as usize);
                    let d = super::scan::sqdist_early_exit(query_proxy, row, heap.worst());
                    if d.is_finite() {
                        heap.push(d, gid);
                    }
                }
            }
        }
        self.counters
            .clusters_scanned
            .fetch_add(scanned_lists, Ordering::Relaxed);
        self.counters
            .clusters_pruned
            .fetch_add(pruned_lists, Ordering::Relaxed);
        if self.use_kernel {
            self.counters.record_kernel(&kstats);
        } else {
            self.counters
                .rows_scanned
                .fetch_add(rows_scanned, Ordering::Relaxed);
        }
        heap.into_sorted().into_iter().map(|(_, i)| i).collect()
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        if self.refine_kernel {
            let (out, rows, st) = batched_refine_kernel(ds, &[q], &[cands], k, self.threads);
            self.counters.record_refine(rows, &st);
            return out.into_iter().next().unwrap_or_default();
        }
        exact_refine(ds, q, cands, k, self.threads)
    }

    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        if self.refine_kernel {
            let (out, rows, st) = batched_refine_kernel(ds, qs, pools, k, self.threads);
            self.counters.record_refine(rows, &st);
            return out;
        }
        let (out, rows) = batched_refine(ds, qs, pools, k, self.threads);
        self.counters.refine_rows.fetch_add(rows, Ordering::Relaxed);
        out
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// Kind selection (config / CLI surface)
// ---------------------------------------------------------------------------

/// Build-time knobs shared by every backend kind (`EngineConfig` surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackendOpts {
    pub threads: usize,
    /// IVF lists for `ClusterPruned`
    pub clusters: usize,
    /// probe cap for `ClusterPruned` (0 = exact bounds)
    pub nprobe: usize,
    pub seed: u64,
    /// route scans through the tiled kernel (default) or the scalar paths
    pub kernel: bool,
    /// route the exact refine through the pre-blocked kernel (default);
    /// only effective when `kernel` is on — `false` pins the refine to the
    /// row-major reference ladder
    pub refine_kernel: bool,
    /// heap-aware block ordering for the batched / cluster scans (default)
    pub ordering: bool,
    /// queries per register tile, clamped to 1..=[`kernel::TILE_Q`]
    pub tile_q: usize,
    /// corpus shards: `> 1` wraps the selected backend kind in the
    /// shard-parallel merge layer (`index::shard::ShardedBackend`); `1`
    /// (default) keeps the monolithic backends byte-for-byte as before
    pub shards: usize,
    /// memory budget (MiB) for resident cold-shard `RowBlocks`; `0` means
    /// unbounded (no LRU eviction). Only meaningful when `shards > 1`.
    /// Over a plan-matched streamed dataset whose own budget already
    /// honours this one, residency delegates to the source LRU (one
    /// cache); otherwise this layer's own LRU enforces the bound.
    pub mem_budget_mb: usize,
    /// quantised tier: run coarse screens and the refine pre-rung on int8
    /// codes with sound bounds, rescoring survivors exactly on f32
    /// (kernel paths of Flat/Batched/Sharded; results byte-identical).
    /// Default off.
    pub quant: bool,
    /// explicit SIMD lanes in the tile kernels (runtime-dispatched AVX2,
    /// bit-identical to the scalar loops). Default on; a pure speed knob.
    pub simd: bool,
}

impl Default for BackendOpts {
    fn default() -> Self {
        BackendOpts {
            threads: crate::util::threadpool::default_threads(),
            clusters: 64,
            nprobe: 0,
            seed: 0,
            kernel: true,
            refine_kernel: true,
            ordering: true,
            tile_q: kernel::TILE_Q,
            shards: 1,
            mem_budget_mb: 0,
            quant: false,
            simd: true,
        }
    }
}

/// Config-facing backend taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetrievalBackendKind {
    Flat,
    Batched,
    ClusterPruned,
}

impl RetrievalBackendKind {
    pub fn parse(s: &str) -> Option<RetrievalBackendKind> {
        Some(match s {
            "flat" => RetrievalBackendKind::Flat,
            "batched" => RetrievalBackendKind::Batched,
            "cluster" | "cluster-pruned" | "ivf" => RetrievalBackendKind::ClusterPruned,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RetrievalBackendKind::Flat => "flat",
            RetrievalBackendKind::Batched => "batched",
            RetrievalBackendKind::ClusterPruned => "cluster",
        }
    }

    pub fn all() -> &'static [RetrievalBackendKind] {
        &[
            RetrievalBackendKind::Flat,
            RetrievalBackendKind::Batched,
            RetrievalBackendKind::ClusterPruned,
        ]
    }

    /// Build a shareable backend for a dataset. `opts.clusters`/`opts.nprobe`
    /// only apply to the cluster-pruned backend. With `opts.shards > 1` the
    /// kind is wrapped in the shard-parallel merge layer. Row residency —
    /// resident corpus or `.gds`-streamed shards — comes from the dataset's
    /// own row source, so every kind serves a streamed dataset unchanged.
    /// `opts.quant` applies to the Flat/Batched/Sharded kernel paths;
    /// ClusterPruned keeps its f32 per-list tables (its clusters already
    /// prune on exact bounds, and quantising the many small list tables
    /// buys little — results are identical either way by exactness).
    pub fn build(&self, ds: &Dataset, opts: BackendOpts) -> Arc<dyn RetrievalBackend> {
        // the SIMD knob is process-wide: results are bit-identical either
        // way, so backends built with different settings stay coherent
        kernel::simd::set_enabled(opts.simd);
        if opts.shards > 1 {
            return Arc::new(crate::index::shard::ShardedBackend::build(ds, *self, opts));
        }
        // the scalar reference disables every kernel-path refinement
        let refine = opts.kernel && opts.refine_kernel;
        let quant = opts.kernel && opts.quant;
        match self {
            RetrievalBackendKind::Flat => Arc::new(if opts.kernel {
                FlatScan::new(opts.threads)
                    .with_refine_kernel(refine)
                    .with_quant(quant)
            } else {
                FlatScan::scalar(opts.threads)
            }),
            RetrievalBackendKind::Batched => Arc::new(if opts.kernel {
                BatchedScan::new(opts.threads)
                    .with_tile(opts.tile_q)
                    .with_ordering(opts.ordering)
                    .with_refine_kernel(refine)
                    .with_quant(quant)
            } else {
                BatchedScan::scalar(opts.threads)
            }),
            RetrievalBackendKind::ClusterPruned => Arc::new(
                ClusterPruned::build_inner(
                    ds,
                    opts.clusters.max(1),
                    opts.nprobe,
                    opts.seed,
                    opts.threads,
                    opts.kernel,
                )
                .with_ordering(opts.kernel && opts.ordering)
                .with_refine_kernel(refine),
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::util::prop::{forall, gen};
    use crate::util::rng::Pcg64;

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    fn backends(ds: &Dataset) -> Vec<Box<dyn RetrievalBackend>> {
        vec![
            // [0] is the seed-semantics scalar reference
            Box::new(FlatScan::scalar(2)),
            Box::new(FlatScan::new(2)),
            Box::new(BatchedScan::scalar(2)),
            Box::new(BatchedScan::new(2)),
            Box::new(BatchedScan::new(2).with_ordering(false)),
            Box::new(ClusterPruned::build_with_threads(ds, 12, 0, 7, 2)),
            Box::new(ClusterPruned::build_with_threads(ds, 12, 0, 7, 2).with_ordering(false)),
            Box::new(ClusterPruned::build_with_threads(ds, 12, 0, 7, 2).with_kernel(false)),
            // pruning disabled: every list within nprobe and bounds can
            // never exclude (radius covers all members, nprobe = lists)
            Box::new(ClusterPruned::build_with_threads(ds, 1, 0, 7, 2)),
        ]
    }

    #[test]
    fn parity_flat_batched_cluster_unconditional_and_conditional() {
        // Satellite: every backend — kernel-tiled and scalar — returns
        // identical row ids to the scalar FlatScan reference for random
        // queries, including class-conditional scans.
        let ds = tiny(500, 3);
        let all = backends(&ds);
        let flat = &all[0];
        forall(61, 25, |rng| {
            let m = gen::usize_in(rng, 1, 96);
            let q = gen::vec_normal(rng, ds.proxy_d, 1.0);
            let class = if rng.below(2) == 0 {
                None
            } else {
                Some(rng.below(ds.classes) as u32)
            };
            let want = flat.top_m(&ds, &q, m, class);
            for b in &all[1..] {
                let got = b.top_m(&ds, &q, m, class);
                crate::prop_assert!(
                    got == want,
                    "{} != flat (m={m} class={class:?}): {got:?} vs {want:?}",
                    b.name()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_matches_per_query_results() {
        let ds = tiny(400, 5);
        let batched = BatchedScan::new(2);
        let flat = FlatScan::scalar(2);
        let mut rng = Pcg64::new(11);
        let qs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect())
            .collect();
        let queries: Vec<ProxyQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| ProxyQuery {
                proxy: q,
                class: if i % 3 == 0 { Some((i % 4) as u32) } else { None },
            })
            .collect();
        let got = batched.top_m_batch(&ds, &queries, 24);
        for (i, q) in queries.iter().enumerate() {
            let want = flat.top_m(&ds, q.proxy, 24, q.class);
            assert_eq!(got[i], want, "query {i}");
        }
    }

    #[test]
    fn ragged_query_groups_match_reference() {
        // Satellite: group sizes 1..=9 — under, at and past the TILE_Q
        // register width (9 splits into an 8-tile and a 1-tile).
        let ds = tiny(300, 13);
        let batched = BatchedScan::new(2);
        let flat = FlatScan::scalar(2);
        let mut rng = Pcg64::new(19);
        for b in 1usize..=9 {
            let qs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect())
                .collect();
            let queries: Vec<ProxyQuery> = qs
                .iter()
                .enumerate()
                .map(|(i, q)| ProxyQuery {
                    proxy: q,
                    class: if i % 4 == 1 { Some((i % 3) as u32) } else { None },
                })
                .collect();
            let got = batched.top_m_batch(&ds, &queries, 17);
            assert_eq!(got.len(), b);
            for (i, q) in queries.iter().enumerate() {
                let want = flat.top_m(&ds, q.proxy, 17, q.class);
                assert_eq!(got[i], want, "group {b} query {i}");
            }
        }
    }

    #[test]
    fn batched_scan_counts_one_pass_per_group_and_kernel_tiles() {
        let ds = tiny(300, 6);
        let batched = BatchedScan::new(1);
        let q = vec![0.1f32; ds.proxy_d];
        let queries: Vec<ProxyQuery> = (0..8)
            .map(|_| ProxyQuery {
                proxy: &q,
                class: None,
            })
            .collect();
        let _ = batched.top_m_batch(&ds, &queries, 16);
        let s = batched.stats();
        assert_eq!(s.proxy_passes, 1, "8 queries must share one pass");
        assert_eq!(s.queries, 8);
        assert_eq!(s.rows_scanned, ds.n as u64);
        assert_eq!(
            s.tiles_evaluated,
            ds.proxy_blocks.n_blocks() as u64,
            "an 8-query group is one tile per block"
        );

        let flat = FlatScan::new(1);
        for _ in 0..8 {
            let _ = flat.top_m(&ds, &q, 16, None);
        }
        assert_eq!(flat.stats().proxy_passes, 8, "flat pays one pass per query");
    }

    #[test]
    fn batched_refine_matches_per_query_refine() {
        // Satellite: the union-scan refine ladder returns exactly what the
        // per-query refine returns, including empty and singleton pools.
        let ds = tiny(400, 21);
        let batched = BatchedScan::new(2);
        let flat = FlatScan::scalar(2);
        forall(73, 20, |rng| {
            let nq = gen::usize_in(rng, 1, 9);
            let k = gen::usize_in(rng, 1, 24);
            let qs_data: Vec<Vec<f32>> =
                (0..nq).map(|_| gen::vec_normal(rng, ds.d, 1.0)).collect();
            let pools_data: Vec<Vec<u32>> = (0..nq)
                .map(|i| match i % 4 {
                    0 => Vec::new(),                   // empty pool
                    1 => vec![rng.below(ds.n) as u32], // singleton
                    _ => {
                        // distinct ids — candidate pools are top_m output
                        let len = gen::usize_in(rng, 1, 80);
                        rng.choose_k(ds.n, len.min(ds.n))
                            .into_iter()
                            .map(|i| i as u32)
                            .collect()
                    }
                })
                .collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let pools: Vec<&[u32]> = pools_data.iter().map(|p| p.as_slice()).collect();
            let got = batched.refine_top_k_batch(&ds, &qs, &pools, k);
            for i in 0..nq {
                let want = flat.refine_top_k(&ds, qs[i], pools[i], k);
                crate::prop_assert!(
                    got[i] == want,
                    "refine query {i}/{nq} (k={k}, pool={}): {:?} vs {want:?}",
                    pools[i].len(),
                    got[i]
                );
            }
            Ok(())
        });
        assert!(batched.stats().refine_rows > 0, "refine telemetry counts");
    }

    #[test]
    fn ordered_batched_scan_matches_unordered_and_counts_reorders() {
        let ds = tiny(500, 27);
        let ordered = BatchedScan::new(1);
        let unordered = BatchedScan::new(1).with_ordering(false);
        let mut rng = Pcg64::new(3);
        let qs: Vec<Vec<f32>> = (0..8)
            .map(|i| {
                // near-corpus queries so the heap bound actually bites
                let base = ds.proxy_row(rng.below(ds.n)).to_vec();
                base.iter().map(|&v| v + rng.normal() * 0.1 * i as f32).collect()
            })
            .collect();
        let queries: Vec<ProxyQuery> = qs
            .iter()
            .map(|q| ProxyQuery {
                proxy: q,
                class: None,
            })
            .collect();
        let a = ordered.top_m_batch(&ds, &queries, 20);
        let b = unordered.top_m_batch(&ds, &queries, 20);
        assert_eq!(a, b, "ordering must never change results");
        let so = ordered.stats();
        assert!(so.blocks_reordered > 0, "a 500-row corpus must reorder blocks");
        assert_eq!(unordered.stats().blocks_reordered, 0);
    }

    #[test]
    fn refine_kernel_matches_rowmajor_ladder_and_per_query() {
        // pre-blocked refine (default) vs the row-major reference ladder vs
        // the scalar per-query refine — identical id lists on random pools
        let ds = tiny(450, 33);
        let blocked = BatchedScan::new(2);
        let rowmajor = BatchedScan::new(2).with_refine_kernel(false);
        let flat = FlatScan::scalar(2);
        forall(91, 15, |rng| {
            let nq = gen::usize_in(rng, 1, 10);
            let k = gen::usize_in(rng, 1, 20);
            let qs_data: Vec<Vec<f32>> =
                (0..nq).map(|_| gen::vec_normal(rng, ds.d, 1.0)).collect();
            let pools_data: Vec<Vec<u32>> = (0..nq)
                .map(|i| match i % 4 {
                    0 => Vec::new(),
                    1 => vec![rng.below(ds.n) as u32],
                    _ => rng
                        .choose_k(ds.n, gen::usize_in(rng, 1, 70).min(ds.n))
                        .into_iter()
                        .map(|i| i as u32)
                        .collect(),
                })
                .collect();
            let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
            let pools: Vec<&[u32]> = pools_data.iter().map(|p| p.as_slice()).collect();
            let got = blocked.refine_top_k_batch(&ds, &qs, &pools, k);
            let row = rowmajor.refine_top_k_batch(&ds, &qs, &pools, k);
            for i in 0..nq {
                crate::prop_assert!(
                    got[i] == row[i],
                    "preblocked != rowmajor ladder (query {i}, k={k})"
                );
                let per = flat.refine_top_k(&ds, qs[i], pools[i], k);
                crate::prop_assert!(got[i] == per, "preblocked != per-query (query {i})");
                // the free-fn single-query entry shares the masked path
                let free = exact_refine_kernel(&ds, qs[i], pools[i], k, 2);
                crate::prop_assert!(got[i] == free, "free-fn refine diverged (query {i})");
            }
            Ok(())
        });
        let s = blocked.stats();
        assert!(s.refine_rows > 0 && s.tiles_evaluated > 0, "refine telemetry");
    }

    #[test]
    fn refine_kernel_dedups_duplicate_candidates_like_the_ladder() {
        let ds = tiny(300, 35);
        let blocked = BatchedScan::new(1);
        let rowmajor = BatchedScan::new(1).with_refine_kernel(false);
        let q: Vec<f32> = ds.row(7).to_vec();
        let pool: Vec<u32> = vec![7, 7, 12, 12, 12, 99, 7];
        let qs = [q.as_slice()];
        let pools = [pool.as_slice()];
        let a = blocked.refine_top_k_batch(&ds, &qs, &pools, 5);
        let b = rowmajor.refine_top_k_batch(&ds, &qs, &pools, 5);
        assert_eq!(a, b);
        assert_eq!(a[0][0], 7, "self row first");
        let distinct: std::collections::HashSet<u32> = a[0].iter().copied().collect();
        assert_eq!(distinct.len(), a[0].len(), "duplicates must collapse");
    }

    #[test]
    fn cluster_pruning_skips_lists_and_accounts_for_all() {
        let ds = tiny(600, 9);
        let cp = ClusterPruned::build_with_threads(&ds, 16, 0, 13, 1);
        // self-query at tiny m: after the home cluster the worst retained
        // distance is ~0, so far-away lists must be bound-pruned
        let q = ds.proxy_row(42).to_vec();
        let got = cp.top_m(&ds, &q, 1, None);
        assert_eq!(got[0], 42);
        let s = cp.stats();
        assert_eq!(
            s.clusters_scanned + s.clusters_pruned,
            cp.lists() as u64,
            "every list is either scanned or pruned"
        );
        assert!(s.clusters_pruned > 0, "self-query must prune some lists");
        assert!(s.rows_scanned < ds.n as u64, "pruning must skip rows");
    }

    #[test]
    fn cluster_conditional_probes_class_lists_only() {
        // Satellite: conditional scans touch only class member rows — the
        // per-class lists replace row-by-row label filtering.
        let ds = tiny(500, 15);
        for kernel_on in [true, false] {
            let cp =
                ClusterPruned::build_with_threads(&ds, 8, 0, 3, 1).with_kernel(kernel_on);
            let class = (0..ds.classes)
                .max_by_key(|&c| ds.class_rows[c].len())
                .unwrap() as u32;
            let support = ds.class_rows[class as usize].len() as u64;
            let got = cp.top_m(&ds, &vec![0.05; ds.proxy_d], 16, Some(class));
            assert!(got.iter().all(|&i| ds.labels[i as usize] == class));
            let s = cp.stats();
            assert!(
                s.rows_scanned <= support,
                "kernel={kernel_on}: conditional scan visited {} rows for a class of {support}",
                s.rows_scanned
            );
        }
    }

    #[test]
    fn cluster_reuses_persisted_partition() {
        // Satellite: a matching ds.ivf partition short-circuits k-means and
        // yields the identical backend.
        let mut ds = tiny(300, 17);
        let part = IvfPartition::compute(&ds, 8, 99);
        ds.ivf = Some(part.clone());
        let reused = ClusterPruned::build_with_threads(&ds, 8, 0, 99, 1);
        assert_eq!(reused.centroids, part.centroids, "partition must be reused");
        // a different (lists, seed) must NOT reuse the stored partition
        let fresh = ClusterPruned::build_with_threads(&ds, 12, 0, 99, 1);
        assert_eq!(fresh.lists(), 12);
        // and both serve identical results to the flat reference
        let flat = FlatScan::scalar(1);
        let q = ds.proxy_row(5).to_vec();
        assert_eq!(reused.top_m(&ds, &q, 9, None), flat.top_m(&ds, &q, 9, None));
        assert_eq!(fresh.top_m(&ds, &q, 9, None), flat.top_m(&ds, &q, 9, None));
    }

    #[test]
    fn nprobe_caps_scanned_lists_but_fills_the_heap() {
        let ds = tiny(500, 4);
        let cp = ClusterPruned::build_with_threads(&ds, 16, 2, 21, 1);
        let q = ds.proxy_row(7).to_vec();
        let got = cp.top_m(&ds, &q, 32, None);
        // approximate mode may miss true neighbours but never underfills
        assert_eq!(got.len(), 32, "approximate mode still returns m rows");
        let distinct: std::collections::HashSet<u32> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 32);
    }

    #[test]
    fn conditional_queries_stay_in_class_for_all_backends() {
        let ds = tiny(400, 8);
        for b in backends(&ds) {
            for class in 0..3u32 {
                let got = b.top_m(&ds, &vec![0.05; ds.proxy_d], 16, Some(class));
                assert!(!got.is_empty(), "{}", b.name());
                assert!(
                    got.iter().all(|&i| ds.labels[i as usize] == class),
                    "{} leaked class rows",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn streamed_dataset_serves_every_backend_byte_identically() {
        // Tentpole: the monolithic backends serve a data-free corpus —
        // coarse screens read the resident proxies, refines stream
        // shard-at-a-time — with the exact resident results, across the
        // kernel and the row-major reference ladders
        let ds = tiny(260, 41);
        let dir = std::env::temp_dir().join("golddiff_backend_stream_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = crate::data::store::store_path(&dir, "cifar-sim");
        crate::data::store::save_sharded(&ds, &path, 4).unwrap();
        // a tight budget so the LRU actually cycles during refines
        let st = crate::data::store::open_streaming(&path, 4, 1).unwrap();
        assert!(st.streamed().is_some());
        let mut rng = Pcg64::new(7);
        for kernel in [true, false] {
            let opts = BackendOpts {
                threads: 2,
                clusters: 8,
                kernel,
                refine_kernel: kernel,
                ..BackendOpts::default()
            };
            for &kind in RetrievalBackendKind::all() {
                let res = kind.build(&ds, opts);
                let str_ = kind.build(&st, opts);
                for round in 0..4 {
                    let m = 1 + rng.below(64);
                    let k = 1 + rng.below(20);
                    let qp: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
                    let q: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
                    let a = res.top_m(&ds, &qp, m, None);
                    let b = str_.top_m(&st, &qp, m, None);
                    assert_eq!(a, b, "{} kernel={kernel} coarse round {round}", res.name());
                    let ra = res.refine_top_k(&ds, &q, &a, k);
                    let rb = str_.refine_top_k(&st, &q, &b, k);
                    assert_eq!(ra, rb, "{} kernel={kernel} refine round {round}", res.name());
                }
            }
        }
        assert!(
            st.source_stats().unwrap().rows_streamed > 0,
            "refines must actually stream"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kind_parse_and_build_roundtrip() {
        let ds = tiny(200, 2);
        for kernel in [true, false] {
            let opts = BackendOpts {
                threads: 1,
                clusters: 8,
                kernel,
                ..BackendOpts::default()
            };
            for &k in RetrievalBackendKind::all() {
                assert_eq!(RetrievalBackendKind::parse(k.name()), Some(k));
                let b = k.build(&ds, opts);
                let got = b.top_m(&ds, ds.proxy_row(0), 4, None);
                assert_eq!(got[0], 0, "{} self-query (kernel={kernel})", b.name());
            }
        }
        assert_eq!(RetrievalBackendKind::parse("bogus"), None);
        assert_eq!(
            RetrievalBackendKind::parse("ivf"),
            Some(RetrievalBackendKind::ClusterPruned)
        );
    }

    #[test]
    fn quant_tier_matches_f32_byte_for_byte() {
        // Tentpole: quant on vs off returns identical ids for coarse
        // screens (single, conditional and batched) and refines — the
        // int8 bounds only ever exclude rows the exact path would too,
        // and every survivor is rescored in exact f32.
        let ds = tiny(420, 9);
        let pairs: Vec<(Box<dyn RetrievalBackend>, Box<dyn RetrievalBackend>)> = vec![
            (
                Box::new(FlatScan::new(2)),
                Box::new(FlatScan::new(2).with_quant(true)),
            ),
            (
                Box::new(BatchedScan::new(2)),
                Box::new(BatchedScan::new(2).with_quant(true)),
            ),
            (
                Box::new(BatchedScan::new(2).with_ordering(false)),
                Box::new(BatchedScan::new(2).with_ordering(false).with_quant(true)),
            ),
        ];
        forall(83, 20, |rng| {
            let m = gen::usize_in(rng, 1, 96);
            let k = gen::usize_in(rng, 1, 24);
            let qp = gen::vec_normal(rng, ds.proxy_d, 1.0);
            let q = gen::vec_normal(rng, ds.d, 1.0);
            let class = if rng.below(2) == 0 {
                None
            } else {
                Some(rng.below(ds.classes) as u32)
            };
            for (base, quant) in &pairs {
                let want = base.top_m(&ds, &qp, m, class);
                let got = quant.top_m(&ds, &qp, m, class);
                crate::prop_assert!(
                    got == want,
                    "{} quant screen (m={m} class={class:?})",
                    base.name()
                );
                let rw = base.refine_top_k(&ds, &q, &want, k);
                let rg = quant.refine_top_k(&ds, &q, &want, k);
                crate::prop_assert!(rg == rw, "{} quant refine (k={k})", base.name());
            }
            Ok(())
        });
    }

    #[test]
    fn quant_batch_groups_match_reference() {
        // batched group screens + group refines, quant vs the scalar flat
        // reference, across ragged group sizes and mixed classes
        let ds = tiny(350, 15);
        let quant = BatchedScan::new(2).with_quant(true);
        let flat = FlatScan::scalar(2);
        let mut rng = Pcg64::new(29);
        for b in [1usize, 5, 8, 9] {
            let qs: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect())
                .collect();
            let queries: Vec<ProxyQuery> = qs
                .iter()
                .enumerate()
                .map(|(i, q)| ProxyQuery {
                    proxy: q,
                    class: if i % 3 == 1 { Some((i % 4) as u32) } else { None },
                })
                .collect();
            let got = quant.top_m_batch(&ds, &queries, 21);
            for (i, qq) in queries.iter().enumerate() {
                let want = flat.top_m(&ds, qq.proxy, 21, qq.class);
                assert_eq!(got[i], want, "group {b} query {i}");
            }
            // group refine over the screened pools
            let fq: Vec<Vec<f32>> = (0..b)
                .map(|_| (0..ds.d).map(|_| rng.normal()).collect())
                .collect();
            let fqs: Vec<&[f32]> = fq.iter().map(|v| v.as_slice()).collect();
            let pools: Vec<&[u32]> = got.iter().map(|p| p.as_slice()).collect();
            let rg = quant.refine_top_k_batch(&ds, &fqs, &pools, 9);
            for i in 0..b {
                let want = flat.refine_top_k(&ds, fqs[i], pools[i], 9);
                assert_eq!(rg[i], want, "group {b} refine {i}");
            }
        }
    }

    #[test]
    fn quant_telemetry_counts_and_balances() {
        // the invariant the counters advertise:
        // quant_rows_screened == bound_rejects + rescore_rows, and all
        // three stay zero with the tier off
        let ds = tiny(400, 33);
        let off = BatchedScan::new(2);
        let on = BatchedScan::new(2).with_quant(true);
        let q = ds.proxy_row(13).to_vec();
        let fq = ds.row(13).to_vec();
        for be in [&off, &on] {
            let pool = be.top_m(&ds, &q, 64, None);
            let _ = be.refine_top_k(&ds, &fq, &pool, 8);
        }
        let s_off = off.stats();
        assert_eq!(s_off.quant_rows_screened, 0);
        assert_eq!(s_off.rescore_rows, 0);
        assert_eq!(s_off.bound_rejects, 0);
        let s_on = on.stats();
        assert!(s_on.quant_rows_screened > 0, "screen must count rows");
        assert_eq!(
            s_on.quant_rows_screened,
            s_on.bound_rejects + s_on.rescore_rows,
            "every screened row is either rejected by the bound or rescored"
        );
        on.reset_stats();
        assert_eq!(on.stats().quant_rows_screened, 0, "reset zeroes the tier");
    }

    #[test]
    fn kind_build_honours_quant_and_gates_cluster() {
        // opts.quant flips Flat/Batched byte-identically; ClusterPruned
        // ignores the knob (its lists already prune on exact bounds)
        let ds = tiny(260, 41);
        let mut rng = Pcg64::new(43);
        for &kind in RetrievalBackendKind::all() {
            let base = kind.build(
                &ds,
                BackendOpts {
                    threads: 2,
                    clusters: 8,
                    ..BackendOpts::default()
                },
            );
            let quant = kind.build(
                &ds,
                BackendOpts {
                    threads: 2,
                    clusters: 8,
                    quant: true,
                    ..BackendOpts::default()
                },
            );
            for round in 0..4 {
                let m = 1 + rng.below(48);
                let k = 1 + rng.below(12);
                let qp: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
                let q: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
                let a = base.top_m(&ds, &qp, m, None);
                let b = quant.top_m(&ds, &qp, m, None);
                assert_eq!(a, b, "{} round {round}", base.name());
                assert_eq!(
                    base.refine_top_k(&ds, &q, &a, k),
                    quant.refine_top_k(&ds, &q, &a, k),
                    "{} refine round {round}",
                    base.name()
                );
            }
            if kind == RetrievalBackendKind::ClusterPruned {
                assert_eq!(
                    quant.stats().quant_rows_screened,
                    0,
                    "cluster-pruned ignores the quant knob"
                );
            } else {
                assert!(quant.stats().quant_rows_screened > 0, "{}", quant.name());
            }
            assert_eq!(base.stats().quant_rows_screened, 0);
        }
    }
}
