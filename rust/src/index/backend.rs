//! Pluggable retrieval backends behind one `RetrievalBackend` contract.
//!
//! The coarse half of Adaptive Coarse Screening (Sec. 3.4, Eq. 4) used to be
//! a single hard-wired flat scan that ran once per query — B live sequences
//! in one engine tick paid B full passes over the proxy table. This module
//! turns the retrieval step into a trait with three implementations:
//!
//! * [`FlatScan`] — the original sharded scan, extracted behind the trait.
//!   Bit-stable with the seed `ProxyIndex` semantics; the tested reference.
//! * [`BatchedScan`] — a multi-query scan that makes **one** pass over the
//!   proxy table for a whole batch group, keeping one bounded heap per
//!   query. The corpus traversal is memory-bandwidth dominated, so
//!   amortising it across the batch is where serving throughput comes from.
//! * [`ClusterPruned`] — an IVF-style backend: k-means over the proxy table
//!   (reusing `data::cluster::kmeans`) at build time, then per-query
//!   pruning of whole clusters via the exact triangle-inequality lower
//!   bound `d(q, x) ≥ d(q, c) − r_c`. With `nprobe == 0` results are
//!   *exact* (identical to `FlatScan` up to distance ties); `nprobe > 0`
//!   is the approximate fallback that scans only the nprobe nearest lists.
//!
//! All backends share the exact full-resolution refine (Eq. 5) and expose
//! atomic telemetry counters (`proxy_passes`, `rows_scanned`,
//! `clusters_pruned`, …) that the engine's stats and the perf benches
//! scrape. See `index/README.md` for when each backend wins.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::scan::ProxyIndex;
use super::topk::BoundedMaxHeap;
use crate::data::cluster::kmeans;
use crate::data::dataset::Dataset;
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_chunks;

/// One coarse query of a batch: the s=1/4 proxy embedding plus the optional
/// conditional class restriction.
#[derive(Debug, Clone)]
pub struct ProxyQuery<'a> {
    pub proxy: &'a [f32],
    pub class: Option<u32>,
}

/// Snapshot of a backend's cumulative retrieval telemetry.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetrievalStats {
    /// full traversals of the proxy table (a batched scan counts one pass
    /// for the whole group; cluster-pruned scans never do a full pass)
    pub proxy_passes: u64,
    /// individual coarse queries answered
    pub queries: u64,
    /// proxy rows actually visited across all queries
    pub rows_scanned: u64,
    /// clusters scanned (ClusterPruned only)
    pub clusters_scanned: u64,
    /// clusters skipped via the centroid lower bound or nprobe cap
    pub clusters_pruned: u64,
}

#[derive(Debug, Default)]
struct Counters {
    proxy_passes: AtomicU64,
    queries: AtomicU64,
    rows_scanned: AtomicU64,
    clusters_scanned: AtomicU64,
    clusters_pruned: AtomicU64,
}

impl Counters {
    fn snapshot(&self) -> RetrievalStats {
        RetrievalStats {
            proxy_passes: self.proxy_passes.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            rows_scanned: self.rows_scanned.load(Ordering::Relaxed),
            clusters_scanned: self.clusters_scanned.load(Ordering::Relaxed),
            clusters_pruned: self.clusters_pruned.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.proxy_passes.store(0, Ordering::Relaxed);
        self.queries.store(0, Ordering::Relaxed);
        self.rows_scanned.store(0, Ordering::Relaxed);
        self.clusters_scanned.store(0, Ordering::Relaxed);
        self.clusters_pruned.store(0, Ordering::Relaxed);
    }
}

/// The retrieval contract every backend implements. Coarse top-m produces
/// the candidate pool C_t; the exact refine produces the golden subset S_t.
///
/// `Send + Sync` so one backend instance can be shared by the engine's
/// denoisers and scraped for telemetry from other threads.
pub trait RetrievalBackend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Coarse top-m over the proxy table for a single query. Returns row
    /// ids sorted ascending by proxy distance; class-conditional queries
    /// only see rows of that class.
    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32>;

    /// Coarse top-m for a whole batch group sharing one budget `m`. The
    /// default loops `top_m`; `BatchedScan` overrides it with a one-pass
    /// traversal.
    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        queries
            .iter()
            .map(|q| self.top_m(ds, q.proxy, m, q.class))
            .collect()
    }

    /// Exact full-resolution top-k inside a candidate pool (Eq. 5). Shared
    /// CPU reference used by every backend.
    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        exact_refine(ds, q, cands, k, crate::util::threadpool::default_threads())
    }

    /// Cumulative telemetry since construction (or the last reset).
    fn stats(&self) -> RetrievalStats;

    /// Zero the telemetry counters (bench harness hook).
    fn reset_stats(&self);
}

/// Exact top-k of ||q − x_i||² over `cands`, sorted ascending — the shared
/// refine every backend uses (same algorithm as `ProxyIndex::refine_top_k`).
pub fn exact_refine(ds: &Dataset, q: &[f32], cands: &[u32], k: usize, threads: usize) -> Vec<u32> {
    ProxyIndex { threads }.refine_top_k(ds, q, cands, k)
}

// ---------------------------------------------------------------------------
// FlatScan
// ---------------------------------------------------------------------------

/// The seed's sharded flat scan behind the trait: one full proxy-table pass
/// per query. The CPU reference semantics — all other backends must agree
/// with it (see the parity property tests).
#[derive(Debug, Default)]
pub struct FlatScan {
    inner: ProxyIndex,
    counters: Counters,
}

impl FlatScan {
    pub fn new(threads: usize) -> FlatScan {
        FlatScan {
            inner: ProxyIndex { threads },
            counters: Counters::default(),
        }
    }
}

impl RetrievalBackend for FlatScan {
    fn name(&self) -> &'static str {
        "flat"
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.counters.proxy_passes.fetch_add(1, Ordering::Relaxed);
        self.counters.queries.fetch_add(1, Ordering::Relaxed);
        let got = match class {
            Some(y) => {
                self.counters
                    .rows_scanned
                    .fetch_add(ds.class_rows[y as usize].len() as u64, Ordering::Relaxed);
                self.inner.top_m_class(ds, query_proxy, m, y)
            }
            None => {
                self.counters
                    .rows_scanned
                    .fetch_add(ds.n as u64, Ordering::Relaxed);
                self.inner.top_m(ds, query_proxy, m)
            }
        };
        got
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        self.inner.refine_top_k(ds, q, cands, k)
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// BatchedScan
// ---------------------------------------------------------------------------

/// Multi-query scan: one pass over the proxy table per `top_m_batch` call,
/// one bounded heap per query. Rows stream through the cache once and are
/// scored against every query in the group, so the memory-bandwidth cost of
/// the corpus traversal is amortised across the whole batch.
#[derive(Debug)]
pub struct BatchedScan {
    pub threads: usize,
    counters: Counters,
}

impl Default for BatchedScan {
    fn default() -> Self {
        BatchedScan::new(crate::util::threadpool::default_threads())
    }
}

impl BatchedScan {
    pub fn new(threads: usize) -> BatchedScan {
        BatchedScan {
            threads,
            counters: Counters::default(),
        }
    }

    /// Same spawn-overhead threshold as the flat scan (the batch multiplies
    /// the work, never shrinks it, so single-query sharding stays stable).
    fn effective_threads(&self, work: usize) -> usize {
        if work < 2_000_000 {
            1
        } else {
            self.threads
        }
    }
}

impl RetrievalBackend for BatchedScan {
    fn name(&self) -> &'static str {
        "batched"
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.top_m_batch(
            ds,
            &[ProxyQuery {
                proxy: query_proxy,
                class,
            }],
            m,
        )
        .pop()
        .unwrap_or_default()
    }

    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        if queries.is_empty() {
            return Vec::new();
        }
        let b = queries.len();
        let cap = m.max(1).min(ds.n.max(1));
        self.counters.proxy_passes.fetch_add(1, Ordering::Relaxed);
        self.counters.queries.fetch_add(b as u64, Ordering::Relaxed);
        self.counters
            .rows_scanned
            .fetch_add(ds.n as u64, Ordering::Relaxed);

        let threads = self.effective_threads(ds.n * ds.proxy_d);
        let conditional = queries.iter().any(|q| q.class.is_some());
        let shards: Vec<Vec<BoundedMaxHeap>> = parallel_chunks(ds.n, threads, |_, s, e| {
            let mut heaps: Vec<BoundedMaxHeap> =
                (0..b).map(|_| BoundedMaxHeap::new(cap)).collect();
            for i in s..e {
                let row = ds.proxy_row(i);
                let label = if conditional { ds.labels[i] } else { 0 };
                for (j, q) in queries.iter().enumerate() {
                    if let Some(y) = q.class {
                        if y != label {
                            continue;
                        }
                    }
                    let heap = &mut heaps[j];
                    let d = super::scan::sqdist_early_exit(q.proxy, row, heap.worst());
                    if d.is_finite() {
                        heap.push(d, i as u32);
                    }
                }
            }
            heaps
        });

        let mut merged: Vec<BoundedMaxHeap> = (0..b).map(|_| BoundedMaxHeap::new(cap)).collect();
        for shard in shards {
            for (j, heap) in shard.into_iter().enumerate() {
                merged[j].merge(heap);
            }
        }
        merged
            .into_iter()
            .map(|h| h.into_sorted().into_iter().map(|(_, i)| i).collect())
            .collect()
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        exact_refine(ds, q, cands, k, self.threads)
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// ClusterPruned
// ---------------------------------------------------------------------------

/// IVF-style backend: the proxy table is k-means-partitioned into `lists`
/// clusters once at build time; a query visits clusters in ascending
/// centroid distance and, once its heap is full, skips any cluster whose
/// triangle-inequality lower bound `(d(q, c) − r_c)²` already exceeds the
/// worst retained distance. Local-structure arguments (Wang & Vastola 2024)
/// say posterior mass concentrates on a few clusters at moderate-to-low
/// noise, so most lists are skipped with *exact* bounds.
///
/// Knobs:
/// * `nprobe == 0` (default) — exactness: only bound-justified skips, the
///   result equals the flat scan.
/// * `nprobe > 0` — approximate fallback: scan at most `nprobe` nearest
///   clusters (still topping up past the cap if the heap is not yet full,
///   so a class-conditional query always gets its m rows when they exist).
pub struct ClusterPruned {
    pub threads: usize,
    /// number of IVF lists (k-means clusters over the proxy table)
    lists: usize,
    /// 0 = exact bound pruning; >0 = scan at most this many nearest lists
    nprobe: usize,
    /// centroids [lists × proxy_d]
    centroids: Vec<f32>,
    /// member row ids per list
    members: Vec<Vec<u32>>,
    /// max Euclidean member→centroid distance per list
    radius: Vec<f32>,
    counters: Counters,
}

impl ClusterPruned {
    /// Partition the dataset's proxy table (build once per dataset; the
    /// k-means substrate is `data::cluster::kmeans`, the same code the PCA
    /// baseline's dataset build uses).
    pub fn build(ds: &Dataset, lists: usize, nprobe: usize, seed: u64) -> ClusterPruned {
        Self::build_with_threads(
            ds,
            lists,
            nprobe,
            seed,
            crate::util::threadpool::default_threads(),
        )
    }

    pub fn build_with_threads(
        ds: &Dataset,
        lists: usize,
        nprobe: usize,
        seed: u64,
        threads: usize,
    ) -> ClusterPruned {
        let lists = lists.clamp(1, ds.n.max(1));
        let mut rng = Pcg64::with_stream(seed, 0x1f5);
        let (centroids, assign) = kmeans(&ds.proxies, ds.n, ds.proxy_d, lists, 8, &mut rng);
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); lists];
        for (i, &a) in assign.iter().enumerate() {
            members[a as usize].push(i as u32);
        }
        let mut radius = vec![0.0f32; lists];
        for (cl, rows) in members.iter().enumerate() {
            let c = &centroids[cl * ds.proxy_d..(cl + 1) * ds.proxy_d];
            let mut worst = 0.0f32;
            for &i in rows {
                let d = super::scan::sqdist_flat(ds.proxy_row(i as usize), c);
                worst = worst.max(d);
            }
            radius[cl] = worst.sqrt();
        }
        ClusterPruned {
            threads,
            lists,
            nprobe,
            centroids,
            members,
            radius,
            counters: Counters::default(),
        }
    }

    pub fn lists(&self) -> usize {
        self.lists
    }
}

impl RetrievalBackend for ClusterPruned {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        let cap = m.max(1).min(ds.n.max(1));
        self.counters.queries.fetch_add(1, Ordering::Relaxed);

        // rank clusters by centroid distance
        let pd = ds.proxy_d;
        let mut order: Vec<(f32, usize)> = (0..self.lists)
            .map(|cl| {
                (
                    super::scan::sqdist_flat(query_proxy, &self.centroids[cl * pd..(cl + 1) * pd]),
                    cl,
                )
            })
            .collect();
        order.sort_by(|a, b| a.0.total_cmp(&b.0));

        let mut heap = BoundedMaxHeap::new(cap);
        let mut scanned_lists = 0u64;
        let mut pruned_lists = 0u64;
        let mut rows_scanned = 0u64;
        for &(c_d2, cl) in &order {
            // pruning only ever applies once the heap is full — a query
            // must always receive its m rows when they exist
            if heap.len() >= cap {
                let lb = (c_d2.sqrt() - self.radius[cl]).max(0.0);
                if lb * lb >= heap.worst() {
                    pruned_lists += 1;
                    continue;
                }
                if self.nprobe > 0 && scanned_lists >= self.nprobe as u64 {
                    pruned_lists += 1;
                    continue;
                }
            }
            scanned_lists += 1;
            for &gid in &self.members[cl] {
                if let Some(y) = class {
                    if ds.labels[gid as usize] != y {
                        continue;
                    }
                }
                rows_scanned += 1;
                let row = ds.proxy_row(gid as usize);
                let d = super::scan::sqdist_early_exit(query_proxy, row, heap.worst());
                if d.is_finite() {
                    heap.push(d, gid);
                }
            }
        }
        self.counters
            .clusters_scanned
            .fetch_add(scanned_lists, Ordering::Relaxed);
        self.counters
            .clusters_pruned
            .fetch_add(pruned_lists, Ordering::Relaxed);
        self.counters
            .rows_scanned
            .fetch_add(rows_scanned, Ordering::Relaxed);
        heap.into_sorted().into_iter().map(|(_, i)| i).collect()
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        exact_refine(ds, q, cands, k, self.threads)
    }

    fn stats(&self) -> RetrievalStats {
        self.counters.snapshot()
    }

    fn reset_stats(&self) {
        self.counters.reset();
    }
}

// ---------------------------------------------------------------------------
// Kind selection (config / CLI surface)
// ---------------------------------------------------------------------------

/// Config-facing backend taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetrievalBackendKind {
    Flat,
    Batched,
    ClusterPruned,
}

impl RetrievalBackendKind {
    pub fn parse(s: &str) -> Option<RetrievalBackendKind> {
        Some(match s {
            "flat" => RetrievalBackendKind::Flat,
            "batched" => RetrievalBackendKind::Batched,
            "cluster" | "cluster-pruned" | "ivf" => RetrievalBackendKind::ClusterPruned,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RetrievalBackendKind::Flat => "flat",
            RetrievalBackendKind::Batched => "batched",
            RetrievalBackendKind::ClusterPruned => "cluster",
        }
    }

    pub fn all() -> &'static [RetrievalBackendKind] {
        &[
            RetrievalBackendKind::Flat,
            RetrievalBackendKind::Batched,
            RetrievalBackendKind::ClusterPruned,
        ]
    }

    /// Build a shareable backend for a dataset. `clusters`/`nprobe` only
    /// apply to the cluster-pruned backend.
    pub fn build(
        &self,
        ds: &Dataset,
        threads: usize,
        clusters: usize,
        nprobe: usize,
        seed: u64,
    ) -> Arc<dyn RetrievalBackend> {
        match self {
            RetrievalBackendKind::Flat => Arc::new(FlatScan::new(threads)),
            RetrievalBackendKind::Batched => Arc::new(BatchedScan::new(threads)),
            RetrievalBackendKind::ClusterPruned => Arc::new(ClusterPruned::build_with_threads(
                ds,
                clusters.max(1),
                nprobe,
                seed,
                threads,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::util::prop::{forall, gen};

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    fn backends(ds: &Dataset) -> Vec<Box<dyn RetrievalBackend>> {
        vec![
            Box::new(FlatScan::new(2)),
            Box::new(BatchedScan::new(2)),
            Box::new(ClusterPruned::build_with_threads(ds, 12, 0, 7, 2)),
            // pruning disabled: every list within nprobe and bounds can
            // never exclude (radius covers all members, nprobe = lists)
            Box::new(ClusterPruned::build_with_threads(ds, 1, 0, 7, 2)),
        ]
    }

    #[test]
    fn parity_flat_batched_cluster_unconditional_and_conditional() {
        // Satellite: BatchedScan and ClusterPruned (exact mode) return
        // identical row ids to FlatScan for random queries, including
        // class-conditional scans.
        let ds = tiny(500, 3);
        let all = backends(&ds);
        let flat = &all[0];
        forall(61, 25, |rng| {
            let m = gen::usize_in(rng, 1, 96);
            let q = gen::vec_normal(rng, ds.proxy_d, 1.0);
            let class = if rng.below(2) == 0 {
                None
            } else {
                Some(rng.below(ds.classes) as u32)
            };
            let want = flat.top_m(&ds, &q, m, class);
            for b in &all[1..] {
                let got = b.top_m(&ds, &q, m, class);
                crate::prop_assert!(
                    got == want,
                    "{} != flat (m={m} class={class:?}): {got:?} vs {want:?}",
                    b.name()
                );
            }
            Ok(())
        });
    }

    #[test]
    fn batch_matches_per_query_results() {
        let ds = tiny(400, 5);
        let batched = BatchedScan::new(2);
        let flat = FlatScan::new(2);
        let mut rng = Pcg64::new(11);
        let qs: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect())
            .collect();
        let queries: Vec<ProxyQuery> = qs
            .iter()
            .enumerate()
            .map(|(i, q)| ProxyQuery {
                proxy: q,
                class: if i % 3 == 0 { Some((i % 4) as u32) } else { None },
            })
            .collect();
        let got = batched.top_m_batch(&ds, &queries, 24);
        for (i, q) in queries.iter().enumerate() {
            let want = flat.top_m(&ds, q.proxy, 24, q.class);
            assert_eq!(got[i], want, "query {i}");
        }
    }

    #[test]
    fn batched_scan_counts_one_pass_per_group() {
        let ds = tiny(300, 6);
        let batched = BatchedScan::new(1);
        let q = vec![0.1f32; ds.proxy_d];
        let queries: Vec<ProxyQuery> = (0..8)
            .map(|_| ProxyQuery {
                proxy: &q,
                class: None,
            })
            .collect();
        let _ = batched.top_m_batch(&ds, &queries, 16);
        let s = batched.stats();
        assert_eq!(s.proxy_passes, 1, "8 queries must share one pass");
        assert_eq!(s.queries, 8);
        assert_eq!(s.rows_scanned, ds.n as u64);

        let flat = FlatScan::new(1);
        for _ in 0..8 {
            let _ = flat.top_m(&ds, &q, 16, None);
        }
        assert_eq!(flat.stats().proxy_passes, 8, "flat pays one pass per query");
    }

    #[test]
    fn cluster_pruning_skips_lists_and_accounts_for_all() {
        let ds = tiny(600, 9);
        let cp = ClusterPruned::build_with_threads(&ds, 16, 0, 13, 1);
        // self-query at tiny m: after the home cluster the worst retained
        // distance is ~0, so far-away lists must be bound-pruned
        let q = ds.proxy_row(42).to_vec();
        let got = cp.top_m(&ds, &q, 1, None);
        assert_eq!(got[0], 42);
        let s = cp.stats();
        assert_eq!(
            s.clusters_scanned + s.clusters_pruned,
            cp.lists() as u64,
            "every list is either scanned or pruned"
        );
        assert!(s.clusters_pruned > 0, "self-query must prune some lists");
        assert!(s.rows_scanned < ds.n as u64, "pruning must skip rows");
    }

    #[test]
    fn nprobe_caps_scanned_lists_but_fills_the_heap() {
        let ds = tiny(500, 4);
        let cp = ClusterPruned::build_with_threads(&ds, 16, 2, 21, 1);
        let q = ds.proxy_row(7).to_vec();
        let got = cp.top_m(&ds, &q, 32, None);
        // approximate mode may miss true neighbours but never underfills
        assert_eq!(got.len(), 32, "approximate mode still returns m rows");
        let distinct: std::collections::HashSet<u32> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 32);
    }

    #[test]
    fn conditional_queries_stay_in_class_for_all_backends() {
        let ds = tiny(400, 8);
        for b in backends(&ds) {
            for class in 0..3u32 {
                let got = b.top_m(&ds, &vec![0.05; ds.proxy_d], 16, Some(class));
                assert!(!got.is_empty(), "{}", b.name());
                assert!(
                    got.iter().all(|&i| ds.labels[i as usize] == class),
                    "{} leaked class rows",
                    b.name()
                );
            }
        }
    }

    #[test]
    fn kind_parse_and_build_roundtrip() {
        let ds = tiny(200, 2);
        for &k in RetrievalBackendKind::all() {
            assert_eq!(RetrievalBackendKind::parse(k.name()), Some(k));
            let b = k.build(&ds, 1, 8, 0, 0);
            let got = b.top_m(&ds, ds.proxy_row(0), 4, None);
            assert_eq!(got[0], 0, "{} self-query", b.name());
        }
        assert_eq!(RetrievalBackendKind::parse("bogus"), None);
        assert_eq!(
            RetrievalBackendKind::parse("ivf"),
            Some(RetrievalBackendKind::ClusterPruned)
        );
    }
}
