//! The coarse proxy scan: top-m_t nearest rows of the s=1/4 proxy table.
//!
//! This is the L3 half of Adaptive Coarse Screening (Sec. 3.4, Eq. 4). The
//! scan is sharded across a thread pool; each shard keeps a bounded top-m
//! heap and shards merge at the end, so the scan is O(N·d_proxy + m log m)
//! with zero allocation inside the distance loop. The unrolled
//! squared-distance inner loop and early-exit against the shard's current
//! worst retained distance are the §Perf levers (EXPERIMENTS.md).

use super::topk::BoundedMaxHeap;
use crate::data::dataset::Dataset;
use crate::util::threadpool::parallel_chunks;

/// Scan configuration + scratch-free entry points.
#[derive(Debug, Clone)]
pub struct ProxyIndex {
    pub threads: usize,
}

impl Default for ProxyIndex {
    fn default() -> Self {
        ProxyIndex {
            threads: crate::util::threadpool::default_threads(),
        }
    }
}

/// Branchless squared distance — auto-vectorises (the early-exit branch
/// below defeats SIMD, so short rows use this instead).
#[inline]
pub(crate) fn sqdist_flat(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for s in 0..chunks {
        let i = s * 8;
        for j in 0..8 {
            let d = a[i + j] - b[i + j];
            acc[j] += d * d;
        }
    }
    let mut total: f32 = acc.iter().sum();
    for i in chunks * 8..a.len() {
        let d = a[i] - b[i];
        total += d * d;
    }
    total
}

/// Squared distance between an f32 query and a per-row-scaled int8 code
/// vector (`d̂² = Σ (q_j − scale·code_j)²`) — the quantised-tier analogue
/// of [`sqdist_flat`], same 8-lane accumulator idiom so it vectorises.
#[inline]
pub(crate) fn quant_sqdist(q: &[f32], codes: &[i8], scale: f32) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = q.len() / 8;
    for s in 0..chunks {
        let i = s * 8;
        for j in 0..8 {
            let d = q[i + j] - scale * codes[i + j] as f32;
            acc[j] += d * d;
        }
    }
    let mut total: f32 = acc.iter().sum();
    for i in chunks * 8..q.len() {
        let d = q[i] - scale * codes[i] as f32;
        total += d * d;
    }
    total
}

#[inline]
pub(crate) fn sqdist_early_exit(a: &[f32], b: &[f32], cutoff: f32) -> f32 {
    // 64-element strips with a cutoff check between strips: in the
    // late-diffusion regime the heap's worst distance is tiny, so most rows
    // exit after the first strip, while each strip stays vectorisable.
    if a.len() <= 64 {
        return sqdist_flat(a, b);
    }
    let mut acc = 0.0f32;
    let strips = a.len() / 64;
    for s in 0..strips {
        acc += sqdist_flat(&a[s * 64..(s + 1) * 64], &b[s * 64..(s + 1) * 64]);
        if acc >= cutoff {
            return f32::INFINITY;
        }
    }
    let rem = strips * 64;
    acc += sqdist_flat(&a[rem..], &b[rem..]);
    acc
}

impl ProxyIndex {
    /// Scoped-thread spawn costs ~0.3 ms; below this many element-ops a
    /// single-threaded scan wins (measured in benches/perf_hotpath.rs).
    fn effective_threads(&self, work: usize) -> usize {
        if work < 2_000_000 {
            1
        } else {
            self.threads
        }
    }

    /// Unconditional top-m scan over the whole proxy table.
    /// Returns row ids sorted ascending by proxy distance.
    pub fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize) -> Vec<u32> {
        assert_eq!(query_proxy.len(), ds.proxy_d);
        let m = m.max(1).min(ds.n);
        let threads = self.effective_threads(ds.n * ds.proxy_d);
        let shards = parallel_chunks(ds.n, threads, |_, s, e| {
            let mut heap = BoundedMaxHeap::new(m);
            for i in s..e {
                let row = ds.proxy_row(i);
                let d = sqdist_early_exit(query_proxy, row, heap.worst());
                if d.is_finite() {
                    heap.push(d, i as u32);
                }
            }
            heap
        });
        let mut all = BoundedMaxHeap::new(m);
        for shard in shards {
            all.merge(shard);
        }
        all.into_sorted().into_iter().map(|(_, i)| i).collect()
    }

    /// Class-conditional top-m scan (ImageNet-sim conditional generation):
    /// only rows of `class` participate.
    pub fn top_m_class(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: u32) -> Vec<u32> {
        let rows = &ds.class_rows[class as usize];
        let m = m.max(1).min(rows.len().max(1));
        let threads = self.effective_threads(rows.len() * ds.proxy_d);
        let shards = parallel_chunks(rows.len(), threads, |_, s, e| {
            let mut heap = BoundedMaxHeap::new(m);
            for &gid in &rows[s..e] {
                let row = ds.proxy_row(gid as usize);
                let d = sqdist_early_exit(query_proxy, row, heap.worst());
                if d.is_finite() {
                    heap.push(d, gid);
                }
            }
            heap
        });
        let mut all = BoundedMaxHeap::new(m);
        for shard in shards {
            all.merge(shard);
        }
        all.into_sorted().into_iter().map(|(_, i)| i).collect()
    }

    /// Exact full-resolution refine inside a candidate pool: top-k of
    /// ||q - x_i||² over `cands` (Eq. 5) computed on the CPU path.
    /// The runtime-backed engine uses the `exact_dist` XLA artifact instead;
    /// this scalar path is the tested reference and the no-runtime fallback.
    pub fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        let k = k.max(1).min(cands.len().max(1));
        let threads = self.effective_threads(cands.len() * ds.d);
        let shards = parallel_chunks(cands.len(), threads, |_, s, e| {
            let mut heap = BoundedMaxHeap::new(k);
            // source-agnostic row access. The pool arrives in coarse
            // -distance order and MUST be visited in that order (the
            // bit-stable reference contract: visit order resolves exact
            // f32 ties), so on a streamed corpus the cursor re-pins a
            // shard whenever consecutive candidates hop shards — the LRU
            // absorbs the hops while the budget holds a few shards
            let mut cur = ds.row_cursor();
            for &gid in &cands[s..e] {
                let row = cur.row(gid);
                let d = sqdist_early_exit(q, row, heap.worst());
                if d.is_finite() {
                    heap.push(d, gid);
                }
            }
            heap
        });
        let mut all = BoundedMaxHeap::new(k);
        for shard in shards {
            all.merge(shard);
        }
        all.into_sorted().into_iter().map(|(_, i)| i).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::util::prop::{forall, gen};

    fn tiny() -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = 400;
        Dataset::synthesize(&spec, 5)
    }

    fn naive_top_m(ds: &Dataset, qp: &[f32], m: usize) -> Vec<u32> {
        let mut dists: Vec<(f32, u32)> = (0..ds.n)
            .map(|i| {
                let d: f32 = ds
                    .proxy_row(i)
                    .iter()
                    .zip(qp)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                (d, i as u32)
            })
            .collect();
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        dists.truncate(m);
        dists.into_iter().map(|(_, i)| i).collect()
    }

    #[test]
    fn top_m_matches_naive() {
        let ds = tiny();
        let idx = ProxyIndex::default();
        forall(3, 20, |rng| {
            let m = gen::usize_in(rng, 1, 64);
            let qi = rng.below(ds.n);
            let qp = ds.proxy_row(qi).to_vec();
            let got = idx.top_m(&ds, &qp, m);
            let want = naive_top_m(&ds, &qp, m);
            crate::prop_assert!(got == want, "m={m} qi={qi}");
            Ok(())
        });
    }

    #[test]
    fn self_query_returns_self_first() {
        let ds = tiny();
        let idx = ProxyIndex::default();
        let got = idx.top_m(&ds, ds.proxy_row(37), 5);
        assert_eq!(got[0], 37);
    }

    #[test]
    fn class_conditional_scan_stays_in_class() {
        let ds = tiny();
        let idx = ProxyIndex::default();
        let qp = ds.proxy_row(0).to_vec();
        for class in 0..3u32 {
            let got = idx.top_m_class(&ds, &qp, 16, class);
            assert!(!got.is_empty());
            assert!(got.iter().all(|&i| ds.labels[i as usize] == class));
        }
    }

    #[test]
    fn refine_orders_by_full_distance() {
        let ds = tiny();
        let idx = ProxyIndex::default();
        let q = ds.row(11).to_vec();
        let cands: Vec<u32> = (0..200u32).collect();
        let got = idx.refine_top_k(&ds, &q, &cands, 7);
        assert_eq!(got[0], 11);
        // verify sorted by exact distance
        let dist = |i: u32| -> f32 {
            ds.row(i as usize)
                .iter()
                .zip(&q)
                .map(|(a, b)| (a - b) * (a - b))
                .sum()
        };
        for w in got.windows(2) {
            assert!(dist(w[0]) <= dist(w[1]) + 1e-6);
        }
    }

    #[test]
    fn m_larger_than_n_clamps() {
        let ds = tiny();
        let idx = ProxyIndex::default();
        let got = idx.top_m(&ds, ds.proxy_row(0), 10_000);
        assert_eq!(got.len(), ds.n);
    }
}
