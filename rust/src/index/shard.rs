//! Shard-parallel retrieval with exact heap merges — the index layer over
//! [`CorpusShards`].
//!
//! [`ShardedBackend`] wraps any [`RetrievalBackendKind`] and runs its
//! coarse screen **per shard** on the scoped worker pool: each shard scans
//! its own pre-blocked proxy table (kernel register tiles or the scalar
//! reference, heap-aware block ordering per shard) into per-query bounded
//! heaps, and the per-shard results are merged **exactly** — every
//! candidate keeps its scan distance, the merged list is sorted ascending
//! by `(distance, row id)` and truncated to the budget. Because each
//! (query, row) distance is a pure function of the query and the row
//! (kernel: dimension-order accumulation; scalar: strip sums), the merged
//! result is byte-identical for *any* shard count; exact f32 distance ties
//! — broken by row id at the merge — remain the only divergence surface,
//! exactly as everywhere else in `index` (see `index/README.md`).
//!
//! The exact refine runs shard-locally too: each tick group's candidate
//! union is split by owning shard and streamed through the masked refine
//! kernel against that shard's [`RowBlocks`] — built lazily, LRU-cached
//! under the corpus `mem_budget`, and (when a `.gds` [`ShardReader`] is
//! attached) rebuilt from disk after eviction. The concentration
//! warm-start also goes shard-local: once the seed pass fills the heap, a
//! whole shard is skipped when its covering-radius bound
//! `(d(q, c_S) − r_S)²` already exceeds the heap's worst retained
//! distance — the shard-level tier of the block bound, still provably
//! exact. Conditional queries skip shards with zero rows of their class
//! outright.
//!
//! Telemetry: `shards_scanned` / `shards_skipped` count (query, shard)
//! scans executed vs avoided (for a cold screen the two always sum to
//! `queries × shard count`), and `shard_evictions` surfaces the corpus
//! LRU; all flow through [`RetrievalStats`] into `EngineStats` and the
//! server's `stats` op.

use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::backend::{
    batched_refine, batched_refine_scored, group_mean, moved_blocks, quant_prefilter,
    refine_masked_by_shard, refine_masked_by_shard_scored, warm_seed_heap, warm_sweep_blocks,
    BackendOpts, Counters, ProxyQuery, RetrievalBackend, RetrievalBackendKind, RetrievalStats,
};
use super::kernel::{self, block_order, KernelScan, KernelStats, ProxyBlocks, QuantScan, QuantStats};
use super::scan::{sqdist_early_exit, sqdist_flat};
use super::topk::BoundedMaxHeap;
use crate::data::dataset::Dataset;
use crate::data::shard::CorpusShards;
use crate::util::rng::Pcg64;
use crate::util::threadpool::parallel_chunks;

/// Scored candidates: ascending `(squared distance, row id)` — the unit
/// of exchange between shard scans, and (in the distributed tier) between
/// shard workers and the coordinator.
pub type Scored = Vec<(f32, u32)>;

/// Per-shard IVF substrate for the sharded cluster-pruned screen: a fresh
/// deterministic k-means over the shard's proxy rows (the dataset's
/// persisted global partition cannot be reused shard-wise), with the
/// `clusters` budget divided across shards so operator intuition about the
/// knob carries over.
struct ShardIvf {
    lists: usize,
    /// [lists × proxy_d]
    centroids: Vec<f32>,
    /// member row ids per list (global)
    members: Vec<Vec<u32>>,
    /// max member→centroid Euclidean distance per list
    radius: Vec<f32>,
    /// pre-blocked kernel tables per list (kernel path only)
    blocks: Vec<ProxyBlocks>,
}

/// Fold of one shard scan's local telemetry (merged into the shared
/// counters after the parallel region).
#[derive(Debug, Default, Clone, Copy)]
struct ScanTel {
    kst: KernelStats,
    qst: QuantStats,
    rows_scalar: u64,
    reorders: u64,
    scanned: u64,
    skipped: u64,
    clusters_scanned: u64,
    clusters_pruned: u64,
}

impl ScanTel {
    fn add(&mut self, o: &ScanTel) {
        self.kst.add(&o.kst);
        self.qst.add(&o.qst);
        self.rows_scalar += o.rows_scalar;
        self.reorders += o.reorders;
        self.scanned += o.scanned;
        self.skipped += o.skipped;
        self.clusters_scanned += o.clusters_scanned;
        self.clusters_pruned += o.clusters_pruned;
    }
}

/// Any backend kind, scanned shard-parallel and merged exactly.
pub struct ShardedBackend {
    corpus: Arc<CorpusShards>,
    kind: RetrievalBackendKind,
    threads: usize,
    use_kernel: bool,
    refine_kernel: bool,
    ordered: bool,
    /// int8 screen per shard + refine pre-rung (kernel Flat/Batched only;
    /// exact f32 rescore keeps results byte-identical)
    quant: bool,
    tile_q: usize,
    nprobe: usize,
    /// one entry per shard when `kind == ClusterPruned`, empty otherwise
    ivf: Vec<ShardIvf>,
    counters: Counters,
}

impl ShardedBackend {
    /// Build the sharded wrapper for `kind`. Row residency (and, for a
    /// streamed dataset, the disk-backed rebuilds) routes through the
    /// dataset's row source — see [`CorpusShards::row_blocks`].
    pub fn build(ds: &Dataset, kind: RetrievalBackendKind, opts: BackendOpts) -> ShardedBackend {
        let corpus = Arc::new(CorpusShards::build(ds, opts.shards, opts.mem_budget_mb));
        let ivf = if kind == RetrievalBackendKind::ClusterPruned {
            build_shard_ivf(ds, &corpus, &opts)
        } else {
            Vec::new()
        };
        // like `clusters`, the approximate probe budget divides across
        // shards so the total scanned lists stay ≈ nprobe. Approximate
        // mode (`nprobe > 0`) is the one knob whose *results* depend on
        // the shard count — the per-shard partitions themselves do — which
        // is exactly what `is_exact() == false` already signals.
        let ns = corpus.plan().count();
        let nprobe = if opts.nprobe > 0 {
            opts.nprobe.div_ceil(ns).max(1)
        } else {
            0
        };
        ShardedBackend {
            corpus,
            kind,
            threads: opts.threads,
            use_kernel: opts.kernel,
            refine_kernel: opts.kernel && opts.refine_kernel,
            ordered: opts.kernel && opts.ordering,
            quant: opts.kernel && opts.quant && kind != RetrievalBackendKind::ClusterPruned,
            tile_q: opts.tile_q.clamp(1, kernel::TILE_Q),
            nprobe,
            ivf,
            counters: Counters::default(),
        }
    }

    /// The sharded corpus (telemetry / bench introspection).
    pub fn corpus(&self) -> &CorpusShards {
        &self.corpus
    }

    fn cap(&self, ds: &Dataset, m: usize) -> usize {
        m.max(1).min(ds.n.max(1))
    }

    /// Is query `q` eligible in shard `sh` (conditional queries skip
    /// shards holding zero rows of their class)?
    fn eligible(&self, sh: usize, q: &ProxyQuery) -> bool {
        match q.class {
            Some(y) => self
                .corpus
                .proxy(sh)
                .class_counts
                .get(y as usize)
                .is_some_and(|&c| c > 0),
            None => true,
        }
    }

    /// Coarse screen of one shard for a query group through kernel tiles
    /// (or the scalar reference): `tile_w = 1` is the flat discipline, the
    /// batched discipline shares each block-column load across the tile.
    fn scan_shard_tiled(
        &self,
        ds: &Dataset,
        sh: usize,
        queries: &[ProxyQuery],
        cap: usize,
        tile_w: usize,
    ) -> (Vec<Scored>, ScanTel) {
        let sp = self.corpus.proxy(sh);
        let mut tel = ScanTel::default();
        let mut out: Vec<Scored> = vec![Vec::new(); queries.len()];
        let eligible: Vec<usize> = (0..queries.len())
            .filter(|&qi| self.eligible(sh, &queries[qi]))
            .collect();
        tel.skipped += (queries.len() - eligible.len()) as u64;
        if sp.blocks.rows == 0 {
            tel.skipped += eligible.len() as u64;
            return (out, tel);
        }
        tel.scanned += eligible.len() as u64;
        for group in eligible.chunks(tile_w.max(1)) {
            let qs: Vec<&[f32]> = group.iter().map(|&qi| queries[qi].proxy).collect();
            let mut heaps: Vec<BoundedMaxHeap> =
                (0..group.len()).map(|_| BoundedMaxHeap::new(cap)).collect();
            if self.use_kernel {
                let classes: Vec<Option<u32>> =
                    group.iter().map(|&qi| queries[qi].class).collect();
                let order = if self.ordered && sp.blocks.n_blocks() > 1 {
                    let mean = group_mean(&qs, ds.proxy_d);
                    let order = block_order(&sp.blocks, &mean);
                    tel.reorders += moved_blocks(&order);
                    Some(order)
                } else {
                    None
                };
                if self.quant {
                    // int8 screen over this shard's lazily-built quant
                    // twin; threads = 1 — we are already inside the
                    // shard-parallel region
                    let scan = QuantScan {
                        blocks: &sp.blocks,
                        quant: sp.quant(),
                        queries: &qs,
                        classes: &classes,
                        labels: Some(&ds.labels),
                    };
                    scan.screen_into(
                        cap,
                        1,
                        order.as_deref(),
                        &mut heaps,
                        &mut tel.qst,
                        &mut tel.kst,
                    );
                } else {
                    let scan = KernelScan {
                        blocks: &sp.blocks,
                        queries: &qs,
                        classes: &classes,
                        labels: Some(&ds.labels),
                    };
                    match &order {
                        Some(order) => scan.scan_list_into(order, &mut heaps, &mut tel.kst),
                        None => scan.scan_into(0, sp.blocks.n_blocks(), &mut heaps, &mut tel.kst),
                    }
                }
            } else {
                let (s, e) = self.corpus.plan().range(sh);
                tel.rows_scalar += (e - s) as u64;
                for i in s..e {
                    let row = ds.proxy_row(i);
                    for (j, &qi) in group.iter().enumerate() {
                        if let Some(y) = queries[qi].class {
                            if ds.labels[i] != y {
                                continue;
                            }
                        }
                        let d = sqdist_early_exit(queries[qi].proxy, row, heaps[j].worst());
                        if d.is_finite() {
                            heaps[j].push(d, i as u32);
                        }
                    }
                }
            }
            for (&qi, heap) in group.iter().zip(heaps) {
                out[qi] = sorted_scored(heap);
            }
        }
        (out, tel)
    }

    /// Coarse screen of one shard through its local IVF lists: lists are
    /// visited nearest-centroid-first and skipped under the exact
    /// triangle-inequality bound once the heap is full. In approximate
    /// mode the build-time per-shard probe budget (`⌈nprobe/shards⌉`)
    /// caps the scanned lists of each shard, keeping the total ≈ the
    /// configured `nprobe`.
    fn scan_shard_cluster(
        &self,
        ds: &Dataset,
        sh: usize,
        queries: &[ProxyQuery],
        cap: usize,
    ) -> (Vec<Scored>, ScanTel) {
        let ivf = &self.ivf[sh];
        let pd = ds.proxy_d;
        let mut tel = ScanTel::default();
        let out = queries
            .iter()
            .map(|q| {
                if ivf.lists == 0 || !self.eligible(sh, q) {
                    tel.skipped += 1;
                    return Vec::new();
                }
                tel.scanned += 1;
                let mut order: Vec<(f32, usize)> = (0..ivf.lists)
                    .map(|cl| {
                        (
                            sqdist_flat(q.proxy, &ivf.centroids[cl * pd..(cl + 1) * pd]),
                            cl,
                        )
                    })
                    .collect();
                order.sort_by(|a, b| a.0.total_cmp(&b.0));
                let mut heap = BoundedMaxHeap::new(cap);
                let mut scanned_lists = 0u64;
                for &(c_d2, cl) in &order {
                    // pruning only ever applies once the heap is full —
                    // small classes / small shards never under-deliver
                    if heap.len() >= cap {
                        let lb = (c_d2.sqrt() - ivf.radius[cl]).max(0.0);
                        if lb * lb >= heap.worst() {
                            tel.clusters_pruned += 1;
                            continue;
                        }
                        if self.nprobe > 0 && scanned_lists >= self.nprobe as u64 {
                            tel.clusters_pruned += 1;
                            continue;
                        }
                    }
                    scanned_lists += 1;
                    if self.use_kernel {
                        let blocks = &ivf.blocks[cl];
                        let queries1 = [q.proxy];
                        let classes1 = [q.class];
                        let scan = KernelScan {
                            blocks,
                            queries: &queries1,
                            classes: &classes1,
                            labels: Some(&ds.labels),
                        };
                        if self.ordered && blocks.n_blocks() > 1 {
                            let bo = block_order(blocks, q.proxy);
                            tel.reorders += moved_blocks(&bo);
                            scan.scan_list_into(&bo, std::slice::from_mut(&mut heap), &mut tel.kst);
                        } else {
                            scan.scan_into(
                                0,
                                blocks.n_blocks(),
                                std::slice::from_mut(&mut heap),
                                &mut tel.kst,
                            );
                        }
                    } else {
                        for &gid in &ivf.members[cl] {
                            if let Some(y) = q.class {
                                if ds.labels[gid as usize] != y {
                                    continue;
                                }
                            }
                            tel.rows_scalar += 1;
                            let d =
                                sqdist_early_exit(q.proxy, ds.proxy_row(gid as usize), heap.worst());
                            if d.is_finite() {
                                heap.push(d, gid);
                            }
                        }
                    }
                }
                tel.clusters_scanned += scanned_lists;
                sorted_scored(heap)
            })
            .collect();
        (out, tel)
    }

    /// Shard-parallel coarse screen + exact `(distance, row id)` merge.
    fn top_m_batch_scored(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Scored> {
        let all: Vec<usize> = (0..self.corpus.plan().count()).collect();
        self.screen_scored(ds, queries, m, &all)
    }

    /// Worker-facing coarse screen over an explicit shard subset: scans
    /// only `subset` (out-of-range shard ids are ignored) and returns
    /// per-query ascending `(distance, row id)` lists truncated to the
    /// cap. Because the merge is associative over shards, a coordinator
    /// merging several workers' subset results by the same `(distance,
    /// row id)` order reproduces the in-process full screen byte for
    /// byte. The full-corpus screen is the `subset = 0..shards` special
    /// case — one implementation, so the two can never silently diverge.
    pub fn screen_scored(
        &self,
        ds: &Dataset,
        queries: &[ProxyQuery],
        m: usize,
        subset: &[usize],
    ) -> Vec<Scored> {
        let cap = self.cap(ds, m);
        let ns = self.corpus.plan().count();
        let shards: Vec<usize> = subset.iter().copied().filter(|&sh| sh < ns).collect();
        if shards.is_empty() {
            return vec![Vec::new(); queries.len()];
        }
        let chunks = parallel_chunks(
            shards.len(),
            self.threads.max(1).min(shards.len()),
            |_, s, e| {
                let mut tel = ScanTel::default();
                let mut acc: Vec<Vec<Scored>> = Vec::with_capacity(e - s);
                for &sh in &shards[s..e] {
                    let (res, t) = match self.kind {
                        RetrievalBackendKind::ClusterPruned => {
                            self.scan_shard_cluster(ds, sh, queries, cap)
                        }
                        RetrievalBackendKind::Flat => self.scan_shard_tiled(ds, sh, queries, cap, 1),
                        RetrievalBackendKind::Batched => {
                            self.scan_shard_tiled(ds, sh, queries, cap, self.tile_q)
                        }
                    };
                    acc.push(res);
                    tel.add(&t);
                }
                (acc, tel)
            },
        );
        let mut tel = ScanTel::default();
        let mut shard_lists: Vec<Vec<Scored>> = Vec::with_capacity(shards.len());
        for (acc, t) in chunks {
            shard_lists.extend(acc);
            tel.add(&t);
        }
        self.record(&tel);
        (0..queries.len())
            .map(|qi| {
                let mut all: Scored = shard_lists
                    .iter()
                    .flat_map(|s| s[qi].iter().copied())
                    .collect();
                all.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                all.truncate(cap);
                all
            })
            .collect()
    }

    /// The shard-local masked refine: the tick group's candidate union is
    /// split by owning shard, each shard streams its (LRU-cached, possibly
    /// disk-rebuilt) row blocks through the masked refine kernel, and the
    /// per-shard heaps merge exactly by `(distance, row id)`. One shared
    /// implementation with the streamed monolithic path —
    /// [`refine_masked_by_shard`] — so the two can never silently diverge.
    fn refine_sharded(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        let (out, rows, kst) = refine_masked_by_shard(
            self.corpus.plan(),
            &|sh| self.corpus.row_blocks(sh, ds),
            qs,
            pools,
            k,
            self.threads,
        );
        self.counters.record_refine(rows, &kst);
        out
    }

    /// The shard-local seeded screen: once the seed pass fills the heap,
    /// whole shards are skipped under `(d(q, c_S) − r_S)² ≥ worst`, and a
    /// scanned shard sweeps its blocks nearest-first under the block-level
    /// bound — the same exactness argument, one more tier.
    fn warm_sharded(
        &self,
        ds: &Dataset,
        qp: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
    ) -> Option<Vec<u32>> {
        let all: Vec<usize> = (0..self.corpus.plan().count()).collect();
        self.warm_scored(ds, qp, class, m, seeds, &all)
            .map(|sc| sc.into_iter().map(|(_, i)| i).collect())
    }

    /// Worker-facing seeded screen over an explicit shard subset: the seed
    /// pass runs over the *global* seed list (cheap, and it gives every
    /// worker the same initial cutoff), then only `subset` shards are
    /// swept. A subset heap's cutoff is weaker than the full sweep's — it
    /// skips fewer shards, never more rows than exactness allows — so the
    /// union of subset results over a shard partition is a superset of the
    /// full sweep's survivors, and a `(distance, row id)` merge truncated
    /// to the cap reproduces the in-process warm screen byte for byte
    /// (seeds appear in every worker's list; the merge dedups by id).
    /// `None` carries the same contract as the global warm screen: fewer
    /// eligible seed rows than the cap — the caller falls back cold.
    pub fn warm_scored(
        &self,
        ds: &Dataset,
        qp: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
        subset: &[usize],
    ) -> Option<Scored> {
        let cap = self.cap(ds, m);
        let mut heap = warm_seed_heap(ds, qp, class, cap, seeds)?;
        let mut scanned = 0u64;
        let mut skipped = 0u64;
        let ns = self.corpus.plan().count();
        // visit shards nearest-centroid-first (ties by shard id) so near
        // shards tighten the cutoff before far shards face the bound —
        // without this the whole-shard skip would rarely engage when the
        // query's neighbourhood lives in a late shard
        let mut shard_order: Vec<(f32, u32)> = subset
            .iter()
            .copied()
            .filter(|&sh| sh < ns)
            .map(|sh| {
                let c = &self.corpus.proxy(sh).centroid;
                let d2: f32 = c.iter().zip(qp).map(|(a, b)| (a - b) * (a - b)).sum();
                (d2, sh as u32)
            })
            .collect();
        shard_order.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for &(sh_d2, sh) in &shard_order {
            let sh = sh as usize;
            let sp = self.corpus.proxy(sh);
            if sp.blocks.rows == 0 {
                skipped += 1;
                continue;
            }
            if let Some(y) = class {
                if sp.class_counts.get(y as usize).is_none_or(|&c| c == 0) {
                    skipped += 1;
                    continue;
                }
            }
            let lb = (sh_d2.sqrt() - sp.radius).max(0.0);
            if lb * lb >= heap.worst() {
                // every row of the shard is provably ≥ the worst retained
                skipped += 1;
                continue;
            }
            scanned += 1;
            // the same nearest-block-first bounded sweep the global warm
            // screen runs, over this shard's blocks only
            warm_sweep_blocks(ds, &sp.blocks, qp, class, seeds, &mut heap);
        }
        self.counters.shards_scanned.fetch_add(scanned, Ordering::Relaxed);
        self.counters.shards_skipped.fetch_add(skipped, Ordering::Relaxed);
        Some(sorted_scored(heap))
    }

    /// Worker-facing scored refine: the same shard-local (or row-major)
    /// discipline as [`RetrievalBackend::refine_top_k_batch`], but keeping
    /// each survivor's exact f32 distance so a coordinator can merge
    /// workers' sub-pool results by `(distance, row id)`. The int8
    /// pre-rung is deliberately absent here — quantisation needs the
    /// *global* per-query pool, so the coordinator applies
    /// [`quant_prefilter`] before splitting pools across workers.
    pub fn refine_scored(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Scored> {
        if qs.is_empty() {
            return Vec::new();
        }
        if !self.refine_kernel {
            let (out, rows) = batched_refine_scored(ds, qs, pools, k, self.threads);
            self.counters.refine_rows.fetch_add(rows, Ordering::Relaxed);
            return out;
        }
        let (out, rows, kst) = refine_masked_by_shard_scored(
            self.corpus.plan(),
            &|sh| self.corpus.row_blocks(sh, ds),
            qs,
            pools,
            k,
            self.threads,
        );
        self.counters.record_refine(rows, &kst);
        out
    }

    /// The int8 refine pre-rung exactly as [`refine_top_k_batch`] applies
    /// it — `None` when this backend would not run it (quant off, or the
    /// row-major reference ladder). A distributing coordinator calls this
    /// *before* splitting pools across workers: the bound needs each
    /// query's global pool, and filtering first means workers whose shards
    /// lose every candidate are never contacted.
    ///
    /// [`refine_top_k_batch`]: RetrievalBackend::refine_top_k_batch
    pub fn quant_refine_prefilter(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Option<Vec<Vec<u32>>> {
        if !(self.refine_kernel && self.quant) {
            return None;
        }
        quant_prefilter(ds, qs, pools, k, &self.counters)
    }

    /// Record one coarse-screen group's pass/query accounting. Pass
    /// accounting mirrors the monolithic kinds: flat pays one logical
    /// table pass per query, batched one per group, cluster none. Shared
    /// with the remote tier, whose coordinator records the group here on
    /// a successful distributed screen so `stats()` stays comparable
    /// whichever path answered.
    pub(crate) fn record_screen_pass(&self, nq: usize) {
        match self.kind {
            RetrievalBackendKind::Flat => {
                self.counters.proxy_passes.fetch_add(nq as u64, Ordering::Relaxed);
            }
            RetrievalBackendKind::Batched => {
                self.counters.proxy_passes.fetch_add(1, Ordering::Relaxed);
            }
            RetrievalBackendKind::ClusterPruned => {}
        }
        self.counters.queries.fetch_add(nq as u64, Ordering::Relaxed);
    }

    fn record(&self, tel: &ScanTel) {
        self.counters.record_kernel(&tel.kst);
        self.counters.record_quant(&tel.qst);
        self.counters
            .rows_scanned
            .fetch_add(tel.rows_scalar, Ordering::Relaxed);
        self.counters
            .blocks_reordered
            .fetch_add(tel.reorders, Ordering::Relaxed);
        self.counters
            .shards_scanned
            .fetch_add(tel.scanned, Ordering::Relaxed);
        self.counters
            .shards_skipped
            .fetch_add(tel.skipped, Ordering::Relaxed);
        self.counters
            .clusters_scanned
            .fetch_add(tel.clusters_scanned, Ordering::Relaxed);
        self.counters
            .clusters_pruned
            .fetch_add(tel.clusters_pruned, Ordering::Relaxed);
    }
}

/// Heap → ascending `(distance, row id)` — the deterministic order every
/// shard contributes to the merge in.
fn sorted_scored(heap: BoundedMaxHeap) -> Scored {
    let mut v = heap.into_sorted();
    v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    v
}

fn build_shard_ivf(ds: &Dataset, corpus: &CorpusShards, opts: &BackendOpts) -> Vec<ShardIvf> {
    let pd = ds.proxy_d;
    let ns = corpus.plan().count();
    let per_shard = opts.clusters.max(1).div_ceil(ns).max(1);
    // reuse the persisted per-shard partitions when the `.gds` store
    // carried a matching set (satellite: a sharded cluster engine stops
    // paying per-shard k-means on every start); the members/radii/blocks
    // derived below are pure functions of (centroids, assignments), so a
    // persisted partition yields the bit-identical backend
    let persisted = ds
        .shard_ivf
        .as_ref()
        .filter(|p| p.matches(ns, per_shard, opts.seed));
    (0..ns)
        .map(|sh| {
            let (s, e) = corpus.plan().range(sh);
            let rows = e - s;
            if rows == 0 {
                return ShardIvf {
                    lists: 0,
                    centroids: Vec::new(),
                    members: Vec::new(),
                    radius: Vec::new(),
                    blocks: Vec::new(),
                };
            }
            let lists = per_shard.clamp(1, rows);
            let (centroids, assign) = match persisted {
                Some(p) => (p.centroids[sh].clone(), p.assignments[sh].clone()),
                None => {
                    // deterministic per-shard stream: shard 0 of a 1-shard
                    // plan reproduces the global IvfPartition's k-means
                    // verbatim (and ShardIvfPartition::compute this stream)
                    let mut rng = Pcg64::with_stream(
                        opts.seed ^ (sh as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15),
                        0x1f5,
                    );
                    crate::data::cluster::kmeans(
                        &ds.proxies[s * pd..e * pd],
                        rows,
                        pd,
                        lists,
                        8,
                        &mut rng,
                    )
                }
            };
            let mut members: Vec<Vec<u32>> = vec![Vec::new(); lists];
            for (local, &a) in assign.iter().enumerate() {
                members[a as usize].push((s + local) as u32);
            }
            let mut radius = vec![0.0f32; lists];
            for (cl, rows_) in members.iter().enumerate() {
                let c = &centroids[cl * pd..(cl + 1) * pd];
                let mut worst = 0.0f32;
                for &gid in rows_ {
                    worst = worst.max(sqdist_flat(ds.proxy_row(gid as usize), c));
                }
                radius[cl] = worst.sqrt();
            }
            let blocks: Vec<ProxyBlocks> = if opts.kernel {
                members
                    .iter()
                    .map(|m| ProxyBlocks::build_subset(&ds.proxies, pd, m))
                    .collect()
            } else {
                Vec::new()
            };
            ShardIvf {
                lists,
                centroids,
                members,
                radius,
                blocks,
            }
        })
        .collect()
}

impl RetrievalBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        match self.kind {
            RetrievalBackendKind::Flat => "sharded-flat",
            RetrievalBackendKind::Batched => "sharded-batched",
            RetrievalBackendKind::ClusterPruned => "sharded-cluster",
        }
    }

    fn is_exact(&self) -> bool {
        !(self.kind == RetrievalBackendKind::ClusterPruned && self.nprobe > 0)
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.top_m_batch(
            ds,
            &[ProxyQuery {
                proxy: query_proxy,
                class,
            }],
            m,
        )
        .pop()
        .unwrap_or_default()
    }

    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        if queries.is_empty() {
            return Vec::new();
        }
        self.record_screen_pass(queries.len());
        self.top_m_batch_scored(ds, queries, m)
            .into_iter()
            .map(|sc| sc.into_iter().map(|(_, i)| i).collect())
            .collect()
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        self.refine_top_k_batch(ds, &[q], &[cands], k)
            .pop()
            .unwrap_or_default()
    }

    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        assert_eq!(qs.len(), pools.len());
        if qs.is_empty() {
            return Vec::new();
        }
        if !self.refine_kernel {
            // the row-major reference ladder is shard-agnostic and exact
            let (out, rows) = batched_refine(ds, qs, pools, k, self.threads);
            self.counters.refine_rows.fetch_add(rows, Ordering::Relaxed);
            return out;
        }
        if self.quant {
            // pre-rung on the persisted row-tier codes: shards left with
            // zero surviving candidates are never touched, so a streamed
            // corpus skips whole `.gds` block loads
            if let Some(filtered) = quant_prefilter(ds, qs, pools, k, &self.counters) {
                let fp: Vec<&[u32]> = filtered.iter().map(Vec::as_slice).collect();
                return self.refine_sharded(ds, qs, &fp, k);
            }
        }
        self.refine_sharded(ds, qs, pools, k)
    }

    fn warm_top_m(
        &self,
        ds: &Dataset,
        query_proxy: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
    ) -> Option<Vec<u32>> {
        self.warm_sharded(ds, query_proxy, class, m, seeds)
    }

    fn stats(&self) -> RetrievalStats {
        let mut s = self.counters.snapshot();
        let cache = self.corpus.cache_stats();
        s.shard_evictions = cache.evictions;
        s.rows_streamed = cache.rows_streamed;
        s.peak_row_bytes = cache.peak_row_bytes;
        s.retries = cache.retries;
        s.checksum_failures = cache.checksum_failures;
        s.faults_injected = cache.faults_injected;
        s
    }

    fn reset_stats(&self) {
        self.counters.reset();
        self.corpus.reset_counters();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store;
    use crate::data::synthetic::preset;
    use crate::index::backend::FlatScan;
    use crate::util::prop::{forall, gen};

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    fn opts(shards: usize, kernel: bool) -> BackendOpts {
        BackendOpts {
            threads: 2,
            clusters: 10,
            shards,
            kernel,
            refine_kernel: kernel,
            ..BackendOpts::default()
        }
    }

    /// The shard-aware ingest ordering (production `with_clustered_rows`):
    /// rows grouped by proxy-space cluster so shards become spatially
    /// coherent — what makes whole-shard bounds actually bite.
    fn clustered(ds: &Dataset) -> Dataset {
        ds.with_clustered_rows(8, 5)
    }

    #[test]
    fn sharded_top_m_matches_flat_reference_across_kinds_and_counts() {
        // Satellite: every kind × kernel/scalar × shard count returns the
        // scalar FlatScan reference's exact row ids, conditional included —
        // single-row shards (shards ≥ n would clamp) ride along via 7.
        let ds = tiny(260, 3);
        let flat = FlatScan::scalar(2);
        for &kind in RetrievalBackendKind::all() {
            for kernel in [true, false] {
                for shards in [1usize, 2, 7] {
                    let sb = ShardedBackend::build(&ds, kind, opts(shards, kernel));
                    forall(97 + shards as u64, 6, |rng| {
                        let m = gen::usize_in(rng, 1, 70);
                        let q = gen::vec_normal(rng, ds.proxy_d, 1.0);
                        let class = if rng.below(2) == 0 {
                            None
                        } else {
                            Some(rng.below(ds.classes) as u32)
                        };
                        let got = sb.top_m(&ds, &q, m, class);
                        let want = flat.top_m(&ds, &q, m, class);
                        crate::prop_assert!(
                            got == want,
                            "{} shards={shards} kernel={kernel} m={m} class={class:?}",
                            sb.name()
                        );
                        Ok(())
                    });
                }
            }
        }
    }

    #[test]
    fn results_are_byte_identical_across_shard_counts() {
        let ds = tiny(300, 9);
        let mut rng = Pcg64::new(21);
        let qs_data: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect())
            .collect();
        let queries: Vec<ProxyQuery> = qs_data
            .iter()
            .enumerate()
            .map(|(i, q)| ProxyQuery {
                proxy: q,
                class: (i % 3 == 0).then_some((i % 4) as u32),
            })
            .collect();
        for &kind in RetrievalBackendKind::all() {
            let mut reference: Option<Vec<Vec<u32>>> = None;
            for shards in [1usize, 2, 7] {
                let sb = ShardedBackend::build(&ds, kind, opts(shards, true));
                let got = sb.top_m_batch(&ds, &queries, 40);
                match &reference {
                    None => reference = Some(got),
                    Some(want) => {
                        assert_eq!(&got, want, "{} shards={shards}", sb.name());
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_refine_matches_exact_refine_and_dedups() {
        let ds = tiny(280, 17);
        let flat = FlatScan::scalar(2);
        for shards in [2usize, 5] {
            let sb = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(shards, true));
            forall(61 + shards as u64, 10, |rng| {
                let nq = gen::usize_in(rng, 1, 10);
                let k = gen::usize_in(rng, 1, 20);
                let qs_data: Vec<Vec<f32>> =
                    (0..nq).map(|_| gen::vec_normal(rng, ds.d, 1.0)).collect();
                let pools_data: Vec<Vec<u32>> = (0..nq)
                    .map(|i| match i % 4 {
                        0 => Vec::new(),
                        1 => vec![rng.below(ds.n) as u32],
                        _ => rng
                            .choose_k(ds.n, gen::usize_in(rng, 1, 60).min(ds.n))
                            .into_iter()
                            .map(|i| i as u32)
                            .collect(),
                    })
                    .collect();
                let qs: Vec<&[f32]> = qs_data.iter().map(|q| q.as_slice()).collect();
                let pools: Vec<&[u32]> = pools_data.iter().map(|p| p.as_slice()).collect();
                let got = sb.refine_top_k_batch(&ds, &qs, &pools, k);
                for i in 0..nq {
                    let want = flat.refine_top_k(&ds, qs[i], pools[i], k);
                    crate::prop_assert!(
                        got[i] == want,
                        "shards={shards} query {i}/{nq} k={k}: {:?} vs {want:?}",
                        got[i]
                    );
                }
                Ok(())
            });
            // duplicate candidate ids collapse via the membership mask
            let q: Vec<f32> = ds.row(7).to_vec();
            let pool: Vec<u32> = vec![7, 7, 12, 12, 99, 7, 200];
            let got = sb.refine_top_k(&ds, &q, &pool, 5);
            assert_eq!(got[0], 7);
            let distinct: std::collections::HashSet<u32> = got.iter().copied().collect();
            assert_eq!(distinct.len(), got.len(), "duplicates must collapse");
            assert!(sb.stats().refine_rows > 0);
        }
    }

    #[test]
    fn cold_scan_accounting_covers_every_query_shard_pair() {
        let ds = tiny(200, 7);
        let sb = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(4, true));
        let q = vec![0.1f32; ds.proxy_d];
        let queries: Vec<ProxyQuery> = (0..6)
            .map(|_| ProxyQuery {
                proxy: &q,
                class: None,
            })
            .collect();
        let _ = sb.top_m_batch(&ds, &queries, 16);
        let s = sb.stats();
        assert_eq!(s.proxy_passes, 1, "batched sharded group shares one pass");
        assert_eq!(s.queries, 6);
        assert_eq!(
            s.shards_scanned + s.shards_skipped,
            6 * 4,
            "every (query, shard) pair is scanned or skipped"
        );
        assert_eq!(s.shards_skipped, 0, "unconditional queries skip nothing");
    }

    #[test]
    fn conditional_queries_skip_class_absent_shards() {
        // single-row shards: most shards lack any given class, so the
        // class-count skip must fire (and results stay in class)
        let mut spec = preset("moons").unwrap().clone();
        spec.n = 40;
        let ds = Dataset::synthesize(&spec, 2);
        let sb = ShardedBackend::build(&ds, RetrievalBackendKind::Flat, opts(40, true));
        let flat = FlatScan::scalar(1);
        let class = (0..ds.classes)
            .max_by_key(|&c| ds.class_rows[c].len())
            .unwrap() as u32;
        let q = vec![0.2f32; ds.proxy_d];
        let got = sb.top_m(&ds, &q, 8, Some(class));
        assert_eq!(got, flat.top_m(&ds, &q, 8, Some(class)));
        assert!(got.iter().all(|&i| ds.labels[i as usize] == class));
        let s = sb.stats();
        assert!(s.shards_skipped > 0, "class-absent shards must be skipped");
        assert_eq!(s.shards_scanned + s.shards_skipped, ds.n as u64);
    }

    #[test]
    fn warm_sharded_matches_cold_and_skips_far_shards() {
        // spatially coherent shards + full-corpus seeds: the seeded screen
        // must return the cold screen's exact rows while skipping whole
        // shards under the covering-radius bound
        let ds = clustered(&tiny(320, 23));
        let sb = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(8, true));
        let seeds: Vec<u32> = (0..ds.n as u32).collect();
        let q = ds.proxy_row(10).to_vec();
        // m = 1 on a self-query: the seed pass retains distance 0, so the
        // covering-radius bound (≥ 0) must clear every single shard
        let cold1 = sb.top_m(&ds, &q, 1, None);
        sb.reset_stats();
        let warm1 = sb.warm_top_m(&ds, &q, None, 1, &seeds).expect("seeds fill");
        assert_eq!(warm1, cold1, "warm screen must equal the cold screen");
        let s = sb.stats();
        assert_eq!(s.shards_skipped, 8, "zero cutoff must skip every shard");
        assert_eq!(s.shards_scanned, 0);
        // a broad budget still matches cold exactly (skips now optional)
        let cold40 = sb.top_m(&ds, &q, 40, None);
        let warm40 = sb.warm_top_m(&ds, &q, None, 40, &seeds).expect("seeds fill");
        assert_eq!(warm40, cold40);
        // insufficient seeds stand down
        assert!(sb.warm_top_m(&ds, &q, None, 50, &[1, 2, 3]).is_none());
    }

    #[test]
    fn sharded_nprobe_divides_across_shards_and_fills_the_heap() {
        // approximate mode: the probe budget splits across shards
        // (⌈4/4⌉ = 1 list per shard once a heap is full), results may
        // differ from exact but the heap must never under-deliver
        let ds = tiny(300, 4);
        let sb = ShardedBackend::build(
            &ds,
            RetrievalBackendKind::ClusterPruned,
            BackendOpts {
                threads: 1,
                clusters: 16,
                nprobe: 4,
                shards: 4,
                ..BackendOpts::default()
            },
        );
        assert!(!sb.is_exact(), "nprobe > 0 stays the approximate knob");
        let q = ds.proxy_row(7).to_vec();
        let got = sb.top_m(&ds, &q, 32, None);
        assert_eq!(got.len(), 32, "approximate mode still returns m rows");
        let distinct: std::collections::HashSet<u32> = got.iter().copied().collect();
        assert_eq!(distinct.len(), 32);
        // and nprobe = 0 stays exact
        assert!(
            ShardedBackend::build(&ds, RetrievalBackendKind::ClusterPruned, opts(4, true))
            .is_exact()
        );
    }

    #[test]
    fn streamed_budgeted_backend_matches_resident_and_evicts() {
        // a data-free (open_streaming) corpus with a tight budget serves
        // the exact resident results while evicting and re-streaming shards
        let ds = tiny(220, 31);
        let dir = std::env::temp_dir().join("golddiff_sharded_stream_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = store::store_path(&dir, "cifar-sim");
        store::save_sharded(&ds, &path, 4).unwrap();
        // budget of ~1 MiB < the blocked corpus (220 × 3072 × 4 B ≈ 2.7 MiB
        // across 4 shards), so refines must evict and re-stream shards
        let ds_streamed = store::open_streaming(&path, 4, 1).unwrap();
        let streamed = ShardedBackend::build(
            &ds_streamed,
            RetrievalBackendKind::Batched,
            BackendOpts {
                shards: 4,
                mem_budget_mb: 1,
                threads: 1,
                ..BackendOpts::default()
            },
        );
        assert!(streamed.corpus().is_streamed());
        let resident = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(4, true));
        let mut rng = Pcg64::new(4);
        for round in 0..3 {
            let q: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
            let pool: Vec<u32> = rng
                .choose_k(ds.n, 120)
                .into_iter()
                .map(|i| i as u32)
                .collect();
            let a = streamed.refine_top_k(&ds_streamed, &q, &pool, 12);
            let b = resident.refine_top_k(&ds, &q, &pool, 12);
            assert_eq!(a, b, "round {round}");
        }
        let cache = streamed.corpus().cache_stats();
        assert!(cache.evictions > 0, "1 MiB budget must evict: {cache:?}");
        assert!(cache.streamed_loads > 0, "rebuilds must stream from disk");
        assert!(cache.rows_streamed > ds.n as u64, "re-streams count rows");
        assert!(
            cache.peak_row_bytes > 0 && cache.peak_row_bytes <= 1024 * 1024,
            "peak residency bounded by the budget: {cache:?}"
        );
        let stats = streamed.stats();
        assert!(stats.shard_evictions > 0, "telemetry flows");
        assert!(stats.rows_streamed > 0 && stats.peak_row_bytes > 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn persisted_shard_ivf_is_reused_and_serves_identically() {
        // Satellite: a matching ds.shard_ivf short-circuits per-shard
        // k-means and the backend serves the bit-identical results
        use crate::data::dataset::ShardIvfPartition;
        let mut ds = tiny(240, 13);
        let fresh = ShardedBackend::build(&ds, RetrievalBackendKind::ClusterPruned, opts(4, true));
        // persist the partitions the backend would compute (same key:
        // shards=4, per-shard lists = ceil(10/4) = 3, seed = opts default 0)
        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 4, 3, 0));
        let reused = ShardedBackend::build(&ds, RetrievalBackendKind::ClusterPruned, opts(4, true));
        for sh in 0..4 {
            assert_eq!(
                reused.ivf[sh].centroids, fresh.ivf[sh].centroids,
                "shard {sh}: persisted partition must be reused verbatim"
            );
        }
        let mut rng = Pcg64::new(9);
        for _ in 0..5 {
            let q: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
            assert_eq!(
                reused.top_m(&ds, &q, 24, None),
                fresh.top_m(&ds, &q, 24, None)
            );
        }
        // a mismatched key (different seed) must NOT reuse
        ds.shard_ivf = Some(ShardIvfPartition::compute(&ds, 4, 3, 999));
        let other = ShardedBackend::build(&ds, RetrievalBackendKind::ClusterPruned, opts(4, true));
        let q = ds.proxy_row(5).to_vec();
        assert_eq!(
            other.top_m(&ds, &q, 16, None),
            fresh.top_m(&ds, &q, 16, None),
            "results stay exact regardless of partition provenance"
        );
    }

    #[test]
    fn clustered_ingest_makes_warm_screen_skip_shards() {
        // Satellite: on the cluster-ordered corpus the warm screen's
        // whole-shard covering-radius bound actually fires
        let ds = clustered(&tiny(320, 29));
        let sb = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(8, true));
        let seeds: Vec<u32> = (0..ds.n as u32).collect();
        let q = ds.proxy_row(40).to_vec();
        let cold = sb.top_m(&ds, &q, 12, None);
        sb.reset_stats();
        let warm = sb.warm_top_m(&ds, &q, None, 12, &seeds).expect("seeds fill");
        assert_eq!(warm, cold, "warm screen stays exact");
        let s = sb.stats();
        assert!(
            s.shards_skipped > 0,
            "spatially coherent shards must be skipped: {s:?}"
        );
        assert_eq!(s.shards_scanned + s.shards_skipped, 8);
    }

    #[test]
    fn kind_build_routes_through_sharding_only_above_one() {
        let ds = tiny(150, 1);
        let sharded = RetrievalBackendKind::Batched.build(&ds, opts(3, true));
        assert_eq!(sharded.name(), "sharded-batched");
        let plain = RetrievalBackendKind::Batched.build(&ds, opts(1, true));
        assert_eq!(plain.name(), "batched");
        let q = ds.proxy_row(0).to_vec();
        assert_eq!(
            sharded.top_m(&ds, &q, 9, None),
            plain.top_m(&ds, &q, 9, None)
        );
    }

    #[test]
    fn sharded_quant_matches_f32_across_kinds_and_counts() {
        // Tentpole: the quantised tier composes with shard-parallel
        // screens + refines byte-identically, conditional included
        let ds = tiny(280, 51);
        let flat = FlatScan::scalar(2);
        for &kind in [RetrievalBackendKind::Flat, RetrievalBackendKind::Batched].iter() {
            for shards in [2usize, 5] {
                let qopts = BackendOpts {
                    quant: true,
                    ..opts(shards, true)
                };
                let sb = ShardedBackend::build(&ds, kind, qopts);
                assert!(sb.quant, "kernel non-cluster builds take the knob");
                forall(131 + shards as u64, 8, |rng| {
                    let m = gen::usize_in(rng, 1, 70);
                    let k = gen::usize_in(rng, 1, 16);
                    let qp = gen::vec_normal(rng, ds.proxy_d, 1.0);
                    let q = gen::vec_normal(rng, ds.d, 1.0);
                    let class = if rng.below(2) == 0 {
                        None
                    } else {
                        Some(rng.below(ds.classes) as u32)
                    };
                    let want = flat.top_m(&ds, &qp, m, class);
                    let got = sb.top_m(&ds, &qp, m, class);
                    crate::prop_assert!(
                        got == want,
                        "{} shards={shards} quant screen (m={m} class={class:?})",
                        sb.name()
                    );
                    let rw = flat.refine_top_k(&ds, &q, &want, k);
                    let rg = sb.refine_top_k(&ds, &q, &want, k);
                    crate::prop_assert!(
                        rg == rw,
                        "{} shards={shards} quant refine (k={k})",
                        sb.name()
                    );
                    Ok(())
                });
                let s = sb.stats();
                assert!(s.quant_rows_screened > 0);
                assert_eq!(s.quant_rows_screened, s.bound_rejects + s.rescore_rows);
            }
        }
        // the cluster kind ignores the knob even sharded
        let cb = ShardedBackend::build(
            &ds,
            RetrievalBackendKind::ClusterPruned,
            BackendOpts {
                quant: true,
                ..opts(3, true)
            },
        );
        assert!(!cb.quant, "cluster lists keep their exact f32 tables");
    }

    #[test]
    fn streamed_quant_backend_serves_off_the_persisted_tier() {
        // a data-free corpus + quant: the refine pre-rung runs on the
        // store's persisted int8 sections and results stay byte-identical
        // to the resident f32 path
        let ds = tiny(220, 57);
        let dir = std::env::temp_dir().join("golddiff_sharded_quant_stream_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = store::store_path(&dir, "cifar-sim");
        store::save_sharded(&ds, &path, 4).unwrap();
        let st = store::open_streaming(&path, 4, 1).unwrap();
        assert!(st.quant_rows().is_some(), "v4 stores preload the tier");
        let resident = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(4, true));
        let streamed = ShardedBackend::build(
            &st,
            RetrievalBackendKind::Batched,
            BackendOpts {
                quant: true,
                mem_budget_mb: 1,
                ..opts(4, true)
            },
        );
        let mut rng = Pcg64::new(61);
        for round in 0..4 {
            let m = 1 + rng.below(64);
            let k = 1 + rng.below(16);
            let qp: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
            let q: Vec<f32> = (0..ds.d).map(|_| rng.normal()).collect();
            let a = resident.top_m(&ds, &qp, m, None);
            let b = streamed.top_m(&st, &qp, m, None);
            assert_eq!(a, b, "coarse round {round}");
            assert_eq!(
                resident.refine_top_k(&ds, &q, &a, k),
                streamed.refine_top_k(&st, &q, &b, k),
                "refine round {round}"
            );
        }
        let s = streamed.stats();
        assert!(s.quant_rows_screened > 0, "quant tier engaged: {s:?}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
