//! Adaptive Coarse Screening (Sec. 3.4): the sharded proxy-distance scan
//! that produces the candidate pool C_t, and exact top-k selection that
//! produces the golden subset S_t.
//!
//! The retrieval contract lives in [`backend`]: `RetrievalBackend` with the
//! `FlatScan` (per-query reference), `BatchedScan` (one proxy-table pass
//! per batch group) and `ClusterPruned` (IVF-style centroid-bound pruning)
//! implementations, plus the batched refine ladder. [`kernel`] holds the
//! register-tiled multi-query distance kernel and the structure-of-arrays
//! `ProxyBlocks` layout every default backend scans through;
//! `scan::ProxyIndex` remains the low-level scalar sharded-scan primitive
//! the reference paths and the refine step are built on. See
//! `index/README.md` for the backend selection guide and the kernel design
//! notes.

pub mod backend;
pub mod kernel;
pub mod remote;
pub mod scan;
pub mod shard;
pub mod topk;

pub use backend::{
    batched_refine, batched_refine_kernel, exact_refine, exact_refine_kernel, warm_screen_global,
    BackendOpts, BatchedScan, ClusterPruned, FlatScan, ProxyQuery, RetrievalBackend,
    RetrievalBackendKind, RetrievalStats,
};
pub use remote::RemoteShardBackend;
pub use shard::ShardedBackend;
pub use kernel::{
    block_order, KernelScan, KernelStats, ProxyBlocks, RowBlocks, BLOCK_ROWS, TILE_Q,
};
pub use scan::ProxyIndex;
pub use topk::{top_k_smallest, BoundedMaxHeap};
