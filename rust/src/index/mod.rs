//! Adaptive Coarse Screening (Sec. 3.4): the sharded proxy-distance scan
//! that produces the candidate pool C_t, and exact top-k selection that
//! produces the golden subset S_t.

pub mod scan;
pub mod topk;

pub use scan::ProxyIndex;
pub use topk::{top_k_smallest, BoundedMaxHeap};
