//! Adaptive Coarse Screening (Sec. 3.4): the sharded proxy-distance scan
//! that produces the candidate pool C_t, and exact top-k selection that
//! produces the golden subset S_t.
//!
//! The retrieval contract lives in [`backend`]: `RetrievalBackend` with the
//! `FlatScan` (per-query reference), `BatchedScan` (one proxy-table pass
//! per batch group) and `ClusterPruned` (IVF-style centroid-bound pruning)
//! implementations. `scan::ProxyIndex` remains the low-level sharded-scan
//! primitive the flat backend and the refine step are built on. See
//! `index/README.md` for the backend selection guide.

pub mod backend;
pub mod scan;
pub mod topk;

pub use backend::{
    BatchedScan, ClusterPruned, FlatScan, ProxyQuery, RetrievalBackend, RetrievalBackendKind,
    RetrievalStats,
};
pub use scan::ProxyIndex;
pub use topk::{top_k_smallest, BoundedMaxHeap};
