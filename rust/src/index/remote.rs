//! Distributed retrieval tier: a coordinator that fans retrieval ops out
//! to shard workers and merges their results **exactly**.
//!
//! [`RemoteShardBackend`] wraps an in-process [`ShardedBackend`] and a set
//! of [`ShardWorker`] endpoints. Shard `s` routes to worker `s % W`; each
//! op names its worker's explicit shard subset, so re-routing after a
//! worker loss needs no rebalancing handshake. Because every per-(query,
//! row) distance is a pure function of the query and the row, and the
//! merge order `(distance, row id)` is a total order over distinct rows,
//! the top-cap of a union is independent of how the union was grouped —
//! worker-local merges followed by the coordinator merge reproduce the
//! in-process screen byte for byte (`index/README.md` § Distributed).
//!
//! Failure discipline carries the PR-7 contract over the network:
//!
//! - transport errors retry per worker (bounded attempts, doubling
//!   backoff, reconnect between attempts), counted in `remote_retries`;
//! - a worker that stays unreachable marks the tier lost
//!   (`workers_lost`), and every later op takes the in-process fallback —
//!   byte-identical answers, degraded health (`degraded_tiers` gains
//!   `"remote"`) — or panics the op when `remote_fallback` is off, which
//!   the engine's catch-unwind answers as `"internal"`;
//! - a worker refusing an op with `deadline_exceeded` is neither retried
//!   nor fatal: the op computes in-process and the engine's between-group
//!   deadline check expires the request.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use super::backend::{
    BackendOpts, ProxyQuery, RetrievalBackend, RetrievalBackendKind, RetrievalStats,
};
use super::shard::{Scored, ShardedBackend};
use crate::data::dataset::Dataset;
use crate::server::worker::ShardWorker;
use crate::util::json::{decode_scored, encode_f32s, encode_u32s, parse, Json};

/// Transport retry budget per op: attempts beyond the first pay a
/// doubling backoff (1 → 16 ms) and a fresh connection.
const RETRY_ATTEMPTS: u32 = 7;
const BACKOFF_CAP_MS: u64 = 16;

/// `deadline_ms` sentinel for "no deadline set".
const NO_DEADLINE: u64 = u64::MAX;

/// One worker endpoint with its (lazily dialled, re-dialled on retry)
/// connection.
struct WorkerSlot {
    addr: String,
    conn: Mutex<Option<WireConn>>,
}

struct WireConn {
    reader: BufReader<TcpStream>,
    stream: TcpStream,
}

/// Outcome of one worker op after the retry loop.
enum OpOutcome {
    Ok(Json),
    /// The worker refused: the requester's deadline already elapsed.
    Deadline,
    /// Transport exhausted or protocol breach — the tier stood down.
    Lost,
}

/// The distributed retrieval tier (see module docs).
pub struct RemoteShardBackend {
    inner: Arc<ShardedBackend>,
    workers: Vec<WorkerSlot>,
    /// remaining request budget for the next ops (`u64::MAX` = none) —
    /// written by the engine via [`RetrievalBackend::set_deadline`]
    deadline_ms: AtomicU64,
    remote_ops: AtomicU64,
    remote_retries: AtomicU64,
    workers_lost: AtomicU64,
    /// once true every op takes the in-process path (graceful stand-down)
    lost: AtomicBool,
    fallback: bool,
    op_timeout_ms: u64,
    /// loopback workers this coordinator spawned (stopped on drop); empty
    /// when connected to external workers
    owned: Mutex<Vec<ShardWorker>>,
}

impl RemoteShardBackend {
    /// Spawn `workers` loopback [`ShardWorker`]s over ONE shared
    /// in-process backend and coordinate across them. Loopback is the
    /// deterministic single-process harness: every byte still crosses a
    /// real TCP socket and the real wire encoding, so it exercises the
    /// full distributed path, while the shared backend keeps scan
    /// telemetry (and the LRU row cache) unified.
    pub fn loopback(
        ds: Arc<Dataset>,
        kind: RetrievalBackendKind,
        opts: BackendOpts,
        workers: usize,
        fallback: bool,
        op_timeout_ms: u64,
    ) -> Result<RemoteShardBackend> {
        let inner = Arc::new(ShardedBackend::build(&ds, kind, opts));
        let mut owned = Vec::new();
        let mut slots = Vec::new();
        for _ in 0..workers.max(1) {
            let w = ShardWorker::start(Arc::clone(&ds), Arc::clone(&inner), "127.0.0.1:0")?;
            slots.push(WorkerSlot {
                addr: w.addr.to_string(),
                conn: Mutex::new(None),
            });
            owned.push(w);
        }
        Ok(RemoteShardBackend::assemble(inner, slots, owned, fallback, op_timeout_ms))
    }

    /// Coordinate across external workers at `addrs` (comma-separated
    /// `host:port`). Workers must have been started over the same store
    /// with the same backend options — identical per-shard structures are
    /// what make the distributed merge exact. The in-process backend is
    /// still built: it is the stand-down path, the warm/cold fallback and
    /// the quant prefilter host.
    pub fn connect(
        ds: &Dataset,
        kind: RetrievalBackendKind,
        opts: BackendOpts,
        addrs: &str,
        fallback: bool,
        op_timeout_ms: u64,
    ) -> Result<RemoteShardBackend> {
        let inner = Arc::new(ShardedBackend::build(ds, kind, opts));
        let slots: Vec<WorkerSlot> = addrs
            .split(',')
            .map(str::trim)
            .filter(|a| !a.is_empty())
            .map(|a| WorkerSlot {
                addr: a.to_string(),
                conn: Mutex::new(None),
            })
            .collect();
        if slots.is_empty() {
            anyhow::bail!("remote backend needs at least one worker address");
        }
        Ok(RemoteShardBackend::assemble(inner, slots, Vec::new(), fallback, op_timeout_ms))
    }

    fn assemble(
        inner: Arc<ShardedBackend>,
        workers: Vec<WorkerSlot>,
        owned: Vec<ShardWorker>,
        fallback: bool,
        op_timeout_ms: u64,
    ) -> RemoteShardBackend {
        RemoteShardBackend {
            inner,
            workers,
            deadline_ms: AtomicU64::new(NO_DEADLINE),
            remote_ops: AtomicU64::new(0),
            remote_retries: AtomicU64::new(0),
            workers_lost: AtomicU64::new(0),
            lost: AtomicBool::new(false),
            fallback,
            op_timeout_ms,
            owned: Mutex::new(owned),
        }
    }

    /// The shared in-process backend (stand-down path / introspection).
    pub fn inner(&self) -> &ShardedBackend {
        &self.inner
    }

    /// Fault-injection hook: stop loopback worker `wi` — its listener
    /// closes and live connections drain within the worker's read-timeout
    /// tick, so the coordinator's next op to it exhausts its retries and
    /// the tier stands down.
    pub fn stop_worker(&self, wi: usize) {
        if let Some(w) = self.owned.lock().unwrap().get_mut(wi) {
            w.stop();
        }
    }

    /// Is the remote tier still answering (never lost a worker)?
    pub fn tier_up(&self) -> bool {
        !self.lost.load(Ordering::Relaxed)
    }

    fn op_deadline(&self) -> Option<u64> {
        let v = self.deadline_ms.load(Ordering::Relaxed);
        (v != NO_DEADLINE).then_some(v)
    }

    /// `(worker, shard subset)` for every worker that owns ≥ 1 shard
    /// under the `s % W` routing.
    fn worker_subsets(&self) -> Vec<(usize, Vec<u32>)> {
        let ns = self.inner.corpus().plan().count();
        let w = self.workers.len();
        (0..w)
            .map(|wi| (wi, (wi..ns).step_by(w).map(|s| s as u32).collect::<Vec<u32>>()))
            .filter(|(_, subset)| !subset.is_empty())
            .collect()
    }

    /// Mark the tier lost. With `remote_fallback` off this panics the op
    /// instead — the engine's catch-unwind answers `"internal"`, which is
    /// the configured "loud" failure mode.
    fn mark_lost(&self, why: &str) {
        self.workers_lost.fetch_add(1, Ordering::Relaxed);
        self.lost.store(true, Ordering::Relaxed);
        eprintln!("golddiff: remote: {why}; tier standing down to in-process path");
        assert!(self.fallback, "remote worker lost and remote_fallback is off: {why}");
    }

    fn dial(&self, addr: &str) -> std::io::Result<WireConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_millis(self.op_timeout_ms.max(1))))?;
        Ok(WireConn {
            reader: BufReader::new(stream.try_clone()?),
            stream,
        })
    }

    /// One op against worker `wi`: bounded retry with doubling backoff
    /// and a fresh connection per attempt. Only *transport* faults retry
    /// — a parsed `{"ok":false}` reply is the worker speaking clearly,
    /// and repeating the question would not change the answer.
    fn call_worker(&self, wi: usize, req: &Json) -> OpOutcome {
        self.remote_ops.fetch_add(1, Ordering::Relaxed);
        let slot = &self.workers[wi];
        let mut guard = slot.conn.lock().unwrap();
        let mut backoff: u64 = 1;
        for attempt in 0..RETRY_ATTEMPTS {
            if attempt > 0 {
                self.remote_retries.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(backoff));
                backoff = (backoff * 2).min(BACKOFF_CAP_MS);
            }
            if guard.is_none() {
                match self.dial(&slot.addr) {
                    Ok(c) => *guard = Some(c),
                    Err(_) => continue,
                }
            }
            let conn = guard.as_mut().expect("connection dialled above");
            match exchange(conn, req) {
                Ok(j) => {
                    if j.get("ok").and_then(Json::as_bool) == Some(true) {
                        return OpOutcome::Ok(j);
                    }
                    let err = j.get("error").and_then(Json::as_str).unwrap_or("unknown");
                    if err == "deadline_exceeded" {
                        return OpOutcome::Deadline;
                    }
                    // a protocol rejection (bad_field, unknown op) means
                    // the coordinator and worker disagree about the wire
                    // contract — retrying cannot help, stand down
                    self.mark_lost(&format!("worker {wi} rejected op: {err}"));
                    return OpOutcome::Lost;
                }
                Err(_) => {
                    // malformed frame / timeout / closed socket: drop the
                    // connection and retry on a fresh one
                    *guard = None;
                }
            }
        }
        self.mark_lost(&format!("worker {wi} unreachable after {RETRY_ATTEMPTS} attempts"));
        OpOutcome::Lost
    }

    /// Fan one request-per-worker batch out on scoped threads and join.
    fn fan_out(&self, reqs: Vec<(usize, Json)>) -> Vec<OpOutcome> {
        std::thread::scope(|scope| {
            let handles: Vec<_> = reqs
                .into_iter()
                .map(|(wi, req)| scope.spawn(move || self.call_worker(wi, &req)))
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    }

    /// Distributed coarse screen. `None` means "answer in-process" —
    /// either the tier stood down or a worker refused on deadline.
    fn remote_screen(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Option<Vec<Scored>> {
        let cap = screen_cap(ds, m);
        let flat: Vec<f32> = queries.iter().flat_map(|q| q.proxy.iter().copied()).collect();
        let classes: Vec<u32> = queries.iter().map(|q| q.class.unwrap_or(u32::MAX)).collect();
        let reqs: Vec<(usize, Json)> = self
            .worker_subsets()
            .into_iter()
            .map(|(wi, subset)| {
                let mut req = Json::obj();
                req.set("op", "coarse_screen")
                    .set("queries", encode_f32s(&flat).as_str())
                    .set("classes", encode_u32s(&classes).as_str())
                    .set("m", m)
                    .set("shards", encode_u32s(&subset).as_str());
                if let Some(dl) = self.op_deadline() {
                    req.set("deadline_ms", dl);
                }
                (wi, req)
            })
            .collect();
        let mut per_worker: Vec<Vec<Scored>> = Vec::with_capacity(reqs.len());
        for outcome in self.fan_out(reqs) {
            match outcome {
                OpOutcome::Ok(j) => match decode_results(&j, queries.len()) {
                    Some(lists) => per_worker.push(lists),
                    None => {
                        self.mark_lost("worker sent a malformed screen reply");
                        return None;
                    }
                },
                OpOutcome::Deadline | OpOutcome::Lost => return None,
            }
        }
        Some(
            (0..queries.len())
                .map(|qi| {
                    let mut all: Scored =
                        per_worker.iter().flat_map(|w| w[qi].iter().copied()).collect();
                    all.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                    all.truncate(cap);
                    all
                })
                .collect(),
        )
    }

    /// Distributed warm screen. Outer `None` = answer in-process; inner
    /// `None` = unanimous seed-miss, fall back to the cold screen (the
    /// same contract as the in-process warm path, decided by a global
    /// property every worker agrees on).
    fn remote_warm(
        &self,
        ds: &Dataset,
        qp: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
    ) -> Option<Option<Scored>> {
        let cap = screen_cap(ds, m);
        let reqs: Vec<(usize, Json)> = self
            .worker_subsets()
            .into_iter()
            .map(|(wi, subset)| {
                let mut req = Json::obj();
                req.set("op", "warm_screen")
                    .set("query", encode_f32s(qp).as_str())
                    .set("m", m)
                    .set("seeds", encode_u32s(seeds).as_str())
                    .set("shards", encode_u32s(&subset).as_str());
                if let Some(y) = class {
                    req.set("class", y as usize);
                }
                if let Some(dl) = self.op_deadline() {
                    req.set("deadline_ms", dl);
                }
                (wi, req)
            })
            .collect();
        let mut merged: Scored = Vec::new();
        for outcome in self.fan_out(reqs) {
            match outcome {
                OpOutcome::Ok(j) => {
                    if j.get("found").and_then(Json::as_bool) != Some(true) {
                        // seed eligibility is a global property — every
                        // worker reaches the same verdict
                        return Some(None);
                    }
                    let sc = j.get("result").and_then(Json::as_str);
                    match sc.and_then(|s| decode_scored(s).ok()) {
                        Some(sc) => merged.extend(sc),
                        None => {
                            self.mark_lost("worker sent a malformed warm reply");
                            return None;
                        }
                    }
                }
                OpOutcome::Deadline | OpOutcome::Lost => return None,
            }
        }
        // seed rows appear in every worker's list (the seed pass is
        // global); same id ⇒ same distance ⇒ adjacent after the sort,
        // so the dedup is a plain adjacent-id collapse
        merged.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        merged.dedup_by(|a, b| a.1 == b.1);
        merged.truncate(cap);
        Some(Some(merged))
    }

    /// Distributed masked refine. `None` = answer in-process.
    fn remote_refine(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Option<Vec<Vec<u32>>> {
        // the int8 pre-rung needs each query's GLOBAL pool, so it runs
        // here, before the shard split — workers never see pruned rows
        let filtered = self.inner.quant_refine_prefilter(ds, qs, pools, k);
        let eff: Vec<&[u32]> = match &filtered {
            Some(f) => f.iter().map(Vec::as_slice).collect(),
            None => pools.to_vec(),
        };
        // per-query budgets come from the pools actually refined
        let caps: Vec<usize> = eff.iter().map(|p| k.max(1).min(p.len().max(1))).collect();
        let w = self.workers.len();
        let plan = self.inner.corpus().plan();
        let mut sub: Vec<Vec<Vec<u32>>> = vec![vec![Vec::new(); qs.len()]; w];
        for (qi, pool) in eff.iter().enumerate() {
            for &id in *pool {
                sub[plan.shard_of(id as usize) % w][qi].push(id);
            }
        }
        let flat: Vec<f32> = qs.iter().flat_map(|q| q.iter().copied()).collect();
        // a worker whose every sub-pool is empty has nothing to score —
        // skip the round-trip entirely
        let active: Vec<usize> =
            (0..w).filter(|&wi| sub[wi].iter().any(|p| !p.is_empty())).collect();
        if active.is_empty() {
            return Some(vec![Vec::new(); qs.len()]);
        }
        let reqs: Vec<(usize, Json)> = active
            .iter()
            .map(|&wi| {
                let mut req = Json::obj();
                req.set("op", "masked_refine")
                    .set("queries", encode_f32s(&flat).as_str())
                    .set(
                        "pools",
                        Json::Arr(sub[wi].iter().map(|p| Json::Str(encode_u32s(p))).collect()),
                    )
                    .set("k", k);
                if let Some(dl) = self.op_deadline() {
                    req.set("deadline_ms", dl);
                }
                (wi, req)
            })
            .collect();
        let mut per_worker: Vec<Vec<Scored>> = Vec::with_capacity(reqs.len());
        for outcome in self.fan_out(reqs) {
            match outcome {
                OpOutcome::Ok(j) => match decode_results(&j, qs.len()) {
                    Some(lists) => per_worker.push(lists),
                    None => {
                        self.mark_lost("worker sent a malformed refine reply");
                        return None;
                    }
                },
                OpOutcome::Deadline | OpOutcome::Lost => return None,
            }
        }
        Some(
            (0..qs.len())
                .map(|qi| {
                    let mut all: Scored =
                        per_worker.iter().flat_map(|w| w[qi].iter().copied()).collect();
                    all.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                    all.truncate(caps[qi]);
                    all.into_iter().map(|(_, i)| i).collect()
                })
                .collect(),
        )
    }
}

/// One framed request/reply over a live connection. Any failure here —
/// write, read, EOF, unparseable frame — is a transport fault the retry
/// loop answers with a fresh connection.
fn exchange(conn: &mut WireConn, req: &Json) -> Result<Json> {
    conn.stream.write_all(req.to_string_compact().as_bytes())?;
    conn.stream.write_all(b"\n")?;
    let mut line = String::new();
    let n = conn.reader.read_line(&mut line)?;
    if n == 0 {
        anyhow::bail!("worker closed connection");
    }
    parse(line.trim())
}

/// Coarse/warm budget clamp — the same clamp the in-process screen uses.
fn screen_cap(ds: &Dataset, m: usize) -> usize {
    m.max(1).min(ds.n.max(1))
}

/// Decode a worker's `results` array of scored payloads; `None` on any
/// shape violation (a malformed *success* reply is a protocol breach).
fn decode_results(j: &Json, nq: usize) -> Option<Vec<Scored>> {
    let arr = j.get("results")?.as_arr()?;
    if arr.len() != nq {
        return None;
    }
    arr.iter().map(|r| r.as_str().and_then(|s| decode_scored(s).ok())).collect()
}

impl RetrievalBackend for RemoteShardBackend {
    fn name(&self) -> &'static str {
        "remote-sharded"
    }

    fn is_exact(&self) -> bool {
        self.inner.is_exact()
    }

    fn top_m(&self, ds: &Dataset, query_proxy: &[f32], m: usize, class: Option<u32>) -> Vec<u32> {
        self.top_m_batch(
            ds,
            &[ProxyQuery {
                proxy: query_proxy,
                class,
            }],
            m,
        )
        .pop()
        .unwrap_or_default()
    }

    fn top_m_batch(&self, ds: &Dataset, queries: &[ProxyQuery], m: usize) -> Vec<Vec<u32>> {
        if queries.is_empty() {
            return Vec::new();
        }
        if self.tier_up() {
            if let Some(scored) = self.remote_screen(ds, queries, m) {
                // mirror the group's pass/query accounting onto the shared
                // counters only on remote success — the in-process branch
                // below does its own
                self.inner.record_screen_pass(queries.len());
                return scored
                    .into_iter()
                    .map(|sc| sc.into_iter().map(|(_, i)| i).collect())
                    .collect();
            }
        }
        self.inner.top_m_batch(ds, queries, m)
    }

    fn refine_top_k(&self, ds: &Dataset, q: &[f32], cands: &[u32], k: usize) -> Vec<u32> {
        self.refine_top_k_batch(ds, &[q], &[cands], k)
            .pop()
            .unwrap_or_default()
    }

    fn refine_top_k_batch(
        &self,
        ds: &Dataset,
        qs: &[&[f32]],
        pools: &[&[u32]],
        k: usize,
    ) -> Vec<Vec<u32>> {
        assert_eq!(qs.len(), pools.len());
        if qs.is_empty() {
            return Vec::new();
        }
        if self.tier_up() {
            if let Some(out) = self.remote_refine(ds, qs, pools, k) {
                return out;
            }
        }
        self.inner.refine_top_k_batch(ds, qs, pools, k)
    }

    fn warm_top_m(
        &self,
        ds: &Dataset,
        query_proxy: &[f32],
        class: Option<u32>,
        m: usize,
        seeds: &[u32],
    ) -> Option<Vec<u32>> {
        // the workers' bounded sweep requires a sorted in-range seed list
        // (the wire contract rejects anything else); a violation here is
        // an upstream bug — answer in-process rather than standing the
        // tier down over it
        let seeds_wire_ok = seeds.windows(2).all(|w| w[0] < w[1])
            && seeds.last().is_none_or(|&s| (s as usize) < ds.n);
        if self.tier_up() && seeds_wire_ok {
            if let Some(res) = self.remote_warm(ds, query_proxy, class, m, seeds) {
                return res.map(|sc| sc.into_iter().map(|(_, i)| i).collect());
            }
        }
        self.inner.warm_top_m(ds, query_proxy, class, m, seeds)
    }

    fn stats(&self) -> RetrievalStats {
        let mut s = self.inner.stats();
        s.remote_ops = self.remote_ops.load(Ordering::Relaxed);
        s.remote_retries = self.remote_retries.load(Ordering::Relaxed);
        s.workers_lost = self.workers_lost.load(Ordering::Relaxed);
        s
    }

    fn reset_stats(&self) {
        self.inner.reset_stats();
        self.remote_ops.store(0, Ordering::Relaxed);
        self.remote_retries.store(0, Ordering::Relaxed);
        self.workers_lost.store(0, Ordering::Relaxed);
        // `lost` deliberately survives a stats reset: a stood-down tier
        // stays down — losing the *memory* of the loss on a bench-harness
        // reset must not resurrect a dead path
    }

    fn set_deadline(&self, remaining_ms: Option<u64>) {
        self.deadline_ms.store(remaining_ms.unwrap_or(NO_DEADLINE), Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::util::rng::Pcg64;

    fn tiny(n: usize, seed: u64) -> Dataset {
        let mut spec = preset("cifar-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, seed)
    }

    fn opts(shards: usize) -> BackendOpts {
        BackendOpts {
            threads: 2,
            shards,
            kernel: true,
            refine_kernel: true,
            ..BackendOpts::default()
        }
    }

    fn queries(ds: &Dataset, nq: usize, seed: u64) -> (Vec<Vec<f32>>, Vec<Option<u32>>) {
        let mut rng = Pcg64::new(seed);
        let qs = (0..nq).map(|_| (0..ds.proxy_d).map(|_| rng.normal()).collect()).collect();
        let classes = (0..nq)
            .map(|i| (i % 3 == 0).then_some((i % 4) as u32))
            .collect();
        (qs, classes)
    }

    #[test]
    fn loopback_screen_and_refine_match_in_process_bytes() {
        let ds = Arc::new(tiny(240, 31));
        let local = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(4));
        for workers in [1usize, 2, 3] {
            let remote = RemoteShardBackend::loopback(
                Arc::clone(&ds),
                RetrievalBackendKind::Batched,
                opts(4),
                workers,
                true,
                5_000,
            )
            .unwrap();
            let (qdata, classes) = queries(&ds, 5, 7);
            let pq: Vec<ProxyQuery> = qdata
                .iter()
                .zip(&classes)
                .map(|(q, &class)| ProxyQuery { proxy: q, class })
                .collect();
            let got = remote.top_m_batch(&ds, &pq, 33);
            let want = local.top_m_batch(&ds, &pq, 33);
            assert_eq!(got, want, "screen workers={workers}");

            let mut rng = Pcg64::new(5);
            let full: Vec<Vec<f32>> =
                (0..3).map(|_| (0..ds.d).map(|_| rng.normal()).collect()).collect();
            let fq: Vec<&[f32]> = full.iter().map(Vec::as_slice).collect();
            let fpools: Vec<&[u32]> = want[..3].iter().map(Vec::as_slice).collect();
            let got_r = remote.refine_top_k_batch(&ds, &fq, &fpools, 9);
            let want_r = local.refine_top_k_batch(&ds, &fq, &fpools, 9);
            assert_eq!(got_r, want_r, "refine workers={workers}");
            assert!(remote.stats().remote_ops > 0, "ops must have gone remote");
            assert_eq!(remote.stats().workers_lost, 0);
        }
    }

    #[test]
    fn loopback_warm_screen_matches_in_process_bytes() {
        let ds = Arc::new(tiny(200, 13));
        let local = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(3));
        let remote = RemoteShardBackend::loopback(
            Arc::clone(&ds),
            RetrievalBackendKind::Batched,
            opts(3),
            2,
            true,
            5_000,
        )
        .unwrap();
        let mut rng = Pcg64::new(3);
        let qp: Vec<f32> = (0..ds.proxy_d).map(|_| rng.normal()).collect();
        // plenty of seeds → warm hit; 2 seeds with m=40 → unanimous miss
        let many: Vec<u32> = (0..80).map(|i| i * 2).collect();
        let few: Vec<u32> = vec![1, 5];
        for (seeds, m) in [(&many, 25usize), (&few, 40)] {
            let got = remote.warm_top_m(&ds, &qp, None, m, seeds);
            let want = local.warm_top_m(&ds, &qp, None, m, seeds);
            assert_eq!(got, want, "m={m}");
        }
        assert!(remote.stats().remote_ops > 0);
    }

    #[test]
    fn expired_deadline_answers_in_process_without_losing_the_tier() {
        let ds = Arc::new(tiny(150, 9));
        let remote = RemoteShardBackend::loopback(
            Arc::clone(&ds),
            RetrievalBackendKind::Batched,
            opts(2),
            2,
            true,
            5_000,
        )
        .unwrap();
        let local = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(2));
        let (qdata, _) = queries(&ds, 2, 17);
        let pq: Vec<ProxyQuery> = qdata
            .iter()
            .map(|q| ProxyQuery {
                proxy: q,
                class: None,
            })
            .collect();

        // 0 is the deterministic always-expired hook: workers refuse the
        // op, the coordinator answers in-process, the tier stays up
        remote.set_deadline(Some(0));
        let got = remote.top_m_batch(&ds, &pq, 12);
        assert_eq!(got, local.top_m_batch(&ds, &pq, 12));
        let after_refusal = remote.stats();
        assert!(after_refusal.remote_ops > 0, "the refused ops still went out");
        assert_eq!(after_refusal.workers_lost, 0, "a refusal is not a loss");
        assert!(remote.tier_up());

        // clearing the deadline restores the remote path
        remote.set_deadline(None);
        let before = remote.stats().remote_ops;
        let again = remote.top_m_batch(&ds, &pq, 12);
        assert_eq!(again, local.top_m_batch(&ds, &pq, 12));
        assert!(remote.stats().remote_ops > before);
    }

    #[test]
    fn dead_worker_degrades_to_in_process_with_identical_bytes() {
        let ds = Arc::new(tiny(180, 23));
        let local = ShardedBackend::build(&ds, RetrievalBackendKind::Batched, opts(3));
        let remote = RemoteShardBackend::loopback(
            Arc::clone(&ds),
            RetrievalBackendKind::Batched,
            opts(3),
            2,
            true,
            400,
        )
        .unwrap();
        let (qdata, classes) = queries(&ds, 4, 41);
        let pq: Vec<ProxyQuery> = qdata
            .iter()
            .zip(&classes)
            .map(|(q, &class)| ProxyQuery { proxy: q, class })
            .collect();
        // warm the remote path once, then kill a worker mid-tier
        assert_eq!(remote.top_m_batch(&ds, &pq, 20), local.top_m_batch(&ds, &pq, 20));
        remote.stop_worker(1);
        let got = remote.top_m_batch(&ds, &pq, 20);
        assert_eq!(got, local.top_m_batch(&ds, &pq, 20), "degraded answers stay byte-identical");
        let s = remote.stats();
        assert!(s.workers_lost >= 1, "the loss must be counted");
        assert!(s.remote_retries >= 1, "the loss must have been retried first");
        assert!(!remote.tier_up());
        // once lost, ops stop going remote entirely
        let ops_after_loss = remote.stats().remote_ops;
        let _ = remote.top_m_batch(&ds, &pq, 20);
        assert_eq!(remote.stats().remote_ops, ops_after_loss);
    }
}
