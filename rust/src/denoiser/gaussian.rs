//! Gaussian-score fast path for the high-noise regime.
//!
//! The paper's Posterior Progressive Concentration says the golden
//! support is near-global at low SNR — exactly where every screen is
//! most expensive, because no pruning tier can shrink a support that
//! genuinely spans the corpus. But in that same regime the posterior
//! over corpus rows is nearly uniform, so the mixture score collapses
//! to the score of a single moment-matched Gaussian: the closed form
//! here serves those ticks from the corpus moment summary
//! ([`GaussMoments`]) with **zero screens and zero refines**, and the
//! trajectory hands off to golden-subset retrieval once concentration
//! kicks in.
//!
//! ## The switch-point error bound
//!
//! With corpus spread `s̄` (mean per-dimension variance) and noise
//! level σ_t² = (1−ᾱ)/ᾱ, the per-dimension Wiener gain of the
//! moment-matched Gaussian is `s̄/(s̄+σ_t²)` — the fraction of the
//! posterior mean that comes from the *query* rather than the corpus
//! mean. That same ratio governs how far the true mixture posterior
//! can concentrate away from the moment Gaussian: at `σ_t² ≫ s̄` the
//! analytical logits `−‖q−x_i‖²/(2σ_t²)` spread the posterior almost
//! uniformly over the corpus and the approximation error is
//! `O(s̄/σ_t²)`. So we bound
//!
//! ```text
//!   err(i) = s̄ / (s̄ + σ_i²)
//! ```
//!
//! and serve Gaussian ticks for the longest *prefix* of sampling
//! points with `err(i) ≤ tol`. σ² is strictly decreasing along
//! sampling order (ᾱ strictly increases), so `err` is strictly
//! increasing — the prefix is well-defined, and **tightening `tol`
//! can only shrink it** (bound monotonicity, pinned by test).

use super::softmax::PosteriorStats;
use super::{descale, DenoiseResult};
use crate::data::gauss::GaussMoments;
use crate::schedule::noise::NoiseSchedule;

/// How the switch point from Gaussian ticks to retrieval is chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaussSwitch {
    /// Evaluate the error bound against the corpus spread (the default).
    Auto,
    /// Pin the first `n` sampling points Gaussian — the forced override
    /// the determinism matrix and the pinning tests use.
    Forced(usize),
}

impl GaussSwitch {
    /// `"auto"` → bound-driven; a bare integer → forced prefix length.
    /// Anything else is `None` (callers warn and serve the default).
    pub fn parse(s: &str) -> Option<GaussSwitch> {
        match s.trim() {
            "auto" => Some(GaussSwitch::Auto),
            t => t.parse::<usize>().ok().map(GaussSwitch::Forced),
        }
    }
}

/// The approximation-error bound at one noise level: `s̄/(s̄+σ²)`,
/// strictly increasing in 1/σ² — i.e. along sampling order.
pub fn error_bound(sigma2: f64, spread: f64) -> f64 {
    if spread <= 0.0 {
        return 0.0;
    }
    spread / (spread + sigma2.max(0.0))
}

/// The bound-driven switch point: the number of leading sampling points
/// whose error bound stays within `tol`. Returns 0 when even the
/// deepest-noise step violates the bound; never exceeds the schedule.
pub fn switch_point(sched: &NoiseSchedule, spread: f64, tol: f64) -> usize {
    let mut n = 0;
    for i in 0..sched.steps {
        if error_bound(sched.sigma2(i) as f64, spread) > tol {
            break;
        }
        n = i + 1;
    }
    n
}

/// Resolve a configured switch mode to a concrete prefix length for a
/// schedule + corpus: `Auto` evaluates the bound against the corpus
/// spread, `Forced(n)` clamps to the schedule length.
pub fn resolve_switch(
    mode: GaussSwitch,
    sched: &NoiseSchedule,
    moments: &GaussMoments,
    tol: f64,
) -> usize {
    resolve_switch_for(mode, sched, moments, tol, None)
}

/// [`resolve_switch`] for a (possibly conditional) sampling context:
/// `Auto` evaluates the bound against the **class** moment spread — a
/// class concentrated around its own mean has a smaller spread, so its
/// `err(i)` curve rises later and the Gaussian prefix extends deeper into
/// the schedule (later hand-off). Unconditional contexts, out-of-range
/// classes, and classes without support all read the global spread via
/// the `moments_for` fallback rule, so behaviour is unchanged whenever
/// classes are absent. `Forced(n)` ignores the class entirely.
pub fn resolve_switch_for(
    mode: GaussSwitch,
    sched: &NoiseSchedule,
    moments: &GaussMoments,
    tol: f64,
    class: Option<u32>,
) -> usize {
    match mode {
        GaussSwitch::Auto => switch_point(sched, moments.spread_for(class), tol),
        GaussSwitch::Forced(n) => n.min(sched.steps),
    }
}

/// The closed-form posterior mean of the moment-matched Gaussian:
/// per-dimension Wiener shrinkage of the descaled query toward the
/// class (or global) corpus mean. Identical math to the Wiener
/// baseline, but served from the persisted per-class moment tier.
pub fn closed_form_f_hat(
    gm: &GaussMoments,
    x_t: &[f32],
    alpha_bar: f32,
    class: Option<u32>,
) -> Vec<f32> {
    let sigma2 = (1.0 - alpha_bar) / alpha_bar.max(1e-12);
    let (mean, var) = gm.moments_for(class);
    let q = descale(x_t, alpha_bar);
    (0..q.len())
        .map(|j| {
            let g = var[j] / (var[j] + sigma2);
            mean[j] + g * (q[j] - mean[j])
        })
        .collect()
}

/// [`closed_form_f_hat`] wrapped as a [`DenoiseResult`]: zero support
/// (no rows aggregated — the telemetry invariant the zero-screens
/// assertion rides on) and zeroed posterior stats, like Wiener.
pub fn gauss_result(
    gm: &GaussMoments,
    x_t: &[f32],
    alpha_bar: f32,
    class: Option<u32>,
) -> DenoiseResult {
    DenoiseResult {
        f_hat: closed_form_f_hat(gm, x_t, alpha_bar, class),
        stats: PosteriorStats::zero(),
        support: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::ScheduleKind;

    fn tiny(n: usize) -> Dataset {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = n;
        Dataset::synthesize(&spec, 11)
    }

    #[test]
    fn closed_form_is_wiener_shrinkage_over_the_moment_tier() {
        let ds = tiny(160);
        let gm = GaussMoments::build(&ds);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        let a = sched.alpha_bar(2);
        let x_t = vec![0.07f32; ds.d];
        let got = closed_form_f_hat(&gm, &x_t, a, None);
        let sigma2 = (1.0 - a) / a;
        let q = x_t[0] / a.sqrt();
        for j in (0..ds.d).step_by(19) {
            let g = gm.var[j] / (gm.var[j] + sigma2);
            let want = gm.mean[j] + g * (q - gm.mean[j]);
            assert!((got[j] - want).abs() < 1e-6, "dim {j}");
        }
        // conditional queries shrink toward their class mean
        let y = ds.labels[0];
        let cond = closed_form_f_hat(&gm, &vec![0.0; ds.d], sched.alpha_bar(0), Some(y));
        let (cm, _) = gm.moments_for(Some(y));
        let dev: f32 = cond
            .iter()
            .zip(cm)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(dev < 0.05, "deep noise shrinks to the class mean: {dev}");
        // zero support is the telemetry invariant the engine asserts on
        assert_eq!(gauss_result(&gm, &x_t, a, None).support, 0);
    }

    #[test]
    fn error_bound_increases_along_sampling_order() {
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 16);
        let spread = 0.3;
        for i in 1..sched.steps {
            assert!(
                error_bound(sched.sigma2(i) as f64, spread)
                    > error_bound(sched.sigma2(i - 1) as f64, spread),
                "bound must be strictly increasing at step {i}"
            );
        }
        // degenerate spread never claims a Gaussian tick is unsafe
        assert_eq!(error_bound(1.0, 0.0), 0.0);
    }

    #[test]
    fn tightening_tol_never_adds_gaussian_ticks() {
        // Satellite (d): bound monotonicity — a smaller tolerance must
        // never move the switch point toward MORE Gaussian ticks
        for kind in [
            ScheduleKind::DdpmLinear,
            ScheduleKind::Cosine,
            ScheduleKind::EdmVp,
            ScheduleKind::EdmVe,
        ] {
            let sched = NoiseSchedule::new(kind, 20);
            for spread in [0.01f64, 0.3, 4.0] {
                let tols = [1e-6, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 0.999];
                let switches: Vec<usize> = tols
                    .iter()
                    .map(|&t| switch_point(&sched, spread, t))
                    .collect();
                for w in switches.windows(2) {
                    assert!(
                        w[0] <= w[1],
                        "{kind:?} spread={spread}: tightening tol grew the \
                         Gaussian prefix ({switches:?})"
                    );
                }
                // and every switch is a prefix consistent with the bound
                for (&t, &n) in tols.iter().zip(&switches) {
                    for i in 0..n {
                        assert!(error_bound(sched.sigma2(i) as f64, spread) <= t);
                    }
                    if n < sched.steps {
                        assert!(error_bound(sched.sigma2(n) as f64, spread) > t);
                    }
                }
            }
        }
    }

    #[test]
    fn switch_parse_and_resolve() {
        assert_eq!(GaussSwitch::parse("auto"), Some(GaussSwitch::Auto));
        assert_eq!(GaussSwitch::parse("3"), Some(GaussSwitch::Forced(3)));
        assert_eq!(GaussSwitch::parse(" 0 "), Some(GaussSwitch::Forced(0)));
        assert_eq!(GaussSwitch::parse("sometimes"), None);
        let ds = tiny(120);
        let gm = GaussMoments::build(&ds);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 10);
        // forced clamps to the schedule
        assert_eq!(
            resolve_switch(GaussSwitch::Forced(99), &sched, &gm, 0.05),
            sched.steps
        );
        // auto = the bound evaluated at the corpus spread
        assert_eq!(
            resolve_switch(GaussSwitch::Auto, &sched, &gm, 0.05),
            switch_point(&sched, gm.spread(), 0.05)
        );
        // the deepest DDPM step is extremely noisy — a sane tolerance
        // must claim at least one Gaussian tick on real spreads
        assert!(resolve_switch(GaussSwitch::Auto, &sched, &gm, 0.05) >= 1);
    }

    #[test]
    fn per_class_switch_tracks_the_class_spread() {
        // Satellite: a tighter class (smaller spread) must hand off no
        // earlier than the global switch; a looser one no later — and the
        // unconditional resolve is exactly the class-free resolve
        let ds = tiny(200);
        let gm = GaussMoments::build(&ds);
        let sched = NoiseSchedule::new(ScheduleKind::DdpmLinear, 20);
        let tol = 0.05;
        let global = resolve_switch(GaussSwitch::Auto, &sched, &gm, tol);
        assert_eq!(
            resolve_switch_for(GaussSwitch::Auto, &sched, &gm, tol, None),
            global
        );
        for y in 0..gm.classes as u32 {
            let cls = resolve_switch_for(GaussSwitch::Auto, &sched, &gm, tol, Some(y));
            let (sg, sc) = (gm.spread(), gm.spread_for(Some(y)));
            if sc <= sg {
                assert!(cls >= global, "class {y}: tighter spread, earlier handoff");
            } else {
                assert!(cls <= global, "class {y}: looser spread, later handoff");
            }
            // the per-class switch is exactly the bound at the class spread
            assert_eq!(cls, switch_point(&sched, sc, tol));
        }
        // classes without support (or out of range) read the global slot
        assert_eq!(
            resolve_switch_for(GaussSwitch::Auto, &sched, &gm, tol, Some(u32::MAX)),
            global
        );
        // forced mode ignores the class
        assert_eq!(
            resolve_switch_for(GaussSwitch::Forced(7), &sched, &gm, tol, Some(0)),
            7
        );
    }
}
