//! Kamb & Ganguli (2024) patch-based analytical denoiser.
//!
//! Per-pixel posterior: the weight of candidate i at pixel (y, x) is a
//! softmax over the *local patch* distance between the query and candidate
//! patches centred there; the output pixel is the weighted average of the
//! candidates' centre pixels. Translation-equivariant locality ⇒
//! generalisation, at O(N·p_t²·D) cost (Tab. 1) — the paper's slowest
//! baseline, reproduced here with separable box-filtered patch distances
//! (O(N·p_t·D)) and a per-pixel online softmax.
//!
//! Patch-size schedule p_t: the original uses the effective receptive field
//! of a pre-trained U-Net per timestep; we use the standard wide-early /
//! narrow-late heuristic snapped to the compiled sizes {3, 7}.

use super::softmax::PosteriorStats;
use super::{descale, DenoiseResult, Denoiser, StepContext};
use crate::data::dataset::Dataset;

#[derive(Debug)]
pub struct KambDenoiser {
    h: usize,
    w: usize,
    c: usize,
    /// candidate subset to aggregate over (None = full corpus); set by the
    /// GoldDiff wrapper in Tab. 5.
    pub subset: Option<Vec<u32>>,
}

impl KambDenoiser {
    pub fn new(ds: &Dataset) -> Self {
        assert!(ds.h > 1, "Kamb requires 2-D images");
        KambDenoiser {
            h: ds.h,
            w: ds.w,
            c: ds.c,
            subset: None,
        }
    }

    /// p_t: large patches in the high-noise (global) regime, small in the
    /// low-noise (local) regime, matching the compiled {3,7} ladder.
    pub fn patch_for(&self, g: f32) -> usize {
        if g > 0.5 {
            7
        } else {
            3
        }
    }
}

/// Separable box sum of a [h × w] map with window `p` (same padding),
/// normalised by the true per-pixel window size.
fn box_mean(src: &[f32], h: usize, w: usize, p: usize, tmp: &mut [f32], out: &mut [f32]) {
    let r = p / 2;
    // horizontal pass
    for y in 0..h {
        for x in 0..w {
            let lo = x.saturating_sub(r);
            let hi = (x + r).min(w - 1);
            let mut acc = 0.0f32;
            for xx in lo..=hi {
                acc += src[y * w + xx];
            }
            tmp[y * w + x] = acc / (hi - lo + 1) as f32;
        }
    }
    // vertical pass
    for y in 0..h {
        let lo = y.saturating_sub(r);
        let hi = (y + r).min(h - 1);
        for x in 0..w {
            let mut acc = 0.0f32;
            for yy in lo..=hi {
                acc += tmp[yy * w + x];
            }
            out[y * w + x] = acc / (hi - lo + 1) as f32;
        }
    }
}

impl Denoiser for KambDenoiser {
    fn name(&self) -> String {
        "kamb".into()
    }

    fn denoise(&mut self, x_t: &[f32], ctx: &StepContext) -> DenoiseResult {
        let ds = ctx.ds;
        let (h, w, c) = (self.h, self.w, self.c);
        let hw = h * w;
        let q = descale(x_t, ctx.alpha_bar());
        let scale = ctx.logit_scale();
        let p = self.patch_for(ctx.sched.g(ctx.step));

        // per-pixel online softmax state
        let mut m = vec![f32::NEG_INFINITY; hw];
        let mut l = vec![0.0f32; hw];
        let mut acc = vec![0.0f32; hw * c];
        // centre-pixel telemetry
        let centre = (h / 2) * w + w / 2;
        let mut centre_s = 0.0f32; // sum p*logit at centre

        let mut diff2 = vec![0.0f32; hw];
        let mut tmp = vec![0.0f32; hw];
        let mut patch_d2 = vec![0.0f32; hw];

        let rows: Vec<u32> = match &self.subset {
            Some(s) => s.clone(),
            None => ctx.rows().collect(),
        };
        // source-routed candidate pass: a streamed corpus serves the full
        // support as chunked shard-at-a-time reads and golden subsets via
        // the same cursor — per-pixel updates happen in the identical row
        // order, so the output matches the resident pass bit-for-bit
        ds.visit_rows(rows.iter().copied(), |_, cand| {
            // channel-summed squared diff map
            for pix in 0..hw {
                let mut acc2 = 0.0f32;
                for ch in 0..c {
                    let d = q[pix * c + ch] - cand[pix * c + ch];
                    acc2 += d * d;
                }
                diff2[pix] = acc2;
            }
            box_mean(&diff2, h, w, p, &mut tmp, &mut patch_d2);
            for pix in 0..hw {
                let logit = -patch_d2[pix] * scale;
                if logit > m[pix] {
                    let corr = if m[pix].is_finite() {
                        (m[pix] - logit).exp()
                    } else {
                        0.0
                    };
                    l[pix] *= corr;
                    for ch in 0..c {
                        acc[pix * c + ch] *= corr;
                    }
                    if pix == centre {
                        centre_s *= corr;
                    }
                    m[pix] = logit;
                }
                let pw = (logit - m[pix]).exp();
                l[pix] += pw;
                for ch in 0..c {
                    acc[pix * c + ch] += pw * cand[pix * c + ch];
                }
                if pix == centre {
                    centre_s += pw * logit;
                }
            }
        });

        let mut f_hat = vec![0.0f32; hw * c];
        for pix in 0..hw {
            let inv = 1.0 / l[pix];
            for ch in 0..c {
                f_hat[pix * c + ch] = acc[pix * c + ch] * inv;
            }
        }
        let lse = m[centre] + l[centre].ln();
        let mean_logit = centre_s / l[centre];
        DenoiseResult {
            f_hat,
            stats: PosteriorStats {
                max_logit: m[centre],
                logsumexp: lse,
                entropy: (lse - mean_logit).max(0.0),
                top1_weight: (m[centre] - lse).exp(),
            },
            support: rows.len(),
        }
    }

    fn working_set_bytes(&self, ds: &Dataset) -> u64 {
        // corpus + per-pixel softmax state + patch-distance scratch
        (ds.n * ds.d + 5 * ds.d) as u64 * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::preset;
    use crate::schedule::noise::{NoiseSchedule, ScheduleKind};

    fn setup() -> (Dataset, NoiseSchedule) {
        let mut spec = preset("mnist-sim").unwrap().clone();
        spec.n = 120;
        (
            Dataset::synthesize(&spec, 2),
            NoiseSchedule::new(ScheduleKind::DdpmLinear, 10),
        )
    }

    #[test]
    fn box_mean_constant_map_is_identity() {
        let (h, w) = (6, 6);
        let src = vec![3.0f32; h * w];
        let mut tmp = vec![0.0; h * w];
        let mut out = vec![0.0; h * w];
        box_mean(&src, h, w, 3, &mut tmp, &mut out);
        assert!(out.iter().all(|&v| (v - 3.0).abs() < 1e-6));
    }

    #[test]
    fn low_noise_reconstructs_on_manifold_query() {
        let (ds, sched) = setup();
        let mut den = KambDenoiser::new(&ds);
        let step = 9;
        let a = sched.alpha_bar(step);
        let x0 = ds.row(7).to_vec();
        let x_t: Vec<f32> = x0.iter().map(|&v| v * a.sqrt()).collect();
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step,
            class: None,
        };
        let out = den.denoise(&x_t, &ctx);
        let mse: f32 = out
            .f_hat
            .iter()
            .zip(&x0)
            .map(|(p, q)| (p - q) * (p - q))
            .sum::<f32>()
            / ds.d as f32;
        assert!(mse < 0.05, "patch denoiser should reconstruct: mse {mse}");
    }

    #[test]
    fn subset_restriction_is_respected() {
        let (ds, sched) = setup();
        let mut den = KambDenoiser::new(&ds);
        den.subset = Some(vec![4]);
        let ctx = StepContext {
            ds: &ds,
            sched: &sched,
            step: 9,
            class: None,
        };
        let out = den.denoise(&vec![0.2; ds.d], &ctx);
        assert_eq!(out.support, 1);
        // single candidate → output pixels equal that candidate's pixels
        let cand = ds.row(4);
        let err: f32 = out
            .f_hat
            .iter()
            .zip(cand)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-4, "max err {err}");
    }

    #[test]
    fn patch_schedule_is_counter_noise() {
        let (ds, _) = setup();
        let den = KambDenoiser::new(&ds);
        assert_eq!(den.patch_for(0.9), 7);
        assert_eq!(den.patch_for(0.1), 3);
    }
}
